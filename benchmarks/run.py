"""Benchmark aggregator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig8,fig9]

Prints ``name,value,derived`` CSV rows per datapoint.
"""
import argparse
import sys
import time

MODULES = [
    ("fig3", "benchmarks.fig3_phase_sensitivity"),
    ("fig7", "benchmarks.fig7_alloc_schemes"),
    ("fig8", "benchmarks.fig8_throughput"),
    ("fig9", "benchmarks.fig9_goodput"),
    ("fig10", "benchmarks.fig10_itl_goodput"),
    ("fig11", "benchmarks.fig11_tail_latency"),
    ("fig12", "benchmarks.fig12_cluster_goodput"),
    ("util", "benchmarks.util_table"),
    ("overheads", "benchmarks.overheads"),
    ("kernels", "benchmarks.kernel_costs"),
    ("roofline", "benchmarks.roofline_table"),
]


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default=None,
                   help="comma-separated subset of: " +
                        ",".join(k for k, _ in MODULES))
    args = p.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    failures = []
    for key, modname in MODULES:
        if only and key not in only:
            continue
        print(f"# === {key} ({modname}) ===", flush=True)
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["main"])
            mod.main()
            print(f"# {key} done in {time.time() - t0:.0f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((key, repr(e)))
            print(f"# {key} FAILED: {e!r}", file=sys.stderr, flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} benchmark(s) failed: {failures}")


if __name__ == "__main__":
    main()
