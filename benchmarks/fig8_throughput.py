"""Paper Fig 8: unconstrained throughput vs offered load (QPS).

LlaMA-3.1-70B + Mixtral-8x7B x {lmsys, arxiv, loogle} x
{hybrid(512/1024/2048), disagg, rapid}.  Values normalized to
chunked(512) at the lowest QPS, per the paper.

    PYTHONPATH=src python -m benchmarks.fig8_throughput [--smoke]
"""
import argparse

from benchmarks.common import DURATION, MODELS, QPS_SWEEP, emit, run_point

TRACES_ = ("lmsys", "arxiv", "loogle")
BASELINES = [("hybrid", 512), ("hybrid", 1024), ("hybrid", 2048),
             ("disagg", 512), ("rapid", 512)]
# tiny sweep for CI: one model, one trace, two load points, short trace
SMOKE = dict(qps_sweep=(2.0, 8.0), traces=("lmsys",),
             models={"llama3-70b": MODELS["llama3-70b"]}, duration=10.0)


def main(qps_sweep=QPS_SWEEP, traces=TRACES_, models=None,
         duration=DURATION):
    rows = []
    summary = {}
    for arch, mcfg in (models or MODELS).items():
        for trace in traces:
            base = run_point(arch, "hybrid", trace, qps_sweep[0],
                             mcfg["slo_itl_ms"], 512, duration=duration)
            norm = max(base["throughput_tok_s"], 1e-9)
            best_gain = 0.0
            for mode, chunk in BASELINES:
                label = mode if mode != "hybrid" else f"hybrid{chunk}"
                for qps in qps_sweep:
                    s = run_point(arch, mode, trace, qps,
                                  mcfg["slo_itl_ms"], chunk,
                                  duration=duration)
                    v = s["throughput_tok_s"] / norm
                    rows.append((f"fig8_{arch}_{trace}_{label}_qps{qps}",
                                 f"{v:.3f}", "norm_thpt"))
                    if mode == "rapid":
                        summary.setdefault((arch, trace, qps), {})[
                            "rapid"] = s["throughput_tok_s"]
                    elif label == "hybrid512":
                        summary.setdefault((arch, trace, qps), {})[
                            "hybrid"] = s["throughput_tok_s"]
    gains = [v["rapid"] / v["hybrid"] for v in summary.values()
             if v.get("hybrid", 0) > 0 and "rapid" in v]
    if gains:
        rows.append(("fig8_rapid_vs_hybrid512_max_gain",
                     f"{max(gains):.2f}", "paper: up to 4.1x"))
        rows.append(("fig8_rapid_vs_hybrid512_avg_gain",
                     f"{sum(gains) / len(gains):.2f}", "paper: avg 1.7x"))
    emit(rows)
    return dict(max_gain=max(gains) if gains else None,
                avg_gain=sum(gains) / len(gains) if gains else None)


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny sweep (<30 s) for CI")
    args = p.parse_args()
    main(**SMOKE) if args.smoke else main()
