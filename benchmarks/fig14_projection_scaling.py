"""Fig 14 (extension): projection-driven autoscaling with independent
P/D pool scaling vs the reactive TTFT-attainment window.

The PR-3 ``ScalePolicy`` is *trailing*: its attainment window only moves
once delayed requests have already finished late, so under a burst it
drips one replica per check while the prefill backlog compounds.  The
``ProjectionPolicy`` prices every replica's live ``LoadSnapshot`` with
the perfmodel (``forecast_phase_times``) and the trailing arrival token
rate, so at the first check it (a) adds as many replicas as the
projected capacity deficit needs and (b) for split-pool (disagg)
replicas grows the *prefill* chip group independently — decode chips and
their live KV untouched.

Both policies serve the fig13 KV-constrained bimodal trace (70%
chat-length, 30% long-document prompts) on the same starting fleet: one
disagg replica (16 prefill + 16 decode chips), tight KV pools
(``kv_reserve_frac=0.40``), scaling up to 4 replicas.

    PYTHONPATH=src python -m benchmarks.fig14_projection_scaling [--smoke]
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit
from benchmarks.fig13_admission_preemption import kv_constrained_trace
from repro.config import SLOConfig, ServeConfig, get_config
from repro.serving import ProjectionPolicy, ScalePolicy, run_fleet

ARCH = "llama3-70b"
SLO_ITL_MS = 100.0
KV_RESERVE = 0.40
QPS_SWEEP = (8.0, 10.0, 12.0)
DURATION = 15.0
SEED = 7
START_MODE = "disagg"


def serve_cfg() -> ServeConfig:
    return ServeConfig(mode=START_MODE, chips=32,
                       slo=SLOConfig(itl_ms=SLO_ITL_MS),
                       disagg_split=(16, 16), max_batch_slots=128,
                       kv_reserve_frac=KV_RESERVE)


def policies():
    return {
        "reactive": ScalePolicy(min_replicas=1, max_replicas=4,
                                check_interval_s=2.0, window_s=5.0),
        "projection": ProjectionPolicy(min_replicas=1, max_replicas=4,
                                       check_interval_s=2.0,
                                       pool_chip_step=4,
                                       max_pool_chips=32),
    }


def run_point(policy_name: str, qps: float, duration: float = DURATION,
              seed: int = SEED):
    cfg = get_config(ARCH)
    reqs = kv_constrained_trace(qps, duration, seed)
    summary, cluster = run_fleet(cfg, serve_cfg(), [START_MODE],
                                 "least_loaded", reqs,
                                 scale=policies()[policy_name])
    f = summary["fleet"]
    f["scale_ups"] = sum(1 for _, a, _ in cluster._scale_events
                         if a == "up")
    f["pool_grows"] = sum(1 for _, a, _ in cluster._scale_events
                          if a.startswith("pool_"))
    f["final_chips"] = sum(rep.serve.chips for rep in cluster.replicas)
    return f


def main(smoke: bool = False, tag: str = "fig14"):
    qps_sweep = (8.0,) if smoke else QPS_SWEEP
    rows, results = [], {}
    for qps in qps_sweep:
        per_policy = {}
        for name in policies():
            f = run_point(name, qps)
            per_policy[name] = f["goodput_req_s"]
            key = f"{tag}_{ARCH}_qps{qps}_{name}"
            rows.append((f"{key}_goodput", f"{f['goodput_req_s']:.3f}",
                         "fleet goodput req/s"))
            rows.append((f"{key}_slo_ok", f"{f['slo_attainment']:.3f}",
                         "fleet SLO attainment"))
            rows.append((f"{key}_ttft_p99", f"{f['ttft_p99_s']:.3f}",
                         "fleet ttft p99 s"))
            rows.append((f"{key}_scale_ups", f"{f['scale_ups']}",
                         "replica scale-up events"))
            rows.append((f"{key}_pool_grows", f"{f['pool_grows']}",
                         "independent P/D pool growth events"))
            rows.append((f"{key}_chips", f"{f['final_chips']}",
                         "total chips at end of run"))
        gain = per_policy["projection"] / max(per_policy["reactive"], 1e-9)
        rows.append((f"{tag}_qps{qps}_projection_vs_reactive_gain",
                     f"{gain:.2f}",
                     "goodput gain, projection over reactive window"))
        results[qps] = per_policy
    emit(rows)
    if smoke:
        qps = qps_sweep[0]
        reactive = results[qps]["reactive"]
        projected = results[qps]["projection"]
        assert projected > reactive, (
            f"projection-driven autoscaler (independent P/D pools) must "
            f"beat the reactive window on the KV-constrained trace: "
            f"{projected:.3f} <= {reactive:.3f}")
        print(f"# smoke OK: projection {projected:.3f} > "
              f"reactive {reactive:.3f} req/s")
    return results


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="one KV-constrained point + strict-win assertion")
    args = p.parse_args()
    main(smoke=args.smoke)
