"""Paper §3.1 + §3.2 overhead quantification.

  * chunked-prefill tradeoff: hybrid chunk 1024 vs 512 throughput/ITL
    (paper: ~+20% thpt, ~+30% ITL on its hardware)
  * disaggregation KV-transfer overhead: throughput and TTFT vs an
    identical no-transfer configuration (paper: 1.4x thpt / 1.9x TTFT)
  * async one-step-ahead scheduling benefit (Fig 6a vs 6b)
"""
from benchmarks.common import emit


def main():
    rows = []
    # --- §3.1 chunk tradeoff (hybrid engine, saturating load) ----------
    # evaluated with sync scheduling: the per-iteration host cost is the
    # fixed overhead that larger chunks amortize; under fully-async
    # scheduling on a bandwidth-rich v5e instance the effect shrinks to
    # ~nothing (recorded as a hardware-adaptation finding)
    import copy
    import dataclasses
    from benchmarks.common import serve_cfg
    from repro.config import SLOConfig, get_config
    from repro.core import DisaggEngine, HybridEngine
    from repro.serving import TRACES, StreamMetrics, generate_trace
    cfg = get_config("llama3-70b")
    slo = SLOConfig(itl_ms=100.0)

    def serve_stream(eng, reqs):
        # API v2: summarize from the event stream, not records()
        metrics = StreamMetrics()
        eng.subscribe(metrics)
        eng.enqueue([copy.deepcopy(r) for r in reqs])
        eng.loop.run()
        return metrics.summarize(slo, eng.loop.now if eng.loop.now else 1.0)
    reqs_ch = generate_trace(TRACES["arxiv"], qps=12.0, duration_s=45,
                             seed=0)
    chunk_res = {}
    for chunk in (512, 1024):
        eng = HybridEngine(cfg, serve_cfg("hybrid", 100.0, chunk=chunk,
                                          async_sched=False))
        chunk_res[chunk] = serve_stream(eng, reqs_ch)
    s512, s1k = chunk_res[512], chunk_res[1024]
    rows.append(("ovh_chunk1k_thpt_gain",
                 f"{s1k['throughput_tok_s'] / s512['throughput_tok_s']:.3f}",
                 "paper ~1.2x (sync sched)"))
    rows.append(("ovh_chunk1k_itl_ratio",
                 f"{s1k['itl_p95_s'] / s512['itl_p95_s']:.3f}",
                 "paper ~1.3x"))
    # --- §3.2.1 KV transfer overhead -----------------------------------
    # two transports: in-pod ICI (50 GB/s — cheap, an adaptation finding)
    # and NIC/DCN-class 2.5 GB/s (the paper's network regime).  Load is
    # kept under the prefill instance's capacity so queueing delay does
    # not mask the transfer term.
    reqs = generate_trace(TRACES["arxiv"], qps=1.5, duration_s=45, seed=0)
    res = {}
    for label, gbps in (("ici50", 50.0), ("nic2.5", 2.5), ("free", 1e9)):
        eng = DisaggEngine(cfg, serve_cfg("disagg", 100.0))
        eng.serve = dataclasses.replace(eng.serve, kv_transfer_gbps=gbps)
        res[label] = serve_stream(eng, reqs)
    for label in ("ici50", "nic2.5"):
        ttft_ratio = res[label]["ttft_p95_s"] / \
            max(res["free"]["ttft_p95_s"], 1e-9)
        thpt_ratio = res["free"]["throughput_tok_s"] / \
            max(res[label]["throughput_tok_s"], 1e-9)
        rows.append((f"ovh_kv_transfer_ttft_ratio_{label}",
                     f"{ttft_ratio:.2f}",
                     "paper ~1.9x TTFT (network transport)"))
        rows.append((f"ovh_kv_transfer_thpt_ratio_{label}",
                     f"{thpt_ratio:.2f}", "paper ~1.4x thpt"))
    # --- Fig 6: async scheduling ----------------------------------------
    from repro.core import RapidEngine
    sync_cfg = serve_cfg("rapid", 100.0, async_sched=False)
    async_cfg = serve_cfg("rapid", 100.0, async_sched=True)
    a = serve_stream(RapidEngine(cfg, sync_cfg), reqs)
    b = serve_stream(RapidEngine(cfg, async_cfg), reqs)
    rows.append(("ovh_async_sched_itl_gain",
                 f"{a['itl_p95_s'] / max(b['itl_p95_s'], 1e-9):.3f}",
                 "sync p95 ITL / async p95 ITL (Fig 6a vs 6b)"))
    emit(rows)
    return dict(rows=[r[:2] for r in rows])


if __name__ == "__main__":
    main()
