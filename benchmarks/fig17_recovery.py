"""Fig 17 (extension): checkpoint-resume vs re-prefill crash recovery.

Replays one trace through the online gateway twice under the *identical*
deterministic crash storm (``FaultPlan.crash_storm``: scripted worker
kills + staggered replacement workers on the simulated clock):

  * ``reprefill`` — ``checkpoint_interval=0``: crash failover re-runs
    the whole prefill and re-decodes every token the dead worker had
    already produced (the channel dedupes the replay).
  * ``resume``    — periodic KV snapshots (costed with the perfmodel's
    ``kv_migration_seconds``); failover restores the newest snapshot on
    the target and re-computes at most ``checkpoint_interval`` tokens.

Reported per arm: goodput, SLO attainment, replayed (re-computed)
tokens, snapshot/restore counters, worker_lost rejections and span.
Always asserted: no accepted request is lost in either arm, the resume
arm replays strictly fewer tokens, and — the paper-shaped payoff —
checkpoint-resume yields **strictly higher goodput** than re-prefill
under the same storm.

    PYTHONPATH=src python -m benchmarks.fig17_recovery [--smoke]
"""
from __future__ import annotations

import argparse
import copy
import json
from typing import Dict

from benchmarks.common import emit
from repro.config import SLOConfig, ServeConfig, get_config
from repro.serving import (FaultInjector, FaultPlan, Gateway, GatewayPolicy,
                           TRACES, generate_trace)

ARCH = "llama3-70b"
SLO_ITL_MS = 100.0
WORKERS = 3
CHECKPOINT_INTERVAL = 32
ARMS = ("reprefill", "resume")


def _serve() -> ServeConfig:
    # deliberately small replicas (16 chips, not benchmarks.common's 32):
    # recovery cost only shows when re-decoding a crashed request's
    # prefix takes wall-clock the batch actually feels — on oversized
    # replicas both arms hide the replay inside idle capacity
    return ServeConfig(mode="rapid", chips=16,
                       slo=SLOConfig(itl_ms=SLO_ITL_MS),
                       chunk_size=512, token_budget=640,
                       max_batch_slots=64)


def run_arm(arm: str, qps: float, duration: float, crashes: int,
            seed: int, storm_end: float) -> Dict[str, float]:
    cfg = get_config(ARCH)
    serve = _serve()
    interval = CHECKPOINT_INTERVAL if arm == "resume" else 0
    gw = Gateway(cfg, serve, modes=["rapid"] * WORKERS,
                 router="round_robin",
                 policy=GatewayPolicy(checkpoint_interval=interval))
    reqs = [copy.deepcopy(r) for r in
            generate_trace(TRACES["lmsys"], qps=qps, duration_s=duration,
                           seed=0)]
    plan = FaultPlan.crash_storm(seed=seed, workers=WORKERS,
                                 t0=0.2 * duration,
                                 t1=storm_end * duration,
                                 crashes=crashes, restart_after=2.0)
    inj = FaultInjector(gw, plan).arm()
    records, span = gw.serve_trace(reqs)
    fleet = gw.metrics_summary()["fleet"]
    assert len(records) == len(reqs), \
        (arm, "lost requests", len(records), len(reqs))
    assert fleet["completed"] + fleet["rejected"] == len(reqs), (arm, fleet)
    assert inj.injected["crash"] == crashes
    return {
        "n": len(reqs),
        "completed": fleet["completed"],
        "goodput_req_s": fleet["goodput_req_s"],
        "slo_attainment": fleet["slo_attainment"],
        "throughput_tok_s": fleet["throughput_tok_s"],
        "replayed_tokens": fleet["replayed_tokens"],
        "checkpoints": fleet["checkpoints"],
        "resumes": fleet["resumes"],
        "retries": fleet["retries"],
        "worker_lost": fleet["rejections_by_reason"].get("worker_lost", 0),
        "span_s": span,
    }


def main(smoke: bool = False, json_path: str = None):
    # the storm reaches deep into the trace (storm_end) so the recovery
    # tail is on the critical path — crashes that stop long before the
    # trace ends leave both arms time to hide the replay in idle capacity
    qps, duration, crashes, seed, storm_end = \
        (8.0, 15.0, 6, 3, 0.8) if smoke else (12.0, 25.0, 10, 3, 0.85)
    out = {}
    rows = []
    for arm in ARMS:
        s = run_arm(arm, qps, duration, crashes, seed, storm_end)
        out[arm] = s
        rows.append((f"fig17/{arm}/goodput_req_s",
                     f"{s['goodput_req_s']:.3f}",
                     f"replayed={s['replayed_tokens']} "
                     f"ckpts={s['checkpoints']} resumes={s['resumes']} "
                     f"retries={s['retries']} lost={s['worker_lost']}"))
    rep, res = out["reprefill"], out["resume"]
    # the recovery machinery must actually have engaged
    assert rep["retries"] > 0 and res["resumes"] > 0, out
    assert rep["checkpoints"] == 0 and res["checkpoints"] > 0, out
    # bounded replay: snapshots cap re-computation per failover at the
    # checkpoint interval; re-prefill replays the full generated prefix
    assert res["replayed_tokens"] < rep["replayed_tokens"], out
    assert res["replayed_tokens"] <= res["retries"] * CHECKPOINT_INTERVAL, \
        out
    # the headline: resuming from snapshots beats re-prefilling, under
    # the identical crash storm, on end-to-end goodput
    assert res["goodput_req_s"] > rep["goodput_req_s"], out
    emit(rows)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny sweep (<30 s) for CI")
    p.add_argument("--json", default=None)
    args = p.parse_args()
    main(smoke=args.smoke, json_path=args.json)
