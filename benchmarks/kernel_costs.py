"""Kernel micro-bench: wall time per call in interpret mode (CPU) plus
the analytic TPU-v5e roofline estimate for the same shapes.  Interpret
wall-times validate nothing about TPU perf — the derived column is the
real deliverable; the CSV keeps both for regression tracking."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ssm_scan import ssm_scan
from repro.kernels.unified_pd import unified_pd
from repro.perfmodel.hw import TPU_V5E


def _t(fn, *a, n=3, **kw):
    fn(*a, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*a, **kw))
    return (time.perf_counter() - t0) / n * 1e6


def main():
    rng = jax.random.PRNGKey(0)
    rows = []
    # flash prefill, serving-ish shape (small for interpret mode)
    B, Hq, Hkv, S, D = 1, 4, 2, 512, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    us = _t(flash_prefill, q, k, v, block_q=128, block_k=128,
            interpret=True, n=2)
    flops = 2 * 2 * B * Hq * S * S * D * 0.5
    est = flops / TPU_V5E.peak_flops * 1e6
    rows.append(("kernel_flash_prefill_us", f"{us:.0f}",
                 f"tpu_v5e_roofline_us={est:.1f}"))
    # paged attention decode
    N, page, mp, Bd = 64, 16, 16, 8
    kp = jax.random.normal(ks[0], (N, page, Hkv, D), jnp.float32)
    vp = jax.random.normal(ks[1], (N, page, Hkv, D), jnp.float32)
    qd = jax.random.normal(ks[2], (Bd, Hq, D), jnp.float32)
    tabs = jnp.tile(jnp.arange(mp, dtype=jnp.int32), (Bd, 1))
    lens = jnp.full((Bd,), mp * page, jnp.int32)
    us = _t(paged_attention, qd, kp, vp, tabs, lens, interpret=True, n=2)
    bytes_ = Bd * mp * page * Hkv * D * 2 * 4
    est = bytes_ / TPU_V5E.hbm_bw * 1e6
    rows.append(("kernel_paged_attention_us", f"{us:.0f}",
                 f"tpu_v5e_bw_bound_us={est:.2f}"))
    # unified P/D
    us = _t(unified_pd, q.transpose(0, 2, 1, 3)[:, :, :, :]
            if False else q, k, v, qd, kp, vp, tabs, lens,
            f_decode=0.5, block_q=128, block_k=128, interpret=True, n=1)
    rows.append(("kernel_unified_pd_us", f"{us:.0f}",
                 "fused P+D single launch"))
    # ssm scan
    Bm_, L, din, ds = 2, 256, 64, 16
    xs = jax.random.normal(ks[0], (Bm_, L, din), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bm_, L, din)))
    A = -jnp.exp(jax.random.normal(ks[2], (din, ds)) * 0.3)
    Bmat = jax.random.normal(ks[0], (Bm_, L, ds), jnp.float32)
    Cmat = jax.random.normal(ks[1], (Bm_, L, ds), jnp.float32)
    us = _t(ssm_scan, xs, dt, A, Bmat, Cmat, chunk=64, tile_d=64,
            interpret=True, n=2)
    rows.append(("kernel_ssm_scan_us", f"{us:.0f}",
                 "chunked selective scan"))
    emit(rows)
    return dict(rows=[r[:2] for r in rows])


if __name__ == "__main__":
    main()
