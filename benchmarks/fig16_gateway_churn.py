"""Fig 16 (extension): gateway goodput under churn fault injection.

Replays the same trace through the online gateway four times on the
simulated clock — no churn, a worker crash mid-run, a rolling upgrade,
and crash+restart — and reports throughput/goodput plus the recovery
counters (retries, migrations, worker_lost rejections).  The invariant
checked in ``--smoke`` (and always asserted): **no accepted request is
lost** — every submitted request either finishes or ends with a typed
rejection.

    PYTHONPATH=src python -m benchmarks.fig16_gateway_churn [--smoke]
"""
from __future__ import annotations

import argparse
import copy
import json
from typing import Dict

from benchmarks.common import emit, serve_cfg
from repro.config import get_config
from repro.serving import Gateway, TRACES, generate_trace

ARCH = "llama3-70b"
SLO_ITL_MS = 100.0
SCENARIOS = ("baseline", "crash", "upgrade", "crash_restart")


def run_scenario(scenario: str, qps: float, duration: float,
                 seed: int = 0) -> Dict[str, float]:
    cfg = get_config(ARCH)
    serve = serve_cfg("rapid", SLO_ITL_MS)
    gw = Gateway(cfg, serve, modes=["rapid", "rapid"],
                 router="least_loaded")
    reqs = [copy.deepcopy(r) for r in
            generate_trace(TRACES["lmsys"], qps=qps, duration_s=duration,
                           seed=seed)]
    t_fault = duration * 0.3
    if scenario == "crash":
        gw.clock.at(t_fault, lambda: gw.kill_worker(0))
    elif scenario == "upgrade":
        gw.clock.at(t_fault, gw.rolling_upgrade)
    elif scenario == "crash_restart":
        gw.clock.at(t_fault, lambda: gw.kill_worker(0))
        gw.clock.at(t_fault + 5.0, lambda: gw.add_worker("rapid"))

    records, span = gw.serve_trace(reqs)
    fleet = gw.metrics_summary()["fleet"]
    assert len(records) == len(reqs), \
        (scenario, "lost requests", len(records), len(reqs))
    lost = fleet["rejections_by_reason"].get("worker_lost", 0)
    return {
        "n": len(reqs),
        "completed": fleet["completed"],
        "throughput_tok_s": fleet["throughput_tok_s"],
        "goodput_req_s": fleet["goodput_req_s"],
        "retries": fleet["retries"],
        "migrations": fleet["migrations"],
        "worker_lost": lost,
        "rejected": fleet["rejected"],
        "clamped": fleet["loop"]["clamped"],
        "span_s": span,
    }


def main(smoke: bool = False, json_path: str = None):
    qps, duration = (6.0, 10.0) if smoke else (12.0, 45.0)
    out = {}
    rows = []
    for scenario in SCENARIOS:
        s = run_scenario(scenario, qps, duration)
        out[scenario] = s
        rows.append((f"fig16/{scenario}/goodput_req_s",
                     f"{s['goodput_req_s']:.3f}",
                     f"retries={s['retries']} migr={s['migrations']} "
                     f"lost={s['worker_lost']}"))
        # no accepted request lost: completion + typed rejection covers n
        assert s["completed"] + s["rejected"] == s["n"], (scenario, s)
    # churn must actually have been injected
    assert out["crash"]["retries"] > 0 or out["crash"]["worker_lost"] > 0
    assert out["upgrade"]["migrations"] >= 0
    assert out["upgrade"]["retries"] == 0      # drains are not crashes
    emit(rows)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny sweep (<30 s) for CI")
    p.add_argument("--json", default=None)
    args = p.parse_args()
    main(smoke=args.smoke, json_path=args.json)
