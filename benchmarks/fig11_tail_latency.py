"""Paper Fig 11/12: p95 TTFT and p95 ITL, normalized to chunked(512) at
the lowest QPS.  The paper's headline: RAPID p95 TTFT up to 220x lower
than chunked (no chunking, no transfer); disagg shows ~2x lower p95 ITL
than RAPID but at lower throughput.

    PYTHONPATH=src python -m benchmarks.fig11_tail_latency [--smoke]
"""
import argparse

from benchmarks.common import DURATION, MODELS, emit, run_point

QPS = (2.0, 8.0, 16.0)
BASELINES = [("hybrid", 512), ("hybrid", 2048), ("disagg", 512),
             ("rapid", 512)]
# tiny sweep for CI: one model, one trace, two load points, short trace
SMOKE = dict(qps=(2.0, 8.0), traces=("lmsys",),
             models={"llama3-70b": MODELS["llama3-70b"]}, duration=10.0)


def main(qps=QPS, traces=("lmsys", "arxiv"), models=None,
         duration=DURATION, tag="fig11"):
    rows = []
    ttft_ratios, itl_ratios = [], []
    for arch, mcfg in (models or MODELS).items():
        for trace in traces:
            res = {}
            for mode, chunk in BASELINES:
                label = mode if mode != "hybrid" else f"hybrid{chunk}"
                for q in qps:
                    s = run_point(arch, mode, trace, q,
                                  mcfg["slo_itl_ms"], chunk,
                                  duration=duration)
                    res[(label, q)] = s
                    rows.append(
                        (f"{tag}_{arch}_{trace}_{label}_qps{q}_ttft_p95_s",
                         f"{s['ttft_p95_s']:.3f}", "seconds"))
                    rows.append(
                        (f"{tag}_{arch}_{trace}_{label}_qps{q}_itl_p95_ms",
                         f"{s['itl_p95_s'] * 1e3:.1f}", "ms"))
            for q in qps:
                hy, ra = res[("hybrid512", q)], res[("rapid", q)]
                if ra["ttft_p95_s"] > 0:
                    ttft_ratios.append(hy["ttft_p95_s"] / ra["ttft_p95_s"])
                if ra["itl_p95_s"] > 0:
                    itl_ratios.append(hy["itl_p95_s"] / ra["itl_p95_s"])
    rows.append((f"{tag}_ttft_p95_hybrid_over_rapid_max",
                 f"{max(ttft_ratios):.1f}", "paper: up to 220x"))
    rows.append((f"{tag}_ttft_p95_hybrid_over_rapid_avg",
                 f"{sum(ttft_ratios) / len(ttft_ratios):.1f}",
                 "paper: avg 53x"))
    rows.append((f"{tag}_itl_p95_hybrid_over_rapid_max",
                 f"{max(itl_ratios):.1f}", "paper: up to 6x"))
    rows.append((f"{tag}_itl_p95_hybrid_over_rapid_avg",
                 f"{sum(itl_ratios) / len(itl_ratios):.1f}",
                 "paper: avg 1.9x"))
    emit(rows)
    return dict(ttft_max=max(ttft_ratios), itl_max=max(itl_ratios))


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny sweep (<30 s) for CI")
    args = p.parse_args()
    main(**SMOKE) if args.smoke else main()
