"""Paper Fig 11/12: p95 TTFT and p95 ITL, normalized to chunked(512) at
the lowest QPS.  The paper's headline: RAPID p95 TTFT up to 220x lower
than chunked (no chunking, no transfer); disagg shows ~2x lower p95 ITL
than RAPID but at lower throughput."""
from benchmarks.common import MODELS, emit, run_point

QPS = (2.0, 8.0, 16.0)
BASELINES = [("hybrid", 512), ("hybrid", 2048), ("disagg", 512),
             ("rapid", 512)]


def main():
    rows = []
    ttft_ratios, itl_ratios = [], []
    for arch, mcfg in MODELS.items():
        for trace in ("lmsys", "arxiv"):
            res = {}
            for mode, chunk in BASELINES:
                label = mode if mode != "hybrid" else f"hybrid{chunk}"
                for qps in QPS:
                    s = run_point(arch, mode, trace, qps,
                                  mcfg["slo_itl_ms"], chunk)
                    res[(label, qps)] = s
                    rows.append(
                        (f"fig11_{arch}_{trace}_{label}_qps{qps}_ttft_p95_s",
                         f"{s['ttft_p95_s']:.3f}", "seconds"))
                    rows.append(
                        (f"fig11_{arch}_{trace}_{label}_qps{qps}_itl_p95_ms",
                         f"{s['itl_p95_s'] * 1e3:.1f}", "ms"))
            for qps in QPS:
                hy, ra = res[("hybrid512", qps)], res[("rapid", qps)]
                if ra["ttft_p95_s"] > 0:
                    ttft_ratios.append(hy["ttft_p95_s"] / ra["ttft_p95_s"])
                if ra["itl_p95_s"] > 0:
                    itl_ratios.append(hy["itl_p95_s"] / ra["itl_p95_s"])
    rows.append(("fig11_ttft_p95_hybrid_over_rapid_max",
                 f"{max(ttft_ratios):.1f}", "paper: up to 220x"))
    rows.append(("fig11_ttft_p95_hybrid_over_rapid_avg",
                 f"{sum(ttft_ratios) / len(ttft_ratios):.1f}",
                 "paper: avg 53x"))
    rows.append(("fig11_itl_p95_hybrid_over_rapid_max",
                 f"{max(itl_ratios):.1f}", "paper: up to 6x"))
    rows.append(("fig11_itl_p95_hybrid_over_rapid_avg",
                 f"{sum(itl_ratios) / len(itl_ratios):.1f}",
                 "paper: avg 1.9x"))
    emit(rows)
    return dict(ttft_max=max(ttft_ratios), itl_max=max(itl_ratios))


if __name__ == "__main__":
    main()
