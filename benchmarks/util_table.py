"""Paper §5.4: resource utilization.  KV-pool (memory) utilization per
engine + the disagg memory imbalance; compute-utilization proxy from
the interference model's occupancy shares."""
import copy

from benchmarks.common import emit, serve_cfg
from repro.config import get_config
from repro.core import DisaggEngine, make_engine
from repro.serving import TRACES, generate_trace


def main():
    cfg = get_config("llama3-70b")
    reqs = generate_trace(TRACES["arxiv"], qps=8.0, duration_s=45, seed=0)
    rows = []
    utils = {}
    for mode in ("rapid", "hybrid", "disagg"):
        eng = make_engine(mode, cfg, serve_cfg(mode, 100.0))
        eng.enqueue([copy.deepcopy(r) for r in reqs])
        eng.loop.run()
        kv = (sum(s.kv_util for s in eng.util_samples) /
              max(1, len(eng.util_samples)))
        utils[mode] = kv
        rows.append((f"util_{mode}_kv_pool", f"{kv:.3f}",
                     "mean fraction of KV pool live"))
        if isinstance(eng, DisaggEngine):
            # §3.2.2 imbalance: prefill-side pool holds KV only
            # transiently; report its mean occupancy too
            rows.append((f"util_{mode}_prefill_pool",
                         f"{eng.kv_p.utilization:.3f}",
                         "prefill-side residual occupancy"))
    if utils.get("disagg"):
        rows.append(("util_rapid_over_disagg_memory",
                     f"{utils['rapid'] / max(utils['disagg'], 1e-9):.2f}",
                     "paper: up to +37% memory utilization"))
    emit(rows)
    return utils


if __name__ == "__main__":
    main()
