"""Fig 13 (extension): KV-aware admission, cross-replica preemption and
heterogeneous bucketed replicas under a KV-constrained trace.

The PR-1 cluster router never revokes a placement and treats every
replica as identical, so under KV pressure a hot replica thrashes
(preempt/recompute cycles) while neighbours idle.  This sweep serves a
bimodal trace — 70% chat-length prompts, 30% long-document prompts —
against fleets with deliberately tight KV pools (``kv_reserve_frac``)
and compares, at equal total chips:

  * ``baseline``   — PR-1: homogeneous 4x16-chip rapid fleet,
    ``least_loaded`` router, no admission, no preemption revocation.
  * ``adm+reb``    — same fleet plus KV-aware admission
    (serving/admission.py) and the cross-replica rebalance tick.
  * ``het+adm+reb``— heterogeneous ``rapid:2x16,rapid:1x32`` fleet behind
    the BucketServe-style ``bucketed`` router, plus admission and
    rebalancing: long prompts go to the big replica whose pool can
    actually hold them, short prompts stay on the small tiers.

    PYTHONPATH=src python -m benchmarks.fig13_admission_preemption [--smoke]
"""
from __future__ import annotations

import argparse
from typing import List

from benchmarks.common import emit
from repro.config import SLOConfig, ServeConfig, get_config
from repro.core.request import Request
from repro.serving import (AdmissionPolicy, RebalancePolicy, generate_trace,
                           parse_mix, run_fleet)
from repro.serving.traces import TraceSpec

ARCH = "llama3-70b"
SLO_ITL_MS = 100.0
KV_RESERVE = 0.40      # shrinks each pool to ~70k tokens on 16 chips
QPS_SWEEP = (6.0, 8.0, 10.0)
DURATION = 15.0
SEED = 7

SHORT = TraceSpec("short", 2000, 0.4, 200, 0.4, 8000, 512)
LONG = TraceSpec("long", 14000, 0.25, 500, 0.4, 30_000, 1024)

FLEETS = {
    "baseline": dict(modes=["rapid"] * 4, router="least_loaded",
                     admission=None, rebalance=None),
    "adm+reb": dict(modes=["rapid"] * 4, router="least_loaded",
                    admission=AdmissionPolicy(kv_headroom=0.9,
                                              projected_output_frac=1.0),
                    rebalance=RebalancePolicy()),
    "het+adm+reb": dict(modes=parse_mix("rapid:2x16,rapid:1x32"),
                        router="bucketed",
                        admission=AdmissionPolicy(
                            kv_headroom=0.9, projected_output_frac=1.0),
                        rebalance=RebalancePolicy()),
}


def kv_constrained_trace(qps: float, duration: float,
                         seed: int = SEED) -> List[Request]:
    """70/30 bimodal mix: chat-length prompts plus long documents whose
    KV footprint dominates a 16-chip pool."""
    short = generate_trace(SHORT, qps=qps * 0.7, duration_s=duration,
                           seed=seed)
    long_ = generate_trace(LONG, qps=qps * 0.3, duration_s=duration,
                           seed=seed + 1)
    reqs = short + long_
    for i, r in enumerate(reqs):       # de-collide rids across the halves
        r.rid = i
    return reqs


def serve_cfg() -> ServeConfig:
    return ServeConfig(mode="rapid", chips=16,
                       slo=SLOConfig(itl_ms=SLO_ITL_MS),
                       disagg_split=(8, 8), max_batch_slots=128,
                       kv_reserve_frac=KV_RESERVE)


def run_point(fleet: str, qps: float, duration: float = DURATION,
              seed: int = SEED):
    cfg = get_config(ARCH)
    spec = FLEETS[fleet]
    reqs = kv_constrained_trace(qps, duration, seed)
    summary, _ = run_fleet(cfg, serve_cfg(), spec["modes"], spec["router"],
                           reqs, admission=spec["admission"],
                           rebalance=spec["rebalance"])
    return summary["fleet"]


def main(smoke: bool = False, tag: str = "fig13"):
    qps_sweep = (8.0,) if smoke else QPS_SWEEP
    duration = DURATION
    rows, results = [], {}
    for qps in qps_sweep:
        per_fleet = {}
        for fleet in FLEETS:
            f = run_point(fleet, qps, duration)
            per_fleet[fleet] = f["goodput_req_s"]
            key = f"{tag}_{ARCH}_qps{qps}_{fleet}"
            rows.append((f"{key}_goodput", f"{f['goodput_req_s']:.3f}",
                         "fleet goodput req/s"))
            rows.append((f"{key}_slo_ok", f"{f['slo_attainment']:.3f}",
                         "fleet SLO attainment"))
            rows.append((f"{key}_ttft_p99", f"{f['ttft_p99_s']:.3f}",
                         "fleet ttft p99 s"))
            rows.append((f"{key}_preempt", f"{f['preemptions']}",
                         "engine preemptions"))
            rows.append((f"{key}_migrations", f"{f.get('migrations', 0)}",
                         "cross-replica migrations"))
        gain = per_fleet["het+adm+reb"] / max(per_fleet["baseline"], 1e-9)
        rows.append((f"{tag}_qps{qps}_het_vs_baseline_gain",
                     f"{gain:.2f}", "goodput gain over PR-1 least_loaded"))
        results[qps] = per_fleet
    emit(rows)
    if smoke:
        qps = qps_sweep[0]
        base = results[qps]["baseline"]
        treated = results[qps]["het+adm+reb"]
        assert treated > base, (
            f"admission+preemption cluster must beat the least_loaded "
            f"baseline on the KV-constrained trace: {treated:.3f} <= "
            f"{base:.3f}")
        print(f"# smoke OK: het+adm+reb {treated:.3f} > "
              f"baseline {base:.3f} req/s")
    return results


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="one KV-constrained point + strict-win assertion")
    args = p.parse_args()
    main(smoke=args.smoke)
