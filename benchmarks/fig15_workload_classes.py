"""Fig 15 (extension): multi-tenant workload classes under diurnal load.

A production fleet multiplexes latency-sensitive interactive sessions,
throughput batch jobs, and best-effort scavenger traffic over one pool
of chips.  The class-blind stack treats them identically, so under the
diurnal peak the interactive class pays the same queueing and preemption
tax as traffic that has hours of deadline slack.  This sweep serves the
SAME multi-tenant diurnal trace (serving/workloads.py: ~45% interactive
multi-turn sessions with shared prefixes, 35% batch, 20% best_effort)
against the same 4x32-chip rapid fleet and compares:

  * ``class_blind`` — KV-aware admission and preemption, but every class
    identical: no shedding order, no session affinity, victims chosen by
    arrival alone.
  * ``class_aware`` — the full multi-tenant stack: class-ordered
    admission headroom (best_effort shed first, interactive never),
    class-ranked preemption victims, and session-affinity routing so a
    session's next turn lands on the replica parking its prefix KV and
    skips re-prefilling the shared prefix.

The claim (asserted by ``--smoke``): class awareness strictly improves
interactive-class goodput at equal-or-better total token throughput —
the win is redistribution plus prefix-skip capacity, not a throughput
trade.

    PYTHONPATH=src python -m benchmarks.fig15_workload_classes [--smoke]
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit
from repro.config import SLOConfig, ServeConfig, get_config
from repro.core.preemption import PreemptionPolicy
from repro.serving import (AdmissionPolicy, diurnal_rate,
                           generate_multiclass_trace, run_fleet)

ARCH = "llama3-70b"
SLO_ITL_MS = 100.0
KV_RESERVE = 0.55      # tight pools: the diurnal peak must hurt
REPLICAS = 4           # sessions scatter 1/N without affinity routing
QPS_SWEEP = (16.0, 18.0, 20.0)
DURATION = 40.0
SEED = 23

FLEETS = {
    "class_blind": dict(
        admission=AdmissionPolicy(kv_headroom=0.9, max_wait_s=4.0,
                                  class_aware=False),
        preempt=PreemptionPolicy(class_aware=False),
        session_affinity=False),
    "class_aware": dict(
        admission=AdmissionPolicy(kv_headroom=0.9, max_wait_s=4.0,
                                  class_aware=True),
        preempt=PreemptionPolicy(class_aware=True),
        session_affinity=True),
}


def diurnal_trace(qps: float, duration: float = DURATION,
                  seed: int = SEED):
    """Multi-tenant mix under a sinusoidal day/night arrival process
    whose peak runs ~1.6x the mean."""
    rate = diurnal_rate(qps, amplitude=0.6, period_s=duration / 2)
    return generate_multiclass_trace(qps=qps, duration_s=duration,
                                     seed=seed, rate_fn=rate)


def serve_cfg() -> ServeConfig:
    return ServeConfig(mode="rapid", chips=32,
                       slo=SLOConfig(itl_ms=SLO_ITL_MS),
                       disagg_split=(16, 16), max_batch_slots=128,
                       kv_reserve_frac=KV_RESERVE)


def run_point(fleet: str, qps: float, duration: float = DURATION,
              seed: int = SEED):
    cfg = get_config(ARCH)
    spec = FLEETS[fleet]
    reqs = diurnal_trace(qps, duration, seed)
    summary, _ = run_fleet(cfg, serve_cfg(), ["rapid"] * REPLICAS,
                           "least_loaded", reqs,
                           admission=spec["admission"],
                           session_affinity=spec["session_affinity"],
                           preempt_policy=spec["preempt"])
    return summary


def main(smoke: bool = False, tag: str = "fig15"):
    qps_sweep = (20.0,) if smoke else QPS_SWEEP
    rows, results = [], {}
    for qps in qps_sweep:
        per_fleet = {}
        for fleet in FLEETS:
            summary = run_point(fleet, qps)
            f = summary["fleet"]
            inter = summary["per_class"].get("interactive", {})
            per_fleet[fleet] = dict(
                total_tok_s=f["throughput_tok_s"],
                interactive_goodput=inter.get("goodput_req_s", 0.0),
                interactive_attain=inter.get("slo_attainment", 0.0))
            key = f"{tag}_{ARCH}_qps{qps}_{fleet}"
            rows.append((f"{key}_total_tok_s",
                         f"{f['throughput_tok_s']:.1f}",
                         "fleet token throughput tok/s"))
            for cls, s in summary["per_class"].items():
                rows.append((f"{key}_{cls}_goodput",
                             f"{s['goodput_req_s']:.3f}",
                             f"{cls} goodput req/s (own SLO)"))
                rows.append((f"{key}_{cls}_slo_ok",
                             f"{s['slo_attainment']:.3f}",
                             f"{cls} SLO attainment (own SLO)"))
            for reason, n in sorted(
                    f["rejections_by_reason"].items()):
                rows.append((f"{key}_rej_{reason}", f"{n}",
                             "rejections by reason"))
        blind = per_fleet["class_blind"]
        aware = per_fleet["class_aware"]
        gain = aware["interactive_goodput"] / \
            max(blind["interactive_goodput"], 1e-9)
        rows.append((f"{tag}_qps{qps}_interactive_gain", f"{gain:.2f}",
                     "class-aware interactive goodput gain"))
        results[qps] = per_fleet
    emit(rows)
    if smoke:
        qps = qps_sweep[0]
        blind = results[qps]["class_blind"]
        aware = results[qps]["class_aware"]
        assert aware["interactive_goodput"] > \
            blind["interactive_goodput"], (
            f"class-aware stack must strictly beat class-blind on "
            f"interactive goodput: {aware['interactive_goodput']:.3f} <= "
            f"{blind['interactive_goodput']:.3f}")
        assert aware["total_tok_s"] >= blind["total_tok_s"], (
            f"the interactive win must not cost total throughput: "
            f"{aware['total_tok_s']:.1f} < {blind['total_tok_s']:.1f}")
        print(f"# smoke OK: interactive goodput "
              f"{aware['interactive_goodput']:.3f} > "
              f"{blind['interactive_goodput']:.3f} req/s at total "
              f"{aware['total_tok_s']:.1f} >= "
              f"{blind['total_tok_s']:.1f} tok/s")
    return results


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="one diurnal point + strict interactive-win "
                        "assertion at equal-or-better total throughput")
    args = p.parse_args()
    main(smoke=args.smoke)
