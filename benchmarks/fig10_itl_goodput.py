"""Paper Fig 10: goodput under the ITL-only SLO (TTFT unconstrained —
isolates the inter-token latency behaviour after saturation)."""
from benchmarks.fig9_goodput import main as fig9_main


def main():
    return fig9_main(metric="itl_goodput_req_s", tag="fig10")


if __name__ == "__main__":
    main()
