"""Paper Fig 10: goodput under the ITL-only SLO (TTFT unconstrained —
isolates the inter-token latency behaviour after saturation).

    PYTHONPATH=src python -m benchmarks.fig10_itl_goodput [--smoke]
"""
import argparse

from benchmarks.fig9_goodput import SMOKE
from benchmarks.fig9_goodput import main as fig9_main


def main(smoke: bool = False):
    kwargs = SMOKE if smoke else {}
    return fig9_main(metric="itl_goodput_req_s", tag="fig10", **kwargs)


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny sweep (<30 s) for CI")
    args = p.parse_args()
    main(smoke=args.smoke)
