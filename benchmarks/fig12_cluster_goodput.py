"""Fig 12 (extension): fleet goodput — replicas x router x engine mix.

The paper evaluates one engine; this sweep runs the multi-replica
cluster layer (serving/cluster.py) on the paper's traces and reports
fleet-wide goodput and tail TTFT for every (replica count, router,
engine mix) combination.  Offered load scales with the replica count so
per-replica pressure is constant across the sweep — what changes the
outcome is routing quality and the engine mix, which is exactly the
DistServe/BucketServe cluster-level question.

    PYTHONPATH=src python -m benchmarks.fig12_cluster_goodput [--smoke]
"""
from __future__ import annotations

import argparse

from benchmarks.common import MODELS, emit, serve_cfg
from repro.config import get_config
from repro.serving import TRACES, generate_trace, run_fleet

REPLICAS = (1, 2, 4)
ROUTERS_ = ("round_robin", "least_loaded", "slo_aware")
MIXES = {
    "rapid": lambda n: ["rapid"] * n,
    "hybrid": lambda n: ["hybrid"] * n,
    # half-and-half fleet: the router decides which engine sees which load
    "rapid+hybrid": lambda n: (["rapid"] * ((n + 1) // 2)
                               + ["hybrid"] * (n // 2)),
}
PER_REPLICA_QPS = 6.0
DURATION = 45.0


def run_cluster_point(arch: str, modes, router: str, trace: str,
                      qps: float, slo_itl_ms: float,
                      duration: float = DURATION, seed: int = 0):
    cfg = get_config(arch)
    serve = serve_cfg(modes[0], slo_itl_ms)
    reqs = generate_trace(TRACES[trace], qps=qps, duration_s=duration,
                          seed=seed)
    summary, _ = run_fleet(cfg, serve, modes, router, reqs)
    return summary


def main(smoke: bool = False, tag: str = "fig12"):
    replicas = (2,) if smoke else REPLICAS
    routers = ("round_robin", "least_loaded") if smoke else ROUTERS_
    mixes = ("rapid",) if smoke else tuple(MIXES)
    models = dict(list(MODELS.items())[:1]) if smoke else MODELS
    traces = ("lmsys",) if smoke else ("lmsys", "arxiv")
    duration = 15.0 if smoke else DURATION
    rows, results = [], {}
    for arch, mcfg in models.items():
        for trace in traces:
            for n in replicas:
                qps = PER_REPLICA_QPS * n
                for mix_name in mixes:
                    modes = MIXES[mix_name](n)
                    for router in routers:
                        res = run_cluster_point(
                            arch, modes, router, trace, qps,
                            mcfg["slo_itl_ms"], duration)
                        f = res["fleet"]
                        key = (f"{tag}_{arch}_{trace}_r{n}_"
                               f"{mix_name}_{router}")
                        rows.append((f"{key}_goodput",
                                     f"{f['goodput_req_s']:.3f}",
                                     "fleet goodput req/s"))
                        rows.append((f"{key}_ttft_p99",
                                     f"{f['ttft_p99_s']:.3f}",
                                     "fleet ttft p99 s"))
                        results[key] = f["goodput_req_s"]
    emit(rows)
    return results


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="one tiny point per axis (CI smoke)")
    args = p.parse_args()
    main(smoke=args.smoke)
