"""Paper Fig 7: decode latency vs batch size under allocation schemes.

P100-D100 (overallocation) vs distinct splits (D25/P75 ... D75/P25),
with a co-resident saturating prefill.  Shows the overallocation curve
crossing the ITL SLO as the decode batch grows — the trigger for the
Adaptive Resource Manager's mode switch.
"""
from benchmarks.common import CHIPS, emit
from repro.config import get_config
from repro.perfmodel import costs as C
from repro.perfmodel import interference as I
from repro.perfmodel.hw import TPU_V5E

BATCHES = (1, 2, 4, 8, 16, 32, 64, 128, 256)
SCHEMES = {"P100-D100": None, "D25-P75": 0.25, "D50-P50": 0.5,
           "D75-P25": 0.75}
CTX = 8192
# v5e-32 is bandwidth-rich relative to the paper's 8x MI300X node, so
# the overallocation curve crosses tighter SLOs (25/50 ms) at practical
# batch sizes while the 100 ms SLO holds almost everywhere — an
# adaptation finding recorded in EXPERIMENTS.md.
SLOS_S = (0.025, 0.050, 0.100)


def main():
    cfg = get_config("llama3-70b")
    p = C.prefill_cost(cfg, [8192], CHIPS)
    rows = []
    crossover = {}
    for bs in BATCHES:
        d = C.decode_cost(cfg, bs, bs * float(CTX), CHIPS)
        for name, f in SCHEMES.items():
            r = I.overlapped_times(p, d, TPU_V5E, CHIPS, f_decode=f)
            rows.append((f"fig7_decode_ms_bs{bs}_{name}",
                         f"{r.t_decode * 1e3:.2f}", f"ctx={CTX}"))
            if name == "P100-D100":
                for slo in SLOS_S:
                    if r.t_decode <= slo:
                        crossover[slo] = bs
    for slo in SLOS_S:
        rows.append((f"fig7_overalloc_crossover_bs_slo{int(slo*1e3)}ms",
                     str(crossover.get(slo)),
                     "largest bs meeting SLO under overallocation"))
    emit(rows)
    return dict(crossover=crossover)


if __name__ == "__main__":
    main()
