"""Paper Fig 3: phase performance vs compute-resource fraction.

Prefill (compute-bound) degrades ~proportionally as its share shrinks;
decode (bandwidth-bound) holds performance down to ~40-50% compute.
Values are normalized slowdown vs f=1.0 (lower is better, 1 = peak).
"""
from benchmarks.common import CHIPS, emit
from repro.config import get_config
from repro.perfmodel import costs as C
from repro.perfmodel import interference as I
from repro.perfmodel.hw import TPU_V5E

FRACS = (1.0, 0.9, 0.75, 0.5, 0.4, 0.25)


def main():
    cfg = get_config("llama3-70b")
    rows = []
    p = C.prefill_cost(cfg, [4096], CHIPS)
    base_p = I.phase_time(p, TPU_V5E, CHIPS, f=1.0)
    for f in FRACS:
        t = I.phase_time(p, TPU_V5E, CHIPS, f=f)
        rows.append((f"fig3a_prefill_slowdown_f{f}", f"{t / base_p:.3f}",
                     "norm_to_f1"))
    for bs in (8, 64, 256):
        d = C.decode_cost(cfg, bs, bs * 2048.0, CHIPS)
        base_d = I.phase_time(d, TPU_V5E, CHIPS, f=1.0)
        for f in FRACS:
            t = I.phase_time(d, TPU_V5E, CHIPS, f=f)
            rows.append((f"fig3b_decode_bs{bs}_slowdown_f{f}",
                         f"{t / base_d:.3f}", "norm_to_f1"))
    emit(rows)
    return dict(rows=[r[:2] for r in rows])


if __name__ == "__main__":
    main()
