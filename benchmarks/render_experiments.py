"""Render the §Dry-run/§Roofline tables of EXPERIMENTS.md from
dryrun_results.json (so the tables are regenerable from artifacts)."""
import json
import sys

from repro.launch.roofline import RooflineTerms


def render(path="dryrun_results.json"):
    rs = [RooflineTerms(**r) for r in json.load(open(path))]
    out = []
    out.append("| arch | shape | mesh | t_compute | t_memory | "
               "t_collective | bottleneck | useful | MFU |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for t in sorted(rs, key=lambda t: (t.mesh, t.shape, t.arch)):
        tc, tm, tl = t.terms()
        out.append(
            f"| {t.arch} | {t.shape} | {t.mesh} | {tc:.3e} | {tm:.3e} | "
            f"{tl:.3e} | {t.bottleneck} | {t.useful_flops_ratio:.2f} | "
            f"{t.roofline_fraction():.3f} |")
    return "\n".join(out)


if __name__ == "__main__":
    print(render(sys.argv[1] if len(sys.argv) > 1 else
                 "dryrun_results.json"))
