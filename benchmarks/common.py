"""Shared benchmark machinery: engine sweeps + CSV emission.

Every benchmark prints ``name,value,derived`` CSV rows (one per paper
figure datapoint) and returns a dict for benchmarks.run aggregation.
Serving instances: 32 chips for LlaMA-3.1-70B-class models (TPU v5e has
16 GB/chip — the 8x MI300X node of the paper is ~1.5 TB HBM; 32 v5e =
512 GB holds weights + KV comfortably, DESIGN.md §6), disagg split 16P/16D.

Benchmarks are Serving API v2 consumers: ``run_point`` subscribes a
``StreamMetrics`` to the engine's event stream and summarizes from it —
no blocking ``run()`` / post-hoc ``records()``.
"""
from __future__ import annotations

import copy
from typing import Dict, List

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core import make_engine
from repro.serving import TRACES, StreamMetrics, generate_trace

CHIPS = 32
MODELS = {
    "llama3-70b": dict(slo_itl_ms=100.0),
    "mixtral-8x7b": dict(slo_itl_ms=50.0),
}
QPS_SWEEP = (1.0, 2.0, 4.0, 8.0, 16.0, 24.0)
DURATION = 45.0


def serve_cfg(mode: str, slo_itl_ms: float, chunk: int = 512,
              async_sched: bool = True) -> ServeConfig:
    # token budget tracks the chunk knob (Sarathi semantics): decodes
    # always fit, prefill gets ~one chunk per iteration — this is what
    # the paper's "chunk size" sweep actually varies
    return ServeConfig(mode=mode, chips=CHIPS,
                       slo=SLOConfig(itl_ms=slo_itl_ms),
                       chunk_size=chunk, token_budget=chunk + 128,
                       disagg_split=(16, 16), max_batch_slots=128,
                       async_scheduling=async_sched)


def run_point(arch: str, mode: str, trace: str, qps: float,
              slo_itl_ms: float, chunk: int = 512, seed: int = 0,
              duration: float = DURATION) -> Dict[str, float]:
    cfg = get_config(arch)
    reqs = generate_trace(TRACES[trace], qps=qps, duration_s=duration,
                          seed=seed)
    eng = make_engine(mode, cfg, serve_cfg(mode, slo_itl_ms, chunk))
    metrics = StreamMetrics()
    eng.subscribe(metrics)
    eng.enqueue([copy.deepcopy(r) for r in reqs])
    eng.loop.run()
    span = eng.loop.now if eng.loop.now > 0 else 1.0
    out = metrics.summarize(SLOConfig(itl_ms=slo_itl_ms), span)
    out["kv_util"] = (sum(s.kv_util for s in eng.util_samples) /
                      max(1, len(eng.util_samples)))
    return out


def emit(rows: List[tuple]) -> None:
    for name, value, derived in rows:
        print(f"{name},{value},{derived}")
