"""§Roofline table from the dry-run JSON (launch/dryrun.py --json).

Reads dryrun_results.json if present and prints the per-(arch x shape x
mesh) three-term roofline + bottleneck + useful-FLOPs ratio rows that
EXPERIMENTS.md §Roofline embeds.  (The dry-run itself needs 512 fake
devices, so it cannot run inside this process — see launch/dryrun.py.)
"""
import json
import os

from benchmarks.common import emit
from repro.launch.roofline import RooflineTerms

RESULTS = os.path.join(os.path.dirname(__file__), "..",
                       "dryrun_results.json")


def load_terms():
    if not os.path.exists(RESULTS):
        return []
    with open(RESULTS) as f:
        raw = json.load(f)
    return [RooflineTerms(**r) for r in raw]


def main():
    terms = load_terms()
    rows = []
    if not terms:
        rows.append(("roofline_table", "SKIPPED",
                     "run: python -m repro.launch.dryrun --all "
                     "--both-meshes --json dryrun_results.json"))
        emit(rows)
        return dict(cells=0)
    for t in terms:
        tc, tm, tl = t.terms()
        rows.append((
            f"roofline_{t.arch}_{t.shape}_{t.mesh}",
            f"{max(tc, tm) + tl:.4e}",
            f"compute={tc:.3e}s memory={tm:.3e}s collective={tl:.3e}s "
            f"bottleneck={t.bottleneck} useful={t.useful_flops_ratio:.2f} "
            f"mfu={t.roofline_fraction():.3f}"))
    emit(rows)
    return dict(cells=len(terms))


if __name__ == "__main__":
    main()
