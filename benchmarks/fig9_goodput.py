"""Paper Fig 9: goodput (TTFT + ITL SLOs) vs offered load.

Goodput = SLO-satisfying requests completed per second; TTFT ceiling is
length-proportional (1 s per 1000 prompt tokens), ITL SLO per model.

    PYTHONPATH=src python -m benchmarks.fig9_goodput [--smoke]
"""
import argparse

from benchmarks.common import DURATION, MODELS, QPS_SWEEP, emit, run_point

TRACES_ = ("lmsys", "arxiv")
BASELINES = [("hybrid", 512), ("hybrid", 1024), ("hybrid", 2048),
             ("disagg", 512), ("rapid", 512)]
METRIC = "goodput_req_s"
# tiny sweep for CI: one model, one trace, two load points, short trace
SMOKE = dict(qps_sweep=(2.0, 8.0), traces=("lmsys",),
             models={"llama3-70b": MODELS["llama3-70b"]}, duration=10.0)


def main(metric=METRIC, tag="fig9", qps_sweep=QPS_SWEEP, traces=TRACES_,
         models=None, duration=DURATION):
    rows = []
    gains = []
    for arch, mcfg in (models or MODELS).items():
        for trace in traces:
            base = run_point(arch, "hybrid", trace, qps_sweep[0],
                             mcfg["slo_itl_ms"], 512, duration=duration)
            norm = max(base[metric], 1e-9)
            per_qps = {}
            for mode, chunk in BASELINES:
                label = mode if mode != "hybrid" else f"hybrid{chunk}"
                for qps in qps_sweep:
                    s = run_point(arch, mode, trace, qps,
                                  mcfg["slo_itl_ms"], chunk,
                                  duration=duration)
                    rows.append((f"{tag}_{arch}_{trace}_{label}_qps{qps}",
                                 f"{s[metric] / norm:.3f}",
                                 f"norm_{metric}"))
                    per_qps.setdefault(qps, {})[label] = s[metric]
            for qps, vals in per_qps.items():
                hy = vals.get("hybrid512", 0.0)
                ra = vals.get("rapid", 0.0)
                if hy > 0.05:        # paper: "where baseline not negligible"
                    gains.append(ra / hy)
    if gains:
        rows.append((f"{tag}_rapid_vs_hybrid512_max_gain",
                     f"{max(gains):.2f}", "paper fig9: up to 32x"))
        rows.append((f"{tag}_rapid_vs_hybrid512_avg_gain",
                     f"{sum(gains) / len(gains):.2f}", "paper: avg 4.9x"))
    emit(rows)
    return dict(max_gain=max(gains) if gains else None,
                avg_gain=sum(gains) / len(gains) if gains else None)


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny sweep (<30 s) for CI")
    args = p.parse_args()
    main(**SMOKE) if args.smoke else main()
