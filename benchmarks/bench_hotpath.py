"""Hot-path macro-benchmark: simulator throughput on a cluster trace.

Drives a 3-replica mixed-mode cluster (rapid + hybrid + disagg behind the
least-loaded router, with the rebalance tick on) through a ~20k-request
bimodal trace — short chat prompts interleaved with long documents at
~1.5x fleet capacity, so queues actually get deep — and reports how fast
the *simulator* runs: simulated requests per wall-second, p50/p95
per-event dispatch cost, and event-loop health (``EventLoop.stats``).

The same trace is then replayed against an in-process **pre-optimization
baseline**: the PR-4 hot path (full ``load_snapshot`` queue rescans on
every router/rebalance call, ``list()`` queue materialization on every
scheduler wake, linear-scan remove/membership, O(batch) executor context
sums, uncached step-cost pricing, per-read event-log copies, O(n)
``Cluster._outstanding`` walks) reconstructed from the seed sources and
monkeypatched in — "pinned" meaning the legacy implementations live in
this file and no longer drift with the optimized modules.  The baseline
is deliberately *conservative*: shared lower layers it still runs
(memoized per-config scalars, the scalar percentile, ``slots=True``
event records, the queue container's own O(1) append/pop) are PR-5
improvements too, so the measured speedup **understates** the true
PR-4 delta.  Both runs must produce *identical* simulation results
(asserted); only the wall-clock differs.

``--fleet`` switches to the **fleet-vectorized pricing** benchmark: a
128-replica pinned-size cluster behind the slo_aware router with the
projection autoscaler's forecasts on every tick, run twice — once with
``batch_pricing=False`` (the scalar per-replica reference path: every
arrival and every tick walks the fleet through the N=1 cost views) and
once with ``batch_pricing=True`` (the whole fleet priced through
``perfmodel.batch`` in one array call per cost kind).  Both arms must
simulate the identical virtual history (asserted); the speedup is the
pure win of vectorizing the control plane.

Results are written to ``BENCH_hotpath.json`` (read-modify-write: each
mode updates its own section, v1 files are upgraded in place)::

    {
      "schema": "bench_hotpath/v2",
      "hotpath": {
        "config":    {requests, trace, router, replicas, arch, seed},
        "optimized": {wall_s, span_s, completed, rejected, tokens,
                      migrations, events_dispatched, req_per_wall_s,
                      events_per_wall_s, event_cost_us: {p50, p95},
                      loop: {dispatched, clamped, peak_heap},
                      cache_stats: {<fn>: {hits, misses, currsize,
                      maxsize}, ...}},
        "baseline":  {... same fields ...},
        "speedup":   optimized.req_per_wall_s / baseline.req_per_wall_s
      },
      "fleet": {
        "config":  {requests, replicas, modes, router, arch, trace,
                    seed, smoke},
        "batched": {... same per-run fields ...},
        "scalar":  {... same per-run fields ...},
        "speedup": batched.req_per_wall_s / scalar.req_per_wall_s
      },
      "fleet_smoke": { ... the CI reduced-trace run, same shape ... }
    }

``cache_stats`` reports the per-run hit/miss deltas of every memoized
perfmodel entry point (``costs.cache_stats()``) — the caches are bounded
now, so occupancy vs ``maxsize`` and the hit rate are part of the
tracked perf surface.

``--smoke`` (CI) asserts the speedup floor (``SMOKE_MIN_SPEEDUP`` for
the hot path, ``FLEET_SMOKE_MIN_SPEEDUP`` for ``--fleet``) and that the
two runs' simulation outputs match exactly.
"""
from __future__ import annotations

import argparse
import copy
import functools
import heapq
import json
import time
from typing import Dict, List

import numpy as np

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core import engines as E
from repro.core import events as EV
from repro.core import executor as X
from repro.core import scheduler as S
from repro.core.queues import IndexedQueue
from repro.core.request import State
from repro.kvcache import kv_pages_for
from repro.perfmodel import costs as C
from repro.perfmodel import interference as I
from repro.serving import cluster as CL
from repro.serving import metrics as M
from repro.serving.sim import EventLoop
from repro.serving.traces import TraceSpec, generate_trace

ARCH = "llama3-70b"
REPLICAS = ["rapid", "hybrid", "disagg"]
ROUTER = "least_loaded"
DEFAULT_REQUESTS = 20_000
SMOKE_MIN_SPEEDUP = 4.0

# bimodal request mix: interactive chat + long-document summarization;
# outputs kept short so wall time is dominated by the control plane
# (queues, routing, snapshots) the benchmark is about, not token events
SHORT = TraceSpec("hot-short", mean_prompt=512, sigma_prompt=0.6,
                  mean_output=24, sigma_output=0.5,
                  max_prompt=8192, max_output=64)
LONG = TraceSpec("hot-long", mean_prompt=6144, sigma_prompt=0.5,
                 mean_output=24, sigma_output=0.5,
                 max_prompt=16384, max_output=64)
QPS_TOTAL = 60.0      # ~1.5x the 3-replica prefill capacity: queues deepen


def bimodal_trace(n_requests: int, seed: int):
    """~n_requests arrivals, half short / half long, merged by arrival."""
    duration = n_requests / QPS_TOTAL
    short = generate_trace(SHORT, qps=QPS_TOTAL / 2, duration_s=duration,
                           seed=seed)
    long_ = generate_trace(LONG, qps=QPS_TOTAL / 2, duration_s=duration,
                           seed=seed + 1)
    merged = sorted(short + long_, key=lambda r: (r.arrival, r.prompt_len))
    for i, r in enumerate(merged):
        r.rid = i
    return merged


def _serve() -> ServeConfig:
    return ServeConfig(mode="rapid", chips=32, slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(16, 16), max_batch_slots=128)


class TimedLoop(EventLoop):
    """EventLoop that times every callback (per-event cost distribution).

    Both the optimized and the baseline run use this loop, so the
    perf_counter overhead cancels out of the speedup ratio."""

    def __init__(self):
        super().__init__()
        self.samples_ns: List[int] = []

    def run(self, until=None, max_events: int = 50_000_000) -> None:
        assert until is None, "benchmark drains the loop in one pass"
        heap = self._heap
        samples = self.samples_ns
        clock = time.perf_counter_ns
        n = 0
        while heap and n < max_events:
            t, _, fn = heapq.heappop(heap)
            self.now = t
            t0 = clock()
            fn()
            samples.append(clock() - t0)
            n += 1
        self.stats.dispatched += n
        if n >= max_events:
            raise RuntimeError("event budget exceeded (runaway sim?)")


# ---------------------------------------------------------------------------
# Pinned pre-optimization baseline (the PR-4 hot path, verbatim).
#
# Everything below reconstructs the seed implementations that PR-5
# replaced; ``legacy_hot_path()`` swaps them in for the baseline run and
# restores the optimized code afterwards.  The reconstructions are
# semantically identical to both the seed AND the optimized code — the
# benchmark asserts the two runs' simulation outputs match exactly.
# ---------------------------------------------------------------------------


def _legacy_load_snapshot(self):
    # PR-4: full queue rescan on every call (routers call this per
    # arrival per replica; the rebalance tick per replica per tick)
    return E.Engine.load_snapshot_recompute(self)


# real (optimized) implementations bound at import time: the legacy
# shims below must not resolve through the patched class attributes
_REAL_IQ_REMOVE = IndexedQueue.remove
_REAL_METRICS_CALL = M.StreamMetrics.__call__


def _legacy_iq_remove(self, r):
    # deque.remove(): linear scan from the head to the victim
    for x in self:
        if x is r:
            break
    else:
        raise ValueError(f"request {r.rid} not in queue")
    _REAL_IQ_REMOVE(self, r)


def _legacy_iq_contains(self, r):
    # list.__contains__: linear scan
    for x in self:
        if x is r:
            return True
    return False


def _legacy_rapid_schedule(self, view):
    # PR-4 RapidScheduler.schedule: list() materializes whole queues on
    # every wake
    plan = S.StepPlan()
    serve = view.serve
    ps = serve.page_size
    admitted = []
    if view.wake.kind == "arrival" or view.wake.kv_freed:
        free = view.kv.allocator.free_count
        for r in list(view.queues["waiting_kv"]):
            if not self._fits_pool(r.prompt_len, view.kv, ps):
                plan.rejects.append((r, "waiting_kv"))
                continue
            need = kv_pages_for(r.prompt_len, ps)
            if need > free:
                break
            free -= need
            plan.admits.append(S.Admission(
                r, "waiting_kv", "waiting_prefill",
                State.WAITING_PREFILL))
            admitted.append(r)
    if not view.lanes["prefill"].busy:
        batch = []
        tokens = 0
        for r in list(view.queues["waiting_prefill"]) + admitted:
            if batch and tokens + r.prompt_len > serve.prefill_max_tokens:
                break
            batch.append(r)
            tokens += r.prompt_len
        if batch:
            plan.prefill = S.PrefillLaunch(batch, "waiting_prefill")
    if not view.lanes["decode"].busy:
        joins = []
        slots = len(view.running)
        for r in view.queues["pending_join"]:
            if slots >= serve.max_batch_slots:
                break
            joins.append(r)
            slots += 1
        bs = len(view.running) + len(joins)
        if bs:
            prefill_active = view.lanes["prefill"].busy or \
                plan.prefill is not None
            alloc = self.arm.allocate(bs, prefill_active)
            plan.decode = S.DecodeLaunch(joins, f_decode=alloc.f_decode)
    return plan


def _legacy_hybrid_schedule(self, view):
    plan = S.StepPlan()
    if view.lanes["step"].busy:
        return plan
    serve = view.serve
    ps = serve.page_size
    free = view.kv.allocator.free_count
    slots = len(view.queues["chunking"]) + len(view.running)
    admitted = []
    for r in list(view.queues["waiting"]):
        if not self._fits_pool(r.prompt_len, view.kv, ps):
            plan.rejects.append((r, "waiting"))
            continue
        need = kv_pages_for(r.prompt_len, ps)
        if need > free or slots >= serve.max_batch_slots:
            break
        free -= need
        slots += 1
        plan.admits.append(S.Admission(
            r, "waiting", "chunking", State.PREFILLING,
            stamp_prefill_start=True))
        admitted.append(r)
    bs = len(view.running)
    budget = max(0, serve.token_budget - bs)
    chunks = []
    for r in list(view.queues["chunking"]) + admitted:
        if budget <= 0:
            break
        take = min(serve.chunk_size, budget,
                   r.prompt_len - r.prefill_tokens_done)
        if take <= 0:
            continue
        chunks.append((r, take))
        budget -= take
    if chunks or bs:
        plan.hybrid = S.HybridLaunch(chunks)
    return plan


def _legacy_disagg_schedule(self, view):
    plan = S.StepPlan()
    serve = view.serve
    ps = serve.page_size
    if view.wake.kind in ("transfer_arrived", "admit_retry"):
        r = view.wake.request
        if not self._fits_pool(r.prompt_len, view.kv, ps):
            plan.rejects.append((r, None))
        elif kv_pages_for(r.prompt_len, ps) > \
                view.kv.allocator.free_count:
            plan.retries.append(S.AdmitRetry(r, serve.slo.itl_ms / 1e3))
        else:
            plan.admits.append(S.Admission(
                r, None, "pending_join", State.PREFILL_FINISHED,
                stamp_t_blocks=False))
    if not view.lanes["prefill"].busy:
        free_p = view.kv_p.allocator.free_count
        batch = []
        tokens = 0
        for r in list(view.queues["waiting_prefill"]):
            if not self._fits_pool(r.prompt_len, view.kv_p, ps) or \
                    not self._fits_pool(r.prompt_len, view.kv, ps):
                plan.rejects.append((r, "waiting_prefill"))
                continue
            need = kv_pages_for(r.prompt_len, ps)
            if need > free_p:
                break
            if batch and tokens + r.prompt_len > serve.prefill_max_tokens:
                break
            free_p -= need
            batch.append(r)
            tokens += r.prompt_len
        if batch:
            plan.prefill = S.PrefillLaunch(batch, "waiting_prefill",
                                           pool="prefill")
    if not view.lanes["decode"].busy:
        joins = []
        slots = len(view.running)
        newly = [a.request for a in plan.admits
                 if a.to_queue == "pending_join"]
        for r in list(view.queues["pending_join"]) + newly:
            if slots >= serve.max_batch_slots:
                break
            joins.append(r)
            slots += 1
        if view.running or joins:
            plan.decode = S.DecodeLaunch(joins)
    return plan


# Pinned pre-refactor scalar pricing (pure Python, uncached entry
# points).  The live ``perfmodel.costs`` functions are now N=1 views
# over the vectorized ``perfmodel.batch`` layer, so grabbing their
# ``__wrapped__`` would time the NEW formula layer against itself; the
# baseline must run the OLD pure-Python bodies verbatim.  They are
# bit-identical to the batch layer by its contract (the identical-
# output assertion below depends on that).  ``active_weight_bytes`` is
# memoized exactly like the PR-5 original, so the baseline is not
# artificially slowed.


def _raw_attn_flops(cfg, q_tokens, ctx_tokens, causal_half):
    if cfg.sliding_window:
        ctx_tokens = min(ctx_tokens, cfg.sliding_window)
    per_layer = 2 * 2 * q_tokens * ctx_tokens * cfg.num_heads * \
        cfg.head_dim
    if causal_half:
        per_layer *= 0.5
    return per_layer * cfg.attn_layer_count


def _raw_ssm_flops(cfg, tokens):
    if not any(m in ("mamba", "mlstm", "slstm")
               for m in cfg.layer_pattern):
        return 0.0
    total = 0.0
    for i in range(cfg.num_layers):
        mx = cfg.mixer_at(i)
        if mx == "mamba":
            m = cfg.mamba
            total += 9.0 * tokens * cfg.d_inner * m.d_state
        elif mx == "mlstm":
            x = cfg.xlstm
            din = int(x.proj_factor * cfg.d_model)
            dh = din // x.num_heads
            total += 8.0 * tokens * din * dh
        elif mx == "slstm":
            total += 10.0 * tokens * cfg.d_model
    return total


def _raw_tp_collective_bytes(cfg, tokens, tp, dtype_bytes):
    if tp <= 1:
        return 0.0
    payload = tokens * cfg.d_model * dtype_bytes
    ring = 2.0 * (tp - 1) / tp
    return 2.0 * cfg.num_layers * payload * ring


@functools.lru_cache(maxsize=65536)
def _raw_active_weight_bytes(cfg, tokens, dtype_bytes):
    if cfg.moe is None:
        return cfg.param_count() * dtype_bytes
    total = cfg.param_count()
    moe_layers = sum(1 for i in range(cfg.num_layers)
                     if cfg.ffn_at(i) == "moe")
    glu = 3
    expert_params = moe_layers * cfg.moe.num_experts * glu * \
        cfg.d_model * cfg.moe.d_ff_expert
    rest = total - expert_params
    p_touch = 1.0 - (1.0 - cfg.moe.top_k / cfg.moe.num_experts) ** tokens
    return (rest + expert_params * min(1.0, p_touch)) * dtype_bytes


def _raw_kv_read_bytes(cfg, context_tokens, dtype_bytes):
    per_tok = cfg.kv_bytes_per_token(dtype_bytes)
    if cfg.sliding_window:
        context_tokens = min(context_tokens, cfg.sliding_window)
    return per_tok * context_tokens


def _RAW_PREFILL(cfg, seq_lens, tp, dtype_bytes):
    T = float(sum(seq_lens))
    if T == 0:
        return C.ZERO_COST
    n_active = cfg.active_param_count()
    flops = 2.0 * n_active * T + \
        (sum(_raw_attn_flops(cfg, s, s, True) for s in seq_lens)
         if cfg.attn_layer_count else 0.0) + _raw_ssm_flops(cfg, T)
    bytes_ = _raw_active_weight_bytes(cfg, int(T), dtype_bytes)
    bytes_ += 2.0 * T * cfg.kv_bytes_per_token(dtype_bytes)
    bytes_ += 4.0 * T * cfg.d_model * dtype_bytes
    coll = _raw_tp_collective_bytes(cfg, T, tp, dtype_bytes) / max(tp, 1)
    return C.StepCost(flops, bytes_, coll)


def _RAW_CHUNK(cfg, chunk_tokens, ctx_so_far, tp, dtype_bytes):
    T = float(chunk_tokens)
    n_active = cfg.active_param_count()
    flops = 2.0 * n_active * T + \
        _raw_attn_flops(cfg, T, ctx_so_far + T / 2, False) + \
        _raw_ssm_flops(cfg, T)
    bytes_ = _raw_active_weight_bytes(cfg, int(T), dtype_bytes)
    bytes_ += _raw_kv_read_bytes(cfg, ctx_so_far, dtype_bytes) * 1.0
    bytes_ += 2.0 * T * cfg.kv_bytes_per_token(dtype_bytes)
    bytes_ += 4.0 * T * cfg.d_model * dtype_bytes
    coll = _raw_tp_collective_bytes(cfg, T, tp, dtype_bytes) / max(tp, 1)
    return C.StepCost(flops, bytes_, coll)


def _RAW_DECODE(cfg, batch, ctx_tokens_total, tp, dtype_bytes):
    if batch == 0:
        return C.ZERO_COST
    B = float(batch)
    n_active = cfg.active_param_count()
    flops = 2.0 * n_active * B
    flops += _raw_attn_flops(cfg, B, ctx_tokens_total / B, False)
    flops += _raw_ssm_flops(cfg, B)
    bytes_ = _raw_active_weight_bytes(cfg, batch, dtype_bytes)
    bytes_ += _raw_kv_read_bytes(cfg, ctx_tokens_total / B, dtype_bytes) * B
    bytes_ += B * cfg.state_bytes_per_seq(dtype_bytes)
    bytes_ += 4.0 * B * cfg.d_model * dtype_bytes
    coll = _raw_tp_collective_bytes(cfg, B, tp, dtype_bytes) / max(tp, 1)
    return C.StepCost(flops, bytes_, coll)


def _legacy_execute(self, plan, view):
    # PR-4 PerfModelExecutor.execute: O(batch) context sums per decode
    # launch, pricing recomputed from scratch on every call
    serve = view.serve
    p_out = d_out = h_out = None
    if plan.prefill is not None:
        chips = self._chips("prefill", serve)
        cost = _RAW_PREFILL(
            self.cfg, tuple(r.prompt_len for r in plan.prefill.batch),
            chips, 2)
        dlane = view.lanes.get("decode", None)
        if self.colocated and dlane is not None and dlane.busy and \
                dlane.cost is not None:
            dur = I.overlapped_times(cost, dlane.cost, self.hw, chips,
                                     f_decode=dlane.f_decode).t_prefill
        else:
            dur = I.phase_time(cost, self.hw, chips)
        p_out = X.LaunchOutcome(self._step_time(dur, serve), cost)
    if plan.decode is not None:
        chips = self._chips("decode", serve)
        batch = list(view.running) + list(plan.decode.joins)
        ctx_total = float(sum(r.context_len for r in batch))
        cost = _RAW_DECODE(self.cfg, len(batch), ctx_total, chips, 2)
        if p_out is not None:
            p_cost = p_out.cost
        else:
            plane = view.lanes.get("prefill", None)
            p_cost = plane.cost if plane is not None and plane.busy \
                else None
        if self.colocated and p_cost is not None:
            dur = I.overlapped_times(p_cost, cost, self.hw, chips,
                                     f_decode=plan.decode.f_decode
                                     ).t_decode
        else:
            dur = I.phase_time(cost, self.hw, chips)
        d_out = X.LaunchOutcome(self._step_time(dur, serve), cost)
    if plan.hybrid is not None:
        chips = self._chips("step", serve)
        cost = C.ZERO_COST
        for r, take in plan.hybrid.chunks:
            cost = cost + _RAW_CHUNK(
                self.cfg, take, r.prefill_tokens_done, chips, 2)
        bs = len(view.running)
        if bs:
            ctx_total = float(sum(r.context_len for r in view.running))
            cost = cost + _RAW_DECODE(self.cfg, bs, ctx_total, chips, 2)
        dur = I.phase_time(cost, self.hw, chips)
        h_out = X.LaunchOutcome(self._step_time(dur, serve), cost)
    return X.StepOutputs(prefill=p_out, decode=d_out, hybrid=h_out)


def _legacy_emit(self, ev):
    # PR-4 EventStream.emit: per-rid fanout dict probed on every event
    self._log.append(ev)
    for fn in self._subs:
        fn(ev)
    for fn in self._per_rid.get(ev.rid, ()):
        fn(ev)


def _legacy_events(self):
    # PR-4 EventStream.events(): a fresh full copy per read
    return tuple(self._log)


def _legacy_metrics_call(self, ev):
    if isinstance(ev, EV.TokenEvent):
        # PR-4: setdefault allocates a fresh empty list on every token
        self._token_times.setdefault(ev.rid, []).append(ev.t)
    else:
        _REAL_METRICS_CALL(self, ev)   # terminal events: identical paths


def _legacy_outstanding(self):
    # PR-4 Cluster._outstanding: walk every request ever enqueued
    return any(r.t_finish is None and r.state is not State.REJECTED
               for r in self._all)


_LEGACY_PATCHES = [
    (E.Engine, "load_snapshot", _legacy_load_snapshot),
    (IndexedQueue, "remove", _legacy_iq_remove),
    (IndexedQueue, "__contains__", _legacy_iq_contains),
    (S.RapidScheduler, "schedule", _legacy_rapid_schedule),
    (S.HybridScheduler, "schedule", _legacy_hybrid_schedule),
    (S.DisaggScheduler, "schedule", _legacy_disagg_schedule),
    (X.PerfModelExecutor, "execute", _legacy_execute),
    (EV.EventStream, "emit", _legacy_emit),
    (EV.EventStream, "events", _legacy_events),
    (M.StreamMetrics, "__call__", _legacy_metrics_call),
    (CL.Cluster, "_outstanding", _legacy_outstanding),
]


class legacy_hot_path:
    """Context manager: swap in the pinned PR-4 hot path."""

    def __enter__(self):
        self._saved = [(tgt, name, tgt.__dict__[name])
                       for tgt, name, _ in _LEGACY_PATCHES]
        for tgt, name, fn in _LEGACY_PATCHES:
            setattr(tgt, name, fn)
        return self

    def __exit__(self, *exc):
        for tgt, name, fn in self._saved:
            setattr(tgt, name, fn)
        return False


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------


def _cache_deltas(before: dict, after: dict) -> dict:
    """Per-run lru_cache hit/miss deltas for every memoized perfmodel
    entry point (C.cache_stats()), plus the absolute occupancy — a miss
    now pays the N=1 batch-layer view, so cache behavior is a first-
    class perf signal."""
    out = {}
    for name, a in after.items():
        b = before.get(name, {})
        out[name] = {
            "hits": a["hits"] - b.get("hits", 0),
            "misses": a["misses"] - b.get("misses", 0),
            "currsize": a["currsize"],
            "maxsize": a["maxsize"],
        }
    return out


def _measure(cluster, loop: TimedLoop, requests) -> Dict[str, object]:
    """Drain one cluster run and collect the stats record."""
    reqs = [copy.deepcopy(r) for r in requests]   # copies outside the clock
    caches0 = C.cache_stats()
    wall0 = time.perf_counter()
    _, span = cluster.run(reqs)
    wall = time.perf_counter() - wall0
    summary = cluster.metrics.summarize(cluster.serve.slo, span)
    ev_us = np.asarray(loop.samples_ns, dtype=np.float64) / 1e3
    return {
        "wall_s": round(wall, 3),
        "span_s": span,
        "completed": int(summary["completed"]),
        "rejected": int(summary["rejected"]),
        "tokens": int(summary["tokens"]),
        "migrations": len(cluster._migrations),
        "events_dispatched": loop.stats.dispatched,
        "req_per_wall_s": round(summary["completed"] / wall, 1),
        "events_per_wall_s": round(loop.stats.dispatched / wall, 1),
        "event_cost_us": {
            "p50": round(float(np.percentile(ev_us, 50)), 2),
            "p95": round(float(np.percentile(ev_us, 95)), 2),
        },
        "loop": loop.stats.as_dict(),
        "cache_stats": _cache_deltas(caches0, C.cache_stats()),
    }


def run_once(requests, seed: int) -> Dict[str, object]:
    cfg = get_config(ARCH)
    serve = _serve()
    loop = TimedLoop()
    cluster = CL.Cluster(cfg, serve, REPLICAS, router=ROUTER,
                         rebalance=CL.RebalancePolicy(), loop=loop)
    return _measure(cluster, loop, requests)


# -- fleet-scale configuration (the batched-pricing showcase) ---------------
#
# 128 replicas behind the slo_aware router with the projection
# autoscaler's forecasts running every tick: every arrival prices all
# replicas (router scores) and every tick prices the whole fleet twice
# (sustained rates + backlog projections).  The scalar arm walks the
# replicas one at a time through the N=1 cost views; the batched arm
# prices the fleet through perfmodel.batch in one call per cost kind.
# Both arms simulate the identical virtual history (asserted) — the
# pool size is pinned (min_replicas == max_replicas) so the projections
# run every tick without scaling the fleet.
FLEET_ARCH = "qwen2.5-14b"
FLEET_REPLICAS = 128
FLEET_ROUTER = "slo_aware"
FLEET_DEFAULT_REQUESTS = 200_000
FLEET_SMOKE_REQUESTS = 2_000
FLEET_SMOKE_REPLICAS = 128
FLEET_MIN_SPEEDUP = 3.0          # full-run gate (acceptance criterion)
FLEET_SMOKE_MIN_SPEEDUP = 2.0    # conservative CI floor (tiny trace)
# ~1.3x fleet prefill capacity with widely dispersed prompt lengths:
# replica queues stay deep and distinct, so the scalar arm's per-replica
# score keys (queued tokens + prompt) actually vary — an idle fleet
# would let its lru_cache absorb the scalar cost and hide the win
FLEET_QPS = 1500.0
FLEET_SPEC = TraceSpec("fleet-mixed", mean_prompt=4096, sigma_prompt=0.8,
                       mean_output=8, sigma_output=0.4,
                       max_prompt=16384, max_output=16)


def fleet_trace(n_requests: int, seed: int):
    reqs = generate_trace(FLEET_SPEC, qps=FLEET_QPS,
                          duration_s=n_requests / FLEET_QPS, seed=seed)
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


def _fleet_serve() -> ServeConfig:
    return ServeConfig(mode="rapid", chips=8, slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(4, 4), max_batch_slots=64)


def run_fleet_once(requests, n_replicas: int,
                   batch_pricing: bool) -> Dict[str, object]:
    cfg = get_config(FLEET_ARCH)
    serve = _fleet_serve()
    loop = TimedLoop()
    modes = [REPLICAS[i % len(REPLICAS)] for i in range(n_replicas)]
    pol = CL.ProjectionPolicy(min_replicas=n_replicas,
                              max_replicas=n_replicas,
                              check_interval_s=0.5, pool_scaling=False)
    cluster = CL.Cluster(cfg, serve, modes, router=FLEET_ROUTER,
                         scale=pol, rebalance=CL.RebalancePolicy(),
                         loop=loop, batch_pricing=batch_pricing)
    return _measure(cluster, loop, requests)


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------


_IDENTITY_KEYS = ("span_s", "completed", "rejected", "tokens",
                  "migrations", "events_dispatched")


def _assert_identical(a: Dict, b: Dict, what: str) -> None:
    # cost changed, behavior must not have: the two runs simulated the
    # exact same virtual history
    for k in _IDENTITY_KEYS:
        assert a[k] == b[k], \
            f"{what} runs diverged on {k}: {a[k]} vs {b[k]}"


def _merge_out(path: str, section: str, payload: Dict) -> Dict:
    """Read-modify-write ``BENCH_hotpath.json``: update one section,
    preserve the other, upgrade any v1 record in place."""
    try:
        with open(path) as f:
            prev = json.load(f)
    except (OSError, ValueError):
        prev = {}
    if prev.get("schema") == "bench_hotpath/v1":
        prev = {"hotpath": {k: prev[k]
                            for k in ("config", "optimized", "baseline",
                                      "speedup") if k in prev}}
    result = {"schema": "bench_hotpath/v2"}
    for k in ("hotpath", "fleet", "fleet_smoke"):
        if k in prev:
            result[k] = prev[k]
    result[section] = payload
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    return result


def _print_arm(tag: str, r: Dict) -> None:
    cs = r["cache_stats"]
    probes = sum(c["hits"] + c["misses"] for c in cs.values())
    hits = sum(c["hits"] for c in cs.values())
    print(f"{tag}: {r['wall_s']:8.2f}s wall  "
          f"{r['req_per_wall_s']:9.1f} req/s  "
          f"p50/p95 {r['event_cost_us']['p50']}/"
          f"{r['event_cost_us']['p95']} us/event  "
          f"cache {hits}/{probes} hits")


def run_hotpath_bench(args) -> Dict[str, object]:
    n_req = args.requests if args.requests else DEFAULT_REQUESTS
    trace = bimodal_trace(n_req, args.seed)
    print(f"# bench_hotpath: {len(trace)} requests, "
          f"{sum(r.prompt_len for r in trace)} prompt tokens, "
          f"replicas={REPLICAS}, router={ROUTER}")

    # interpreter warmup (bytecode, numpy, perfmodel first-touch) so the
    # baseline-first ordering doesn't hand the optimized run a freebie
    run_once(bimodal_trace(500, args.seed + 17), args.seed)

    with legacy_hot_path():
        base = run_once(trace, args.seed)
    _print_arm("baseline ", base)
    opt = run_once(trace, args.seed)
    _print_arm("optimized", opt)

    speedup = opt["req_per_wall_s"] / max(base["req_per_wall_s"], 1e-9)
    payload = {
        "config": {
            "requests": len(trace),
            "trace": f"bimodal {SHORT.mean_prompt}/{LONG.mean_prompt} "
                     f"prompt @ {QPS_TOTAL} qps",
            "router": ROUTER,
            "replicas": REPLICAS,
            "arch": ARCH,
            "seed": args.seed,
        },
        "optimized": opt,
        "baseline": base,
        "speedup": round(speedup, 2),
    }
    result = _merge_out(args.out, "hotpath", payload)
    print(f"speedup: {speedup:.2f}x  -> {args.out}")

    _assert_identical(opt, base, "baseline/optimized")
    if args.smoke:
        assert speedup >= SMOKE_MIN_SPEEDUP, (
            f"hot-path smoke: expected >= {SMOKE_MIN_SPEEDUP}x over the "
            f"pinned PR-4 baseline, measured {speedup:.2f}x")
        print(f"SMOKE OK: {speedup:.2f}x >= {SMOKE_MIN_SPEEDUP}x")
    return result


def run_fleet_bench(args) -> Dict[str, object]:
    n_req = args.requests or \
        (FLEET_SMOKE_REQUESTS if args.smoke else FLEET_DEFAULT_REQUESTS)
    n_rep = args.replicas or \
        (FLEET_SMOKE_REPLICAS if args.smoke else FLEET_REPLICAS)
    trace = fleet_trace(n_req, args.seed)
    print(f"# bench_hotpath --fleet: {len(trace)} requests, "
          f"{n_rep} replicas, router={FLEET_ROUTER}, arch={FLEET_ARCH}")

    run_fleet_once(fleet_trace(200, args.seed + 17), n_rep, True)  # warmup

    scalar = run_fleet_once(trace, n_rep, batch_pricing=False)
    _print_arm("scalar   ", scalar)
    batched = run_fleet_once(trace, n_rep, batch_pricing=True)
    _print_arm("batched  ", batched)

    speedup = batched["req_per_wall_s"] / \
        max(scalar["req_per_wall_s"], 1e-9)
    payload = {
        "config": {
            "requests": len(trace),
            "replicas": n_rep,
            "modes": REPLICAS,
            "router": FLEET_ROUTER,
            "arch": FLEET_ARCH,
            "trace": f"{FLEET_SPEC.mean_prompt} prompt / "
                     f"{FLEET_SPEC.mean_output} output @ {FLEET_QPS} qps",
            "seed": args.seed,
            "smoke": bool(args.smoke),
        },
        "batched": batched,
        "scalar": scalar,
        "speedup": round(speedup, 2),
    }
    # CI smoke runs a reduced trace: record it beside the full-run
    # numbers, never over them
    result = _merge_out(args.out,
                        "fleet_smoke" if args.smoke else "fleet", payload)
    print(f"fleet speedup: {speedup:.2f}x  -> {args.out}")

    _assert_identical(batched, scalar, "batched/scalar")
    floor = FLEET_SMOKE_MIN_SPEEDUP if args.smoke else FLEET_MIN_SPEEDUP
    assert speedup >= floor, (
        f"fleet bench: expected >= {floor}x batched-over-scalar at "
        f"{n_rep} replicas, measured {speedup:.2f}x")
    print(f"FLEET OK: {speedup:.2f}x >= {floor}x at {n_rep} replicas")
    return result


def main(argv=None) -> Dict[str, object]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=None,
                    help=f"trace size (default {DEFAULT_REQUESTS}; "
                         f"--fleet: {FLEET_DEFAULT_REQUESTS}, or "
                         f"{FLEET_SMOKE_REQUESTS} with --smoke)")
    ap.add_argument("--replicas", type=int, default=None,
                    help=f"--fleet replica count (default "
                         f"{FLEET_REPLICAS})")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_hotpath.json")
    ap.add_argument("--fleet", action="store_true",
                    help="run the fleet-vectorized pricing bench "
                         "(batched vs scalar cluster ticks) instead of "
                         "the hot-path bench")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: assert the speedup floor and "
                         "identical simulation outputs")
    args = ap.parse_args(argv)
    if args.fleet:
        return run_fleet_bench(args)
    return run_hotpath_bench(args)


if __name__ == "__main__":
    main()
