"""Hot-path macro-benchmark: simulator throughput on a cluster trace.

Drives a 3-replica mixed-mode cluster (rapid + hybrid + disagg behind the
least-loaded router, with the rebalance tick on) through a ~20k-request
bimodal trace — short chat prompts interleaved with long documents at
~1.5x fleet capacity, so queues actually get deep — and reports how fast
the *simulator* runs: simulated requests per wall-second, p50/p95
per-event dispatch cost, and event-loop health (``EventLoop.stats``).

The same trace is then replayed against an in-process **pre-optimization
baseline**: the PR-4 hot path (full ``load_snapshot`` queue rescans on
every router/rebalance call, ``list()`` queue materialization on every
scheduler wake, linear-scan remove/membership, O(batch) executor context
sums, uncached step-cost pricing, per-read event-log copies, O(n)
``Cluster._outstanding`` walks) reconstructed from the seed sources and
monkeypatched in — "pinned" meaning the legacy implementations live in
this file and no longer drift with the optimized modules.  The baseline
is deliberately *conservative*: shared lower layers it still runs
(memoized per-config scalars, the scalar percentile, ``slots=True``
event records, the queue container's own O(1) append/pop) are PR-5
improvements too, so the measured speedup **understates** the true
PR-4 delta.  Both runs must produce *identical* simulation results
(asserted); only the wall-clock differs.

Results are written to ``BENCH_hotpath.json`` (schema below) so the perf
trajectory is tracked run over run::

    {
      "schema": "bench_hotpath/v1",
      "config":    {requests, trace, router, replicas, seed},
      "optimized": {wall_s, span_s, completed, rejected, tokens,
                    events_dispatched, req_per_wall_s, events_per_wall_s,
                    event_cost_us: {p50, p95}, loop: {dispatched,
                    clamped, peak_heap}},
      "baseline":  {... same fields ...},
      "speedup":   optimized.req_per_wall_s / baseline.req_per_wall_s
    }

``--smoke`` (CI) asserts the speedup is at least ``SMOKE_MIN_SPEEDUP``
and that the two runs' simulation outputs match exactly.
"""
from __future__ import annotations

import argparse
import copy
import heapq
import json
import time
from typing import Dict, List

import numpy as np

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core import engines as E
from repro.core import events as EV
from repro.core import executor as X
from repro.core import scheduler as S
from repro.core.queues import IndexedQueue
from repro.core.request import State
from repro.kvcache import kv_pages_for
from repro.perfmodel import costs as C
from repro.perfmodel import interference as I
from repro.serving import cluster as CL
from repro.serving import metrics as M
from repro.serving.sim import EventLoop
from repro.serving.traces import TraceSpec, generate_trace

ARCH = "llama3-70b"
REPLICAS = ["rapid", "hybrid", "disagg"]
ROUTER = "least_loaded"
DEFAULT_REQUESTS = 20_000
SMOKE_MIN_SPEEDUP = 4.0

# bimodal request mix: interactive chat + long-document summarization;
# outputs kept short so wall time is dominated by the control plane
# (queues, routing, snapshots) the benchmark is about, not token events
SHORT = TraceSpec("hot-short", mean_prompt=512, sigma_prompt=0.6,
                  mean_output=24, sigma_output=0.5,
                  max_prompt=8192, max_output=64)
LONG = TraceSpec("hot-long", mean_prompt=6144, sigma_prompt=0.5,
                 mean_output=24, sigma_output=0.5,
                 max_prompt=16384, max_output=64)
QPS_TOTAL = 60.0      # ~1.5x the 3-replica prefill capacity: queues deepen


def bimodal_trace(n_requests: int, seed: int):
    """~n_requests arrivals, half short / half long, merged by arrival."""
    duration = n_requests / QPS_TOTAL
    short = generate_trace(SHORT, qps=QPS_TOTAL / 2, duration_s=duration,
                           seed=seed)
    long_ = generate_trace(LONG, qps=QPS_TOTAL / 2, duration_s=duration,
                           seed=seed + 1)
    merged = sorted(short + long_, key=lambda r: (r.arrival, r.prompt_len))
    for i, r in enumerate(merged):
        r.rid = i
    return merged


def _serve() -> ServeConfig:
    return ServeConfig(mode="rapid", chips=32, slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(16, 16), max_batch_slots=128)


class TimedLoop(EventLoop):
    """EventLoop that times every callback (per-event cost distribution).

    Both the optimized and the baseline run use this loop, so the
    perf_counter overhead cancels out of the speedup ratio."""

    def __init__(self):
        super().__init__()
        self.samples_ns: List[int] = []

    def run(self, until=None, max_events: int = 50_000_000) -> None:
        assert until is None, "benchmark drains the loop in one pass"
        heap = self._heap
        samples = self.samples_ns
        clock = time.perf_counter_ns
        n = 0
        while heap and n < max_events:
            t, _, fn = heapq.heappop(heap)
            self.now = t
            t0 = clock()
            fn()
            samples.append(clock() - t0)
            n += 1
        self.stats.dispatched += n
        if n >= max_events:
            raise RuntimeError("event budget exceeded (runaway sim?)")


# ---------------------------------------------------------------------------
# Pinned pre-optimization baseline (the PR-4 hot path, verbatim).
#
# Everything below reconstructs the seed implementations that PR-5
# replaced; ``legacy_hot_path()`` swaps them in for the baseline run and
# restores the optimized code afterwards.  The reconstructions are
# semantically identical to both the seed AND the optimized code — the
# benchmark asserts the two runs' simulation outputs match exactly.
# ---------------------------------------------------------------------------


def _legacy_load_snapshot(self):
    # PR-4: full queue rescan on every call (routers call this per
    # arrival per replica; the rebalance tick per replica per tick)
    return E.Engine.load_snapshot_recompute(self)


# real (optimized) implementations bound at import time: the legacy
# shims below must not resolve through the patched class attributes
_REAL_IQ_REMOVE = IndexedQueue.remove
_REAL_METRICS_CALL = M.StreamMetrics.__call__


def _legacy_iq_remove(self, r):
    # deque.remove(): linear scan from the head to the victim
    for x in self:
        if x is r:
            break
    else:
        raise ValueError(f"request {r.rid} not in queue")
    _REAL_IQ_REMOVE(self, r)


def _legacy_iq_contains(self, r):
    # list.__contains__: linear scan
    for x in self:
        if x is r:
            return True
    return False


def _legacy_rapid_schedule(self, view):
    # PR-4 RapidScheduler.schedule: list() materializes whole queues on
    # every wake
    plan = S.StepPlan()
    serve = view.serve
    ps = serve.page_size
    admitted = []
    if view.wake.kind == "arrival" or view.wake.kv_freed:
        free = view.kv.allocator.free_count
        for r in list(view.queues["waiting_kv"]):
            if not self._fits_pool(r.prompt_len, view.kv, ps):
                plan.rejects.append((r, "waiting_kv"))
                continue
            need = kv_pages_for(r.prompt_len, ps)
            if need > free:
                break
            free -= need
            plan.admits.append(S.Admission(
                r, "waiting_kv", "waiting_prefill",
                State.WAITING_PREFILL))
            admitted.append(r)
    if not view.lanes["prefill"].busy:
        batch = []
        tokens = 0
        for r in list(view.queues["waiting_prefill"]) + admitted:
            if batch and tokens + r.prompt_len > serve.prefill_max_tokens:
                break
            batch.append(r)
            tokens += r.prompt_len
        if batch:
            plan.prefill = S.PrefillLaunch(batch, "waiting_prefill")
    if not view.lanes["decode"].busy:
        joins = []
        slots = len(view.running)
        for r in view.queues["pending_join"]:
            if slots >= serve.max_batch_slots:
                break
            joins.append(r)
            slots += 1
        bs = len(view.running) + len(joins)
        if bs:
            prefill_active = view.lanes["prefill"].busy or \
                plan.prefill is not None
            alloc = self.arm.allocate(bs, prefill_active)
            plan.decode = S.DecodeLaunch(joins, f_decode=alloc.f_decode)
    return plan


def _legacy_hybrid_schedule(self, view):
    plan = S.StepPlan()
    if view.lanes["step"].busy:
        return plan
    serve = view.serve
    ps = serve.page_size
    free = view.kv.allocator.free_count
    slots = len(view.queues["chunking"]) + len(view.running)
    admitted = []
    for r in list(view.queues["waiting"]):
        if not self._fits_pool(r.prompt_len, view.kv, ps):
            plan.rejects.append((r, "waiting"))
            continue
        need = kv_pages_for(r.prompt_len, ps)
        if need > free or slots >= serve.max_batch_slots:
            break
        free -= need
        slots += 1
        plan.admits.append(S.Admission(
            r, "waiting", "chunking", State.PREFILLING,
            stamp_prefill_start=True))
        admitted.append(r)
    bs = len(view.running)
    budget = max(0, serve.token_budget - bs)
    chunks = []
    for r in list(view.queues["chunking"]) + admitted:
        if budget <= 0:
            break
        take = min(serve.chunk_size, budget,
                   r.prompt_len - r.prefill_tokens_done)
        if take <= 0:
            continue
        chunks.append((r, take))
        budget -= take
    if chunks or bs:
        plan.hybrid = S.HybridLaunch(chunks)
    return plan


def _legacy_disagg_schedule(self, view):
    plan = S.StepPlan()
    serve = view.serve
    ps = serve.page_size
    if view.wake.kind in ("transfer_arrived", "admit_retry"):
        r = view.wake.request
        if not self._fits_pool(r.prompt_len, view.kv, ps):
            plan.rejects.append((r, None))
        elif kv_pages_for(r.prompt_len, ps) > \
                view.kv.allocator.free_count:
            plan.retries.append(S.AdmitRetry(r, serve.slo.itl_ms / 1e3))
        else:
            plan.admits.append(S.Admission(
                r, None, "pending_join", State.PREFILL_FINISHED,
                stamp_t_blocks=False))
    if not view.lanes["prefill"].busy:
        free_p = view.kv_p.allocator.free_count
        batch = []
        tokens = 0
        for r in list(view.queues["waiting_prefill"]):
            if not self._fits_pool(r.prompt_len, view.kv_p, ps) or \
                    not self._fits_pool(r.prompt_len, view.kv, ps):
                plan.rejects.append((r, "waiting_prefill"))
                continue
            need = kv_pages_for(r.prompt_len, ps)
            if need > free_p:
                break
            if batch and tokens + r.prompt_len > serve.prefill_max_tokens:
                break
            free_p -= need
            batch.append(r)
            tokens += r.prompt_len
        if batch:
            plan.prefill = S.PrefillLaunch(batch, "waiting_prefill",
                                           pool="prefill")
    if not view.lanes["decode"].busy:
        joins = []
        slots = len(view.running)
        newly = [a.request for a in plan.admits
                 if a.to_queue == "pending_join"]
        for r in list(view.queues["pending_join"]) + newly:
            if slots >= serve.max_batch_slots:
                break
            joins.append(r)
            slots += 1
        if view.running or joins:
            plan.decode = S.DecodeLaunch(joins)
    return plan


# uncached pricing entry points (bypass the PR-5 lru_cache layers)
_RAW_PREFILL = C._prefill_cost.__wrapped__
_RAW_DECODE = C.decode_cost.__wrapped__
_RAW_CHUNK = C.chunk_prefill_cost.__wrapped__


def _legacy_execute(self, plan, view):
    # PR-4 PerfModelExecutor.execute: O(batch) context sums per decode
    # launch, pricing recomputed from scratch on every call
    serve = view.serve
    p_out = d_out = h_out = None
    if plan.prefill is not None:
        chips = self._chips("prefill", serve)
        cost = _RAW_PREFILL(
            self.cfg, tuple(r.prompt_len for r in plan.prefill.batch),
            chips, 2)
        dlane = view.lanes.get("decode", None)
        if self.colocated and dlane is not None and dlane.busy and \
                dlane.cost is not None:
            dur = I.overlapped_times(cost, dlane.cost, self.hw, chips,
                                     f_decode=dlane.f_decode).t_prefill
        else:
            dur = I.phase_time(cost, self.hw, chips)
        p_out = X.LaunchOutcome(self._step_time(dur, serve), cost)
    if plan.decode is not None:
        chips = self._chips("decode", serve)
        batch = list(view.running) + list(plan.decode.joins)
        ctx_total = float(sum(r.context_len for r in batch))
        cost = _RAW_DECODE(self.cfg, len(batch), ctx_total, chips, 2)
        if p_out is not None:
            p_cost = p_out.cost
        else:
            plane = view.lanes.get("prefill", None)
            p_cost = plane.cost if plane is not None and plane.busy \
                else None
        if self.colocated and p_cost is not None:
            dur = I.overlapped_times(p_cost, cost, self.hw, chips,
                                     f_decode=plan.decode.f_decode
                                     ).t_decode
        else:
            dur = I.phase_time(cost, self.hw, chips)
        d_out = X.LaunchOutcome(self._step_time(dur, serve), cost)
    if plan.hybrid is not None:
        chips = self._chips("step", serve)
        cost = C.ZERO_COST
        for r, take in plan.hybrid.chunks:
            cost = cost + _RAW_CHUNK(
                self.cfg, take, r.prefill_tokens_done, chips, 2)
        bs = len(view.running)
        if bs:
            ctx_total = float(sum(r.context_len for r in view.running))
            cost = cost + _RAW_DECODE(self.cfg, bs, ctx_total, chips, 2)
        dur = I.phase_time(cost, self.hw, chips)
        h_out = X.LaunchOutcome(self._step_time(dur, serve), cost)
    return X.StepOutputs(prefill=p_out, decode=d_out, hybrid=h_out)


def _legacy_emit(self, ev):
    # PR-4 EventStream.emit: per-rid fanout dict probed on every event
    self._log.append(ev)
    for fn in self._subs:
        fn(ev)
    for fn in self._per_rid.get(ev.rid, ()):
        fn(ev)


def _legacy_events(self):
    # PR-4 EventStream.events(): a fresh full copy per read
    return tuple(self._log)


def _legacy_metrics_call(self, ev):
    if isinstance(ev, EV.TokenEvent):
        # PR-4: setdefault allocates a fresh empty list on every token
        self._token_times.setdefault(ev.rid, []).append(ev.t)
    else:
        _REAL_METRICS_CALL(self, ev)   # terminal events: identical paths


def _legacy_outstanding(self):
    # PR-4 Cluster._outstanding: walk every request ever enqueued
    return any(r.t_finish is None and r.state is not State.REJECTED
               for r in self._all)


_LEGACY_PATCHES = [
    (E.Engine, "load_snapshot", _legacy_load_snapshot),
    (IndexedQueue, "remove", _legacy_iq_remove),
    (IndexedQueue, "__contains__", _legacy_iq_contains),
    (S.RapidScheduler, "schedule", _legacy_rapid_schedule),
    (S.HybridScheduler, "schedule", _legacy_hybrid_schedule),
    (S.DisaggScheduler, "schedule", _legacy_disagg_schedule),
    (X.PerfModelExecutor, "execute", _legacy_execute),
    (EV.EventStream, "emit", _legacy_emit),
    (EV.EventStream, "events", _legacy_events),
    (M.StreamMetrics, "__call__", _legacy_metrics_call),
    (CL.Cluster, "_outstanding", _legacy_outstanding),
]


class legacy_hot_path:
    """Context manager: swap in the pinned PR-4 hot path."""

    def __enter__(self):
        self._saved = [(tgt, name, tgt.__dict__[name])
                       for tgt, name, _ in _LEGACY_PATCHES]
        for tgt, name, fn in _LEGACY_PATCHES:
            setattr(tgt, name, fn)
        return self

    def __exit__(self, *exc):
        for tgt, name, fn in self._saved:
            setattr(tgt, name, fn)
        return False


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------


def run_once(requests, seed: int) -> Dict[str, object]:
    cfg = get_config(ARCH)
    serve = _serve()
    loop = TimedLoop()
    cluster = CL.Cluster(cfg, serve, REPLICAS, router=ROUTER,
                         rebalance=CL.RebalancePolicy(), loop=loop)
    reqs = [copy.deepcopy(r) for r in requests]   # copies outside the clock
    wall0 = time.perf_counter()
    _, span = cluster.run(reqs)
    wall = time.perf_counter() - wall0
    summary = cluster.metrics.summarize(serve.slo, span)
    ev_us = np.asarray(loop.samples_ns, dtype=np.float64) / 1e3
    return {
        "wall_s": round(wall, 3),
        "span_s": span,
        "completed": int(summary["completed"]),
        "rejected": int(summary["rejected"]),
        "tokens": int(summary["tokens"]),
        "migrations": len(cluster._migrations),
        "events_dispatched": loop.stats.dispatched,
        "req_per_wall_s": round(summary["completed"] / wall, 1),
        "events_per_wall_s": round(loop.stats.dispatched / wall, 1),
        "event_cost_us": {
            "p50": round(float(np.percentile(ev_us, 50)), 2),
            "p95": round(float(np.percentile(ev_us, 95)), 2),
        },
        "loop": loop.stats.as_dict(),
    }


def main(argv=None) -> Dict[str, object]:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=DEFAULT_REQUESTS)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_hotpath.json")
    ap.add_argument("--smoke", action="store_true",
                    help=f"assert >= {SMOKE_MIN_SPEEDUP}x speedup and "
                         "identical simulation outputs")
    args = ap.parse_args(argv)

    trace = bimodal_trace(args.requests, args.seed)
    print(f"# bench_hotpath: {len(trace)} requests, "
          f"{sum(r.prompt_len for r in trace)} prompt tokens, "
          f"replicas={REPLICAS}, router={ROUTER}")

    # interpreter warmup (bytecode, numpy, perfmodel first-touch) so the
    # baseline-first ordering doesn't hand the optimized run a freebie
    run_once(bimodal_trace(500, args.seed + 17), args.seed)

    with legacy_hot_path():
        base = run_once(trace, args.seed)
    print(f"baseline : {base['wall_s']:8.2f}s wall  "
          f"{base['req_per_wall_s']:9.1f} req/s  "
          f"p50/p95 {base['event_cost_us']['p50']}/"
          f"{base['event_cost_us']['p95']} us/event")
    opt = run_once(trace, args.seed)
    print(f"optimized: {opt['wall_s']:8.2f}s wall  "
          f"{opt['req_per_wall_s']:9.1f} req/s  "
          f"p50/p95 {opt['event_cost_us']['p50']}/"
          f"{opt['event_cost_us']['p95']} us/event")

    speedup = opt["req_per_wall_s"] / max(base["req_per_wall_s"], 1e-9)
    result = {
        "schema": "bench_hotpath/v1",
        "config": {
            "requests": len(trace),
            "trace": f"bimodal {SHORT.mean_prompt}/{LONG.mean_prompt} "
                     f"prompt @ {QPS_TOTAL} qps",
            "router": ROUTER,
            "replicas": REPLICAS,
            "arch": ARCH,
            "seed": args.seed,
        },
        "optimized": opt,
        "baseline": base,
        "speedup": round(speedup, 2),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"speedup: {speedup:.2f}x  -> {args.out}")

    # cost changed, behavior must not have: the two runs simulated the
    # exact same virtual history
    for k in ("span_s", "completed", "rejected", "tokens", "migrations",
              "events_dispatched"):
        assert opt[k] == base[k], \
            f"baseline/optimized diverged on {k}: {base[k]} vs {opt[k]}"
    if args.smoke:
        assert speedup >= SMOKE_MIN_SPEEDUP, (
            f"hot-path smoke: expected >= {SMOKE_MIN_SPEEDUP}x over the "
            f"pinned PR-4 baseline, measured {speedup:.2f}x")
        print(f"SMOKE OK: {speedup:.2f}x >= {SMOKE_MIN_SPEEDUP}x")
    return result


if __name__ == "__main__":
    main()
