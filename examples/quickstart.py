"""Quickstart: build a model, generate tokens, serve one RAPID trace on
the streaming request-lifecycle API — the 60-second tour of the public
API (Serving API v2: submit work, subscribe to the event stream).

    PYTHONPATH=src python examples/quickstart.py
"""
import copy

import jax
import jax.numpy as jnp

from repro.config import (SLOConfig, ServeConfig, get_config,
                          get_reduced_config, list_archs)
from repro.core import RapidEngine, TokenEvent
from repro.models.transformer import (decode_forward, forward,
                                      greedy_sample, init_cache,
                                      init_model, write_prefill_to_cache)
from repro.serving import TRACES, StreamMetrics, generate_trace

print("architectures:", ", ".join(list_archs()))

# ---- 1. build a (reduced) model and generate 8 tokens ------------------
cfg = get_reduced_config("granite-8b")
params, specs = init_model(jax.random.PRNGKey(0), cfg)
prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0,
                            cfg.vocab_size)
pos = jnp.arange(12)[None]
logits, kv = forward(params, cfg, prompt, pos, 1, return_aux=True)
cache = init_cache(cfg, batch=1, max_seq=32, tp=1)
cache = write_prefill_to_cache(cfg, cache, kv, 12)
tok = greedy_sample(logits[:, -1:], cfg.vocab_size)
out = [int(tok[0, 0])]
seq_lens = jnp.array([12], jnp.int32)
for _ in range(7):
    lg, cache = decode_forward(params, cfg, tok, seq_lens[:, None],
                               cache, seq_lens, 1)
    seq_lens = seq_lens + 1
    tok = greedy_sample(lg, cfg.vocab_size)
    out.append(int(tok[0, 0]))
print("generated token ids:", out)

# ---- 2. serve a trace with the RAPID engine (virtual clock) -------------
# Serving API v2: enqueue requests, subscribe consumers to the typed
# event stream (TokenEvent / PhaseEvent / FinishedEvent / RejectedEvent)
big = get_config("llama3-70b")
serve = ServeConfig(mode="rapid", chips=32, slo=SLOConfig(itl_ms=100.0))
reqs = generate_trace(TRACES["lmsys"], qps=4.0, duration_s=30, seed=0)
eng = RapidEngine(big, serve)

metrics = StreamMetrics()              # folds the stream into records
eng.subscribe(metrics)
first_tokens = []                      # watch one request's tokens live
eng.subscribe(lambda ev: first_tokens.append(ev)
              if isinstance(ev, TokenEvent) else None,
              rid=reqs[0].rid)
eng.enqueue([copy.deepcopy(r) for r in reqs])
eng.loop.run()

span = eng.loop.now
s = metrics.summarize(serve.slo, span)
print(f"RAPID on lmsys@4qps: {s['throughput_tok_s']:.0f} tok/s, "
      f"goodput {s['goodput_req_s']:.2f} req/s, "
      f"p95 ITL {s['itl_p95_s'] * 1e3:.0f} ms, "
      f"p95 TTFT {s['ttft_p95_s']:.2f} s")
print(f"request 0 streamed {len(first_tokens)} tokens; first at "
      f"t={first_tokens[0].t:.3f}s, last at t={first_tokens[-1].t:.3f}s")
