"""Scheduler comparison: RAPID vs hybrid batching vs disaggregated on
the same trace, reproducing the shape of the paper's Figs 8-11 in one
table.  Since the Scheduler/Executor split, "engine mode" literally IS
the scheduler class — the execution substrate is shared, so this is a
pure policy comparison (Serving API v2: metrics come from the event
stream).

    PYTHONPATH=src python examples/scheduler_comparison.py --qps 16
"""
import argparse
import copy

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core import make_engine
from repro.serving import TRACES, StreamMetrics, generate_trace


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-70b")
    ap.add_argument("--trace", default="lmsys", choices=list(TRACES))
    ap.add_argument("--qps", type=float, default=16.0)
    ap.add_argument("--duration", type=float, default=45.0)
    ap.add_argument("--chips", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    slo = SLOConfig(itl_ms=100.0)
    reqs = generate_trace(TRACES[args.trace], qps=args.qps,
                          duration_s=args.duration, seed=0)
    print(f"{args.arch} / {args.trace} @ {args.qps} qps "
          f"({len(reqs)} requests, {args.chips} chips)\n")
    print(f"{'engine':10s} {'thpt tok/s':>11s} {'goodput/s':>10s} "
          f"{'ITL-gp/s':>9s} {'p95 TTFT':>9s} {'p95 ITL':>8s} "
          f"{'SLO ok':>7s}")
    for mode in ("rapid", "hybrid", "disagg"):
        serve = ServeConfig(mode=mode, chips=args.chips, slo=slo,
                            disagg_split=(args.chips // 2,
                                          args.chips // 2),
                            max_batch_slots=128)
        eng = make_engine(mode, cfg, serve)
        metrics = StreamMetrics()
        eng.subscribe(metrics)
        eng.enqueue([copy.deepcopy(r) for r in reqs])
        eng.loop.run()
        s = metrics.summarize(slo, eng.loop.now if eng.loop.now else 1.0)
        print(f"{mode:10s} {s['throughput_tok_s']:11.0f} "
              f"{s['goodput_req_s']:10.2f} "
              f"{s['itl_goodput_req_s']:9.2f} "
              f"{s['ttft_p95_s']:8.2f}s {s['itl_p95_s'] * 1e3:6.0f}ms "
              f"{s['slo_attainment'] * 100:6.1f}%")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
