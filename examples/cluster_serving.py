"""Multi-replica cluster serving demo.

Serves one LMSYS-like trace against a 4-replica fleet three times — one
per router — and prints the fleet summary plus the per-replica load
split, then shows SLO-driven autoscaling absorbing a burst.  The fleet
summary comes from the cluster's merged event stream
(``cluster.metrics``), the Serving API v2 path.

    PYTHONPATH=src python examples/cluster_serving.py
"""
import copy

from repro.config import SLOConfig, ServeConfig, get_config
from repro.serving import (TRACES, Cluster, ScalePolicy, fleet_summarize,
                           generate_trace)

ARCH = "llama3-70b"
QPS, DURATION = 20.0, 30.0


def build(mode="rapid"):
    return ServeConfig(mode=mode, chips=32, slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(16, 16), max_batch_slots=128)


def main():
    cfg = get_config(ARCH)
    serve = build()
    reqs = generate_trace(TRACES["lmsys"], qps=QPS, duration_s=DURATION,
                          seed=0)
    print(f"trace: {len(reqs)} requests @ {QPS} qps "
          f"({ARCH}, 4x32-chip replicas)\n")

    for router in ("round_robin", "least_loaded", "slo_aware"):
        cluster = Cluster(cfg, serve, ["rapid"] * 4, router=router)
        _, span = cluster.run([copy.deepcopy(r) for r in reqs])
        res = fleet_summarize(cluster.per_replica_records(), serve.slo,
                              span, fleet_records=cluster.metrics.records)
        f = res["fleet"]
        split = " ".join(f"{n}:{c}" for n, c in
                         sorted(cluster.per_replica_counts().items()))
        print(f"{router:12s} goodput={f['goodput_req_s']:6.2f} req/s  "
              f"ttft_p99={f['ttft_p99_s']:6.2f}s  "
              f"slo_ok={f['slo_attainment'] * 100:5.1f}%   [{split}]")

    # SLO-driven scaling: start with 1 replica, let the controller grow
    # the fleet while the TTFT-attainment window is red
    policy = ScalePolicy(min_replicas=1, max_replicas=4,
                         check_interval_s=2.0, window_s=5.0)
    cluster = Cluster(cfg, serve, ["rapid"], router="least_loaded",
                      scale=policy)
    _, span = cluster.run([copy.deepcopy(r) for r in reqs])
    res = fleet_summarize(cluster.per_replica_records(), serve.slo, span,
                          fleet_records=cluster.metrics.records)
    f = res["fleet"]
    print(f"\nautoscaled   goodput={f['goodput_req_s']:6.2f} req/s  "
          f"ttft_p99={f['ttft_p99_s']:6.2f}s  "
          f"replicas={cluster.num_replicas}")
    for t, action, n in cluster._scale_events:
        print(f"  t={t:6.1f}s scale_{action} -> {n} routable")


if __name__ == "__main__":
    main()
