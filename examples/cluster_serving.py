"""Multi-replica cluster serving demo.

Serves one LMSYS-like trace against a 4-replica fleet three times — one
per router — and prints the fleet summary plus the per-replica load
split, then shows SLO-driven autoscaling absorbing a burst: first the
reactive TTFT-attainment window, then the projection-driven policy
(perfmodel forecasts; for disagg replicas it also grows the prefill and
decode chip pools independently).  The fleet summary comes from the
cluster's merged event stream (``cluster.metrics``), the Serving API v2
path.

    PYTHONPATH=src python examples/cluster_serving.py
"""
import copy

from repro.config import SLOConfig, ServeConfig, get_config
from repro.serving import (TRACES, Cluster, ProjectionPolicy, ScalePolicy,
                           fleet_summarize, generate_trace)

ARCH = "llama3-70b"
QPS, DURATION = 20.0, 30.0


def build(mode="rapid"):
    return ServeConfig(mode=mode, chips=32, slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(16, 16), max_batch_slots=128)


def main():
    cfg = get_config(ARCH)
    serve = build()
    reqs = generate_trace(TRACES["lmsys"], qps=QPS, duration_s=DURATION,
                          seed=0)
    print(f"trace: {len(reqs)} requests @ {QPS} qps "
          f"({ARCH}, 4x32-chip replicas)\n")

    for router in ("round_robin", "least_loaded", "slo_aware"):
        cluster = Cluster(cfg, serve, ["rapid"] * 4, router=router)
        _, span = cluster.run([copy.deepcopy(r) for r in reqs])
        res = fleet_summarize(cluster.per_replica_records(), serve.slo,
                              span, fleet_records=cluster.metrics.records)
        f = res["fleet"]
        split = " ".join(f"{n}:{c}" for n, c in
                         sorted(cluster.per_replica_counts().items()))
        print(f"{router:12s} goodput={f['goodput_req_s']:6.2f} req/s  "
              f"ttft_p99={f['ttft_p99_s']:6.2f}s  "
              f"slo_ok={f['slo_attainment'] * 100:5.1f}%   [{split}]")

    # SLO-driven scaling: start with 1 replica under a genuinely hot
    # burst (~2x one replica's prefill rate), let the controller grow
    # the fleet — reactive attainment window vs perfmodel projections
    hot = generate_trace(TRACES["lmsys"], qps=2.4 * QPS,
                         duration_s=DURATION / 2, seed=0)
    for label, policy, modes, serve_i in (
            ("reactive", ScalePolicy(min_replicas=1, max_replicas=4,
                                     check_interval_s=2.0, window_s=5.0),
             ["rapid"], serve),
            ("projection", ProjectionPolicy(min_replicas=1, max_replicas=4,
                                            check_interval_s=2.0),
             ["rapid"], serve),
            ("projection (disagg per-pool)",
             ProjectionPolicy(min_replicas=1, max_replicas=2,
                              check_interval_s=2.0, pool_chip_step=4,
                              max_pool_chips=32),
             ["disagg"], build("disagg"))):
        cluster = Cluster(cfg, serve_i, modes, router="least_loaded",
                          scale=policy)
        _, span = cluster.run([copy.deepcopy(r) for r in hot])
        res = fleet_summarize(cluster.per_replica_records(), serve_i.slo,
                              span, fleet_records=cluster.metrics.records)
        f = res["fleet"]
        print(f"\nautoscaled [{label}]  "
              f"goodput={f['goodput_req_s']:6.2f} req/s  "
              f"ttft_p99={f['ttft_p99_s']:6.2f}s  "
              f"replicas={cluster.num_replicas}")
        for t, action, n in cluster._scale_events:
            unit = "chips" if action.startswith("pool_") else "routable"
            print(f"  t={t:6.1f}s {action} -> {n} {unit}")


if __name__ == "__main__":
    main()
