"""End-to-end REAL serving driver: batched requests against a reduced
model on CPU, run through the actual RAPID concurrent-P/D control flow —
decode-owned block allocation, whole-prompt prefill, batched decode with
the paged-attention kernel path, continuous batching, per-request
TTFT/ITL measured in wall-clock.

    PYTHONPATH=src python examples/serve_real.py --requests 12
"""
import argparse
import collections
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_reduced_config
from repro.kvcache import KVCacheManager
from repro.models.transformer import (decode_forward, forward,
                                      greedy_sample, init_cache,
                                      init_model, write_prefill_to_cache)

MAX_SEQ = 96
SLOTS = 4      # decode batch slots


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced_config(args.arch)
    params, _ = init_model(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)

    # request stream: (prompt tokens, max_new)
    waiting = collections.deque()
    for rid in range(args.requests):
        plen = int(rng.integers(6, 24))
        toks = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        waiting.append(dict(rid=rid, prompt=toks,
                            max_new=int(rng.integers(4, 12)),
                            t_arrive=time.perf_counter()))

    # decode-owned KV bookkeeping (Fig 4): blocks allocated at admission
    kv_mgr = KVCacheManager(num_blocks=SLOTS * MAX_SEQ // 16 + 8,
                            page_size=16)
    cache = init_cache(cfg, SLOTS, MAX_SEQ, 1)
    seq_lens = jnp.zeros((SLOTS,), jnp.int32)
    cur_tok = jnp.zeros((SLOTS, 1), jnp.int32)
    slot_req = [None] * SLOTS

    decode_fn = jax.jit(lambda p, t, ps, c, sl: decode_forward(
        p, cfg, t, ps, c, sl, 1))
    done = []

    def admit(slot):
        """Prefill one waiting request into `slot` (whole prompt)."""
        nonlocal cache, seq_lens, cur_tok
        r = waiting.popleft()
        kv_mgr.allocate_prompt(r["rid"], len(r["prompt"]))   # decode-owned
        prompt = jnp.asarray(r["prompt"])[None]
        pos = jnp.arange(prompt.shape[1])[None]
        logits, aux = forward(params, cfg, prompt, pos, 1, return_aux=True)
        one = init_cache(cfg, 1, MAX_SEQ, 1)
        one = write_prefill_to_cache(cfg, one, aux, prompt.shape[1])
        cache = jax.tree.map(
            lambda c, o: c.at[:, slot:slot + 1].set(o), cache, one)
        tok = greedy_sample(logits[:, -1:], cfg.vocab_size)
        r["t_first"] = time.perf_counter()
        r["tokens"] = [int(tok[0, 0])]
        r["itl"] = []
        seq_lens = seq_lens.at[slot].set(prompt.shape[1])
        cur_tok = cur_tok.at[slot].set(tok[0])
        slot_req[slot] = r

    t0 = time.perf_counter()
    steps = 0
    while waiting or any(slot_req):
        for s in range(SLOTS):
            if slot_req[s] is None and waiting:
                admit(s)
        # one concurrent decode step over all active slots
        lg, cache = decode_fn(params, cur_tok, seq_lens[:, None], cache,
                              seq_lens)
        nxt = greedy_sample(lg, cfg.vocab_size)
        now = time.perf_counter()
        steps += 1
        for s in range(SLOTS):
            r = slot_req[s]
            if r is None:
                continue
            kv_mgr.append_token(r["rid"])
            r["itl"].append(now - (r.get("t_last") or r["t_first"]))
            r["t_last"] = now
            r["tokens"].append(int(nxt[s, 0]))
            seq_lens = seq_lens.at[s].add(1)
            cur_tok = cur_tok.at[s].set(nxt[s])
            if len(r["tokens"]) >= r["max_new"]:
                kv_mgr.free(r["rid"])
                r["t_done"] = now
                done.append(r)
                slot_req[s] = None

    wall = time.perf_counter() - t0
    total_tokens = sum(len(r["tokens"]) for r in done)
    itls = [i for r in done for i in r["itl"]]
    print(f"served {len(done)} requests, {total_tokens} tokens in "
          f"{wall:.1f}s ({steps} decode steps)")
    print(f"  mean ITL {1e3 * np.mean(itls):.1f} ms   "
          f"p95 ITL {1e3 * np.percentile(itls, 95):.1f} ms")
    print(f"  KV pool fully reclaimed: "
          f"{kv_mgr.allocator.free_count == kv_mgr.allocator.num_blocks}")
    assert kv_mgr.allocator.free_count == kv_mgr.allocator.num_blocks
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
