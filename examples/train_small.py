"""Train a ~100M-class reduced model for a few hundred steps on CPU with
the full production substrate: microbatched grad accumulation, WSD
schedule, async checkpointing, an injected failure + restart, and
gradient compression — loss must descend through all of it.

    PYTHONPATH=src python examples/train_small.py --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import get_reduced_config, replace
from repro.data import TokenPipeline
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import OptConfig
from repro.training.resilience import FailureEvent, TrainingSupervisor
from repro.training.train_lib import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    ap.add_argument("--fail-at", type=int, default=150)
    args = ap.parse_args(argv)

    # xlstm-125m-family reduced, scaled up a bit (~15M params — enough
    # to show real learning on CPU in minutes)
    cfg = replace(get_reduced_config("xlstm-125m"),
                  num_layers=6, d_model=128, vocab_size=2048)
    opt = OptConfig(lr=3e-3, warmup_steps=20,
                    stable_steps=args.steps, decay_steps=50,
                    grad_accum_dtype="float32")
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model: {cfg.num_layers}L d={cfg.d_model} "
          f"({n_params / 1e6:.1f}M params)")

    step_fn = jax.jit(make_train_step(cfg, opt, microbatches=2,
                                      compress_grads=True))
    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq_len, seed=0)
    pos = jnp.broadcast_to(jnp.arange(args.seq_len)[None],
                           (args.batch, args.seq_len))

    def batches():
        for _ in range(args.steps):
            x, y = pipe.next_batch()
            yield {"inputs": jnp.asarray(x), "labels": jnp.asarray(y),
                   "positions": pos}

    ckpt = CheckpointManager(args.ckpt_dir, keep=2, async_save=True)
    sup = TrainingSupervisor(step_fn, ckpt, ckpt_every=50)
    t0 = time.time()
    state = sup.run(state, batches(),
                    failures=[FailureEvent(step=args.fail_at)])
    losses = [e["loss"] for e in sup.log if e["event"] == "step"]
    dt = time.time() - t0
    print(f"steps: {len(losses)}  restarts: {sup.restarts}  "
          f"wall: {dt:.0f}s ({dt / max(len(losses), 1) * 1e3:.0f} ms/step)")
    print(f"loss: {losses[0]:.3f} -> min {min(losses):.3f} "
          f"-> final {losses[-1]:.3f}")
    assert losses[-1] < losses[0] - 1.0, "training failed to learn"
    print("OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
