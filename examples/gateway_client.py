"""Gateway client demo: simulated churn walkthrough + real HTTP client.

Default (no server needed) — drive the gateway on the simulated clock
through a crash-and-failover scenario and print the per-request event
streams:

    PYTHONPATH=src python examples/gateway_client.py

Against a live server (start one with
``python -m repro.launch.serve --serve http --port 8080``):

    PYTHONPATH=src python examples/gateway_client.py \
        --url http://127.0.0.1:8080 --prompt-len 512 --max-new-tokens 32

The HTTP path is a stdlib-only NDJSON streaming client: it prints each
typed event line as it arrives (the same ``core.events`` records the
simulator emits, via ``event_from_json``).
"""
import argparse
import json
import socket
import sys
import urllib.parse

sys.path.insert(0, "src")

from repro.core.events import (FinishedEvent, RejectedEvent,  # noqa: E402
                               TokenEvent, event_from_json)


def sim_demo() -> int:
    from repro.config import SLOConfig, ServeConfig, get_config
    from repro.core.request import Request
    from repro.serving import Gateway

    cfg = get_config("llama3-70b")
    serve = ServeConfig(mode="rapid", chips=16, slo=SLOConfig(itl_ms=100.0),
                        chunk_size=512, disagg_split=(8, 8),
                        max_batch_slots=64)
    gw = Gateway(cfg, serve, modes=["rapid", "rapid"], router="round_robin")
    print("fleet:", gw.health()["workers"])

    seen = {}
    reqs = [Request(rid=i, arrival=0.01 * i, prompt_len=256,
                    max_new_tokens=120) for i in range(6)]
    gw._expected = len(reqs)
    for r in reqs:
        def go(r=r):
            seen[r.rid] = []
            gw.submit(r, consumer=seen[r.rid].append)
        gw.clock.at(r.arrival, go)

    print("t=0.20  killing worker rapid-0 mid-decode ...")
    gw.clock.at(0.2, lambda: gw.kill_worker(0))
    gw.clock.run()

    for rid in sorted(seen):
        evs = seen[rid]
        toks = [e for e in evs if isinstance(e, TokenEvent)]
        fin = evs[-1]
        if isinstance(fin, FinishedEvent):
            print(f"  r{rid}: {len(toks)} tokens, retries={fin.retries}, "
                  f"finished t={fin.t:.2f}s")
        elif isinstance(fin, RejectedEvent):
            print(f"  r{rid}: REJECTED ({fin.reason}) after "
                  f"{fin.output_len} tokens")
    s = gw.metrics_summary()["fleet"]
    print(f"fleet: completed={s['completed']} retries={s['retries']} "
          f"rejected={s['rejected']} loop={s['loop']}")
    print("workers now:", gw.health()["workers"])
    return 0


def http_demo(url: str, prompt_len: int, max_new_tokens: int,
              session_id: str = None) -> int:
    u = urllib.parse.urlparse(url)
    host, port = u.hostname or "127.0.0.1", u.port or 8080
    body = {"prompt_len": prompt_len, "max_new_tokens": max_new_tokens}
    if session_id:
        body["session_id"] = session_id
    payload = json.dumps(body).encode()
    with socket.create_connection((host, port), timeout=30) as sock:
        sock.sendall((f"POST /v1/generate HTTP/1.1\r\nHost: {host}\r\n"
                      f"Content-Length: {len(payload)}\r\n\r\n").encode()
                     + payload)
        f = sock.makefile("rb")
        status = f.readline().decode().split()
        if status[1] != "200":
            print("HTTP", status[1], file=sys.stderr)
            return 1
        while f.readline() not in (b"\r\n", b"\n", b""):
            pass                                 # skip headers
        n = 0
        for line in f:
            ev = event_from_json(line.decode())
            if isinstance(ev, TokenEvent):
                n += 1
                print(f"\rtokens: {n}", end="", flush=True)
            elif isinstance(ev, FinishedEvent):
                print(f"\nfinished: {ev.output_len} tokens, "
                      f"retries={ev.retries}, truncated={ev.truncated}")
            elif isinstance(ev, RejectedEvent):
                print(f"\nrejected: {ev.reason}")
            else:
                print(f"[{ev.phase}]", end=" ", flush=True)
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--url", default=None,
                   help="gateway base URL; omit for the simulated demo")
    p.add_argument("--prompt-len", type=int, default=512)
    p.add_argument("--max-new-tokens", type=int, default=32)
    p.add_argument("--session-id", default=None)
    args = p.parse_args(argv)
    if args.url:
        return http_demo(args.url, args.prompt_len, args.max_new_tokens,
                         args.session_id)
    return sim_demo()


if __name__ == "__main__":
    raise SystemExit(main())
