"""KV-aware admission + cross-replica preemption + heterogeneous
bucketed replicas, side by side on a KV-constrained bimodal trace.

Three fleets at equal total chips (64):
  1. the PR-1 baseline: 4x16-chip rapid replicas, least_loaded router;
  2. the same fleet with KV-aware admission and the rebalance tick;
  3. a heterogeneous rapid:2x16,rapid:1x32 fleet behind the bucketed
     router (long prompts go to the big replica), plus admission and
     rebalancing.

    PYTHONPATH=src python examples/admission_preemption.py
"""
from repro.config import SLOConfig, ServeConfig, get_config
from repro.serving import (AdmissionPolicy, RebalancePolicy,
                           generate_trace, parse_mix, run_fleet)
from repro.serving.traces import TraceSpec

ARCH = "llama3-70b"
QPS, DURATION, SEED = 8.0, 15.0, 7


def trace():
    short = generate_trace(TraceSpec("short", 2000, 0.4, 200, 0.4, 8000,
                                     512),
                           qps=QPS * 0.7, duration_s=DURATION, seed=SEED)
    long_ = generate_trace(TraceSpec("long", 14_000, 0.25, 500, 0.4,
                                     30_000, 1024),
                           qps=QPS * 0.3, duration_s=DURATION,
                           seed=SEED + 1)
    reqs = short + long_
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


def main():
    cfg = get_config(ARCH)
    serve = ServeConfig(mode="rapid", chips=16,
                        slo=SLOConfig(itl_ms=100.0), disagg_split=(8, 8),
                        max_batch_slots=128, kv_reserve_frac=0.40)
    adm = AdmissionPolicy(kv_headroom=0.9, projected_output_frac=1.0)
    reb = RebalancePolicy()
    fleets = [
        ("baseline 4x16 least_loaded", ["rapid"] * 4, "least_loaded",
         None, None),
        ("4x16 + admission + rebalance", ["rapid"] * 4, "least_loaded",
         adm, reb),
        ("2x16+1x32 bucketed + adm + reb",
         parse_mix("rapid:2x16,rapid:1x32"), "bucketed", adm, reb),
    ]
    reqs = trace()
    print(f"trace: {len(reqs)} requests @ {QPS} qps, 70% chat / 30% "
          f"long-doc ({ARCH}, tight KV pools)\n")
    for name, modes, router, admission, rebalance in fleets:
        res, cluster = run_fleet(cfg, serve, modes, router, reqs,
                                 admission=admission, rebalance=rebalance)
        f = res["fleet"]
        print(f"{name:32s} goodput={f['goodput_req_s']:5.2f} req/s  "
              f"slo_ok={f['slo_attainment'] * 100:5.1f}%  "
              f"ttft_p99={f['ttft_p99_s']:5.2f}s  "
              f"preempt={f['preemptions']:3d}  "
              f"migr={f['migrations']:2d}  rej={f['rejected']:2d}")
        if res.get("admission"):
            print(f"{'':32s} admission: {res['admission']}")
        for t, src, dst, rid, had_kv in cluster._migrations:
            kind = "KV-transfer" if had_kv else "requeue"
            print(f"{'':32s} t={t:5.1f}s migrate rid={rid} "
                  f"{src} -> {dst} ({kind})")


if __name__ == "__main__":
    main()
