"""Mixtral-8x7B — the paper's MoE evaluation model.  [arXiv:2401.04088]

32L, d_model=4096, 32H (GQA kv=8), expert d_ff=14336, vocab=32000,
8 experts top-2, sliding window 4096.
"""
from repro.config import MoEConfig, ModelConfig, register

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    ffn_pattern=("moe",),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336),
    sliding_window=4096,
    train_microbatches=16,
    source="[arXiv:2401.04088; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=500,
        head_dim=32,
        ffn_pattern=("moe",),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
        sliding_window=16,
    )


register(CONFIG, reduced)
