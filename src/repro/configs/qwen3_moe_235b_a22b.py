"""Qwen3-MoE-235B-A22B: 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]

94L, d_model=4096, 64H (GQA kv=4), expert d_ff=1536, vocab=151936.
Every layer is MoE (no dense FFN layers).
"""
from repro.config import MoEConfig, ModelConfig, register

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    d_ff=1536,
    vocab_size=151_936,
    head_dim=128,
    ffn_pattern=("moe",),
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536),
    rope_theta=1_000_000.0,
    opt_dtype="bfloat16",
    train_microbatches=16,
    source="[hf:Qwen/Qwen3-30B-A3B; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=64,
        vocab_size=512,
        head_dim=32,
        ffn_pattern=("moe",),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64),
    )


register(CONFIG, reduced)
