"""xLSTM-125M: sLSTM + mLSTM blocks.  [arXiv:2405.04517; unverified]

12L, d_model=768, 4 heads, vocab=50304, no separate FFN (d_ff=0 — xLSTM
blocks carry their own pre/post up-projections).  Pattern mLSTM:sLSTM 2:1
(the paper's xLSTM[7:1] ratio does not divide 12 layers; recorded as an
assumption in DESIGN.md).
"""
from repro.config import ModelConfig, XLSTMConfig, register

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=192,
    layer_pattern=("mlstm", "mlstm", "slstm"),
    ffn_pattern=("none",),
    xlstm=XLSTMConfig(proj_factor=2.0, num_heads=4),
    rope_type="none",
    tie_embeddings=True,
    train_microbatches=2,
    source="[arXiv:2405.04517; unverified]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=256,
        head_dim=16,
        layer_pattern=("mlstm", "mlstm", "slstm"),
        ffn_pattern=("none",),
        xlstm=XLSTMConfig(proj_factor=2.0, num_heads=4),
        rope_type="none",
        tie_embeddings=True,
    )


register(CONFIG, reduced)
