"""Qwen2.5-14B: GQA with QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]

48L, d_model=5120, 40H (GQA kv=8), d_ff=13824, vocab=152064.
"""
from repro.config import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    train_microbatches=8,
    source="[hf:Qwen/Qwen2.5-0.5B; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        qkv_bias=True,
    )


register(CONFIG, reduced)
