"""Architecture registry — import every config module to populate it."""
from repro.configs import (  # noqa: F401
    jamba_1_5_large_398b,
    xlstm_125m,
    starcoder2_3b,
    granite_8b,
    qwen2_5_14b,
    minicpm_2b,
    musicgen_large,
    qwen3_moe_235b_a22b,
    mixtral_8x22b,
    qwen2_vl_72b,
    llama3_70b,
    mixtral_8x7b,
)
