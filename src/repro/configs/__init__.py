"""Architecture registry — import every config module to populate it."""
from repro.configs import (  # noqa: F401
    granite_8b,
    jamba_1_5_large_398b,
    llama3_70b,
    minicpm_2b,
    mixtral_8x22b,
    mixtral_8x7b,
    musicgen_large,
    qwen2_5_14b,
    qwen2_vl_72b,
    qwen3_moe_235b_a22b,
    starcoder2_3b,
    xlstm_125m,
)
