"""Qwen2-VL-72B backbone: M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

80L, d_model=8192, 64H (GQA kv=8), d_ff=29568, vocab=152064.  The vision
tower is a STUB: ``input_specs()`` provides precomputed patch embeddings
(frontend='embed_stub') plus 3-D (t,h,w) position ids consumed by M-RoPE.
"""
from repro.config import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152_064,
    head_dim=128,
    qkv_bias=True,
    rope_type="mrope",
    rope_theta=1_000_000.0,
    frontend="embed_stub",
    opt_dtype="bfloat16",
    train_microbatches=16,
    source="[arXiv:2409.12191; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        qkv_bias=True,
        rope_type="mrope",
        frontend="embed_stub",
    )


register(CONFIG, reduced)
