"""Jamba-1.5-Large (398B): Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf] — 72L, d_model=8192, 64H (GQA kv=8), d_ff=24576,
vocab=65536.  Each period of 8 layers has one attention layer (position 4,
matching Jamba's attn_layer_offset); MoE replaces the dense FFN on every
other layer (e=2).  Jamba uses no explicit positional encoding (the Mamba
layers carry position); rope_type="none".
"""
from repro.config import MambaConfig, MoEConfig, ModelConfig, register

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    head_dim=128,
    layer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    ffn_pattern=("dense", "moe"),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    rope_type="none",
    opt_dtype="bfloat16",
    train_microbatches=16,
    source="[arXiv:2403.19887; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=8,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        layer_pattern=("mamba", "mamba", "mamba", "mamba",
                       "attn", "mamba", "mamba", "mamba"),
        ffn_pattern=("dense", "moe"),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
        rope_type="none",
    )


register(CONFIG, reduced)
