"""LLaMA-3.1-70B — the paper's dense evaluation model.  [arXiv:2407.21783]

80L, d_model=8192, 64H (GQA kv=8), d_ff=28672, vocab=128256.
"""
from repro.config import ModelConfig, register

CONFIG = ModelConfig(
    name="llama3-70b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128_256,
    head_dim=128,
    rope_theta=500_000.0,
    opt_dtype="bfloat16",
    train_microbatches=16,
    source="[arXiv:2407.21783; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llama3-70b",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
    )


register(CONFIG, reduced)
