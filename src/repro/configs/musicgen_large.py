"""MusicGen-large: decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]

48L, d_model=2048, 32H (kv=32), d_ff=8192, vocab=2048.  The EnCodec audio
frontend is a STUB: ``input_specs()`` provides precomputed frame embeddings
(frontend='embed_stub'); the backbone is what we lower/serve.  Plain GELU
MLP (T5-style), no GLU; sinusoidal positions in the original -> modeled as
rope_type='none' with embeddings arriving position-encoded from the stub.
"""
from repro.config import ModelConfig, register

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    head_dim=64,
    ffn_glu=False,
    act="gelu",
    rope_type="none",
    frontend="embed_stub",
    train_microbatches=4,
    source="[arXiv:2306.05284; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=256,
        vocab_size=128,
        head_dim=32,
        ffn_glu=False,
        act="gelu",
        rope_type="none",
        frontend="embed_stub",
    )


register(CONFIG, reduced)
