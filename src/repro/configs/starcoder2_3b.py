"""StarCoder2-3B: GQA + RoPE, plain GELU MLP, biases.  [arXiv:2402.19173; hf]

30L, d_model=3072, 24H (GQA kv=2), d_ff=12288, vocab=49152.
"""
from repro.config import ModelConfig, register

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    head_dim=128,
    qkv_bias=True,
    ffn_glu=False,
    act="gelu",
    rope_theta=999_999.0,
    train_microbatches=4,
    source="[arXiv:2402.19173; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        qkv_bias=True,
        ffn_glu=False,
        act="gelu",
    )


register(CONFIG, reduced)
