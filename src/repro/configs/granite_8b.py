"""Granite-8B-Code: llama-arch (SwiGLU, RoPE, GQA).  [arXiv:2405.04324; hf]

36L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=49152.
"""
from repro.config import ModelConfig, register

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    head_dim=128,
    tie_embeddings=True,
    train_microbatches=8,
    source="[arXiv:2405.04324; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-8b",
        family="dense",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        tie_embeddings=True,
    )


register(CONFIG, reduced)
