"""MiniCPM-2B: llama-like, MHA (kv=36), tied embeddings, WSD schedule.

[arXiv:2404.06395; hf] — 40L, d_model=2304, 36H (kv=36), d_ff=5760,
vocab=122753, head_dim=64.  (The WSD learning-rate schedule is a training
detail, implemented in repro/training/schedule.py.)
"""
from repro.config import ModelConfig, register

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    head_dim=64,
    tie_embeddings=True,
    train_microbatches=4,
    source="[arXiv:2404.06395; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b",
        family="dense",
        num_layers=2,
        d_model=96,
        num_heads=6,
        num_kv_heads=6,
        d_ff=192,
        vocab_size=511,  # odd on purpose: exercises vocab padding
        head_dim=16,
        tie_embeddings=True,
    )


register(CONFIG, reduced)
