"""Mixtral-8x22B: 8 experts top-2, sliding-window attention.

[arXiv:2401.04088; hf] — 56L, d_model=6144, 48H (GQA kv=8), expert
d_ff=16384, vocab=32768.  SWA window 4096 bounds the KV cache, making
long_500k decode sub-quadratic (O(window) per token).
"""
from repro.config import MoEConfig, ModelConfig, register

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    ffn_pattern=("moe",),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16384),
    sliding_window=4096,
    opt_dtype="bfloat16",
    train_microbatches=16,
    source="[arXiv:2401.04088; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=512,
        head_dim=32,
        ffn_pattern=("moe",),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
        sliding_window=16,
    )


register(CONFIG, reduced)
