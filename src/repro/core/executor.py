"""Step execution backends for the generic serving engine (API v2).

An ``Executor`` prices the steps a ``Scheduler`` decided to launch: it
turns a ``StepPlan`` into per-lane durations (and the ``StepCost``
objects the interference model needs for overlapped steps).  The default
``PerfModelExecutor`` wraps ``perfmodel.costs`` + ``perfmodel.
interference`` — engine control flow is real, only durations are
modelled (DESIGN.md §6).

The split exists so a *real-kernel* executor can drop in behind the same
interface: one that launches ``kernels/unified_pd.py`` (the fused
prefill+decode Pallas kernel) and reports measured wall-clock step times
instead of modelled ones.  ``KernelExecutor`` below is the documented
stub for that door.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.scheduler import SchedView, StepPlan
from repro.perfmodel import batch as B
from repro.perfmodel import costs as C
from repro.perfmodel import interference as I
from repro.perfmodel.hw import TPU_V5E, HardwareSpec


@dataclasses.dataclass(frozen=True)
class LaunchOutcome:
    """One priced lane step: wall-clock duration (host overhead included,
    Fig 6) plus the device cost the interference model consumes while
    the step is in flight."""
    duration_s: float
    cost: C.StepCost


@dataclasses.dataclass(frozen=True)
class StepOutputs:
    """Durations for every launch in a ``StepPlan`` (None = not in plan)."""
    prefill: Optional[LaunchOutcome] = None
    decode: Optional[LaunchOutcome] = None
    hybrid: Optional[LaunchOutcome] = None


class Executor:
    """Protocol: price a StepPlan.  Implementations must price launches
    in plan order — prefill before decode — so a decode launched in the
    same plan sees the new prefill in flight (colocated interference)."""

    def execute(self, plan: StepPlan, view: SchedView) -> StepOutputs:
        raise NotImplementedError

    def price_batch(self, plans: Sequence[StepPlan],
                    views: Sequence[SchedView]) -> "list[StepOutputs]":
        """Price many (plan, view) pairs in one call.  The pairs must be
        causally independent (different replicas, or speculative what-if
        pricing) — implementations may reorder the underlying cost
        evaluations.  Default: the sequential scalar path."""
        return [self.execute(p, v) for p, v in zip(plans, views)]

    def transfer_seconds(self, r, serve) -> float:
        """Disagg KV-transfer time for one request (ICI on the critical
        path, §3.2.1)."""
        raise NotImplementedError


class PerfModelExecutor(Executor):
    """Default executor: calibrated TPU-v5e perfmodel durations.

    ``colocated`` selects the paper's intra-GPU interference coupling:
    when prefill and decode share chips, an overlapped step's duration
    comes from ``interference.overlapped_times`` under the decode lane's
    resource split ``f_decode``; split-pool (disagg) lanes run at their
    own ``phase_time``.
    """

    def __init__(self, cfg, hw: HardwareSpec = TPU_V5E,
                 colocated: bool = True,
                 lane_chips: Optional[Dict[str, int]] = None):
        self.cfg = cfg
        self.hw = hw
        self.colocated = colocated
        self.lane_chips = lane_chips or {}

    def _chips(self, lane: str, serve) -> int:
        return self.lane_chips.get(lane, serve.chips)

    # -- host-side scheduling overhead (Fig 6a vs 6b) -----------------------
    def _step_time(self, device_s: float, serve) -> float:
        cpu = serve.scheduler_overhead_ms / 1e3
        if serve.async_scheduling:
            return max(device_s, cpu)
        return device_s + cpu

    def execute(self, plan: StepPlan, view: SchedView) -> StepOutputs:
        return self._assemble(plan, view, C.prefill_cost,
                              C.chunk_prefill_cost, C.decode_cost)

    def price_batch(self, plans: Sequence[StepPlan],
                    views: Sequence[SchedView]) -> "list[StepOutputs]":
        """Batched pricing: every cost any plan needs is collected,
        deduplicated by operating point, and priced through the
        ``perfmodel.batch`` array layer in one call per cost kind — the
        per-call ``lru_cache`` memoization of the scalar path becomes
        vectorized key dedup here.  Control flow is ``_assemble`` both
        times (a recording pass, then a lookup pass), so the batched and
        scalar paths cannot drift; the costs themselves are bit-identical
        by the batch layer's contract."""
        pre_k: dict = {}
        chk_k: dict = {}
        dec_k: dict = {}

        def rec_pre(cfg, seq_lens, tp):
            pre_k[(tuple(seq_lens), tp)] = None
            return C.ZERO_COST

        def rec_chk(cfg, chunk_tokens, ctx_so_far, tp):
            chk_k[(chunk_tokens, ctx_so_far, tp)] = None
            return C.ZERO_COST

        def rec_dec(cfg, bs, ctx_total, tp):
            dec_k[(bs, ctx_total, tp)] = None
            return C.ZERO_COST

        for p, v in zip(plans, views):
            self._assemble(p, v, rec_pre, rec_chk, rec_dec)

        if pre_k:
            ks = list(pre_k)
            got = B.prefill_cost(self.cfg, [k[0] for k in ks],
                                 np.array([k[1] for k in ks]))
            for i, k in enumerate(ks):
                pre_k[k] = got.item(i) if any(k[0]) else C.ZERO_COST
        if chk_k:
            ks = list(chk_k)
            got = B.chunk_prefill_cost(
                self.cfg, [k[0] for k in ks], [k[1] for k in ks],
                np.array([k[2] for k in ks]))
            for i, k in enumerate(ks):
                chk_k[k] = got.item(i)
        if dec_k:
            ks = list(dec_k)
            got = B.decode_cost(self.cfg, [k[0] for k in ks],
                                [k[1] for k in ks],
                                np.array([k[2] for k in ks]))
            for i, k in enumerate(ks):
                dec_k[k] = got.item(i) if k[0] else C.ZERO_COST

        def use_pre(cfg, seq_lens, tp):
            return pre_k[(tuple(seq_lens), tp)]

        def use_chk(cfg, chunk_tokens, ctx_so_far, tp):
            return chk_k[(chunk_tokens, ctx_so_far, tp)]

        def use_dec(cfg, bs, ctx_total, tp):
            return dec_k[(bs, ctx_total, tp)]

        return [self._assemble(p, v, use_pre, use_chk, use_dec)
                for p, v in zip(plans, views)]

    def _assemble(self, plan: StepPlan, view: SchedView, prefill_cost,
                  chunk_prefill_cost, decode_cost) -> StepOutputs:
        """The one pricing control flow: which costs a plan needs and how
        they couple through the interference model.  ``execute`` injects
        the memoized scalar pricers; ``price_batch`` injects recorders,
        then lookups into the batched results."""
        serve = view.serve
        p_out = d_out = h_out = None
        if plan.prefill is not None:
            chips = self._chips("prefill", serve)
            batch = plan.prefill.batch
            if any(r.cached_prefix_len for r in batch):
                # session prefix skip: each request only prefills its new
                # suffix, attending over the cached prefix as context
                cost = C.ZERO_COST
                for r in batch:
                    cost = cost + chunk_prefill_cost(
                        self.cfg, r.prefill_tokens_needed,
                        r.cached_prefix_len, chips)
            else:
                cost = prefill_cost(
                    self.cfg, [r.prompt_len for r in batch], chips)
            dlane = view.lanes.get("decode", None)
            if self.colocated and dlane is not None and dlane.busy and \
                    dlane.cost is not None:
                dur = I.overlapped_times(cost, dlane.cost, self.hw, chips,
                                         f_decode=dlane.f_decode).t_prefill
            else:
                dur = I.phase_time(cost, self.hw, chips)
            p_out = LaunchOutcome(self._step_time(dur, serve), cost)
        if plan.decode is not None:
            chips = self._chips("decode", serve)
            # running batch context from the queue's incremental counter
            # (identical integer sum, without the O(batch) walk)
            bs = len(view.running) + len(plan.decode.joins)
            ctx_total = float(view.running.ctx_tokens +
                              sum(r.context_len for r in plan.decode.joins))
            cost = decode_cost(self.cfg, bs, ctx_total, chips)
            if p_out is not None:
                p_cost = p_out.cost          # launched in this same plan
            else:
                plane = view.lanes.get("prefill", None)
                p_cost = plane.cost if plane is not None and plane.busy \
                    else None
            if self.colocated and p_cost is not None:
                dur = I.overlapped_times(p_cost, cost, self.hw, chips,
                                         f_decode=plan.decode.f_decode
                                         ).t_decode
            else:
                dur = I.phase_time(cost, self.hw, chips)
            d_out = LaunchOutcome(self._step_time(dur, serve), cost)
        if plan.hybrid is not None:
            chips = self._chips("step", serve)
            cost = C.ZERO_COST
            for r, take in plan.hybrid.chunks:
                cost = cost + chunk_prefill_cost(
                    self.cfg, take,
                    r.cached_prefix_len + r.prefill_tokens_done, chips)
            bs = len(view.running)
            if bs:
                ctx_total = float(view.running.ctx_tokens)
                cost = cost + decode_cost(self.cfg, bs, ctx_total, chips)
            dur = I.phase_time(cost, self.hw, chips)
            h_out = LaunchOutcome(self._step_time(dur, serve), cost)
        return StepOutputs(prefill=p_out, decode=d_out, hybrid=h_out)

    def transfer_seconds(self, r, serve) -> float:
        return C.kv_transfer_bytes(self.cfg, r.prompt_len) / \
            (serve.kv_transfer_gbps * 1e9)


class KernelExecutor(Executor):
    """Door-opener stub: execute steps with the real fused P/D kernel.

    A full implementation would build model state once, then run
    ``kernels.unified_pd`` for colocated plans (prefill + decode in one
    fused launch) and the flash-prefill / paged-attention kernels for
    split lanes, reporting measured wall-clock durations.  Kept as an
    explicit stub so the interface is designed-in rather than bolted on;
    see examples/serve_real.py for the CPU-real generation path.
    """

    def __init__(self, *a, **kw):
        raise NotImplementedError(
            "KernelExecutor is a design stub: durations come from "
            "PerfModelExecutor until the real-kernel executor PR "
            "(kernels/unified_pd.py) lands")
