"""Adaptive Resource Manager (paper §4.5.3) + offline profiling.

Two regimes, switched at runtime on the decode batch size:

  * overallocation — both phases get 100% of compute (f=None); the
    hardware scheduler (TPU analogue: occupancy-demand sharing, see
    perfmodel/interference.py) fills gaps.  Used while the decode batch is
    small enough that inter-stream interference keeps ITL under the SLO.

  * distinct allocation — decode gets the *minimum* capacity fraction
    that meets the ITL SLO (from an offline profile, the CU-mask table
    analogue); prefill gets the rest.

The offline profile is built with the same perfmodel the simulator uses —
the moral equivalent of the paper's microbenchmark profiling pass, and it
is regenerated per (model, chips, SLO) triple.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional

from repro.perfmodel import costs as C
from repro.perfmodel import interference as I
from repro.perfmodel.hw import HardwareSpec

# capacity-fraction grid matching the paper's profiled CU-mask settings
F_GRID = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.75, 0.8, 0.85, 0.9]
BS_BUCKETS = [1, 2, 4, 8, 16, 32, 48, 64, 96, 128, 192, 256]


@dataclasses.dataclass(frozen=True)
class DecodeProfile:
    """bs bucket -> min f_d meeting the SLO; and the largest bs for which
    overallocation still meets the SLO (the Fig 7 crossover)."""
    buckets: List[int]
    min_f: Dict[int, float]
    overalloc_bs_limit: int
    slo_itl_s: float


def build_decode_profile(cfg, hw: HardwareSpec, chips: int,
                         slo_itl_s: float, avg_ctx: int,
                         tp: Optional[int] = None) -> DecodeProfile:
    """Offline profiling pass: sweep (bs, f) and record SLO frontiers."""
    tp = tp or chips
    min_f: Dict[int, float] = {}
    overalloc_limit = 0
    # a representative co-resident prefill (saturating, compute-bound)
    p_cost = C.prefill_cost(cfg, [4096], tp)
    for bs in BS_BUCKETS:
        d_cost = C.decode_cost(cfg, bs, float(bs * avg_ctx), tp)
        # overallocation check (P100-D100 of Fig 7)
        r = I.overlapped_times(p_cost, d_cost, hw, chips)
        if r.t_decode <= slo_itl_s:
            overalloc_limit = bs
        # distinct-allocation frontier
        for f in F_GRID:
            t_d = I.phase_time(d_cost, hw, chips, f=f,
                               mem_interference=I.MEM_INTERFERENCE_DECODE)
            if t_d <= slo_itl_s:
                min_f[bs] = f
                break
        else:
            min_f[bs] = F_GRID[-1]  # best effort: SLO unreachable at this bs
    return DecodeProfile(list(BS_BUCKETS), min_f, overalloc_limit, slo_itl_s)


@dataclasses.dataclass
class Allocation:
    f_decode: Optional[float]   # None => overallocation
    mode: str

    @property
    def f_prefill(self) -> float:
        return 1.0 if self.f_decode is None else 1.0 - self.f_decode


class AdaptiveResourceManager:
    """Runtime allocation policy driven by the offline profile."""

    def __init__(self, profile: DecodeProfile):
        self.profile = profile
        self.history: List[Allocation] = []

    def allocate(self, decode_bs: int, prefill_active: bool) -> Allocation:
        if decode_bs == 0 or not prefill_active:
            a = Allocation(None, "solo")
        elif decode_bs <= self.profile.overalloc_bs_limit:
            a = Allocation(None, "overalloc")
        else:
            i = bisect.bisect_left(self.profile.buckets, decode_bs)
            i = min(i, len(self.profile.buckets) - 1)
            a = Allocation(self.profile.min_f[self.profile.buckets[i]],
                           "distinct")
        self.history.append(a)
        return a
