"""Adaptive Resource Manager (paper §4.5.3) + offline profiling.

Two regimes, switched at runtime on the decode batch size:

  * overallocation — both phases get 100% of compute (f=None); the
    hardware scheduler (TPU analogue: occupancy-demand sharing, see
    perfmodel/interference.py) fills gaps.  Used while the decode batch is
    small enough that inter-stream interference keeps ITL under the SLO.

  * distinct allocation — decode gets the *minimum* capacity fraction
    that meets the ITL SLO (from an offline profile, the CU-mask table
    analogue); prefill gets the rest.

The offline profile is built with the same perfmodel the simulator uses —
the moral equivalent of the paper's microbenchmark profiling pass, and it
is regenerated per (model, chips, SLO) triple.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional

from repro.perfmodel import costs as C
from repro.perfmodel import interference as I
from repro.perfmodel.hw import HardwareSpec

# capacity-fraction grid matching the paper's profiled CU-mask settings
F_GRID = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.75, 0.8, 0.85, 0.9]
BS_BUCKETS = [1, 2, 4, 8, 16, 32, 48, 64, 96, 128, 192, 256]


@dataclasses.dataclass(frozen=True)
class DecodeProfile:
    """bs bucket -> min f_d meeting the SLO; plus the Fig 7 crossover:
    ``overalloc_bs_limit`` is the largest profiled bs *below the first
    SLO miss* for which overallocation meets the SLO.  The scan stops
    raising the limit at the first miss — a non-monotone interference
    curve (a mid bs failing while a larger bs passes) must not re-open
    the overallocation regime above the crossover, or the runtime would
    overallocate at batch sizes bracketed by known SLO violations."""
    buckets: List[int]
    min_f: Dict[int, float]
    overalloc_bs_limit: int
    slo_itl_s: float


def build_decode_profile(cfg, hw: HardwareSpec, chips: int,
                         slo_itl_s: float, avg_ctx: int,
                         tp: Optional[int] = None) -> DecodeProfile:
    """Offline profiling pass: sweep (bs, f) and record SLO frontiers."""
    tp = tp or chips
    min_f: Dict[int, float] = {}
    overalloc_limit = 0
    crossover_hit = False
    # a representative co-resident prefill (saturating, compute-bound)
    p_cost = C.prefill_cost(cfg, [4096], tp)
    for bs in BS_BUCKETS:
        d_cost = C.decode_cost(cfg, bs, float(bs * avg_ctx), tp)
        # overallocation check (P100-D100 of Fig 7): the crossover is the
        # FIRST SLO miss — later passes on a non-monotone curve must not
        # raise the limit past a known-violating batch size
        r = I.overlapped_times(p_cost, d_cost, hw, chips)
        if r.t_decode <= slo_itl_s and not crossover_hit:
            overalloc_limit = bs
        elif r.t_decode > slo_itl_s:
            crossover_hit = True
        # distinct-allocation frontier
        for f in F_GRID:
            t_d = I.phase_time(d_cost, hw, chips, f=f,
                               mem_interference=I.MEM_INTERFERENCE_DECODE)
            if t_d <= slo_itl_s:
                min_f[bs] = f
                break
        else:
            min_f[bs] = F_GRID[-1]  # best effort: SLO unreachable at this bs
    return DecodeProfile(list(BS_BUCKETS), min_f, overalloc_limit, slo_itl_s)


_PROFILE_CACHE: Dict[tuple, DecodeProfile] = {}


def cached_decode_profile(cfg, hw: HardwareSpec, chips: int,
                          slo_itl_s: float, avg_ctx: int,
                          tp: Optional[int] = None) -> DecodeProfile:
    """Memoized ``build_decode_profile`` for runtime consumers.

    Every autoscaled rapid replica clone used to re-run the full offline
    sweep (``len(BS_BUCKETS) * len(F_GRID)`` perfmodel evaluations) for a
    (model, chips, SLO) triple the fleet already profiled; identical
    triples now share one read-only ``DecodeProfile``.  Tests that
    monkeypatch the interference model must call ``build_decode_profile``
    directly — this cache assumes the real perfmodel."""
    key = (cfg, hw, chips, slo_itl_s, avg_ctx, tp)
    prof = _PROFILE_CACHE.get(key)
    if prof is None:
        prof = _PROFILE_CACHE[key] = build_decode_profile(
            cfg, hw, chips, slo_itl_s, avg_ctx, tp=tp)
    return prof


@dataclasses.dataclass
class Allocation:
    f_decode: Optional[float]   # None => overallocation
    mode: str                   # solo | overalloc | distinct | distinct_clamped

    @property
    def f_prefill(self) -> float:
        return 1.0 if self.f_decode is None else 1.0 - self.f_decode


class AdaptiveResourceManager:
    """Runtime allocation policy driven by the offline profile.

    Regime selection is explicit in ``allocate`` (the branches are
    pinned by tests, not by evaluation order):

      * ``decode_bs <= 0``      -> ``solo``: no decode work exists, so
        prefill (or an idle engine) owns the chips regardless of
        ``prefill_active``;
      * ``not prefill_active``  -> ``solo``: decode runs alone at f=1;
      * ``bs <= crossover``     -> ``overalloc`` (both phases at 100%);
      * within profiled buckets -> ``distinct`` at the bucket's min f_d
        (between-bucket sizes round UP to the next bucket);
      * above the largest bucket -> ``distinct_clamped``: the profile
        has no data, so decode gets the conservative ``F_GRID[-1]``
        rather than silently reusing the last bucket's (smaller) f_d —
        the clamp is visible in ``Allocation.mode`` / ``history``.
    """

    def __init__(self, profile: DecodeProfile):
        self.profile = profile
        self.history: List[Allocation] = []

    def allocate(self, decode_bs: int, prefill_active: bool) -> Allocation:
        if decode_bs <= 0:
            # no decode work: prefill-only (or idle) — solo even when a
            # prefill is active, and regardless of the crossover value
            a = Allocation(None, "solo")
        elif not prefill_active:
            # decode alone owns the chips: no split needed
            a = Allocation(None, "solo")
        elif decode_bs <= self.profile.overalloc_bs_limit:
            a = Allocation(None, "overalloc")
        else:
            i = bisect.bisect_left(self.profile.buckets, decode_bs)
            if i >= len(self.profile.buckets):
                # beyond the profiled range: conservative extrapolation —
                # the largest profiled f_d would under-provision a bigger
                # batch, so give decode the top of the capacity grid and
                # record the clamp where history consumers can see it
                a = Allocation(F_GRID[-1], "distinct_clamped")
            else:
                a = Allocation(self.profile.min_f[self.profile.buckets[i]],
                               "distinct")
        self.history.append(a)
        return a
