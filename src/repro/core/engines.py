"""Generic serving engine: one execution substrate, pluggable policies.

Serving API v2 (this module + core/scheduler.py + core/executor.py +
core/events.py) splits the historical monolithic engines into

  * a ``Scheduler`` — pure policy: consulted at every wake point with a
    read-only ``SchedView``, returns a ``StepPlan`` (admissions,
    rejections, lane launches, timed retries);
  * an ``Executor`` — prices the launched steps (default
    ``PerfModelExecutor``; a real-kernel executor slots in behind the
    same interface);
  * this ``Engine`` — the substrate: queues, decode-owned paged-KV
    pools, the event loop, preemption, KV transfers, and a typed
    request-lifecycle **event stream** (``TokenEvent`` / ``PhaseEvent``
    / ``FinishedEvent`` / ``RejectedEvent``) consumed via
    ``engine.subscribe()`` / ``engine.events()``.

``RapidEngine`` / ``HybridEngine`` / ``DisaggEngine`` are thin
constructors binding the matching scheduler; ``make_engine`` keeps the
historical entry point.  Callers submit work (``enqueue``/``submit``)
and consume the stream (see README "Serving API v2"); the free function
``drive(engine, requests)`` is the blocking convenience for standalone
engines — the old ``Engine.run()`` shim is gone.

Parity: the scheduler/executor engines reproduce the pre-split engines'
per-request TTFT/ITL/finish metrics exactly (tests/test_parity.py golden
traces; tests/test_cluster.py single-replica equivalence).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.config import ServeConfig
from repro.core.events import (EventStream, FinishedEvent, PhaseEvent,
                               RejectedEvent, TokenEvent)
from repro.core.executor import Executor, PerfModelExecutor
from repro.core.preemption import DEFAULT_PREEMPTION, PreemptionPolicy
from repro.core.queues import IndexedQueue
from repro.core.request import Request, State
from repro.core.scheduler import (DisaggScheduler, HybridScheduler,
                                  LaneState, RapidScheduler, SchedView,
                                  Scheduler, StepPlan, Wake,
                                  kv_pool_blocks as kv_pool_blocks,
                                  make_scheduler)
from repro.kvcache import KVCacheManager, OutOfBlocks, kv_pages_for
from repro.perfmodel.hw import TPU_V5E, HardwareSpec
from repro.serving.metrics import RequestRecord
from repro.serving.sim import EventLoop


@dataclasses.dataclass
class UtilSample:
    t: float
    kv_util: float
    busy: bool


@dataclasses.dataclass(frozen=True)
class LoadSnapshot:
    """Instantaneous engine load, consumed by cluster routers.

    ``queued_prefill_tokens`` counts prompt tokens that still need prefill
    compute (including the un-chunked remainder on hybrid engines) — the
    quantity a least-loaded router balances.  ``decode_ctx_tokens`` is the
    total live context of the running decode batch, which the SLO-aware
    router feeds to the decode cost model.

    ``kv_free_blocks`` / ``kv_total_blocks`` describe the decode-side
    paged-KV pool, and ``queued_kv_pages`` the pages that queued-but-
    unallocated requests will claim when admitted — together they let the
    cluster admission controller project whether a new request fits
    without the engine ever hitting ``OutOfBlocks`` mid-flight.

    Split-pool (disagg) engines additionally expose the transient
    *prefill-side* pool (``prefill_kv_free_blocks`` /
    ``prefill_kv_total_blocks``, with ``queued_prefill_kv_pages`` the
    claims of queued-but-unstarted prompts against it) and their per-pool
    chip counts — the signals projection-driven admission and the
    per-pool autoscaler consume.  Colocated engines report zero pool
    fields and ``chips_prefill == chips_decode == serve.chips``.
    """
    queued_requests: int
    queued_prefill_tokens: int
    running_decode: int
    decode_ctx_tokens: int
    kv_utilization: float
    prefill_busy: bool
    decode_busy: bool
    kv_free_blocks: int = 0
    kv_total_blocks: int = 0
    queued_kv_pages: int = 0
    # split-pool (disagg) per-pool occupancy; zeros on colocated engines
    prefill_kv_free_blocks: int = 0
    prefill_kv_total_blocks: int = 0
    queued_prefill_kv_pages: int = 0
    chips_prefill: int = 0
    chips_decode: int = 0
    # decode-pool blocks parked for finished sessions (prefix cache) —
    # allocated but reclaimable, so admission adds them to free headroom
    kv_session_blocks: int = 0

    @property
    def prefill_kv_utilization(self) -> float:
        if self.prefill_kv_total_blocks <= 0:
            return 0.0
        return 1.0 - self.prefill_kv_free_blocks / \
            self.prefill_kv_total_blocks


class Engine:
    """Scheduler/executor-driven serving engine (one replica)."""

    def __init__(self, cfg, serve: ServeConfig, hw: HardwareSpec = TPU_V5E,
                 scheduler: Optional[Scheduler] = None,
                 executor: Optional[Executor] = None,
                 loop: Optional[EventLoop] = None,
                 preempt_policy: PreemptionPolicy = DEFAULT_PREEMPTION):
        self.cfg = cfg
        self.serve = serve
        self.hw = hw
        # injected loop => this engine is one replica of a cluster sharing
        # a single virtual clock; standalone engines own a private loop
        self.loop = loop if loop is not None else EventLoop()
        self.scheduler = scheduler if scheduler is not None \
            else make_scheduler(serve.mode, cfg, serve, hw)
        self.preempt_policy = preempt_policy
        sched = self.scheduler
        pools = sched.pool_blocks(cfg, serve, hw)
        # session prefix cache budget: inert unless requests carry
        # session ids AND the topology keeps KV resident across turns
        # (colocated join-route engines; disagg decode KV is freed on
        # finish like before)
        session_blocks = int(serve.session_cache_frac * pools["decode"]) \
            if sched.prefill_route == "join" else 0
        self.kv = KVCacheManager(pools["decode"], serve.page_size,
                                 session_cache_blocks=session_blocks)
        self.kv_p = KVCacheManager(pools["prefill"], serve.page_size) \
            if "prefill" in pools else None
        lane_chips = sched.lane_chips(serve)
        if not sched.colocated:
            self.chips_p = lane_chips["prefill"]
            self.chips_d = lane_chips["decode"]
        self.executor = executor if executor is not None else \
            PerfModelExecutor(cfg, hw, colocated=sched.colocated,
                              lane_chips=lane_chips)
        self.arm = getattr(sched, "arm", None)     # rapid compat
        # queues: named order-preserving indexed queues (O(1) remove /
        # membership + incremental load accounting, core/queues.py), also
        # exposed as attributes for direct inspection (waiting_kv /
        # waiting_prefill / pending_join / ...)
        self.queues: Dict[str, IndexedQueue] = {
            name: IndexedQueue(serve.page_size)
            for name in sched.queue_names}
        for name, q in self.queues.items():
            setattr(self, name, q)
        self.running = IndexedQueue(serve.page_size)
        self._lane_busy: Dict[str, bool] = {ln: False for ln in sched.lanes}
        self._lane_cost: Dict[str, object] = {ln: None for ln in sched.lanes}
        self._lane_f: Dict[str, Optional[float]] = \
            {ln: None for ln in sched.lanes}
        self.inflight_prefill_tokens = 0
        self.inflight_transfers = 0
        self.inflight_transfer_tokens = 0
        self.finished: List[Request] = []
        self.rejected: List[Request] = []
        self.util_samples: List[UtilSample] = []
        self._all: List[Request] = []
        self.stream = EventStream()

    # -- lane state (legacy flag names kept as read-only views) -------------
    @property
    def prefill_busy(self) -> bool:
        return self._lane_busy.get("prefill",
                                   self._lane_busy.get("step", False))

    @property
    def decode_busy(self) -> bool:
        return self._lane_busy.get("decode",
                                   self._lane_busy.get("step", False))

    @property
    def busy(self) -> bool:                       # hybrid legacy name
        return self._lane_busy.get("step", False)

    # -- streaming API -------------------------------------------------------
    def subscribe(self, fn, rid: Optional[int] = None):
        """Attach a consumer to the typed event stream; ``rid`` narrows
        to one request.  Returns ``fn`` for later ``unsubscribe``."""
        return self.stream.subscribe(fn, rid)

    def events(self):
        """Replay log of every event emitted so far."""
        return self.stream.events()

    def submit(self, r: Request) -> None:
        """Admit one request now (the streaming entry point)."""
        sched = self.scheduler
        r.state = sched.arrival_state
        self.queues[sched.arrival_queue].append(r)
        self.stream.emit(PhaseEvent(r.rid, self.loop.now, "queued"))
        self._wake(Wake("arrival"))

    def enqueue(self, requests: List[Request]) -> None:
        """Seed arrival events on the (possibly shared) loop without
        running it — the cluster drives the loop itself."""
        self._all.extend(requests)
        for r in requests:
            self.loop.at(r.arrival, lambda r=r: self.submit(r))

    def records(self) -> List[RequestRecord]:
        return [RequestRecord.from_request(r) for r in self._all]

    # -- scheduler consultation ---------------------------------------------
    def _view(self, wake: Wake) -> SchedView:
        sched = self.scheduler
        lanes = {ln: LaneState(self._lane_busy[ln], self._lane_cost[ln],
                               self._lane_f[ln]) for ln in sched.lanes}
        return SchedView(now=self.loop.now, serve=self.serve,
                         queues=self.queues, running=self.running,
                         kv=self.kv, kv_p=self.kv_p, lanes=lanes, wake=wake)

    def _wake(self, wake: Wake) -> None:
        view = self._view(wake)
        plan = self.scheduler.schedule(view)
        self._apply(plan, view)

    def _apply(self, plan: StepPlan, view: SchedView) -> None:
        now = self.loop.now
        failed_admits: set = set()
        for r, qname in plan.rejects:
            if qname is None:                     # in-flight transfer
                self.inflight_transfers -= 1
                self.inflight_transfer_tokens -= r.prompt_len
            else:
                self.queues[qname].remove(r)
            self._reject(r)
        for adm in plan.admits:
            r = adm.request
            if adm.from_queue is None:            # in-flight transfer
                self.inflight_transfers -= 1
                self.inflight_transfer_tokens -= r.prompt_len
            else:
                self.queues[adm.from_queue].remove(r)
            # clamp the trace-optimistic shared prefix to what is
            # actually parked HERE (sessions may land on a replica
            # without their prefix, or the cache may have evicted it);
            # transfer-route (disagg) engines never park and sessionless
            # requests have no cache entry, so the clamp zeroes the
            # field there — prefill never skips tokens without KV.
            # A gateway-staged checkpoint restore (crash failover) is the
            # second KV source that can make prefix compute skippable.
            r.cached_prefix_len = max(
                self.kv.session_hit_tokens(
                    r.session_id, r.prompt_len, r.cached_prefix_len),
                self.kv.restore_hit_tokens(r.rid, r.prompt_len))
            try:
                r.blocks = self.kv.allocate_prompt(
                    r.rid, r.prompt_len, session_id=r.session_id,
                    max_prefix=r.cached_prefix_len)
            except OutOfBlocks:
                # defensive: scheduler projections and pool state can
                # only drift on sessionful traces (adoption races);
                # requeue instead of crashing the loop.  Unreachable on
                # the default single-class path.
                r.cached_prefix_len = 0
                if adm.from_queue is None:
                    self.inflight_transfers += 1
                    self.inflight_transfer_tokens += r.prompt_len
                    self.loop.after(
                        self.serve.slo.itl_ms / 1e3,
                        lambda r=r: self._wake(
                            Wake("admit_retry", request=r)))
                else:
                    self.queues[adm.from_queue].appendleft(r)
                failed_admits.add(r.rid)
                continue
            if adm.truncate_to is not None and \
                    adm.truncate_to < r.max_new_tokens:
                r.max_new_tokens = adm.truncate_to
                r.truncated = True
            if adm.stamp_t_blocks:
                r.t_blocks = now
            r.state = adm.state
            if adm.stamp_prefill_start:
                r.t_prefill_start = now
            self.queues[adm.to_queue].append(r)
            self.stream.emit(PhaseEvent(r.rid, now, "kv_allocated"))
        if failed_admits:
            # a failed admit never reached its target queue, so it must
            # not appear in a launch planned on the assumption it would
            # (only reachable on sessionful traces — adoption races)
            if plan.prefill is not None:
                plan.prefill.batch = [r for r in plan.prefill.batch
                                      if r.rid not in failed_admits]
                if not plan.prefill.batch:
                    plan.prefill = None
            if plan.hybrid is not None:
                plan.hybrid.chunks = [(r, t) for r, t in plan.hybrid.chunks
                                      if r.rid not in failed_admits]
                if not plan.hybrid.chunks and not self.running:
                    plan.hybrid = None
            if plan.decode is not None:
                plan.decode.joins = [r for r in plan.decode.joins
                                     if r.rid not in failed_admits]
        outs = self.executor.execute(plan, view)
        if plan.prefill is not None:
            batch = plan.prefill.batch
            q = self.queues[plan.prefill.queue]
            for r in batch:
                q.remove(r)
                if plan.prefill.pool == "prefill":
                    # split pools never park session KV, and the decode-
                    # side clamp runs only after transfer: drop the
                    # optimistic prefix claim before pricing the prefill
                    r.cached_prefix_len = 0
                    self.kv_p.allocate_prompt(r.rid, r.prompt_len)
                r.state = State.PREFILLING
                r.t_prefill_start = now
                self.stream.emit(PhaseEvent(r.rid, now, "prefill"))
            self._lane_busy["prefill"] = True
            self._lane_cost["prefill"] = outs.prefill.cost
            self.inflight_prefill_tokens = sum(r.prefill_tokens_needed
                                               for r in batch)
            self.loop.after(outs.prefill.duration_s,
                            lambda b=batch: self._prefill_done(b))
        if plan.decode is not None:
            for r in plan.decode.joins:
                self.queues["pending_join"].remove(r)
                r.state = State.DECODING
                self.running.append(r)
                self.stream.emit(PhaseEvent(r.rid, now, "decode"))
            self._lane_busy["decode"] = True
            self._lane_cost["decode"] = outs.decode.cost
            self._lane_f["decode"] = plan.decode.f_decode
            batch = list(self.running)
            self.loop.after(outs.decode.duration_s,
                            lambda b=batch: self._decode_done(b))
        if plan.hybrid is not None:
            self._lane_busy["step"] = True
            self._lane_cost["step"] = outs.hybrid.cost
            batch = list(self.running)
            chunks = plan.hybrid.chunks
            self.loop.after(outs.hybrid.duration_s,
                            lambda b=batch, c=chunks: self._step_done(b, c))
        for retry in plan.retries:
            self.loop.after(
                retry.delay_s,
                lambda r=retry.request: self._wake(
                    Wake("admit_retry", request=r)))

    # -- step completions (the execution substrate) -------------------------
    def _prefill_done(self, batch: List[Request]) -> None:
        now = self.loop.now
        sched = self.scheduler
        freed = False
        for r in batch:
            r.t_prefill_end = now
            # whole-prompt prefill covered every non-cached token;
            # recording it keeps the conservation invariant
            # prefill_tokens_done + cached_prefix_len == prompt_len
            r.prefill_tokens_done = r.prefill_tokens_needed
            if sched.prefill_route == "transfer":
                # KV transfer on the critical path (ICI), then decode-side
                # admission + first-token recompute (vLLM v1, §3.2.1)
                xfer = self.executor.transfer_seconds(r, self.serve)
                self.inflight_transfers += 1
                self.inflight_transfer_tokens += r.prompt_len
                self.stream.emit(PhaseEvent(r.rid, now, "transfer"))
                self.loop.after(xfer, lambda r=r: self._transfer_arrived(r))
            else:
                r.emit_token(now)             # first token from prefill
                self.stream.emit(TokenEvent(r.rid, now,
                                            r.tokens_generated - 1))
                r.state = State.PREFILL_FINISHED
                if r.done:                    # single-token request
                    self._release_kv(r)
                    self._finish(r)
                    freed = True
                else:
                    self.queues["pending_join"].append(r)
        self._lane_busy["prefill"] = False
        self._lane_cost["prefill"] = None
        self.inflight_prefill_tokens = 0
        self._wake(Wake("prefill_done", kv_freed=freed))

    def _transfer_arrived(self, r: Request) -> None:
        self.kv_p.free(r.rid)         # prefill-side memory released ONCE
        self._wake(Wake("transfer_arrived", request=r))

    def _decode_done(self, batch: List[Request]) -> None:
        now = self.loop.now
        freed = False
        for r in batch:
            if r not in self.running:     # preempted mid-loop
                continue
            try:
                self.kv.append_token(r.rid)
            except OutOfBlocks:
                victim = self._preempt_victim()
                if victim is None or victim is r:
                    continue
                self.kv.append_token(r.rid)
            r.emit_token(now)
            self.running.note_token(r)
            self.stream.emit(TokenEvent(r.rid, now, r.tokens_generated - 1))
            if r.done:
                self._release_kv(r)
                self.running.remove(r)
                self._finish(r)
                freed = True
        self._lane_busy["decode"] = False
        self._lane_cost["decode"] = None
        self.util_samples.append(UtilSample(now, self.kv.utilization, True))
        self._wake(Wake("decode_done", kv_freed=freed))

    def _step_done(self, decode_batch: List[Request],
                   chunks: List[tuple]) -> None:
        now = self.loop.now
        chunking = self.queues["chunking"]
        for r, take in chunks:
            r.prefill_tokens_done += take
            chunking.note_chunk_progress(r, take)
            if r.prefill_tokens_done >= r.prefill_tokens_needed:
                r.t_prefill_end = now
                r.emit_token(now)     # last chunk produces first token
                self.stream.emit(TokenEvent(r.rid, now,
                                            r.tokens_generated - 1))
                chunking.remove(r)
                if r.done:
                    self._release_kv(r)
                    self._finish(r)
                else:
                    r.state = State.DECODING
                    self.running.append(r)
                    self.stream.emit(PhaseEvent(r.rid, now, "decode"))
        for r in decode_batch:
            if r not in self.running:     # preempted mid-loop
                continue
            try:
                self.kv.append_token(r.rid)
            except OutOfBlocks:
                victim = self._preempt_victim()
                if victim is None or victim is r:
                    continue
                self.kv.append_token(r.rid)
            r.emit_token(now)
            self.running.note_token(r)
            self.stream.emit(TokenEvent(r.rid, now, r.tokens_generated - 1))
            if r.done:
                self._release_kv(r)
                self.running.remove(r)
                self._finish(r)
        self._lane_busy["step"] = False
        self._lane_cost["step"] = None
        self.util_samples.append(UtilSample(now, self.kv.utilization, True))
        self._wake(Wake("step_done"))

    # -- terminal transitions ------------------------------------------------
    def _release_kv(self, r: Request) -> None:
        """Release a finishing request's decode-pool KV: park it for the
        session's next turn when the request is sessionful (colocated
        engines), else free it exactly as before."""
        if r.session_id is not None and \
                self.kv.session_cache_blocks > 0:
            self.kv.release_to_session(r.rid, r.session_id)
        else:
            self.kv.free(r.rid)

    def _finish(self, r: Request) -> None:
        r.state = State.FINISHED
        r.t_finish = self.loop.now
        self.finished.append(r)
        self.stream.emit(FinishedEvent(
            r.rid, self.loop.now, r.arrival, r.prompt_len,
            r.tokens_generated, r.preemptions, r.slo_class,
            retries=r.retries, truncated=r.truncated))

    def _reject(self, r: Request, reason: str = "never_fits") -> None:
        """A request whose prompt can never fit the pool is turned away
        instead of deadlocking the queue head (or, for disagg, retrying
        forever) — the caller sees ``state == REJECTED``, never an
        ``OutOfBlocks`` escaping the event loop."""
        r.state = State.REJECTED
        r.blocks = None
        r.reject_reason = reason
        self.rejected.append(r)
        self.stream.emit(RejectedEvent(
            r.rid, self.loop.now, r.arrival, r.prompt_len, reason,
            r.tokens_generated, r.preemptions, r.slo_class,
            retries=r.retries))

    # -- local preemption (recompute on resume) ------------------------------
    def _preempt_victim(self) -> Optional[Request]:
        """Preempt one running request; the shared ``PreemptionPolicy``
        ranks victims, the scheduler's topology names the re-entry
        queue."""
        victim = self._evict_running()
        if victim is not None:
            self._requeue_preempted(victim)
        return victim

    def _evict_running(self) -> Optional[Request]:
        victim = self.preempt_policy.choose(self.running)
        if victim is None:
            return None
        self.running.remove(victim)
        self.kv.preempt(victim.rid)
        victim.preemptions += 1
        victim.blocks = None
        victim.prefill_tokens_done = 0
        # recompute-on-resume re-prefills the WHOLE context: the cached
        # prefix's pages were just freed with the rest of the victim's KV
        victim.cached_prefix_len = 0
        self.stream.emit(PhaseEvent(victim.rid, self.loop.now, "preempted"))
        return victim

    def _requeue_preempted(self, victim: Request) -> None:
        # recompute-on-resume: the whole context becomes the new "prompt"
        sched = self.scheduler
        victim.state = sched.requeue_state
        self.queues[sched.requeue_queue].appendleft(victim)

    # -- targeted removal / crash halt (serving gateway) --------------------
    def evict_request(self, r: Request) -> bool:
        """Remove ONE specific request from this engine entirely.  Unlike
        ``_preempt_victim`` the victim is chosen by the caller (gateway
        backpressure pause, targeted recovery) and is NOT requeued here —
        the caller re-``submit()``s it (possibly on another replica)
        later; recompute-on-resume re-prefills the context and token
        emission continues from ``tokens_generated``.  Returns False when
        ``r`` is pinned inside an in-flight lane step (mid-prefill,
        mid-transfer): callers retry after the step completes."""
        if r in self.running:
            self.running.remove(r)
            self.kv.preempt(r.rid)
            r.preemptions += 1
            r.blocks = None
            r.prefill_tokens_done = 0
            r.cached_prefix_len = 0
            r.state = State.PREEMPTED
            self.stream.emit(PhaseEvent(r.rid, self.loop.now, "preempted"))
            return True
        for q in self.queues.values():
            if r in q:
                q.remove(r)
                # only count a preemption when work is actually lost:
                # a request still waiting for KV has nothing to recompute
                if r.blocks is not None or r.prefill_tokens_done > 0:
                    r.preemptions += 1
                    self.stream.emit(PhaseEvent(r.rid, self.loop.now,
                                                "preempted"))
                if r.blocks is not None:
                    self.kv.preempt(r.rid)
                    r.blocks = None
                    r.cached_prefix_len = 0
                r.prefill_tokens_done = 0
                r.state = State.PREEMPTED
                return True
        return False

    def halt(self) -> None:
        """Model this engine crashing: stop planning new work.  Pending
        step-completion callbacks are already on the (shared) loop and
        still fire — they emit into a stream nobody forwards anymore and
        then find an inert scheduler, so the replica freezes instead of
        leaking events forever.  Irreversible; the gateway replaces a
        crashed worker with a fresh one."""
        if not isinstance(self.scheduler, _HaltedScheduler):
            self.scheduler = _HaltedScheduler(self.scheduler)

    @property
    def halted(self) -> bool:
        return isinstance(self.scheduler, _HaltedScheduler)

    # -- cross-replica migration (cluster rebalance tick) -------------------
    def _peek_queued_for_migration(self) -> Optional[Request]:
        """Newest request still waiting for KV/prefill — it holds no KV,
        so moving it is a free re-route."""
        q = self.queues[self.scheduler.migration_queue]
        return q[-1] if q else None

    def _pop_queued_for_migration(self) -> Optional[Request]:
        q = self.queues[self.scheduler.migration_queue]
        return q.pop() if q else None

    def migration_candidate(self):
        """Peek at what ``evict_for_migration`` would take: (request,
        has_kv) or None.  No side effects — the cluster uses this to
        check bucket compatibility and migration caps before evicting."""
        q = self._peek_queued_for_migration()
        if q is not None:
            return q, False
        victim = self.preempt_policy.choose(self.running)
        return (victim, True) if victim is not None else None

    def evict_for_migration(self):
        """Remove one request from this engine entirely for re-enqueue on
        another replica.  Returns (request, had_kv) or None; ``had_kv``
        means live KV was dropped (the cluster charges a transfer cost)."""
        q = self._pop_queued_for_migration()
        if q is not None:
            q.state = State.ARRIVED
            return q, False
        victim = self._evict_running()
        if victim is None:
            return None
        victim.state = State.ARRIVED
        return victim, True

    # -- runtime pool scaling (cluster autoscaler) ---------------------------
    def resize_lane(self, lane: str, chips: int) -> None:
        """Grow one lane's chip group in place (split-pool engines only):
        the matching KV pool gains the extra chips' HBM worth of pages,
        the executor prices that lane on the new chip count, and the
        OTHER pool — including every live KV page in it — is untouched.
        Chip groups only grow; shrinking would strand live KV."""
        sched = self.scheduler
        old = sched.lane_chips(self.serve).get(lane)
        if old is None:
            raise KeyError(f"engine has no lane {lane!r}")
        if chips < old:
            raise ValueError(
                f"lane {lane!r} only grows ({old} -> {chips} shrinks)")
        if chips == old:
            return
        pools = sched.resize_lane(lane, chips, self.cfg, self.serve,
                                  self.hw)
        for pool, mgr in (("decode", self.kv), ("prefill", self.kv_p)):
            if mgr is not None and pools.get(pool, 0) > \
                    mgr.allocator.num_blocks:
                mgr.grow(pools[pool] - mgr.allocator.num_blocks)
        self.chips_p = sched.chips_p
        self.chips_d = sched.chips_d
        if hasattr(self.executor, "lane_chips"):
            self.executor.lane_chips[lane] = chips
        # total chips / split recorded on the config so routers and
        # admission (which read serve.chips) see the new capacity
        self.serve = dataclasses.replace(
            self.serve, chips=self.chips_p + self.chips_d,
            disagg_split=(self.chips_p, self.chips_d))

    # -- load view ------------------------------------------------------------
    def load_snapshot(self) -> LoadSnapshot:
        """O(1) load view from the incremental ``IndexedQueue`` counters.

        Routers, admission and the autoscaler call this per arrival and
        per tick; the PR-4 implementation re-walked every queue on every
        call (kept below as ``load_snapshot_recompute`` — the reference
        the property tests compare against, and the pinned baseline the
        hot-path benchmark measures its speedup from)."""
        sched = self.scheduler
        ps = self.serve.page_size
        queues = self.queues
        queued = sum(len(queues[q]) for q in sched.count_queues)
        # pending_prefill_tokens nets out session-cached prefixes (and
        # chunked progress); equal to prompt_tokens for whole queues of
        # sessionless requests, so the legacy accounting is unchanged
        tokens = sum(queues[q].pending_prefill_tokens
                     for q in sched.token_queues)
        tokens += sum(queues[q].pending_prefill_tokens
                      for q in sched.partial_token_queues)
        tokens += self.inflight_prefill_tokens
        pages = sum(queues[q].kv_pages for q in sched.unalloc_queues)
        # split-pool engines: the same queued prompts also claim transient
        # prefill-side pages before they ever reach the decode pool
        prefill_free = prefill_total = prefill_pages = 0
        if self.kv_p is not None:
            prefill_free = self.kv_p.allocator.free_count
            prefill_total = self.kv_p.allocator.num_blocks
            prefill_pages = pages
        running = len(self.running)
        ctx = self.running.ctx_tokens
        if sched.prefill_route == "transfer":
            # transfers in flight count as imminent decode load: they are
            # done with prefill but WILL join the decode batch, so both
            # routers and the autoscaler's idle detection must see them
            queued += self.inflight_transfers
            running += self.inflight_transfers
            ctx += self.inflight_transfer_tokens
            pages += kv_pages_for(self.inflight_transfer_tokens, ps)
        return LoadSnapshot(
            queued_requests=queued,
            queued_prefill_tokens=tokens,
            running_decode=running,
            decode_ctx_tokens=ctx,
            kv_utilization=self.kv.utilization,
            prefill_busy=self.prefill_busy,
            decode_busy=self.decode_busy,
            kv_free_blocks=self.kv.allocator.free_count,
            kv_total_blocks=self.kv.allocator.num_blocks,
            queued_kv_pages=pages,
            prefill_kv_free_blocks=prefill_free,
            prefill_kv_total_blocks=prefill_total,
            queued_prefill_kv_pages=prefill_pages,
            chips_prefill=getattr(self, "chips_p", self.serve.chips),
            chips_decode=getattr(self, "chips_d", self.serve.chips),
            kv_session_blocks=self.kv.session_blocks)

    def router_load(self) -> "tuple[int, int, int]":
        """The three ``LoadSnapshot`` fields routers price on —
        ``(queued_prefill_tokens, running_decode, decode_ctx_tokens)`` —
        read straight from the incremental counters, skipping the full
        16-field snapshot build (KV occupancy, page claims, lane flags).

        The batched slo_aware router gathers one of these per replica
        per arrival; at fleet scale the full snapshot's construction
        cost dominates the priced decision itself.  Must stay
        value-identical to ``load_snapshot()`` — pinned by
        ``test_load_accounting``."""
        sched = self.scheduler
        queues = self.queues
        tokens = self.inflight_prefill_tokens
        for q in sched.token_queues:
            tokens += queues[q].pending_prefill_tokens
        for q in sched.partial_token_queues:
            tokens += queues[q].pending_prefill_tokens
        running = len(self.running)
        ctx = self.running.ctx_tokens
        if sched.prefill_route == "transfer":
            running += self.inflight_transfers
            ctx += self.inflight_transfer_tokens
        return tokens, running, ctx

    def load_snapshot_recompute(self) -> LoadSnapshot:
        """Recompute the load view from scratch by walking every queue —
        the PR-4 O(n) implementation, kept verbatim as (a) the oracle the
        hypothesis property tests compare the incremental counters
        against and (b) the pinned pre-optimization baseline
        ``benchmarks/bench_hotpath.py`` measures its speedup from.
        Must stay semantically identical to ``load_snapshot``."""
        sched = self.scheduler
        ps = self.serve.page_size
        queued = sum(len(self.queues[q]) for q in sched.count_queues)
        tokens = sum(r.prompt_len - r.cached_prefix_len
                     - r.prefill_tokens_done
                     for q in sched.token_queues for r in self.queues[q])
        tokens += sum(r.prompt_len - r.cached_prefix_len
                      - r.prefill_tokens_done
                      for q in sched.partial_token_queues
                      for r in self.queues[q])
        tokens += self.inflight_prefill_tokens
        pages = sum(kv_pages_for(r.prompt_len, ps)
                    for q in sched.unalloc_queues for r in self.queues[q])
        prefill_free = prefill_total = prefill_pages = 0
        if self.kv_p is not None:
            prefill_free = self.kv_p.allocator.free_count
            prefill_total = self.kv_p.allocator.num_blocks
            prefill_pages = pages
        running = len(self.running)
        ctx = sum(r.context_len for r in self.running)
        if sched.prefill_route == "transfer":
            queued += self.inflight_transfers
            running += self.inflight_transfers
            ctx += self.inflight_transfer_tokens
            pages += kv_pages_for(self.inflight_transfer_tokens, ps)
        return LoadSnapshot(
            queued_requests=queued,
            queued_prefill_tokens=tokens,
            running_decode=running,
            decode_ctx_tokens=ctx,
            kv_utilization=self.kv.utilization,
            prefill_busy=self.prefill_busy,
            decode_busy=self.decode_busy,
            kv_free_blocks=self.kv.allocator.free_count,
            kv_total_blocks=self.kv.allocator.num_blocks,
            queued_kv_pages=pages,
            prefill_kv_free_blocks=prefill_free,
            prefill_kv_total_blocks=prefill_total,
            queued_prefill_kv_pages=prefill_pages,
            chips_prefill=getattr(self, "chips_p", self.serve.chips),
            chips_decode=getattr(self, "chips_d", self.serve.chips),
            kv_session_blocks=self.kv.session_blocks)


class _HaltedScheduler:
    """Scheduler stand-in installed by ``Engine.halt()``: keeps the
    topology attributes (queue accounting, load snapshots still work)
    but plans nothing, so in-flight completions drain without launching
    new steps."""

    def __init__(self, inner: Scheduler):
        self._inner = inner

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def schedule(self, view: SchedView) -> StepPlan:
        return StepPlan()


# legacy name: PR-1/PR-2 callers subclassed/annotated against BaseEngine
BaseEngine = Engine


# ---------------------------------------------------------------------------
# Thin mode-bound constructors (compatibility + convenience)
# ---------------------------------------------------------------------------


class RapidEngine(Engine):
    """Paper §4 engine: RapidScheduler on the shared substrate."""

    def __init__(self, cfg, serve: ServeConfig, hw: HardwareSpec = TPU_V5E,
                 avg_ctx_hint: int = 4096,
                 loop: Optional[EventLoop] = None,
                 preempt_policy: PreemptionPolicy = DEFAULT_PREEMPTION):
        super().__init__(
            cfg, serve, hw,
            scheduler=RapidScheduler(cfg, serve, hw, avg_ctx_hint),
            loop=loop, preempt_policy=preempt_policy)


class HybridEngine(Engine):
    """Sarathi/vLLM-v1 chunked-prefill baseline."""

    def __init__(self, cfg, serve: ServeConfig, hw: HardwareSpec = TPU_V5E,
                 loop: Optional[EventLoop] = None,
                 preempt_policy: PreemptionPolicy = DEFAULT_PREEMPTION):
        super().__init__(cfg, serve, hw,
                         scheduler=HybridScheduler(cfg, serve, hw),
                         loop=loop, preempt_policy=preempt_policy)


class DisaggEngine(Engine):
    """DistServe-style split-pool baseline."""

    def __init__(self, cfg, serve: ServeConfig, hw: HardwareSpec = TPU_V5E,
                 loop: Optional[EventLoop] = None,
                 preempt_policy: PreemptionPolicy = DEFAULT_PREEMPTION):
        super().__init__(cfg, serve, hw,
                         scheduler=DisaggScheduler(cfg, serve, hw),
                         loop=loop, preempt_policy=preempt_policy)


ENGINES = {
    "rapid": RapidEngine,
    "hybrid": HybridEngine,
    "disagg": DisaggEngine,
}


def make_engine(mode: str, cfg, serve: ServeConfig,
                hw: HardwareSpec = TPU_V5E,
                loop: Optional[EventLoop] = None,
                preempt_policy: PreemptionPolicy = DEFAULT_PREEMPTION
                ) -> Engine:
    if mode not in ENGINES:
        raise KeyError(
            f"unknown engine mode {mode!r}; known: {sorted(ENGINES)}")
    return ENGINES[mode](cfg, serve, hw, loop=loop,
                         preempt_policy=preempt_policy)


def drive(engine: BaseEngine, requests: List[Request]
          ) -> "tuple[List[RequestRecord], float]":
    """Blocking convenience driver for a STANDALONE engine (tests,
    examples, single-replica experiments): enqueue the trace, run its
    loop dry, and return ``(records, span_s)``.

    This replaces the old ``Engine.run()`` shim.  It is a free function
    on purpose: cluster and gateway callers share one loop across many
    engines and must drive it themselves, consuming the typed event
    stream (``engine.subscribe`` / ``serving.metrics.StreamMetrics``)
    rather than scraping records after the fact."""
    engine.enqueue(list(requests))
    engine.loop.run()
    span = engine.loop.now if engine.loop.now > 0 else 1.0
    return engine.records(), span
