"""Serving engines: RAPID (the paper), hybrid batching, disaggregated.

All three are *real* control code — FCFS queues, decode-owned paged-KV
allocation, notifications, preemption, admission — driven by the
discrete-event loop; only step durations come from the perfmodel
(DESIGN.md §6).  The same engine classes also drive the real CPU serving
example (examples/serve_trace.py) where durations are wall-clock.

RapidEngine (paper §4):
  * prefill and decode are two concurrent actors on the SAME chips;
    whole-prompt prefill (no chunking), separate batches, overlapping
    steps.
  * decode owns the KV manager; arrival -> decode allocates prompt blocks
    -> notify prefill -> prefill runs -> notify decode -> join batch
    (Fig 4), all lock-free message passing.
  * Adaptive Resource Manager picks overallocation vs distinct f_d per
    step from the offline profile (§4.5.3).
  * async one-step-ahead scheduling (NanoFlow-style): host work is hidden
    under device execution (Fig 6b) => step time = max(device, host).

HybridEngine (Sarathi/vLLM-v1 chunked prefill):
  * one lockstep batch per iteration: all running decodes + prefill
    chunks up to the token budget.  Decode ITL is coupled to the full
    hybrid step duration — the §3.1 overhead RAPID removes.

DisaggEngine (DistServe/Splitwise-style, vLLM v1 semantics):
  * separate prefill/decode chip pools, KV transferred over ICI on the
    critical path; the first token is *recomputed* on the decode instance
    after transfer (vLLM v1 behaviour, paper §3.2.1).
  * memory imbalance: only the decode pool holds long-lived KV (§3.2.2).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional

from repro.config import ServeConfig
from repro.core.preemption import DEFAULT_PREEMPTION, PreemptionPolicy
from repro.core.request import Request, State
from repro.core.resource_manager import (AdaptiveResourceManager,
                                         build_decode_profile)
from repro.kvcache import KVCacheManager, OutOfBlocks, kv_pages_for
from repro.perfmodel import costs as C
from repro.perfmodel import interference as I
from repro.perfmodel.hw import TPU_V5E, HardwareSpec
from repro.serving.metrics import RequestRecord
from repro.serving.sim import EventLoop


def kv_pool_blocks(cfg, hw: HardwareSpec, chips: int, page_size: int,
                   reserve_frac: float = 0.05) -> int:
    """Pool size: chip-group HBM minus weights, minus activation reserve."""
    total = chips * hw.hbm_bytes * (1.0 - reserve_frac)
    weights = C.weight_bytes(cfg)
    free = total - weights
    if free <= 0:
        raise ValueError(
            f"{cfg.name}: weights ({weights/2**30:.0f} GiB) exceed "
            f"{chips}x{hw.hbm_bytes/2**30:.0f} GiB; increase chips")
    per_block = page_size * cfg.kv_bytes_per_token()
    return max(64, int(free // per_block))


@dataclasses.dataclass
class UtilSample:
    t: float
    kv_util: float
    busy: bool


@dataclasses.dataclass(frozen=True)
class LoadSnapshot:
    """Instantaneous engine load, consumed by cluster routers.

    ``queued_prefill_tokens`` counts prompt tokens that still need prefill
    compute (including the un-chunked remainder on hybrid engines) — the
    quantity a least-loaded router balances.  ``decode_ctx_tokens`` is the
    total live context of the running decode batch, which the SLO-aware
    router feeds to the decode cost model.

    ``kv_free_blocks`` / ``kv_total_blocks`` describe the decode-side
    paged-KV pool, and ``queued_kv_pages`` the pages that queued-but-
    unallocated requests will claim when admitted — together they let the
    cluster admission controller project whether a new request fits
    without the engine ever hitting ``OutOfBlocks`` mid-flight.
    """
    queued_requests: int
    queued_prefill_tokens: int
    running_decode: int
    decode_ctx_tokens: int
    kv_utilization: float
    prefill_busy: bool
    decode_busy: bool
    kv_free_blocks: int = 0
    kv_total_blocks: int = 0
    queued_kv_pages: int = 0


class BaseEngine:
    def __init__(self, cfg, serve: ServeConfig, hw: HardwareSpec = TPU_V5E,
                 loop: Optional[EventLoop] = None,
                 preempt_policy: PreemptionPolicy = DEFAULT_PREEMPTION):
        self.cfg = cfg
        self.serve = serve
        self.hw = hw
        # injected loop => this engine is one replica of a cluster sharing
        # a single virtual clock; standalone engines own a private loop
        self.loop = loop if loop is not None else EventLoop()
        self.preempt_policy = preempt_policy
        self.finished: List[Request] = []
        self.rejected: List[Request] = []
        self.util_samples: List[UtilSample] = []
        self._all: List[Request] = []

    # -- host-side scheduling overhead (Fig 6a vs 6b) -----------------------
    def _step_time(self, device_s: float) -> float:
        cpu = self.serve.scheduler_overhead_ms / 1e3
        if self.serve.async_scheduling:
            return max(device_s, cpu)
        return device_s + cpu

    def _finish(self, r: Request) -> None:
        r.state = State.FINISHED
        r.t_finish = self.loop.now
        self.finished.append(r)

    def enqueue(self, requests: List[Request]) -> None:
        """Seed arrival events on the (possibly shared) loop without
        running it — the cluster drives the loop itself."""
        self._all.extend(requests)
        for r in requests:
            self.loop.at(r.arrival, lambda r=r: self.submit(r))

    def run(self, requests: List[Request], drain: bool = True):
        self.enqueue(requests)
        self.loop.run()
        span = self.loop.now if self.loop.now > 0 else 1.0
        return [RequestRecord.from_request(r) for r in self._all], span

    def records(self) -> List[RequestRecord]:
        return [RequestRecord.from_request(r) for r in self._all]

    def submit(self, r: Request) -> None:
        raise NotImplementedError

    def load_snapshot(self) -> LoadSnapshot:
        raise NotImplementedError

    # -- admission: clean per-request rejection ------------------------------
    def _reject(self, r: Request) -> None:
        """A request whose prompt can never fit the pool is turned away
        instead of deadlocking the queue head (or, for disagg, retrying
        forever) — the caller sees ``state == REJECTED``, never an
        ``OutOfBlocks`` escaping the event loop."""
        r.state = State.REJECTED
        r.blocks = None
        self.rejected.append(r)

    def _prompt_fits_pool(self, prompt_len: int, kv) -> bool:
        return kv_pages_for(prompt_len, self.serve.page_size) <= \
            kv.allocator.num_blocks

    # -- local preemption (template; queue re-entry is engine-specific) -----
    def _requeue_preempted(self, victim: Request) -> None:
        raise NotImplementedError

    def _preempt_victim(self) -> Optional[Request]:
        """Preempt one running request (recompute on resume); the shared
        ``PreemptionPolicy`` ranks victims, each engine re-queues its own
        way."""
        victim = self._evict_running()
        if victim is not None:
            self._requeue_preempted(victim)
        return victim

    def _evict_running(self) -> Optional[Request]:
        victim = self.preempt_policy.choose(self.running)
        if victim is None:
            return None
        self.running.remove(victim)
        self.kv.preempt(victim.rid)
        victim.preemptions += 1
        victim.blocks = None
        victim.prefill_tokens_done = 0
        return victim

    # -- cross-replica migration (cluster rebalance tick) -------------------
    def _pop_queued_for_migration(self) -> Optional[Request]:
        """Newest request still waiting for KV/prefill — it holds no KV,
        so moving it is a free re-route.  Engine-specific queue."""
        return None

    def migration_candidate(self):
        """Peek at what ``evict_for_migration`` would take: (request,
        has_kv) or None.  No side effects — the cluster uses this to
        check bucket compatibility and migration caps before evicting."""
        q = self._peek_queued_for_migration()
        if q is not None:
            return q, False
        victim = self.preempt_policy.choose(self.running)
        return (victim, True) if victim is not None else None

    def _peek_queued_for_migration(self) -> Optional[Request]:
        return None

    def evict_for_migration(self):
        """Remove one request from this engine entirely for re-enqueue on
        another replica.  Returns (request, had_kv) or None; ``had_kv``
        means live KV was dropped (the cluster charges a transfer cost)."""
        q = self._pop_queued_for_migration()
        if q is not None:
            q.state = State.ARRIVED
            return q, False
        victim = self._evict_running()
        if victim is None:
            return None
        victim.state = State.ARRIVED
        return victim, True


# ---------------------------------------------------------------------------
# RAPID-Serve
# ---------------------------------------------------------------------------


class RapidEngine(BaseEngine):
    def __init__(self, cfg, serve: ServeConfig, hw: HardwareSpec = TPU_V5E,
                 avg_ctx_hint: int = 4096,
                 loop: Optional[EventLoop] = None):
        super().__init__(cfg, serve, hw, loop=loop)
        tp = serve.chips
        blocks = kv_pool_blocks(cfg, hw, serve.chips, serve.page_size,
                                serve.kv_reserve_frac)
        self.kv = KVCacheManager(blocks, serve.page_size)
        profile = build_decode_profile(
            cfg, hw, serve.chips, serve.slo.itl_ms / 1e3, avg_ctx_hint,
            tp=tp)
        self.arm = AdaptiveResourceManager(profile)
        self.tp = tp
        # queues (Fig 4)
        self.waiting_kv: Deque[Request] = collections.deque()
        self.waiting_prefill: Deque[Request] = collections.deque()
        self.pending_join: Deque[Request] = collections.deque()
        self.running: List[Request] = []
        # actor state
        self.prefill_busy = False
        self.decode_busy = False
        self.cur_prefill_cost: Optional[C.StepCost] = None
        self.cur_decode_cost: Optional[C.StepCost] = None
        self.cur_f_decode: Optional[float] = None
        self.inflight_prefill_tokens = 0

    # -- Fig 4: arrival -> decode-side block allocation ---------------------
    def submit(self, r: Request) -> None:
        r.state = State.WAITING_KV
        self.waiting_kv.append(r)
        self._drain_waiting_kv()

    def _drain_waiting_kv(self) -> None:
        progressed = False
        while self.waiting_kv:
            head = self.waiting_kv[0]
            if not self._prompt_fits_pool(head.prompt_len, self.kv):
                # can NEVER fit: reject cleanly instead of wedging the
                # queue head (everything behind it would starve)
                self._reject(self.waiting_kv.popleft())
                continue
            if not self.kv.can_allocate(head.prompt_len):
                break
            r = self.waiting_kv.popleft()
            r.blocks = self.kv.allocate_prompt(r.rid, r.prompt_len)
            r.t_blocks = self.loop.now
            r.state = State.WAITING_PREFILL
            self.waiting_prefill.append(r)   # notification to prefill
            progressed = True
        if progressed:
            self._kick_prefill()

    # -- prefill actor -------------------------------------------------------
    def _kick_prefill(self) -> None:
        if self.prefill_busy or not self.waiting_prefill:
            return
        batch: List[Request] = []
        tokens = 0
        while self.waiting_prefill:
            nxt = self.waiting_prefill[0]
            if batch and tokens + nxt.prompt_len > self.serve.prefill_max_tokens:
                break
            batch.append(self.waiting_prefill.popleft())
            tokens += nxt.prompt_len
        for r in batch:
            r.state = State.PREFILLING
            r.t_prefill_start = self.loop.now
        self.prefill_busy = True
        self.inflight_prefill_tokens = tokens
        p_cost = C.prefill_cost(self.cfg, [r.prompt_len for r in batch],
                                self.tp)
        self.cur_prefill_cost = p_cost
        dur = self._prefill_duration(p_cost)
        self.loop.after(self._step_time(dur),
                        lambda: self._prefill_done(batch))

    def _prefill_duration(self, p_cost: C.StepCost) -> float:
        if not self.decode_busy or self.cur_decode_cost is None:
            return I.phase_time(p_cost, self.hw, self.serve.chips)
        r = I.overlapped_times(p_cost, self.cur_decode_cost, self.hw,
                               self.serve.chips, f_decode=self.cur_f_decode)
        return r.t_prefill

    def _prefill_done(self, batch: List[Request]) -> None:
        now = self.loop.now
        for r in batch:
            r.t_prefill_end = now
            r.emit_token(now)             # first token from prefill
            r.state = State.PREFILL_FINISHED
            if r.done:                    # single-token request
                self.kv.free(r.rid)
                self._finish(r)
                self._drain_waiting_kv()
            else:
                self.pending_join.append(r)   # notification to decode
        self.prefill_busy = False
        self.inflight_prefill_tokens = 0
        self.cur_prefill_cost = None
        self._kick_prefill()
        self._kick_decode()

    # -- decode actor ---------------------------------------------------------
    def _kick_decode(self) -> None:
        if self.decode_busy:
            return
        while self.pending_join and \
                len(self.running) < self.serve.max_batch_slots:
            r = self.pending_join.popleft()
            r.state = State.DECODING
            self.running.append(r)
        if not self.running:
            return
        bs = len(self.running)
        alloc = self.arm.allocate(bs, self.prefill_busy)
        ctx_total = float(sum(r.context_len for r in self.running))
        d_cost = C.decode_cost(self.cfg, bs, ctx_total, self.tp)
        self.cur_decode_cost = d_cost
        self.cur_f_decode = alloc.f_decode
        if self.prefill_busy and self.cur_prefill_cost is not None:
            res = I.overlapped_times(self.cur_prefill_cost, d_cost, self.hw,
                                     self.serve.chips,
                                     f_decode=alloc.f_decode)
            dur = res.t_decode
        else:
            dur = I.phase_time(d_cost, self.hw, self.serve.chips)
        self.decode_busy = True
        batch = list(self.running)
        self.loop.after(self._step_time(dur),
                        lambda: self._decode_done(batch))

    def _decode_done(self, batch: List[Request]) -> None:
        now = self.loop.now
        freed = False
        for r in batch:
            if r not in self.running:     # preempted mid-loop
                continue
            try:
                self.kv.append_token(r.rid)
            except OutOfBlocks:
                victim = self._preempt_victim()
                if victim is None or victim is r:
                    continue
                self.kv.append_token(r.rid)
            r.emit_token(now)
            if r.done:
                self.kv.free(r.rid)
                self.running.remove(r)
                self._finish(r)
                freed = True
        self.decode_busy = False
        self.cur_decode_cost = None
        self.util_samples.append(
            UtilSample(now, self.kv.utilization, True))
        if freed:
            self._drain_waiting_kv()
        self._kick_decode()

    def _requeue_preempted(self, victim: Request) -> None:
        victim.state = State.WAITING_KV
        self.waiting_kv.appendleft(victim)

    def _peek_queued_for_migration(self) -> Optional[Request]:
        # waiting_kv holds no blocks yet; waiting_prefill already does
        return self.waiting_kv[-1] if self.waiting_kv else None

    def _pop_queued_for_migration(self) -> Optional[Request]:
        return self.waiting_kv.pop() if self.waiting_kv else None

    def load_snapshot(self) -> LoadSnapshot:
        queued = (list(self.waiting_kv) + list(self.waiting_prefill)
                  + list(self.pending_join))
        pending_tokens = sum(r.prompt_len for r in self.waiting_kv) + \
            sum(r.prompt_len for r in self.waiting_prefill) + \
            self.inflight_prefill_tokens
        ps = self.serve.page_size
        return LoadSnapshot(
            queued_requests=len(queued),
            queued_prefill_tokens=pending_tokens,
            running_decode=len(self.running),
            decode_ctx_tokens=sum(r.context_len for r in self.running),
            kv_utilization=self.kv.utilization,
            prefill_busy=self.prefill_busy,
            decode_busy=self.decode_busy,
            kv_free_blocks=self.kv.allocator.free_count,
            kv_total_blocks=self.kv.allocator.num_blocks,
            queued_kv_pages=sum(kv_pages_for(r.prompt_len, ps)
                                for r in self.waiting_kv))


# ---------------------------------------------------------------------------
# Hybrid batching with chunked prefill (Sarathi / vLLM-v1)
# ---------------------------------------------------------------------------


class HybridEngine(BaseEngine):
    def __init__(self, cfg, serve: ServeConfig, hw: HardwareSpec = TPU_V5E,
                 loop: Optional[EventLoop] = None):
        super().__init__(cfg, serve, hw, loop=loop)
        self.tp = serve.chips
        blocks = kv_pool_blocks(cfg, hw, serve.chips, serve.page_size,
                                serve.kv_reserve_frac)
        self.kv = KVCacheManager(blocks, serve.page_size)
        self.waiting: Deque[Request] = collections.deque()
        self.chunking: List[Request] = []   # admitted, prompt in progress
        self.running: List[Request] = []
        self.busy = False

    def submit(self, r: Request) -> None:
        r.state = State.WAITING_KV
        self.waiting.append(r)
        self._kick()

    def _admit(self) -> None:
        while self.waiting:
            head = self.waiting[0]
            if not self._prompt_fits_pool(head.prompt_len, self.kv):
                self._reject(self.waiting.popleft())
                continue
            if not self.kv.can_allocate(head.prompt_len) or \
                    len(self.chunking) + len(self.running) >= \
                    self.serve.max_batch_slots:
                break
            r = self.waiting.popleft()
            r.blocks = self.kv.allocate_prompt(r.rid, r.prompt_len)
            r.t_blocks = self.loop.now
            r.state = State.PREFILLING
            r.t_prefill_start = self.loop.now
            self.chunking.append(r)

    def _kick(self) -> None:
        if self.busy:
            return
        self._admit()
        bs = len(self.running)
        if bs == 0 and not self.chunking:
            return
        # Sarathi: budget filled with decodes first, then prefill chunks
        budget = max(0, self.serve.token_budget - bs)
        cost = C.ZERO_COST
        chunks: List[tuple] = []
        for r in self.chunking:
            if budget <= 0:
                break
            take = min(self.serve.chunk_size, budget,
                       r.prompt_len - r.prefill_tokens_done)
            if take <= 0:
                continue
            cost = cost + C.chunk_prefill_cost(
                self.cfg, take, r.prefill_tokens_done, self.tp)
            chunks.append((r, take))
            budget -= take
        if bs:
            ctx_total = float(sum(r.context_len for r in self.running))
            cost = cost + C.decode_cost(self.cfg, bs, ctx_total, self.tp)
        if not chunks and bs == 0:
            return
        self.busy = True
        dur = I.phase_time(cost, self.hw, self.serve.chips)
        batch = list(self.running)
        self.loop.after(self._step_time(dur),
                        lambda: self._step_done(batch, chunks))

    def _step_done(self, decode_batch: List[Request],
                   chunks: List[tuple]) -> None:
        now = self.loop.now
        freed = False
        for r, take in chunks:
            r.prefill_tokens_done += take
            if r.prefill_tokens_done >= r.prompt_len:
                r.t_prefill_end = now
                r.emit_token(now)     # last chunk produces first token
                self.chunking.remove(r)
                if r.done:
                    self.kv.free(r.rid)
                    self._finish(r)
                    freed = True
                else:
                    r.state = State.DECODING
                    self.running.append(r)
        for r in decode_batch:
            if r not in self.running:     # preempted mid-loop
                continue
            try:
                self.kv.append_token(r.rid)
            except OutOfBlocks:
                victim = self._preempt_victim()
                if victim is None or victim is r:
                    continue
                self.kv.append_token(r.rid)
            r.emit_token(now)
            if r.done:
                self.kv.free(r.rid)
                self.running.remove(r)
                self._finish(r)
                freed = True
        self.busy = False
        self.util_samples.append(UtilSample(now, self.kv.utilization, True))
        del freed
        self._kick()

    def _requeue_preempted(self, victim: Request) -> None:
        # recompute-on-resume: the whole context becomes the new "prompt"
        victim.state = State.WAITING_KV
        self.waiting.appendleft(victim)

    def _peek_queued_for_migration(self) -> Optional[Request]:
        return self.waiting[-1] if self.waiting else None

    def _pop_queued_for_migration(self) -> Optional[Request]:
        return self.waiting.pop() if self.waiting else None

    def load_snapshot(self) -> LoadSnapshot:
        pending_tokens = sum(r.prompt_len for r in self.waiting) + \
            sum(r.prompt_len - r.prefill_tokens_done for r in self.chunking)
        ps = self.serve.page_size
        return LoadSnapshot(
            queued_requests=len(self.waiting) + len(self.chunking),
            queued_prefill_tokens=pending_tokens,
            running_decode=len(self.running),
            decode_ctx_tokens=sum(r.context_len for r in self.running),
            kv_utilization=self.kv.utilization,
            prefill_busy=self.busy,
            decode_busy=self.busy,
            kv_free_blocks=self.kv.allocator.free_count,
            kv_total_blocks=self.kv.allocator.num_blocks,
            queued_kv_pages=sum(kv_pages_for(r.prompt_len, ps)
                                for r in self.waiting))


# ---------------------------------------------------------------------------
# Disaggregated serving (DistServe-style, vLLM v1 transfer semantics)
# ---------------------------------------------------------------------------


class DisaggEngine(BaseEngine):
    def __init__(self, cfg, serve: ServeConfig, hw: HardwareSpec = TPU_V5E,
                 loop: Optional[EventLoop] = None):
        super().__init__(cfg, serve, hw, loop=loop)
        self.chips_p, self.chips_d = serve.disagg_split
        # each pool holds a full weight replica; KV capacity only matters
        # on the decode side (the §3.2.2 imbalance)
        blocks_d = kv_pool_blocks(cfg, hw, self.chips_d, serve.page_size,
                                  serve.kv_reserve_frac)
        blocks_p = kv_pool_blocks(cfg, hw, self.chips_p, serve.page_size,
                                  serve.kv_reserve_frac)
        self.kv = KVCacheManager(blocks_d, serve.page_size)       # decode
        self.kv_p = KVCacheManager(blocks_p, serve.page_size)     # transient
        self.waiting_prefill: Deque[Request] = collections.deque()
        self.pending_join: Deque[Request] = collections.deque()
        self.running: List[Request] = []
        self.prefill_busy = False
        self.decode_busy = False
        self.inflight_prefill_tokens = 0
        # requests whose KV transfer is in flight (prefill done, decode
        # admission pending) — in no queue, but very much still load
        self.inflight_transfers = 0
        self.inflight_transfer_tokens = 0

    def submit(self, r: Request) -> None:
        r.state = State.WAITING_PREFILL
        self.waiting_prefill.append(r)
        self._kick_prefill()

    def _kick_prefill(self) -> None:
        if self.prefill_busy or not self.waiting_prefill:
            return
        batch: List[Request] = []
        tokens = 0
        while self.waiting_prefill:
            nxt = self.waiting_prefill[0]
            if not self._prompt_fits_pool(nxt.prompt_len, self.kv_p) or \
                    not self._prompt_fits_pool(nxt.prompt_len, self.kv):
                # oversized for the prefill pool (queue-head wedge) or the
                # decode pool (would retry admission forever in
                # _kv_arrived): reject up front
                self._reject(self.waiting_prefill.popleft())
                continue
            if not self.kv_p.can_allocate(nxt.prompt_len):
                break
            if batch and tokens + nxt.prompt_len > self.serve.prefill_max_tokens:
                break
            r = self.waiting_prefill.popleft()
            self.kv_p.allocate_prompt(r.rid, r.prompt_len)
            batch.append(r)
            tokens += nxt.prompt_len
        if not batch:
            return
        for r in batch:
            r.state = State.PREFILLING
            r.t_prefill_start = self.loop.now
        self.prefill_busy = True
        self.inflight_prefill_tokens = tokens
        p_cost = C.prefill_cost(self.cfg, [r.prompt_len for r in batch],
                                self.chips_p)
        dur = I.phase_time(p_cost, self.hw, self.chips_p)
        self.loop.after(self._step_time(dur),
                        lambda: self._prefill_done(batch))

    def _prefill_done(self, batch: List[Request]) -> None:
        now = self.loop.now
        for r in batch:
            r.t_prefill_end = now
            # KV transfer on the critical path (ICI), then decode-side
            # admission + first-token recompute (vLLM v1, §3.2.1)
            xfer = C.kv_transfer_bytes(self.cfg, r.prompt_len) / \
                (self.serve.kv_transfer_gbps * 1e9)
            self.inflight_transfers += 1
            self.inflight_transfer_tokens += r.prompt_len
            self.loop.after(xfer, lambda r=r: self._kv_arrived(r))
        self.prefill_busy = False
        self.inflight_prefill_tokens = 0
        self._kick_prefill()

    def _kv_arrived(self, r: Request) -> None:
        self.kv_p.free(r.rid)           # prefill-side memory released ONCE
        self._kick_prefill()
        self._try_admit_decode(r)

    def _try_admit_decode(self, r: Request) -> None:
        """Decode-side admission after transfer; retries must re-enter
        here, NOT _kv_arrived, or the kv_p seq would be freed twice."""
        if not self._prompt_fits_pool(r.prompt_len, self.kv):
            # can NEVER fit the decode pool — without this the retry loop
            # below spins until the event budget blows up (the OutOfBlocks
            # flavour this engine used to surface); reject cleanly
            self.inflight_transfers -= 1
            self.inflight_transfer_tokens -= r.prompt_len
            self._reject(r)
            return
        if not self.kv.can_allocate(r.prompt_len):
            # decode pool full: back-pressure; retry on next decode step
            self.loop.after(self.serve.slo.itl_ms / 1e3,
                            lambda: self._try_admit_decode(r))
            return
        r.blocks = self.kv.allocate_prompt(r.rid, r.prompt_len)
        r.state = State.PREFILL_FINISHED
        self.inflight_transfers -= 1
        self.inflight_transfer_tokens -= r.prompt_len
        self.pending_join.append(r)
        self._kick_decode()

    def _kick_decode(self) -> None:
        if self.decode_busy:
            return
        while self.pending_join and \
                len(self.running) < self.serve.max_batch_slots:
            r = self.pending_join.popleft()
            r.state = State.DECODING
            self.running.append(r)
        if not self.running:
            return
        bs = len(self.running)
        ctx_total = float(sum(r.context_len for r in self.running))
        d_cost = C.decode_cost(self.cfg, bs, ctx_total, self.chips_d)
        dur = I.phase_time(d_cost, self.hw, self.chips_d)
        self.decode_busy = True
        batch = list(self.running)
        self.loop.after(self._step_time(dur),
                        lambda: self._decode_done(batch))

    def _decode_done(self, batch: List[Request]) -> None:
        now = self.loop.now
        for r in batch:
            if r not in self.running:     # preempted mid-loop
                continue
            try:
                self.kv.append_token(r.rid)
            except OutOfBlocks:
                victim = self._preempt_victim()
                if victim is None or victim is r:
                    continue
                self.kv.append_token(r.rid)
            # first emission after transfer = the recomputed token 1
            # (TTFT lands here, vLLM v1 semantics — paper §3.2.1)
            r.emit_token(now)
            if r.done:
                self.kv.free(r.rid)
                self.running.remove(r)
                self._finish(r)
        self.decode_busy = False
        self.util_samples.append(UtilSample(now, self.kv.utilization, True))
        self._kick_decode()

    def _requeue_preempted(self, victim: Request) -> None:
        victim.state = State.WAITING_PREFILL
        self.waiting_prefill.appendleft(victim)
        self._kick_prefill()

    def _peek_queued_for_migration(self) -> Optional[Request]:
        return self.waiting_prefill[-1] if self.waiting_prefill else None

    def _pop_queued_for_migration(self) -> Optional[Request]:
        return self.waiting_prefill.pop() if self.waiting_prefill else None

    def load_snapshot(self) -> LoadSnapshot:
        pending_tokens = sum(r.prompt_len for r in self.waiting_prefill) + \
            self.inflight_prefill_tokens
        ps = self.serve.page_size
        # transfers in flight count as imminent decode load: they are done
        # with prefill but WILL join the decode batch, so both routers and
        # the autoscaler's idle detection must see them
        return LoadSnapshot(
            queued_requests=len(self.waiting_prefill)
            + len(self.pending_join) + self.inflight_transfers,
            queued_prefill_tokens=pending_tokens,
            running_decode=len(self.running) + self.inflight_transfers,
            decode_ctx_tokens=sum(r.context_len for r in self.running)
            + self.inflight_transfer_tokens,
            kv_utilization=self.kv.utilization,
            prefill_busy=self.prefill_busy,
            decode_busy=self.decode_busy,
            kv_free_blocks=self.kv.allocator.free_count,
            kv_total_blocks=self.kv.allocator.num_blocks,
            queued_kv_pages=sum(kv_pages_for(r.prompt_len, ps)
                                for r in self.waiting_prefill)
            + kv_pages_for(self.inflight_transfer_tokens, ps))


ENGINES = {
    "rapid": RapidEngine,
    "hybrid": HybridEngine,
    "disagg": DisaggEngine,
}


def make_engine(mode: str, cfg, serve: ServeConfig,
                hw: HardwareSpec = TPU_V5E,
                loop: Optional[EventLoop] = None) -> BaseEngine:
    if mode not in ENGINES:
        raise KeyError(
            f"unknown engine mode {mode!r}; known: {sorted(ENGINES)}")
    return ENGINES[mode](cfg, serve, hw, loop=loop)
