"""Scheduling policies for the generic serving engine (Serving API v2).

The paper's core claim (§4) is that prefill and decode are independently
schedulable actors on shared chips.  This module makes the *policy* half
of that claim a first-class object: a ``Scheduler`` is consulted by the
generic ``core.engines.Engine`` at every wake point (arrival, step
completion, KV-transfer arrival, admission retry) with a read-only
``SchedView`` of the engine state and returns a ``StepPlan`` — which
requests to reject or admit, which batches to launch on which lane, and
with what resource split.  Schedulers never touch the event loop and
never mutate engine state; the engine applies the plan and the
``core.executor`` prices the launched steps.

Adding a new scheduling policy is therefore a one-class change::

    class MyScheduler(Scheduler):
        mode = "mine"
        ...topology class attrs...
        def schedule(self, view): ...

    eng = Engine(cfg, serve, scheduler=MyScheduler(...))

The three built-ins reproduce the historical engines exactly (asserted
against golden traces in tests/test_parity.py):

  * ``RapidScheduler``  — the paper: concurrent whole-prompt prefill and
    decode actors on the same chips, decode-owned KV admission (Fig 4),
    adaptive resource split from the offline profile (§4.5.3).
  * ``HybridScheduler`` — Sarathi/vLLM-v1 chunked prefill: one lockstep
    batch per iteration, decodes first then prefill chunks up to the
    token budget.
  * ``DisaggScheduler`` — DistServe-style split pools with KV transfer
    on the critical path and decode-side admission backpressure.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Mapping, Optional, Tuple

from repro.core.queues import IndexedQueue
from repro.core.request import Request, State
from repro.core.resource_manager import (AdaptiveResourceManager,
                                         cached_decode_profile)
from repro.kvcache import KVCacheManager, kv_pages_for
from repro.perfmodel import costs as C
from repro.perfmodel.hw import TPU_V5E, HardwareSpec


def kv_pool_blocks(cfg, hw: HardwareSpec, chips: int, page_size: int,
                   reserve_frac: float = 0.05) -> int:
    """Pool size: chip-group HBM minus weights, minus activation reserve."""
    total = chips * hw.hbm_bytes * (1.0 - reserve_frac)
    weights = C.weight_bytes(cfg)
    free = total - weights
    if free <= 0:
        raise ValueError(
            f"{cfg.name}: weights ({weights/2**30:.0f} GiB) exceed "
            f"{chips}x{hw.hbm_bytes/2**30:.0f} GiB; increase chips")
    per_block = page_size * cfg.kv_bytes_per_token()
    return max(64, int(free // per_block))


# ---------------------------------------------------------------------------
# Wake points and the scheduler's view of the engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Wake:
    """Why the engine is consulting the scheduler.

    ``kind`` is one of ``arrival``, ``prefill_done``, ``decode_done``,
    ``step_done``, ``transfer_arrived``, ``admit_retry``.  ``request``
    carries the subject of transfer/retry wakes.  ``kv_freed`` is True
    when a request finished and released decode-pool blocks during this
    wake — the signal gating RAPID's admission drain (allocation can
    only progress after a free, and draining on *preemption*-freed
    blocks would re-admit the victim a step early).
    """
    kind: str
    request: Optional[Request] = None
    kv_freed: bool = False


@dataclasses.dataclass(frozen=True)
class LaneState:
    """One execution lane as the scheduler/executor sees it."""
    busy: bool = False
    cost: Optional[C.StepCost] = None   # in-flight step cost, if busy
    f_decode: Optional[float] = None    # decode lane's resource share


@dataclasses.dataclass(frozen=True)
class SchedView:
    """Read-only snapshot handed to ``Scheduler.schedule``.

    Queues and ``running`` are the live containers — schedulers must
    treat them as immutable and express changes through the returned
    ``StepPlan``.
    """
    now: float
    serve: object                       # ServeConfig
    queues: Mapping[str, IndexedQueue]
    running: IndexedQueue
    kv: KVCacheManager
    kv_p: Optional[KVCacheManager]
    lanes: Mapping[str, LaneState]
    wake: Wake


# ---------------------------------------------------------------------------
# StepPlan: everything a scheduler may ask the engine to do
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Admission:
    """Allocate decode-pool blocks for ``request`` and move it between
    queues.  ``from_queue is None`` means the request is an in-flight
    disagg transfer (held outside any queue).  ``truncate_to`` asks the
    engine to cap the request's ``max_new_tokens`` at admission (and
    mark it ``truncated``) so prompt+output fits the pool — colocated
    topologies truncate where disagg rejects (ROADMAP item 5)."""
    request: Request
    from_queue: Optional[str]
    to_queue: str
    state: State
    stamp_t_blocks: bool = True
    stamp_prefill_start: bool = False
    truncate_to: Optional[int] = None


@dataclasses.dataclass
class PrefillLaunch:
    """Start a whole-prompt prefill step over ``batch`` (popped from
    ``queue``).  ``pool="prefill"`` additionally allocates transient
    prefill-side KV (disagg)."""
    batch: List[Request]
    queue: str
    pool: Optional[str] = None


@dataclasses.dataclass
class DecodeLaunch:
    """Join ``joins`` into the running batch and start a decode step.
    ``f_decode`` is the adaptive resource split (None = overallocate)."""
    joins: List[Request]
    f_decode: Optional[float] = None


@dataclasses.dataclass
class HybridLaunch:
    """One lockstep hybrid iteration: the running decodes plus prefill
    ``chunks`` of (request, tokens)."""
    chunks: List[Tuple[Request, int]]


@dataclasses.dataclass
class AdmitRetry:
    """Re-consult the scheduler about ``request`` after ``delay_s``
    (disagg decode-pool backpressure)."""
    request: Request
    delay_s: float


@dataclasses.dataclass
class StepPlan:
    """What to do *now*: rejections, admissions, lane launches and timed
    retries.  The engine applies fields in declaration order; launches
    are priced by the executor with prefill before decode so a decode
    launched alongside a prefill sees it in flight (the historical
    kick-prefill-then-kick-decode coupling)."""
    rejects: List[Tuple[Request, Optional[str]]] = \
        dataclasses.field(default_factory=list)
    admits: List[Admission] = dataclasses.field(default_factory=list)
    prefill: Optional[PrefillLaunch] = None
    decode: Optional[DecodeLaunch] = None
    hybrid: Optional[HybridLaunch] = None
    retries: List[AdmitRetry] = dataclasses.field(default_factory=list)


# ---------------------------------------------------------------------------
# Scheduler protocol
# ---------------------------------------------------------------------------


class Scheduler:
    """Pure scheduling policy + engine topology declaration.

    Subclasses set the class attributes below (which queues exist, which
    lanes run, where arrivals and preempted victims go, how the load
    snapshot is accounted) and implement ``schedule(view) -> StepPlan``.
    """

    mode: str = "base"
    lanes: Tuple[str, ...] = ("prefill", "decode")
    queue_names: Tuple[str, ...] = ()
    arrival_queue: str = ""
    arrival_state: State = State.WAITING_KV
    requeue_queue: str = ""             # preempted victims (appendleft)
    requeue_state: State = State.WAITING_KV
    migration_queue: str = ""           # cluster rebalance peek/pop
    colocated: bool = True              # P and D share chips (interference)
    has_prefill_pool: bool = False      # transient prefill-side KV (disagg)
    prefill_route: str = "join"         # "join" | "transfer"
    prefill_emits_first_token: bool = True
    # LoadSnapshot accounting
    count_queues: Tuple[str, ...] = ()
    token_queues: Tuple[str, ...] = ()          # full prompt_len pending
    partial_token_queues: Tuple[str, ...] = ()  # prompt minus chunked-done
    unalloc_queues: Tuple[str, ...] = ()        # not yet holding KV pages

    def schedule(self, view: SchedView) -> StepPlan:
        raise NotImplementedError

    # -- engine construction hooks ------------------------------------------
    def pool_blocks(self, cfg, serve, hw: HardwareSpec) -> Dict[str, int]:
        return {"decode": kv_pool_blocks(cfg, hw, serve.chips,
                                         serve.page_size,
                                         serve.kv_reserve_frac)}

    def lane_chips(self, serve) -> Dict[str, int]:
        return {lane: serve.chips for lane in self.lanes}

    def resize_lane(self, lane: str, chips: int, cfg, serve,
                    hw: HardwareSpec) -> Dict[str, int]:
        """Grow one lane's chip group at runtime (cluster autoscaler
        adding chips to one pool of a split-pool replica).  Returns the
        new ``pool_blocks`` mapping.  Colocated topologies share every
        chip between both phases, so per-lane resizing is undefined —
        the cluster scales those replicas whole."""
        raise NotImplementedError(
            f"{type(self).__name__} is colocated: per-pool scaling only "
            "applies to split-pool (disagg) topologies")

    # -- shared helpers ------------------------------------------------------
    @staticmethod
    def _fits_pool(prompt_len: int, kv: KVCacheManager,
                   page_size: int) -> bool:
        """Can the prompt *ever* fit this pool?"""
        return kv_pages_for(prompt_len, page_size) <= kv.allocator.num_blocks

    @staticmethod
    def _lifetime_cap(r: Request, kv: KVCacheManager,
                      page_size: int) -> Optional[int]:
        """Colocated pools: cap for the single-request decode stall
        (ROADMAP item 5).  A prompt that fits but whose prompt+output
        never will would, once running alone, self-preempt on every
        decode step forever.  Production systems truncate instead: cap
        ``max_new_tokens`` so the fully-grown context fits the pool.
        Generating N tokens appends N-1 tokens of KV beyond the prompt
        (the first token comes out of prefill; the last token's KV is
        never appended), so the exact bound is
        ``prompt + max_new - 1 <= pool_tokens``.  Returns the cap, or
        None when the request already fits over its lifetime."""
        pool_tokens = kv.allocator.num_blocks * page_size
        if r.prompt_len + r.max_new_tokens - 1 <= pool_tokens:
            return None
        return pool_tokens - r.prompt_len + 1

    @staticmethod
    def _pages_needed(r: Request, kv: KVCacheManager, page_size: int,
                      claimed: set) -> int:
        """Pages admitting ``r`` would newly claim, net of any parked
        session prefix it can adopt.  ``claimed`` tracks sessions whose
        prefix an earlier admission in the SAME plan already adopts —
        two queued turns of one session must not both count the hit.
        Reduces to ``kv_pages_for(prompt_len)`` for sessionless
        requests."""
        if r.session_id is None or r.session_id in claimed:
            return kv_pages_for(r.prompt_len, page_size)
        need = kv.pages_needed(r.prompt_len, r.session_id,
                               r.cached_prefix_len)
        claimed.add(r.session_id)
        return need


# ---------------------------------------------------------------------------
# RAPID (the paper)
# ---------------------------------------------------------------------------


class RapidScheduler(Scheduler):
    """Paper §4: concurrent P/D actors, decode-owned KV admission."""

    mode = "rapid"
    lanes = ("prefill", "decode")
    queue_names = ("waiting_kv", "waiting_prefill", "pending_join")
    arrival_queue = "waiting_kv"
    arrival_state = State.WAITING_KV
    requeue_queue = "waiting_kv"
    requeue_state = State.WAITING_KV
    migration_queue = "waiting_kv"
    count_queues = queue_names
    token_queues = ("waiting_kv", "waiting_prefill")
    unalloc_queues = ("waiting_kv",)

    def __init__(self, cfg, serve, hw: HardwareSpec = TPU_V5E,
                 avg_ctx_hint: int = 4096):
        profile = cached_decode_profile(
            cfg, hw, serve.chips, serve.slo.itl_ms / 1e3, avg_ctx_hint,
            tp=serve.chips)
        self.arm = AdaptiveResourceManager(profile)

    def schedule(self, view: SchedView) -> StepPlan:
        plan = StepPlan()
        serve = view.serve
        ps = serve.page_size
        admitted: List[Request] = []
        # -- Fig 4 drain: decode-side block allocation, FCFS -------------
        # drain at arrival and whenever a *finish* freed blocks; never on
        # preemption-freed blocks alone (at decode_done OR at a later
        # prefill_done) — the decode-owned protocol re-admits a preempted
        # victim only after a finish returns capacity
        if view.wake.kind == "arrival" or view.wake.kv_freed:
            # available_blocks = free + reclaimable session-parked pages;
            # identical to free_count on sessionless traces
            free = view.kv.available_blocks
            claimed = set()     # sessions whose parked prefix this plan
            for r in view.queues["waiting_kv"]:   # already hands out
                if not self._fits_pool(r.prompt_len, view.kv, ps):
                    plan.rejects.append((r, "waiting_kv"))
                    continue
                need = self._pages_needed(r, view.kv, ps, claimed)
                if need > free:
                    break
                free -= need
                plan.admits.append(Admission(
                    r, "waiting_kv", "waiting_prefill",
                    State.WAITING_PREFILL,
                    truncate_to=self._lifetime_cap(r, view.kv, ps)))
                admitted.append(r)
        # -- prefill actor: whole prompts up to the token cap ------------
        if not view.lanes["prefill"].busy:
            batch: List[Request] = []
            tokens = 0
            for r in itertools.chain(view.queues["waiting_prefill"],
                                     admitted):
                if batch and tokens + r.prompt_len > serve.prefill_max_tokens:
                    break
                batch.append(r)
                tokens += r.prompt_len
            if batch:
                plan.prefill = PrefillLaunch(batch, "waiting_prefill")
        # -- decode actor: join then step --------------------------------
        if not view.lanes["decode"].busy:
            joins: List[Request] = []
            slots = len(view.running)
            for r in view.queues["pending_join"]:
                if slots >= serve.max_batch_slots:
                    break
                joins.append(r)
                slots += 1
            bs = len(view.running) + len(joins)
            if bs:
                prefill_active = view.lanes["prefill"].busy or \
                    plan.prefill is not None
                alloc = self.arm.allocate(bs, prefill_active)
                plan.decode = DecodeLaunch(joins, f_decode=alloc.f_decode)
        return plan


# ---------------------------------------------------------------------------
# Hybrid batching with chunked prefill (Sarathi / vLLM-v1)
# ---------------------------------------------------------------------------


class HybridScheduler(Scheduler):
    """One lockstep batch per iteration: decodes first, then prefill
    chunks up to the token budget — the §3.1 ITL coupling RAPID removes."""

    mode = "hybrid"
    lanes = ("step",)
    queue_names = ("waiting", "chunking")
    arrival_queue = "waiting"
    arrival_state = State.WAITING_KV
    requeue_queue = "waiting"
    requeue_state = State.WAITING_KV
    migration_queue = "waiting"
    count_queues = ("waiting", "chunking")
    token_queues = ("waiting",)
    partial_token_queues = ("chunking",)
    unalloc_queues = ("waiting",)

    def __init__(self, cfg, serve, hw: HardwareSpec = TPU_V5E):
        del cfg, serve, hw                # stateless policy

    def schedule(self, view: SchedView) -> StepPlan:
        plan = StepPlan()
        if view.lanes["step"].busy:
            return plan
        serve = view.serve
        ps = serve.page_size
        # -- admission: blocks + batch slots, FCFS -----------------------
        free = view.kv.available_blocks
        slots = len(view.queues["chunking"]) + len(view.running)
        admitted: List[Request] = []
        claimed = set()
        for r in view.queues["waiting"]:
            if not self._fits_pool(r.prompt_len, view.kv, ps):
                plan.rejects.append((r, "waiting"))
                continue
            need = self._pages_needed(r, view.kv, ps, claimed)
            if need > free or slots >= serve.max_batch_slots:
                break
            free -= need
            slots += 1
            plan.admits.append(Admission(
                r, "waiting", "chunking", State.PREFILLING,
                stamp_prefill_start=True,
                truncate_to=self._lifetime_cap(r, view.kv, ps)))
            admitted.append(r)
        # -- Sarathi: budget filled with decodes first, then chunks ------
        bs = len(view.running)
        budget = max(0, serve.token_budget - bs)
        chunks: List[Tuple[Request, int]] = []
        for r in itertools.chain(view.queues["chunking"], admitted):
            if budget <= 0:
                break
            take = min(serve.chunk_size, budget,
                       r.prefill_tokens_needed - r.prefill_tokens_done)
            if take <= 0:
                continue
            chunks.append((r, take))
            budget -= take
        if chunks or bs:
            plan.hybrid = HybridLaunch(chunks)
        return plan


# ---------------------------------------------------------------------------
# Disaggregated serving (DistServe-style, vLLM v1 transfer semantics)
# ---------------------------------------------------------------------------


class DisaggScheduler(Scheduler):
    """Split P/D pools; KV transfer on the critical path; decode-side
    admission with timed backpressure retries (§3.2)."""

    mode = "disagg"
    lanes = ("prefill", "decode")
    queue_names = ("waiting_prefill", "pending_join")
    arrival_queue = "waiting_prefill"
    arrival_state = State.WAITING_PREFILL
    requeue_queue = "waiting_prefill"
    requeue_state = State.WAITING_PREFILL
    migration_queue = "waiting_prefill"
    colocated = False
    has_prefill_pool = True
    prefill_route = "transfer"
    prefill_emits_first_token = False
    count_queues = ("waiting_prefill", "pending_join")
    token_queues = ("waiting_prefill",)
    unalloc_queues = ("waiting_prefill",)

    def __init__(self, cfg, serve, hw: HardwareSpec = TPU_V5E):
        del cfg, hw
        self.chips_p, self.chips_d = serve.disagg_split

    def pool_blocks(self, cfg, serve, hw: HardwareSpec) -> Dict[str, int]:
        # each pool holds a full weight replica; long-lived KV capacity
        # only exists on the decode side (the §3.2.2 imbalance)
        return {
            "decode": kv_pool_blocks(cfg, hw, self.chips_d, serve.page_size,
                                     serve.kv_reserve_frac),
            "prefill": kv_pool_blocks(cfg, hw, self.chips_p, serve.page_size,
                                      serve.kv_reserve_frac),
        }

    def lane_chips(self, serve) -> Dict[str, int]:
        return {"prefill": self.chips_p, "decode": self.chips_d}

    def resize_lane(self, lane: str, chips: int, cfg, serve,
                    hw: HardwareSpec) -> Dict[str, int]:
        """Independent P/D pool scaling: grow ONE pool's chip group
        (the other pool — and its KV — is untouched)."""
        if lane not in ("prefill", "decode"):
            raise KeyError(f"disagg has no lane {lane!r}")
        if lane == "prefill":
            self.chips_p = chips
        else:
            self.chips_d = chips
        return self.pool_blocks(cfg, serve, hw)

    def schedule(self, view: SchedView) -> StepPlan:
        plan = StepPlan()
        serve = view.serve
        ps = serve.page_size
        # -- decode-side admission for a completed KV transfer -----------
        if view.wake.kind in ("transfer_arrived", "admit_retry"):
            r = view.wake.request
            if not self._fits_pool(r.prompt_len + r.max_new_tokens,
                                   view.kv, ps):
                # prompt + worst-case output can NEVER fit the decode
                # pool: reject instead of spinning the retry loop (or,
                # once admitted, self-preempting on every decode step —
                # the ROADMAP item 5 livelock) forever
                plan.rejects.append((r, None))
            elif kv_pages_for(r.prompt_len, ps) > \
                    view.kv.allocator.free_count:
                # decode pool full: back-pressure; retry next decode step
                plan.retries.append(AdmitRetry(r, serve.slo.itl_ms / 1e3))
            else:
                plan.admits.append(Admission(
                    r, None, "pending_join", State.PREFILL_FINISHED,
                    stamp_t_blocks=False))
        # -- prefill pool admission + batch formation --------------------
        if not view.lanes["prefill"].busy:
            free_p = view.kv_p.allocator.free_count
            batch: List[Request] = []
            tokens = 0
            for r in view.queues["waiting_prefill"]:
                if not self._fits_pool(r.prompt_len, view.kv_p, ps) or \
                        not self._fits_pool(
                            r.prompt_len + r.max_new_tokens, view.kv, ps):
                    # oversized for the prefill pool (queue-head wedge) or
                    # for the decode pool over its LIFETIME — a prompt
                    # whose prompt+output can never fit would either
                    # retry forever post-transfer or livelock decode by
                    # self-preempting on every step (ROADMAP item 5)
                    plan.rejects.append((r, "waiting_prefill"))
                    continue
                need = kv_pages_for(r.prompt_len, ps)
                if need > free_p:
                    break
                if batch and tokens + r.prompt_len > serve.prefill_max_tokens:
                    break
                free_p -= need
                batch.append(r)
                tokens += r.prompt_len
            if batch:
                plan.prefill = PrefillLaunch(batch, "waiting_prefill",
                                             pool="prefill")
        # -- decode: join then step --------------------------------------
        # a transfer admitted in THIS plan joins immediately (it reaches
        # pending_join before the launch is applied)
        if not view.lanes["decode"].busy:
            joins: List[Request] = []
            slots = len(view.running)
            newly = [a.request for a in plan.admits
                     if a.to_queue == "pending_join"]
            for r in itertools.chain(view.queues["pending_join"], newly):
                if slots >= serve.max_batch_slots:
                    break
                joins.append(r)
                slots += 1
            if view.running or joins:
                plan.decode = DecodeLaunch(joins)
        return plan


SCHEDULERS = {
    "rapid": RapidScheduler,
    "hybrid": HybridScheduler,
    "disagg": DisaggScheduler,
}


def make_scheduler(mode: str, cfg, serve, hw: HardwareSpec = TPU_V5E,
                   **kwargs) -> Scheduler:
    if mode not in SCHEDULERS:
        raise KeyError(
            f"unknown scheduler mode {mode!r}; known: {sorted(SCHEDULERS)}")
    return SCHEDULERS[mode](cfg, serve, hw, **kwargs)
