"""Request lifecycle — the queue states of paper Fig 4.

A request is directed simultaneously to both the prefill and decode sides:
  decode side : WAITING_KV -> (blocks allocated) -> notifies prefill
  prefill side: PENDING_KV -> WAITING_PREFILL -> PREFILLING -> done
  decode side : PREFILL_FINISHED -> DECODING -> FINISHED

Timestamps are recorded at every transition; TTFT/ITL metrics derive from
``token_times`` (token 1 is produced by the prefill step).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class State(enum.Enum):
    ARRIVED = "arrived"
    WAITING_KV = "waiting_kv"          # decode: waiting for block alloc
    WAITING_PREFILL = "waiting_prefill"  # prefill: has blocks, in queue
    PREFILLING = "prefilling"
    PREFILL_FINISHED = "prefill_finished"  # decode notified, joining batch
    DECODING = "decoding"
    FINISHED = "finished"
    PREEMPTED = "preempted"
    REJECTED = "rejected"              # admission control turned it away


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    max_new_tokens: int

    state: State = State.ARRIVED
    blocks: Optional[list] = None
    # progress
    prefill_tokens_done: int = 0       # for chunked prefill baselines
    tokens_generated: int = 0          # includes the prefill-produced token
    token_times: List[float] = dataclasses.field(default_factory=list)
    # timestamps
    t_blocks: Optional[float] = None
    t_prefill_start: Optional[float] = None
    t_prefill_end: Optional[float] = None
    t_finish: Optional[float] = None
    preemptions: int = 0

    @property
    def ttft(self) -> Optional[float]:
        return self.token_times[0] - self.arrival if self.token_times else None

    @property
    def itls(self) -> List[float]:
        ts = self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]

    @property
    def context_len(self) -> int:
        return self.prompt_len + self.tokens_generated

    @property
    def done(self) -> bool:
        return self.tokens_generated >= self.max_new_tokens

    def emit_token(self, now: float) -> None:
        self.tokens_generated += 1
        self.token_times.append(now)
