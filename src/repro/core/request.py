"""Request lifecycle — the queue states of paper Fig 4.

A request is directed simultaneously to both the prefill and decode sides:
  decode side : WAITING_KV -> (blocks allocated) -> notifies prefill
  prefill side: PENDING_KV -> WAITING_PREFILL -> PREFILLING -> done
  decode side : PREFILL_FINISHED -> DECODING -> FINISHED

Timestamps are recorded at every transition; TTFT/ITL metrics derive from
``token_times`` (token 1 is produced by the prefill step).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


# Multi-tenant SLO classes, ordered by importance.  Lower rank = more
# important: admission sheds and preemption evicts the HIGHEST rank
# first, so interactive traffic is the last to suffer.
CLASS_RANK = {"interactive": 0, "batch": 1, "best_effort": 2}


def class_rank(slo_class: str) -> int:
    """Rank for victim/shedding order; unknown classes rank with
    ``interactive`` (never shed by accident of a typo upstream)."""
    return CLASS_RANK.get(slo_class, 0)


class State(enum.Enum):
    ARRIVED = "arrived"
    WAITING_KV = "waiting_kv"          # decode: waiting for block alloc
    WAITING_PREFILL = "waiting_prefill"  # prefill: has blocks, in queue
    PREFILLING = "prefilling"
    PREFILL_FINISHED = "prefill_finished"  # decode notified, joining batch
    DECODING = "decoding"
    FINISHED = "finished"
    PREEMPTED = "preempted"
    REJECTED = "rejected"              # admission control turned it away


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    max_new_tokens: int

    # multi-tenant workload model (defaults reproduce the single-class
    # legacy behaviour bit-for-bit)
    slo_class: str = "interactive"     # interactive | batch | best_effort
    session_id: Optional[str] = None   # multi-turn conversation key
    # tokens at the head of the prompt whose KV may already be resident
    # from an earlier turn of the same session.  The value set by the
    # trace generator is OPTIMISTIC; the engine clamps it at admission
    # to what is actually cached and re-prefills the rest.
    cached_prefix_len: int = 0

    state: State = State.ARRIVED
    blocks: Optional[list] = None
    # progress
    prefill_tokens_done: int = 0       # for chunked prefill baselines
    tokens_generated: int = 0          # includes the prefill-produced token
    token_times: List[float] = dataclasses.field(default_factory=list)
    # timestamps
    t_blocks: Optional[float] = None
    t_prefill_start: Optional[float] = None
    t_prefill_end: Optional[float] = None
    t_finish: Optional[float] = None
    preemptions: int = 0
    reject_reason: Optional[str] = None  # set iff state == REJECTED
    # gateway-level failovers: times this request was re-submitted to a
    # different worker after its replica crashed (serving/gateway.py)
    retries: int = 0
    # admission capped max_new_tokens so prompt+output fits a colocated
    # pool (production-shaped truncation instead of a decode stall)
    truncated: bool = False

    @property
    def ttft(self) -> Optional[float]:
        return self.token_times[0] - self.arrival if self.token_times else None

    @property
    def itls(self) -> List[float]:
        ts = self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]

    @property
    def context_len(self) -> int:
        return self.prompt_len + self.tokens_generated

    @property
    def prefill_tokens_needed(self) -> int:
        """Prompt tokens that actually need prefill compute — the prompt
        minus the session-cached prefix (0 skipped for sessionless
        requests, so this equals ``prompt_len`` on the legacy path)."""
        return self.prompt_len - self.cached_prefix_len

    @property
    def done(self) -> bool:
        return self.tokens_generated >= self.max_new_tokens

    def emit_token(self, now: float) -> None:
        self.tokens_generated += 1
        self.token_times.append(now)
