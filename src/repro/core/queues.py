"""Order-preserving indexed request queues with incremental accounting.

The PR-4 engines kept requests in plain ``deque``/``list`` containers, so
every hot-path transition paid O(n): ``remove()`` on admission/launch,
``in`` membership checks in the decode loop, and — worst of all —
``Engine.load_snapshot()`` re-summing every queue's lengths, prompt
tokens and KV-page claims on every router/admission/autoscaler call.
``IndexedQueue`` replaces all of those with O(1) operations:

  * **order-preserving** — iteration yields requests in FIFO insertion
    order; ``appendleft`` (preemption re-queue) goes to the front;
    ``remove`` preserves the order of everything else.  Backed by an
    ``OrderedDict`` keyed on ``Request.rid`` (unique per engine).
  * **O(1) everything** — append / appendleft / pop / popleft / remove /
    ``in`` / ``len`` / front-and-back peeks.
  * **incremental aggregates** — the quantities ``load_snapshot()``
    needs are maintained at add/remove time instead of recomputed:

      ``len(q)``                   request count
      ``q.prompt_tokens``          sum of members' ``prompt_len``
      ``q.pending_prefill_tokens`` sum of ``prefill_tokens_needed -
                                   prefill_tokens_done`` (session-cached
                                   prefix tokens never need compute)
      ``q.kv_pages``               sum of ``kv_pages_for(prompt_len, page)``
      ``q.ctx_tokens``             sum of members' ``context_len``

Each member's contribution is *snapshotted at add time* and stored next
to the request; ``remove`` subtracts exactly what was added (plus any
``note_*`` adjustments), so in-place ``Request`` mutation can never skew
an aggregate.  The two fields that legitimately change while a request
sits in a container have explicit notification hooks the engine calls:

  * ``note_chunk_progress(r, take)`` — hybrid chunked prefill advanced
    ``prefill_tokens_done`` by ``take`` while ``r`` waits in ``chunking``;
  * ``note_token(r)`` — a decode step appended one token to a *running*
    request (keeps ``ctx_tokens`` live for the running batch).

``tests/test_load_accounting.py`` pins the aggregates against
hand-computed values; the hypothesis property suite asserts
``Engine.load_snapshot() == Engine.load_snapshot_recompute()`` after
arbitrary enqueue/admit/preempt/migrate/finish sequences.
"""
from __future__ import annotations

import collections
from typing import Iterator, List, Optional

from repro.core.request import Request
from repro.kvcache import kv_pages_for


class IndexedQueue:
    """O(1) ordered request container (see module docstring)."""

    __slots__ = ("page_size", "_entries", "prompt_tokens",
                 "pending_prefill_tokens", "kv_pages", "ctx_tokens")

    # entry layout: [request, pending_contrib, ctx_contrib]
    _REQ, _PEND, _CTX = 0, 1, 2

    def __init__(self, page_size: int = 1,
                 items: Optional[List[Request]] = None):
        self.page_size = page_size
        self._entries: "collections.OrderedDict[int, list]" = \
            collections.OrderedDict()
        self.prompt_tokens = 0
        self.pending_prefill_tokens = 0
        self.kv_pages = 0
        self.ctx_tokens = 0
        for r in items or ():
            self.append(r)

    # -- membership transitions ---------------------------------------------
    def _add(self, r: Request) -> list:
        if r.rid in self._entries:
            raise ValueError(f"request {r.rid} already queued")
        pend = r.prompt_len - r.cached_prefix_len - r.prefill_tokens_done
        ctx = r.context_len
        self._entries[r.rid] = entry = [r, pend, ctx]
        self.prompt_tokens += r.prompt_len
        self.pending_prefill_tokens += pend
        self.kv_pages += kv_pages_for(r.prompt_len, self.page_size)
        self.ctx_tokens += ctx
        return entry

    def append(self, r: Request) -> None:
        self._add(r)

    def appendleft(self, r: Request) -> None:
        self._add(r)
        self._entries.move_to_end(r.rid, last=False)

    def _subtract(self, entry: list) -> Request:
        r = entry[self._REQ]
        self.prompt_tokens -= r.prompt_len
        self.pending_prefill_tokens -= entry[self._PEND]
        self.kv_pages -= kv_pages_for(r.prompt_len, self.page_size)
        self.ctx_tokens -= entry[self._CTX]
        return r

    def remove(self, r: Request) -> None:
        entry = self._entries.get(r.rid)
        if entry is None or entry[self._REQ] is not r:
            raise ValueError(f"request {r.rid} not in queue")
        del self._entries[r.rid]
        self._subtract(entry)

    def pop(self) -> Request:
        _, entry = self._entries.popitem(last=True)
        return self._subtract(entry)

    def popleft(self) -> Request:
        _, entry = self._entries.popitem(last=False)
        return self._subtract(entry)

    # -- in-place mutation hooks --------------------------------------------
    def note_chunk_progress(self, r: Request, take: int) -> None:
        """``r.prefill_tokens_done`` advanced by ``take`` while queued."""
        self._entries[r.rid][self._PEND] -= take
        self.pending_prefill_tokens -= take

    def note_token(self, r: Request, n: int = 1) -> None:
        """``r`` generated ``n`` tokens while a member (running batch)."""
        self._entries[r.rid][self._CTX] += n
        self.ctx_tokens += n

    # -- views ---------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        return bool(self._entries)

    def __contains__(self, r) -> bool:
        entry = self._entries.get(getattr(r, "rid", None))
        return entry is not None and entry[self._REQ] is r

    def __iter__(self) -> Iterator[Request]:
        for entry in self._entries.values():
            yield entry[self._REQ]

    def __getitem__(self, i: int) -> Request:
        """O(1) front/back peeks (the engine only ever peeks the ends);
        other indices fall back to an O(n) walk."""
        n = len(self._entries)
        if not n:
            raise IndexError("peek of empty IndexedQueue")
        if i == 0:
            key = next(iter(self._entries))
        elif i == -1 or i == n - 1:
            key = next(reversed(self._entries))
        else:
            if i < 0:
                i += n
            if not 0 <= i < n:
                raise IndexError(i)
            key = list(self._entries)[i]
        return self._entries[key][self._REQ]

    def __repr__(self) -> str:
        return (f"IndexedQueue(len={len(self)}, "
                f"prompt_tokens={self.prompt_tokens}, "
                f"kv_pages={self.kv_pages})")
