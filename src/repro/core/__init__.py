"""RAPID-Serve core: the paper's serving engine + baselines."""
from repro.core.request import Request, State  # noqa: F401
from repro.core.preemption import (  # noqa: F401
    DEFAULT_PREEMPTION, PreemptionPolicy,
)
from repro.core.resource_manager import (  # noqa: F401
    AdaptiveResourceManager, Allocation, DecodeProfile,
    build_decode_profile,
)
from repro.core.engines import (  # noqa: F401
    BaseEngine, DisaggEngine, HybridEngine, RapidEngine, make_engine,
    kv_pool_blocks,
)
