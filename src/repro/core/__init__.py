"""RAPID-Serve core: scheduler/executor split serving engine + policies.

Serving API v2 (see README "Serving API v2"): ``Engine`` drives a pure
``Scheduler`` policy and an ``Executor`` pricing backend on the injected
event loop and emits a typed request-lifecycle event stream.
"""
from repro.core.engines import (  # noqa: F401
    BaseEngine, DisaggEngine, Engine, HybridEngine, RapidEngine,
    drive, kv_pool_blocks, make_engine,
)
from repro.core.events import (  # noqa: F401
    CancelledEvent, EventStream, FinishedEvent, PhaseEvent, RejectedEvent,
    TokenEvent,
)
from repro.core.executor import (  # noqa: F401
    Executor, KernelExecutor, PerfModelExecutor, StepOutputs,
)
from repro.core.preemption import (  # noqa: F401
    DEFAULT_PREEMPTION, PreemptionPolicy,
)
from repro.core.request import Request, State  # noqa: F401
from repro.core.resource_manager import (  # noqa: F401
    AdaptiveResourceManager, Allocation, DecodeProfile,
    build_decode_profile,
)
from repro.core.scheduler import (  # noqa: F401
    SCHEDULERS, DisaggScheduler, HybridScheduler, RapidScheduler,
    SchedView, Scheduler, StepPlan, Wake, make_scheduler,
)
