"""Victim selection shared by every engine and the cluster rebalancer.

Each engine used to carry its own copy of ``_preempt_victim``'s chooser
(newest running request loses — recompute-on-resume is cheapest for the
request with the least sunk prefill work).  The cluster-level
cross-replica preemption/migration tick needs the *same* ranking, so the
choice lives here as a small policy object the engines and the cluster
both consult.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.request import Request, class_rank


@dataclasses.dataclass(frozen=True)
class PreemptionPolicy:
    """Ranks running requests for eviction.

    ``order`` breaks ties *within* an SLO class:

    ``newest``        — latest arrival loses (least sunk work; default,
                        matches the engines' historical behaviour).
    ``least_progress``— fewest generated tokens loses (minimizes wasted
                        decode work when arrivals are bursty).

    With ``class_aware`` on (default) victims are ranked by SLO class
    FIRST — best_effort loses before batch loses before interactive —
    and ``order`` only decides among the worst class present.  In a
    single-class batch every rank ties, so the choice is identical to
    the class-blind ranking (golden parity).
    """

    order: str = "newest"
    class_aware: bool = True

    def choose(self, running: Sequence[Request]) -> Optional[Request]:
        if not running:
            return None
        rank = class_rank if self.class_aware else (lambda r: 0)
        if self.order == "newest":
            return max(running,
                       key=lambda r: (rank(r.slo_class), r.arrival))
        if self.order == "least_progress":
            return min(running,
                       key=lambda r: (-rank(r.slo_class),
                                      r.tokens_generated, -r.arrival))
        raise ValueError(f"unknown preemption order {self.order!r}")


DEFAULT_PREEMPTION = PreemptionPolicy()
