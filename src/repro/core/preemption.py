"""Victim selection shared by every engine and the cluster rebalancer.

Each engine used to carry its own copy of ``_preempt_victim``'s chooser
(newest running request loses — recompute-on-resume is cheapest for the
request with the least sunk prefill work).  The cluster-level
cross-replica preemption/migration tick needs the *same* ranking, so the
choice lives here as a small policy object the engines and the cluster
both consult.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.request import Request


@dataclasses.dataclass(frozen=True)
class PreemptionPolicy:
    """Ranks running requests for eviction.

    ``newest``        — latest arrival loses (least sunk work; default,
                        matches the engines' historical behaviour).
    ``least_progress``— fewest generated tokens loses (minimizes wasted
                        decode work when arrivals are bursty).
    """

    order: str = "newest"

    def choose(self, running: Sequence[Request]) -> Optional[Request]:
        if not running:
            return None
        if self.order == "newest":
            return max(running, key=lambda r: r.arrival)
        if self.order == "least_progress":
            return min(running, key=lambda r: (r.tokens_generated,
                                               -r.arrival))
        raise ValueError(f"unknown preemption order {self.order!r}")


DEFAULT_PREEMPTION = PreemptionPolicy()
