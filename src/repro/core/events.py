"""Typed request-lifecycle event stream (Serving API v2).

Engines emit a stream of small frozen event records as requests move
through the system; callers *subscribe* instead of scraping
``records()`` after the fact:

  * ``TokenEvent``    — one generated token (``index`` is 0-based; the
    first token of a request is the one produced by prefill).
  * ``PhaseEvent``    — a lifecycle transition: ``queued`` (arrival),
    ``kv_allocated`` (decode-side block allocation, paper Fig 4),
    ``prefill`` (prefill step started), ``transfer`` (disagg KV transfer
    started), ``decode`` (joined the decode batch), ``preempted``.
  * ``FinishedEvent`` — terminal success; carries enough metadata
    (arrival, prompt_len, output_len, preemptions) that consumers can
    build a full ``RequestRecord`` from the stream alone.
  * ``RejectedEvent`` — terminal admission failure.

Every request ends with exactly one ``FinishedEvent`` or
``RejectedEvent``; its ``TokenEvent`` times are monotone and count
exactly ``max_new_tokens`` on success (asserted in tests/test_events.py).
The gateway layer adds a third terminal, ``CancelledEvent``, for client
cancellation/disconnect — engines themselves never emit it.

The stream is also the serving gateway's **wire format**: each event
maps to one JSON line (``event_to_json`` / ``event_from_json``) with a
``type`` discriminator, and the mapping round-trips bit-identically —
``json`` serializes floats via ``repr``, which Python guarantees parses
back to the same float (tests/test_event_wire.py pins this over
engine-generated traces).

``EventStream`` is a synchronous pub/sub hub with a replay log: under
the virtual clock "streaming" means subscribers run inline at emission
time (same ``loop.now``), and ``events()`` returns everything emitted so
far for post-hoc consumers.

Hot-path notes (the stream sits on every token of every request): the
event records are ``slots=True`` frozen dataclasses (no per-instance
``__dict__``), ``emit`` skips the per-rid fanout dict entirely while no
per-rid subscriber exists (the overwhelmingly common case), and
``events()`` amortizes its immutable replay view — the tuple is rebuilt
only when something was emitted since the last call, so polling
consumers stop paying a full copy per read.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, List, Mapping, Optional, Tuple, Union


@dataclasses.dataclass(frozen=True, slots=True)
class TokenEvent:
    rid: int
    t: float
    index: int          # 0-based position in the request's output


@dataclasses.dataclass(frozen=True, slots=True)
class PhaseEvent:
    rid: int
    t: float
    phase: str          # queued|kv_allocated|prefill|transfer|decode|preempted


@dataclasses.dataclass(frozen=True, slots=True)
class FinishedEvent:
    """Terminal success.  ``retries`` counts gateway-level failovers
    (the request was re-submitted to another worker after its replica
    crashed); ``truncated`` means admission capped ``max_new_tokens`` so
    prompt+output fits a colocated pool (``output_len`` is the capped
    count)."""
    rid: int
    t: float
    arrival: float
    prompt_len: int
    output_len: int
    preemptions: int = 0
    slo_class: str = "interactive"
    retries: int = 0
    truncated: bool = False


@dataclasses.dataclass(frozen=True, slots=True)
class RejectedEvent:
    """Terminal admission failure.  ``reason`` is one of

      * ``never_fits``  — prompt (+ worst-case output, disagg) can never
        fit the pool, no amount of waiting helps;
      * ``kv_headroom`` — pools are full now and the cluster-side wait
        deadline expired;
      * ``class_shed``  — class-aware admission shed a lower-importance
        class to protect interactive headroom;
      * ``worker_lost`` — the gateway exhausted its failover retries (or
        had no healthy worker left) after replica crashes.
    """
    rid: int
    t: float
    arrival: float
    prompt_len: int
    reason: str = "never_fits"
    output_len: int = 0
    preemptions: int = 0
    slo_class: str = "interactive"
    retries: int = 0


@dataclasses.dataclass(frozen=True, slots=True)
class CancelledEvent:
    """Terminal client-side cancellation (explicit ``cancel(rid)`` or a
    mid-stream disconnect).  ``output_len`` is the number of tokens the
    client actually received before cancelling; ``reason`` is
    ``client_cancel`` or ``disconnect``."""
    rid: int
    t: float
    arrival: float
    prompt_len: int
    output_len: int = 0
    preemptions: int = 0
    slo_class: str = "interactive"
    retries: int = 0
    reason: str = "client_cancel"


Event = Union[TokenEvent, PhaseEvent, FinishedEvent, RejectedEvent,
              CancelledEvent]

TERMINAL_EVENTS = (FinishedEvent, RejectedEvent, CancelledEvent)


# ---------------------------------------------------------------------------
# Wire format (serving gateway): one JSON line per event
# ---------------------------------------------------------------------------

WIRE_TYPES: Dict[str, type] = {
    "token": TokenEvent,
    "phase": PhaseEvent,
    "finished": FinishedEvent,
    "rejected": RejectedEvent,
    "cancelled": CancelledEvent,
}
_WIRE_TAGS: Dict[type, str] = {cls: tag for tag, cls in WIRE_TYPES.items()}


def event_to_wire(ev: Event) -> Dict[str, object]:
    """Event -> plain dict with a ``type`` discriminator."""
    d: Dict[str, object] = {"type": _WIRE_TAGS[type(ev)]}
    for f in dataclasses.fields(ev):
        d[f.name] = getattr(ev, f.name)
    return d


def event_from_wire(d: Mapping[str, object]) -> Event:
    """Inverse of ``event_to_wire``; raises ``ValueError`` on unknown or
    missing ``type`` tags (a malformed wire line must not surface as a
    ``KeyError`` deep in a stream consumer)."""
    kw = dict(d)
    tag = kw.pop("type", None)
    cls = WIRE_TYPES.get(tag)
    if cls is None:
        raise ValueError(f"unknown wire event type {tag!r}")
    try:
        return cls(**kw)
    except TypeError as e:
        raise ValueError(f"bad wire fields for {tag!r}: {e}") from None


def event_to_json(ev: Event) -> str:
    """One JSON line (no trailing newline).  Floats serialize via
    ``repr`` so decode returns the identical value."""
    return json.dumps(event_to_wire(ev), separators=(",", ":"))


def event_from_json(line: str) -> Event:
    try:
        d = json.loads(line)
    except json.JSONDecodeError as e:
        raise ValueError(f"bad wire line: {e}") from None
    if not isinstance(d, dict):
        raise ValueError(f"bad wire line: expected object, got {type(d)}")
    return event_from_wire(d)


class EventStream:
    """Synchronous pub/sub with a replay log.

    ``subscribe(fn)`` registers a global consumer; ``subscribe(fn,
    rid=...)`` a per-request one (only that request's events).  Consumers
    are plain callables invoked inline at emission time — on the virtual
    clock that is "streaming".  ``events()`` returns the replay log.
    """

    def __init__(self):
        self._log: List[Event] = []
        self._subs: List[Callable[[Event], None]] = []
        self._per_rid: Dict[int, List[Callable[[Event], None]]] = {}
        self._view: Tuple[Event, ...] = ()   # cached replay tuple

    def emit(self, ev: Event) -> None:
        self._log.append(ev)
        for fn in self._subs:
            fn(ev)
        if self._per_rid:                    # skip fanout dict when empty
            for fn in self._per_rid.get(ev.rid, ()):
                fn(ev)

    def subscribe(self, fn: Callable[[Event], None],
                  rid: Optional[int] = None) -> Callable[[Event], None]:
        """Register ``fn``; returns it so callers can unsubscribe."""
        if rid is None:
            self._subs.append(fn)
        else:
            self._per_rid.setdefault(rid, []).append(fn)
        return fn

    def unsubscribe(self, fn: Callable[[Event], None],
                    rid: Optional[int] = None) -> None:
        if rid is None:
            self._subs.remove(fn)
        else:
            self._per_rid[rid].remove(fn)
            if not self._per_rid[rid]:       # keep the empty-dict fast path
                del self._per_rid[rid]

    def events(self) -> Tuple[Event, ...]:
        """Immutable replay log.  Amortized: the tuple is only rebuilt
        when events were emitted since the previous call, so interleaved
        emit/read patterns cost O(new events), not O(log) per read."""
        if len(self._view) != len(self._log):
            self._view = tuple(self._log)
        return self._view

    def __len__(self) -> int:
        return len(self._log)
