"""Training step builder: microbatched grad accumulation + remat + ZeRO.

The built ``train_step(state, batch) -> (state, metrics)`` is what the
multi-pod dry-run lowers for the ``train_4k`` shape of every arch, and
what launch/train.py jits for the real CPU example run.  Gradient
accumulation is a ``lax.scan`` over microbatches (sequential — peak
activation memory is one microbatch); per-layer remat is on by default
(transformer.forward(remat=True)); gradient compression (int8 +
per-leaf scale) optionally wraps the cross-pod reduction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import lm_loss
from repro.training.compression import (compress_gradients,
                                        decompress_gradients)
from repro.training.optimizer import (AdamWState, OptConfig, adamw_init,
                                      adamw_update)


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState
    step: jax.Array

    def tree_flatten(self):  # pragma: no cover - pytree protocol
        return (self.params, self.opt.mu, self.opt.nu, self.opt.count,
                self.step), None

    @classmethod
    def tree_unflatten(cls, aux, children):  # pragma: no cover
        params, mu, nu, count, step = children
        return cls(params, AdamWState(mu, nu, count), step)


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: s.tree_flatten(),
    TrainState.tree_unflatten)


def train_state_specs(param_specs):
    """Logical specs for the TrainState pytree (moments mirror params)."""
    return TrainState(params=param_specs,
                      opt=AdamWState(mu=param_specs, nu=param_specs,
                                     count=()),
                      step=())


def init_train_state(rng, cfg, opt: OptConfig, tp: int = 1) -> TrainState:
    from repro.models.transformer import init_model
    params, _ = init_model(rng, cfg, tp)
    return TrainState(params=params, opt=adamw_init(params, opt),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(cfg, opt: OptConfig, tp: int = 1, *,
                    microbatches: int = 1, impl: str = "ref",
                    constrain=None, remat: bool = True,
                    compress_grads: bool = False,
                    grad_shardings: Optional[Any] = None) -> Callable:
    """Returns train_step(state, batch)->(state, metrics).

    batch: {"inputs": (B,S) int32 | (B,S,d) f32, "labels": (B,S) int32,
            "positions": (B,S[,3]) int32}.  B must divide by microbatches;
    each microbatch is forward+backward'd inside a lax.scan; gradients
    accumulate in ``opt.grad_accum_dtype`` (bf16 for the >=70B archs —
    f32 grads alone would be 1.6 TB for jamba-398B).

    ``grad_shardings`` (tree of NamedSharding matching params) pins the
    accumulator's layout: without it GSPMD replicates the scan carry and
    every device holds FULL f32 gradients (+65 GB/chip at 398B scale —
    found by the dry-run, see EXPERIMENTS.md §Perf).
    """
    constrain = constrain or (lambda a, spec: a)
    acc_dt = jnp.dtype(opt.grad_accum_dtype)

    def loss_fn(params, inputs, labels, positions):
        return lm_loss(params, cfg, inputs, labels, positions, tp,
                       impl=impl, constrain=constrain, remat=remat)

    def pin(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g,
                            grad_shardings)

    def train_step(state: TrainState, batch):
        B = batch["labels"].shape[0]
        mb = microbatches
        assert B % mb == 0, (B, mb)

        def split(x):
            return x.reshape(mb, B // mb, *x.shape[1:])

        mbatch = jax.tree.map(split, batch)
        g_zero = pin(jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dt), state.params))

        def accum(carry, mb_batch):
            g_acc, loss_acc = carry
            # barrier the params INSIDE the loop body: the CPU backend
            # upcasts bf16 weights to f32 at each dot and LICM would
            # otherwise hoist those converts out of the scan, pinning
            # f32 copies of all expert weights for the whole step
            # (+5 GB/chip at jamba scale, §Perf log).  No-op on TPU
            # (bf16 feeds the MXU directly).
            # (tied to the loop-varying microbatch: a barrier over the
            # params alone is itself loop-invariant and hoists too)
            params_local, mb_batch = jax.lax.optimization_barrier(
                (state.params, mb_batch))
            loss, g = jax.value_and_grad(loss_fn)(
                params_local, mb_batch["inputs"], mb_batch["labels"],
                mb_batch["positions"])
            g_acc = pin(jax.tree.map(
                lambda a, b: a + (b / mb).astype(acc_dt), g_acc, pin(g)))
            return (g_acc, loss_acc + loss / mb), None

        (grads, loss), _ = jax.lax.scan(accum, (g_zero, 0.0), mbatch)
        if compress_grads:
            grads = decompress_gradients(compress_gradients(grads))
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads,
                             state.params)
        params, opt_state, om = adamw_update(state.params, grads,
                                             state.opt, opt)
        metrics = {"loss": loss, **om, "step": state.step + 1}
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step
