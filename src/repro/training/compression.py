"""Gradient compression for cross-pod (DCN) reductions.

int8 block-quantized gradients with per-block f32 scales: 4x less DCN
traffic for the pod-level all-reduce (the ICI-level reduce-scatter stays
full precision).  The quantize->reduce->dequantize round trip is modeled
here as quantize->dequantize (GSPMD inserts the actual reduction); tests
bound the quantization error and the training example verifies loss
still descends with compression on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(g):
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), g.shape, pad


def _dequantize(q, scale, shape, pad):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compress_gradients(grads):
    return jax.tree.map(_quantize, grads)


def decompress_gradients(compressed):
    return jax.tree.map(
        lambda t: _dequantize(*t), compressed,
        is_leaf=lambda t: isinstance(t, tuple) and len(t) == 4
        and hasattr(t[0], "dtype"))
