"""Sharded, atomic, async checkpointing + elastic restore.

Layout: one directory per step, one .npy per pytree leaf (flattened key
path), plus a JSON manifest with the treedef, shapes, dtypes and the
mesh the checkpoint was written under.  Writes go to ``<dir>.tmp`` and
are renamed atomically; an optional background thread makes the save
non-blocking (the train loop only syncs at the next save).

Elastic restore: leaves are stored unsharded (gathered); on restore they
are re-placed under the *current* mesh/shardings, so a checkpoint taken
on a 16x16 pod restarts cleanly on 8x16 (scale-down) or 2x16x16
(scale-up) — exercised in tests/test_training.py.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

# numpy can't natively serialize ml_dtypes; round-trip via a uint view
_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8}


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Atomic synchronous save; returns the final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    names, leaves, _ = _leaf_paths(tree)
    manifest = {"step": step, "leaves": []}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if dtype in _EXOTIC:
            arr = arr.view(_EXOTIC[dtype])
        fname = name.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"name": name, "file": fname, "shape": list(arr.shape),
             "dtype": dtype})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like: Any, step: Optional[int] = None,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; optionally re-place leaves
    under ``shardings`` (elastic mesh change)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    names, leaves, treedef = _leaf_paths(like)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    out = []
    shard_leaves = (jax.tree.leaves(
        shardings, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))
        if shardings is not None else [None] * len(leaves))
    for name, leaf, shd in zip(names, leaves, shard_leaves):
        entry = by_name[name]
        arr = np.load(os.path.join(path, entry["file"]))
        if entry["dtype"] in _EXOTIC:
            arr = arr.view(getattr(ml_dtypes, entry["dtype"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{name}: checkpoint shape {arr.shape} != {leaf.shape}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Async double-buffered manager with retention."""

    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        if self.async_save:
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(step, host_tree),
                daemon=True)
            self._thread.start()
        else:
            self._save_and_gc(step, host_tree)

    def _save_and_gc(self, step: int, tree: Any) -> None:
        save_checkpoint(self.directory, step, tree)
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        self.wait()
        return restore_checkpoint(self.directory, like, step, shardings)

    @property
    def latest(self) -> Optional[int]:
        return latest_step(self.directory)
