"""AdamW with configurable moment dtype + ZeRO sharding, WSD schedule.

Optimizer moments inherit the parameter's logical PartitionSpec and are
additionally FSDP-sharded over the data axis (ZeRO-1/3 hybrid) via
sharding.param_sharding(fsdp=True) — for the >=70B archs the moments are
kept in bf16 (cfg.opt_dtype), recorded per config so the dry-run memory
analysis reflects the real deployment plan.

WSD (warmup-stable-decay) is MiniCPM's schedule (arXiv:2404.06395) — the
one non-llama training detail of the assigned pool.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    grad_accum_dtype: str = "float32"
    # WSD schedule
    warmup_steps: int = 100
    stable_steps: int = 10_000
    decay_steps: int = 1_000
    min_lr_frac: float = 0.1


def wsd_schedule(step, opt: OptConfig):
    """Warmup -> stable -> (cosine) decay; returns lr multiplier."""
    step = jnp.asarray(step, jnp.float32)
    w, s, d = opt.warmup_steps, opt.stable_steps, opt.decay_steps
    warm = step / jnp.maximum(w, 1)
    in_decay = jnp.clip((step - w - s) / jnp.maximum(d, 1), 0.0, 1.0)
    decay = opt.min_lr_frac + (1 - opt.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(jnp.pi * in_decay))
    return jnp.where(step < w, warm, decay) * opt.lr


@dataclasses.dataclass
class AdamWState:
    mu: Any
    nu: Any
    count: jax.Array


def adamw_init(params, opt: OptConfig) -> AdamWState:
    dt = jnp.dtype(opt.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def global_norm(grads) -> jax.Array:
    leaves = jax.tree.leaves(grads)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw_update(params, grads, state: AdamWState, opt: OptConfig):
    # grad clipping is folded into the per-leaf update (the scale is a
    # scalar): a standalone clip pass materializes f32 copies of EVERY
    # grad leaf simultaneously (+5 GB/chip at jamba scale, §Perf log)
    gnorm = global_norm(grads)
    clip_scale = jnp.minimum(1.0, opt.grad_clip / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    lr = wsd_schedule(count, opt)
    b1, b2 = opt.beta1, opt.beta2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * clip_scale
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + opt.eps)
        step = step + opt.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * step
        return p2.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    # Leaf updates are CHAINED through optimization_barrier so the
    # scheduler cannot interleave them: unconstrained, every leaf's f32
    # upcast temporaries go live simultaneously (+12 GB/chip at jamba
    # scale — dry-run buffer-assignment dump, EXPERIMENTS.md §Perf).
    # Serializing bounds the live set to one leaf and lets buffer
    # assignment reuse the same f32 scratch for all of them.
    flat, treedef = jax.tree_util.tree_flatten(params)
    g_flat = treedef.flatten_up_to(grads)
    m_flat = treedef.flatten_up_to(state.mu)
    v_flat = treedef.flatten_up_to(state.nu)
    token = jnp.zeros((), jnp.float32)
    out_p, out_m, out_v = [], [], []
    # biggest leaves first: they dominate the arena high-water mark
    order = sorted(range(len(flat)), key=lambda i: -flat[i].size)
    results = [None] * len(flat)
    for i in order:
        # gate EVERY input on the token — gating only p lets the
        # scheduler hoist all m/v/g f32 converts to program start
        p, g, m, v, _ = jax.lax.optimization_barrier(
            (flat[i], g_flat[i], m_flat[i], v_flat[i], token))
        p2, m2, v2 = upd(p, g, m, v)
        token = jax.lax.optimization_barrier(
            (p2.ravel()[0].astype(jnp.float32), token))[0]
        results[i] = (p2, m2, v2)
    new_params = jax.tree_util.tree_unflatten(
        treedef, [r[0] for r in results])
    new_mu = jax.tree_util.tree_unflatten(treedef,
                                          [r[1] for r in results])
    new_nu = jax.tree_util.tree_unflatten(treedef,
                                          [r[2] for r in results])
    return new_params, AdamWState(new_mu, new_nu, count), \
        {"grad_norm": gnorm, "lr": lr}
