from repro.training.checkpoint import (  # noqa: F401
    CheckpointManager, restore_checkpoint, save_checkpoint,
)
from repro.training.compression import (  # noqa: F401
    compress_gradients, decompress_gradients,
)
from repro.training.optimizer import (  # noqa: F401
    AdamWState, OptConfig, adamw_init, adamw_update, clip_by_global_norm,
    wsd_schedule,
)
from repro.training.train_lib import (  # noqa: F401
    TrainState, make_train_step, train_state_specs,
)
