from repro.training.optimizer import (  # noqa: F401
    AdamWState, adamw_init, adamw_update, OptConfig, wsd_schedule,
    clip_by_global_norm,
)
from repro.training.train_lib import (  # noqa: F401
    make_train_step, TrainState, train_state_specs,
)
from repro.training.checkpoint import (  # noqa: F401
    CheckpointManager, save_checkpoint, restore_checkpoint,
)
from repro.training.compression import (  # noqa: F401
    compress_gradients, decompress_gradients,
)
