"""Fault tolerance + straggler mitigation for 1000+ node deployments.

The pieces a real multi-pod run needs, built so the control logic is
fully testable in this container:

  * HeartbeatMonitor — per-worker liveness with configurable timeout;
    on expiry the supervisor declares the worker dead.
  * StragglerDetector — per-step worker durations; a worker slower than
    ``threshold x`` the p50 for ``patience`` consecutive steps is flagged
    (real deployments swap it out / re-shard around it).
  * TrainingSupervisor — checkpoint/restart orchestration: runs the step
    function, saves every ``ckpt_every``, and on an injected/declared
    failure restores the latest checkpoint and continues, optionally on
    a *different* mesh shape (elastic scale-down: lost pod -> continue on
    the survivors).  tests/test_training.py exercises loss-continuity
    across a failure and a reshard.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class WorkerState:
    last_beat: float
    durations: List[float] = dataclasses.field(default_factory=list)
    flagged: int = 0


class HeartbeatMonitor:
    def __init__(self, workers: List[str], timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        self.workers: Dict[str, WorkerState] = {
            w: WorkerState(last_beat=clock()) for w in workers}

    def beat(self, worker: str) -> None:
        self.workers[worker].last_beat = self.clock()

    def dead_workers(self) -> List[str]:
        now = self.clock()
        return [w for w, s in self.workers.items()
                if now - s.last_beat > self.timeout_s]


class StragglerDetector:
    """Flags workers persistently slower than the fleet median."""

    def __init__(self, threshold: float = 1.5, patience: int = 3):
        self.threshold = threshold
        self.patience = patience
        self._flags: Dict[str, int] = {}

    def observe(self, step_durations: Dict[str, float]) -> List[str]:
        med = float(np.median(list(step_durations.values())))
        out = []
        for w, d in step_durations.items():
            if d > self.threshold * med:
                self._flags[w] = self._flags.get(w, 0) + 1
            else:
                self._flags[w] = 0
            if self._flags[w] >= self.patience:
                out.append(w)
        return out


@dataclasses.dataclass
class FailureEvent:
    step: int
    kind: str = "worker_loss"        # worker_loss | preemption
    new_mesh: Optional[tuple] = None  # elastic: continue on this mesh


class TrainingSupervisor:
    """Checkpoint/restart driver.  ``step_fn(state, batch) -> (state,
    metrics)``; ``reshard_fn(state, mesh_shape) -> state`` re-places the
    state for an elastic mesh change."""

    def __init__(self, step_fn: Callable, ckpt_manager, *,
                 ckpt_every: int = 10,
                 reshard_fn: Optional[Callable] = None):
        self.step_fn = step_fn
        self.ckpt = ckpt_manager
        self.ckpt_every = ckpt_every
        self.reshard_fn = reshard_fn
        self.restarts = 0
        self.log: List[dict] = []

    def run(self, state: Any, batches, *, start_step: int = 0,
            failures: Optional[List[FailureEvent]] = None) -> Any:
        failures = {f.step: f for f in (failures or [])}
        step = start_step
        for batch in batches:
            if step in failures:
                ev = failures.pop(step)
                self.restarts += 1
                latest = self.ckpt.latest
                if latest is None:
                    raise RuntimeError("failure before first checkpoint")
                state = self.ckpt.restore(state)
                step = latest
                if ev.new_mesh is not None and self.reshard_fn is not None:
                    state = self.reshard_fn(state, ev.new_mesh)
                self.log.append({"event": "restart", "from_step": latest,
                                 "kind": ev.kind, "mesh": ev.new_mesh})
                continue
            state, metrics = self.step_fn(state, batch)
            step += 1
            self.log.append({"event": "step", "step": step,
                             "loss": float(metrics["loss"])})
            if step % self.ckpt_every == 0:
                self.ckpt.save(step, state)
        self.ckpt.wait()
        return state
