from repro.models.transformer import (  # noqa: F401
    decode_forward, forward, init_cache, init_model,
)
