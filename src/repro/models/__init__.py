from repro.models.transformer import (  # noqa: F401
    init_model, init_cache, forward, decode_forward,
)
