"""GQA attention with RoPE / M-RoPE, sliding window, paged/slot KV decode.

Two execution paths:
  * ``ref``    — pure jnp (chunked, flash-style memory behaviour via
                 lax.scan over query chunks).  This is the path the
                 multi-pod dry-run lowers (XLA-native, shardable).
  * ``pallas`` — the TPU kernels in ``repro.kernels`` (flash_prefill /
                 paged_attention / unified_pd), validated in interpret mode.

Head-count padding: query heads are padded to a multiple of the TP degree;
KV heads are padded only when ``cfg.kv_shard_mode(tp) == "heads"`` (cost
<= 2x), otherwise the KV cache is sequence-sharded (context-parallel
decode).  Padded heads are real compute (recorded in the roofline's
useful-FLOPs ratio) — the logical model is unchanged.

Sliding-window attention stores a ring-buffer cache of ``window`` slots so
long-context decode reads O(window), not O(S).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import (ParamBuilder, apply_rope, mrope_cos_sin,
                                 rope_cos_sin)

NEG_INF = -1e30


def init_attention(b: ParamBuilder, cfg, tp: int):
    d = cfg.d_model
    hp = cfg.heads_padded(tp)
    kvp = cfg.kv_heads_padded(tp)
    D = cfg.head_dim
    kv_spec = "model" if cfg.kv_shard_mode(tp) == "heads" else None
    b.param("wq", (d, hp * D), (None, "model"))
    b.param("wk", (d, kvp * D), (None, kv_spec))
    b.param("wv", (d, kvp * D), (None, kv_spec))
    b.param("wo", (hp * D, d), ("model", None))
    if cfg.qkv_bias:
        b.param("bq", (hp * D,), ("model",), init="zeros")
        b.param("bk", (kvp * D,), (kv_spec,), init="zeros")
        b.param("bv", (kvp * D,), (kv_spec,), init="zeros")


def _qkv(params, cfg, x, tp, constrain=None):
    B, S, _ = x.shape
    constrain = constrain or (lambda a, spec: a)
    hp, kvp, D = cfg.heads_padded(tp), cfg.kv_heads_padded(tp), cfg.head_dim
    kv_spec = "model" if cfg.kv_shard_mode(tp) == "heads" else None
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = constrain(q, ("batch", None, "model"))
    k = constrain(k, ("batch", None, kv_spec))
    v = constrain(v, ("batch", None, kv_spec))
    return (q.reshape(B, S, hp, D), k.reshape(B, S, kvp, D),
            v.reshape(B, S, kvp, D))


def _rope(cfg, q, k, positions):
    """positions: (B, S) for rope, (B, S, 3) for mrope."""
    if cfg.rope_type == "none":
        return q, k
    if cfg.rope_type == "mrope":
        cos, sin = mrope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)
    else:
        cos, sin = rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)
    return (apply_rope(q, cos, sin).astype(q.dtype),
            apply_rope(k, cos, sin).astype(k.dtype))


def _gqa_scores(q, k):
    """q (B,Sq,Hq,D), k (B,Sk,Hkv,D) -> scores (B,Hkv,G,Sq,Sk)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    return jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / (D ** 0.5)


def _gqa_out(probs, v):
    """probs (B,Hkv,G,Sq,Sk), v (B,Sk,Hkv,D) -> (B,Sq,Hq,D)."""
    B, Hkv, G, Sq, Sk = probs.shape
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, Hkv * G, out.shape[-1])


def chunked_causal_attention(q, k, v, *, chunk_q: int = 512,
                             window: Optional[int] = None):
    """Causal (optionally sliding-window) attention, O(chunk_q * S) memory.

    lax.scan over query chunks keeps the peak score tensor at
    (B, H, chunk_q, S) — the XLA analogue of flash attention's memory
    behaviour, so 32K-token prefill fits on chip.
    """
    B, S, Hq, D = q.shape
    cq = min(chunk_q, S)
    if S % cq:
        cq = S  # fallback for tiny/odd shapes
    n_chunks = S // cq
    qc = q.reshape(B, n_chunks, cq, Hq, D).transpose(1, 0, 2, 3, 4)
    kpos = jnp.arange(S)

    # checkpointed: bwd recomputes each chunk's probs instead of saving
    # (B,H,cq,S) f32 for every chunk — flash-attention memory behaviour
    # in both directions.
    @jax.checkpoint
    def body(_, args):
        i, qi = args
        base = i * cq
        scores = _gqa_scores(qi, k)  # (B,Hkv,G,cq,S)
        qpos = base + jnp.arange(cq)
        mask = kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
        return None, _gqa_out(probs.astype(v.dtype), v)

    _, outs = jax.lax.scan(body, None, (jnp.arange(n_chunks), qc))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, Hq, D)


def full_attention(params, cfg, x, positions, tp, *, impl: str = "ref",
                   constrain=None):
    """Prefill / train path.  Returns (out, (k, v)) — k/v for cache write."""
    q, k, v = _qkv(params, cfg, x, tp, constrain)
    q, k = _rope(cfg, q, k, positions)
    if impl == "pallas":
        from repro.kernels import ops
        out = ops.flash_prefill(q, k, v, window=cfg.sliding_window)
    else:
        out = chunked_causal_attention(q, k, v, window=cfg.sliding_window)
    B, S = x.shape[:2]
    out = out.reshape(B, S, -1)
    return jnp.einsum("bsh,hd->bsd", out, params["wo"]), (k, v)


# ---------------------------------------------------------------------------
# Decode (slot-dense cache; ring buffer under sliding window)
# ---------------------------------------------------------------------------


def cache_shape(cfg, batch: int, max_seq: int, tp: int):
    S = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    return (batch, S, cfg.kv_heads_padded(tp), cfg.head_dim)


def decode_attention(params, cfg, x, positions, cache_k, cache_v, seq_lens,
                     tp, *, impl: str = "ref"):
    """One-token decode step.

    x (B, 1, d); positions (B, 1) or (B, 1, 3); cache_k/v
    (B, Scache, KVp, D); seq_lens (B,) = tokens already in cache.
    Returns (out (B,1,d), cache_k, cache_v).
    """
    B = x.shape[0]
    q, k1, v1 = _qkv(params, cfg, x, tp)
    q, k1 = _rope(cfg, q, k1, positions)
    Scache = cache_k.shape[1]
    w = cfg.sliding_window
    slot = (seq_lens % w) if w else seq_lens
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, slot].set(k1[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, slot].set(v1[:, 0].astype(cache_v.dtype))

    if impl == "pallas":
        from repro.kernels import ops
        out = ops.paged_attention_dense(q[:, 0], cache_k, cache_v,
                                        seq_lens + 1, window=w)
        out = out[:, None]
    else:
        scores = _gqa_scores(q, cache_k)  # (B,Hkv,G,1,Scache)
        kpos = jnp.arange(Scache)
        if w:
            valid = kpos[None, :] < jnp.minimum(seq_lens + 1, w)[:, None]
        else:
            valid = kpos[None, :] <= seq_lens[:, None]
        scores = jnp.where(valid[:, None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
        out = _gqa_out(probs.astype(cache_v.dtype), cache_v)
    out = out.reshape(B, 1, -1)
    return (jnp.einsum("bsh,hd->bsd", out, params["wo"]),
            cache_k, cache_v)


def prefill_into_cache(cache_k, cache_v, k, v, seq_lens=None, window=None):
    """Write a full prompt's K/V into the slot cache (left-aligned).

    k/v (B, S, KVp, D).  With a ring-buffer (window) cache only the last
    ``window`` tokens are kept, at their rotated slots.
    """
    B, S = k.shape[:2]
    if window:
        W = cache_k.shape[1]
        take = min(S, W)
        src_pos = jnp.arange(take) + max(S - W, 0)
        slots = src_pos % W
        cache_k = cache_k.at[:, slots].set(
            k[:, max(S - W, 0):].astype(cache_k.dtype))
        cache_v = cache_v.at[:, slots].set(
            v[:, max(S - W, 0):].astype(cache_v.dtype))
    else:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k.astype(cache_k.dtype), (0, 0, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v.astype(cache_v.dtype), (0, 0, 0, 0))
    return cache_k, cache_v
