"""Composable decoder assembly: layer_pattern x ffn_pattern over periods.

The model is a stack of ``num_layers`` blocks.  Blocks repeat with period
``cfg.period`` (lcm of the mixer and FFN patterns); parameters of repeated
periods are stacked on a leading axis and the forward pass is a
``lax.scan`` over periods (compile-time O(period), not O(num_layers) — a
94-layer qwen3-moe compiles as one 2-layer group scanned 47 times).

Block structure (pre-norm residual):
    x = x + mixer(rmsnorm(x))          mixer in {attn, mamba, mlstm, slstm}
    x = x + ffn(rmsnorm(x))            ffn in {dense, moe, none}
xLSTM mixers carry their own up/down projections, so xlstm archs use
ffn_pattern=("none",).

Two entry points:
  * ``forward``        — train / prefill over a full sequence.  With
                         ``return_aux=True`` also returns per-layer KV (attn)
                         or final recurrent state (mamba/xlstm) for cache
                         population — the serving prefill path.
  * ``decode_forward`` — one-token step against per-layer caches/states.

``inputs`` is either int32 tokens (B, S) or, for ``frontend='embed_stub'``
archs (audio/VLM backbones), precomputed float embeddings (B, S, d_model).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (ParamBuilder, embed_tokens, grad_barrier,
                                 init_embed, lm_logits, rmsnorm)

NEG_INF = -1e30

Constrain = Callable[[jax.Array, tuple], jax.Array]
_IDENTITY: Constrain = lambda a, spec: a


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(b: ParamBuilder, cfg, pos: int, tp: int):
    mixer = cfg.mixer_at(pos)
    b.scope("norm1").param("w", (cfg.d_model,), (None,), init="ones")
    mb = b.scope("mixer")
    if mixer == "attn":
        attn_mod.init_attention(mb, cfg, tp)
    elif mixer == "mamba":
        mamba_mod.init_mamba(mb, cfg)
    elif mixer == "mlstm":
        xlstm_mod.init_mlstm(mb, cfg)
    elif mixer == "slstm":
        xlstm_mod.init_slstm(mb, cfg)
    else:
        raise ValueError(mixer)
    ffn = cfg.ffn_at(pos)
    if ffn != "none":
        b.scope("norm2").param("w", (cfg.d_model,), (None,), init="ones")
        fb = b.scope("ffn")
        if ffn == "dense":
            moe_mod.init_dense_ffn(fb, cfg)
        elif ffn == "moe":
            moe_mod.init_moe(fb, cfg, tp)
        else:
            raise ValueError(ffn)


def init_model(rng: jax.Array, cfg, tp: int = 1):
    """Returns (params, logical_spec_tree); structurally identical trees.

    Layer params are stacked over periods: every leaf under ``layers`` has
    leading dim ``cfg.num_periods`` (spec axis None — FSDP shards a dim
    inside the original shape, see sharding.py).
    """
    import numpy as np
    dtype = jnp.dtype(cfg.dtype)
    b = ParamBuilder(rng, dtype=dtype)
    init_embed(b, cfg)
    period_params = []
    period_specs = None
    for p in range(cfg.num_periods):
        pb = ParamBuilder(jax.random.fold_in(rng, 1000 + p), dtype=dtype)
        for pos in range(cfg.period):
            _init_block(pb.scope(f"pos{pos}"), cfg, pos, tp)
        period_params.append(pb.params)
        period_specs = pb.specs
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *period_params)
    b.params["layers"] = stacked
    b.specs["layers"] = jax.tree.map(
        lambda s: (None,) + tuple(s), period_specs,
        is_leaf=lambda s: isinstance(s, tuple))
    return b.params, b.specs


def init_model_shapes(rng, cfg, tp: int = 1):
    """ShapeDtypeStruct tree of the params (no allocation) + spec tree."""
    closure = {}

    def f(r):
        p, s = init_model(r, cfg, tp)
        closure["specs"] = s
        return p

    shapes = jax.eval_shape(f, rng)
    return shapes, closure["specs"]


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg, inputs, constrain: Constrain):
    if cfg.frontend == "embed_stub":
        x = inputs.astype(jnp.dtype(cfg.dtype))
    else:
        x = embed_tokens(params, cfg, inputs)
        x = constrain(x, ("batch", None, None))
    return x


def _block_forward(p, cfg, pos, x, positions, tp, impl, constrain,
                   collect_aux: bool):
    mixer = cfg.mixer_at(pos)
    h = rmsnorm(x, p["norm1"]["w"], cfg.norm_eps)
    # Megatron-SP boundary: gather S at block entry so the block computes
    # TP-sharded (d/heads over "model"); without this hint GSPMD keeps S
    # sharded and ALL-GATHERS THE WEIGHTS instead (full f32 dW replicas
    # on every chip — +35 GB at jamba scale, dry-run §Perf log).
    h = constrain(h, ("batch", None, None))
    aux = None
    if mixer == "attn":
        out, (k, v) = attn_mod.full_attention(
            p["mixer"], cfg, h, positions, tp, impl=impl,
            constrain=constrain)
        if collect_aux:
            aux = {"k": k, "v": v}
    elif mixer == "mamba":
        out, state = mamba_mod.mamba_forward(
            p["mixer"], cfg, h, return_state=True, impl=impl,
            constrain=constrain)
        if collect_aux:
            aux = state
    elif mixer == "mlstm":
        out, state = xlstm_mod.mlstm_forward(
            p["mixer"], cfg, h, return_state=True)
        if collect_aux:
            aux = state
    elif mixer == "slstm":
        out, state = xlstm_mod.slstm_forward(
            p["mixer"], cfg, h, return_state=True)
        if collect_aux:
            aux = state
    x = x + out
    if cfg.ffn_at(pos) != "none":
        h = rmsnorm(x, p["norm2"]["w"], cfg.norm_eps)
        h = constrain(h, ("batch", None, None))   # SP gather (see above)
        if cfg.ffn_at(pos) == "dense":
            y = moe_mod.dense_ffn(p["ffn"], cfg, h, constrain=constrain)
        else:
            # collect_aux == the serving-prefill path -> inference
            # capacity policy (generation must not drop tokens)
            y = moe_mod.moe_ffn(p["ffn"], cfg, h, constrain=constrain,
                                inference=collect_aux)
        x = x + y
    # Megatron-style sequence parallelism: the inter-block residual is
    # sharded on S over the model axis ("seq" -> "model" under training
    # rules) so the per-period remat checkpoints are TP-sharded instead
    # of replicated — 16x smaller saved activations (see §Perf log).
    x = constrain(x, ("batch", "seq", None))
    return x, aux


def forward(params, cfg, inputs, positions, tp: int = 1, *,
            impl: str = "ref", return_aux: bool = False,
            constrain: Constrain = _IDENTITY, remat: bool = False,
            last_only: bool = False):
    """Full-sequence forward.  Returns logits (B,S,vocab_padded), or
    (logits, aux) with ``return_aux`` where aux is the per-period stacked
    tree of per-position KV / final state (the serving prefill products).
    ``last_only`` computes the LM head on the final position only (the
    serving prefill path — full 32K-position logits would be ~100s of GB).
    """
    x = _embed_inputs(params, cfg, inputs, constrain)

    # Per-LAYER remat (not per-period): inside a period's backward every
    # position's weight-gradient is live simultaneously; for jamba's
    # period of 8 that was ~30 GB/chip of f32 dW temporaries (dry-run
    # §Perf log).  Checkpointing each block bounds live dW to one layer.
    block = _block_forward
    if remat:
        block = jax.checkpoint(
            partial(_block_forward), prevent_cse=False,
            static_argnums=(1, 2, 5, 6, 7, 8))

    def period_body(x, layer_p):
        layer_p, x = grad_barrier((layer_p, x))
        auxes = {}
        for pos in range(cfg.period):
            x, aux = block(layer_p[f"pos{pos}"], cfg, pos, x,
                           positions, tp, impl, constrain, return_aux)
            if return_aux:
                auxes[f"pos{pos}"] = aux
        return x, (auxes if return_aux else None)

    x, aux = jax.lax.scan(period_body, x, params["layers"])
    if last_only:
        x = x[:, -1:]
    logits = lm_logits(params, cfg, x)
    logits = constrain(logits, ("batch", None, "model"))
    if return_aux:
        return logits, aux
    return logits


def lm_loss(params, cfg, tokens_or_embeds, labels, positions, tp: int = 1, *,
            impl: str = "ref", constrain: Constrain = _IDENTITY,
            remat: bool = True, ce_chunk: int = 512):
    """Next-token cross entropy; padded vocab columns masked out.

    The LM head + CE run CHUNKED over the sequence (checkpointed scan):
    full (B,S,V) f32 logits at qwen3/train_4k scale are ~0.6 GB/chip and
    the CE's exp/log temporaries multiply that several times (dry-run
    §Perf log); chunking caps it at (B,ce_chunk,V/​tp).
    """
    # run the trunk WITHOUT the LM head
    x = _embed_inputs(params, cfg, tokens_or_embeds, constrain)

    block = _block_forward
    if remat:
        block = jax.checkpoint(
            partial(_block_forward), prevent_cse=False,
            static_argnums=(1, 2, 5, 6, 7, 8))

    def period_body(x, layer_p):
        # barrier ties the sliced layer params to the loop-varying carry
        # so the CPU backend cannot hoist f32 upcasts of the WHOLE
        # stacked weights out of the scan (§Perf log; no-op on TPU)
        layer_p, x = grad_barrier((layer_p, x))
        for pos in range(cfg.period):
            x, _ = block(layer_p[f"pos{pos}"], cfg, pos, x, positions,
                         tp, impl, constrain, False)
        return x, None

    x, _ = jax.lax.scan(period_body, x, params["layers"])

    B, S, _ = x.shape
    c = min(ce_chunk, S)
    while S % c:
        c -= 1
    nc = S // c
    xc = x.reshape(B, nc, c, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, c).transpose(1, 0, 2)
    pad_mask = (jnp.arange(cfg.vocab_padded) >= cfg.vocab_size
                if cfg.vocab_padded != cfg.vocab_size else None)

    @jax.checkpoint
    def ce_chunk_body(acc, args):
        xi, li = args
        logits = lm_logits(params, cfg, xi).astype(jnp.float32)
        logits = constrain(logits, ("batch", None, "model"))
        if pad_mask is not None:
            logits = jnp.where(pad_mask, NEG_INF, logits)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(ce_chunk_body, jnp.zeros((), jnp.float32),
                            (xc, lc))
    return total / (B * S)


# ---------------------------------------------------------------------------
# Caches + decode
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_seq: int, tp: int = 1,
               dtype=None):
    """Per-layer cache tree, leaves stacked over periods (leading dim P)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    per_pos = {}
    for pos in range(cfg.period):
        mixer = cfg.mixer_at(pos)
        if mixer == "attn":
            shape = attn_mod.cache_shape(cfg, batch, max_seq, tp)
            per_pos[f"pos{pos}"] = {"k": jnp.zeros(shape, dtype),
                                    "v": jnp.zeros(shape, dtype)}
        elif mixer == "mamba":
            m = cfg.mamba
            per_pos[f"pos{pos}"] = {
                "conv": jnp.zeros((batch, m.d_conv - 1, cfg.d_inner), dtype),
                "ssm": jnp.zeros((batch, cfg.d_inner, m.d_state),
                                 jnp.float32),
            }
        elif mixer == "mlstm":
            per_pos[f"pos{pos}"] = xlstm_mod.mlstm_init_state(cfg, batch)
        elif mixer == "slstm":
            per_pos[f"pos{pos}"] = xlstm_mod.slstm_init_state(cfg, batch)
    P = cfg.num_periods
    return jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf, (P,) + leaf.shape), per_pos)


def cache_specs(cfg, tp: int = 1):
    """Logical PartitionSpecs for the cache tree (mirrors init_cache)."""
    kv_spec = ("model" if cfg.kv_shard_mode(tp) == "heads" else None)
    per_pos = {}
    for pos in range(cfg.period):
        mixer = cfg.mixer_at(pos)
        if mixer == "attn":
            # "seq" resolves to the data axis for long-context decode
            # (context-parallel KV) and to None otherwise (sharding.py)
            s = (None, "batch", "seq", kv_spec, None)
            per_pos[f"pos{pos}"] = {"k": s, "v": s}
        elif mixer == "mamba":
            per_pos[f"pos{pos}"] = {
                "conv": (None, "batch", None, "model"),
                "ssm": (None, "batch", "model", None)}
        elif mixer == "mlstm":
            per_pos[f"pos{pos}"] = {"C": (None, "batch", None, None, None),
                                    "n": (None, "batch", None, None),
                                    "m": (None, "batch", None)}
        elif mixer == "slstm":
            per_pos[f"pos{pos}"] = {k: (None, "batch", None)
                                    for k in ("c", "n", "h", "m")}
    return per_pos


def write_prefill_to_cache(cfg, cache, aux, seq_len: int):
    """Populate a fresh cache tree from ``forward(return_aux=True)`` aux.

    attn: K/V written left-aligned (ring-rotated under sliding window);
    recurrent mixers: final state replaces the zero state.
    """
    out = {}
    for pos in range(cfg.period):
        key = f"pos{pos}"
        mixer = cfg.mixer_at(pos)
        if mixer == "attn":
            out[key] = {"k": _write_kv(cache[key]["k"], aux[key]["k"],
                                       cfg.sliding_window),
                        "v": _write_kv(cache[key]["v"], aux[key]["v"],
                                       cfg.sliding_window)}
        else:
            out[key] = jax.tree.map(
                lambda c, s: s.astype(c.dtype).reshape(c.shape),
                cache[key], aux[key])
    return out


def _write_kv(cache, kv, window):
    """cache (P,B,Sc,H,D); kv (P,B,S,H,D)."""
    P = cache.shape[0]
    def one(c, x):
        ck, _ = attn_mod.prefill_into_cache(c, c, x, x, window=window)
        return ck
    return jax.vmap(one)(cache, kv)


def decode_forward(params, cfg, inputs, positions, cache, seq_lens,
                   tp: int = 1, *, impl: str = "ref",
                   constrain: Constrain = _IDENTITY):
    """One-token decode.  inputs (B,1) tokens or (B,1,d) embeds;
    positions (B,1) or (B,1,3); seq_lens (B,) tokens already cached.
    Returns (logits (B,1,vocab_padded), new_cache).
    """
    x = _embed_inputs(params, cfg, inputs, constrain)

    # The cache rides the scan CARRY (not xs/ys): a while-loop carry that
    # is dynamic-update-sliced in place aliases to a single buffer, where
    # an xs->ys cache would double-buffer ~5 GB/chip at decode_32k scale
    # (measured in the dry-run; see EXPERIMENTS.md §Dry-run notes).
    def period_body(carry, scanned):
        x, cache = carry
        layer_p, idx = scanned
        layer_p, x = grad_barrier((layer_p, x))
        new_c = {}
        layer_c = jax.tree.map(
            lambda t: jax.lax.dynamic_index_in_dim(t, idx, 0,
                                                   keepdims=False), cache)
        for pos in range(cfg.period):
            p = layer_p[f"pos{pos}"]
            c = layer_c[f"pos{pos}"]
            mixer = cfg.mixer_at(pos)
            h = rmsnorm(x, p["norm1"]["w"], cfg.norm_eps)
            if mixer == "attn":
                out, ck, cv = attn_mod.decode_attention(
                    p["mixer"], cfg, h, positions, c["k"], c["v"],
                    seq_lens, tp, impl=impl)
                new_c[f"pos{pos}"] = {"k": ck, "v": cv}
            elif mixer == "mamba":
                out, st = mamba_mod.mamba_decode_step(p["mixer"], cfg, h, c)
                new_c[f"pos{pos}"] = st
            elif mixer == "mlstm":
                out, st = xlstm_mod.mlstm_decode_step(p["mixer"], cfg, h, c)
                new_c[f"pos{pos}"] = st
            elif mixer == "slstm":
                out, st = xlstm_mod.slstm_decode_step(p["mixer"], cfg, h, c)
                new_c[f"pos{pos}"] = st
            x = x + out
            if cfg.ffn_at(pos) != "none":
                h = rmsnorm(x, p["norm2"]["w"], cfg.norm_eps)
                if cfg.ffn_at(pos) == "dense":
                    y = moe_mod.dense_ffn(p["ffn"], cfg, h,
                                          constrain=constrain)
                else:
                    y = moe_mod.moe_ffn(p["ffn"], cfg, h,
                                        constrain=constrain, dropless=True)
                x = x + y
        cache = jax.tree.map(
            lambda full, nc: jax.lax.dynamic_update_index_in_dim(
                full, nc.astype(full.dtype), idx, 0), cache, new_c)
        return (x, cache), None

    P_ = cfg.num_periods
    (x, new_cache), _ = jax.lax.scan(
        period_body, (x, cache),
        (params["layers"], jnp.arange(P_, dtype=jnp.int32)))
    logits = lm_logits(params, cfg, x)
    return logits, new_cache


def greedy_sample(logits, vocab_size: int):
    """Argmax over the unpadded vocab.  logits (B,1,Vp) -> (B,1) int32."""
    v = logits[..., :vocab_size]
    return jnp.argmax(v, axis=-1).astype(jnp.int32)
