"""Mamba (S6) selective-state-space mixer.

Train/prefill: chunked associative scan — the sequence is processed in
chunks; within a chunk the linear recurrence h_t = a_t * h_{t-1} + b_t is
computed with ``jax.lax.associative_scan`` and the state is carried across
chunks with ``lax.scan``.  Memory is O(chunk * d_inner * d_state) instead
of O(L * d_inner * d_state).

Decode: O(1) single-step state update; recurrent state = (conv window,
SSM state) — this replaces the KV cache for Mamba layers and flows through
the same decode-owned allocation protocol as KV (DESIGN.md §5).

The TPU hot path is the Pallas kernel in repro/kernels/ssm_scan.py; this
module is the shardable XLA reference used by the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamBuilder


def init_mamba(b: ParamBuilder, cfg):
    m = cfg.mamba
    d, din, R = cfg.d_model, cfg.d_inner, cfg.dt_rank
    b.param("in_proj", (d, 2 * din), (None, "model"))
    b.param("conv_w", (m.d_conv, din), (None, "model"))
    b.param("conv_b", (din,), ("model",), init="zeros")
    b.param("x_proj", (din, R + 2 * m.d_state), ("model", None))
    b.param("dt_proj", (R, din), (None, "model"))
    b.param("dt_bias", (din,), ("model",), init="zeros")
    b.param("A_log", (din, m.d_state), ("model", None),
            init=lambda rng, shape: jnp.log(jnp.broadcast_to(
                jnp.arange(1, shape[1] + 1, dtype=jnp.float32), shape)),
            dtype=jnp.float32)
    b.param("D", (din,), ("model",), init="ones", dtype=jnp.float32)
    b.param("out_proj", (din, d), ("model", None))


def _ssm_inputs(params, cfg, xs):
    """xs (B, L, din) -> dt (B,L,din), Bm/Cm (B,L,ds) in f32."""
    m = cfg.mamba
    R = cfg.dt_rank
    dbc = jnp.einsum("bld,dr->blr", xs, params["x_proj"])
    dt, Bm, Cm = jnp.split(dbc, [R, R + m.d_state], axis=-1)
    dt = jnp.einsum("blr,rd->bld", dt, params["dt_proj"]) + params["dt_bias"]
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def _causal_conv(params, cfg, x, conv_state=None):
    """Depthwise causal conv.  x (B, L, din)."""
    m = cfg.mamba
    w = params["conv_w"]  # (d_conv, din)
    if conv_state is not None:
        x = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    else:
        x = jnp.pad(x, ((0, 0), (m.d_conv - 1, 0), (0, 0)))
    out = sum(x[:, i:i + x.shape[1] - m.d_conv + 1] * w[i]
              for i in range(m.d_conv))
    return out + params["conv_b"]


def mamba_forward(params, cfg, x, *, chunk: int = 256, state=None,
                  return_state: bool = False, impl: str = "ref",
                  constrain=None):
    """x (B, L, d_model) -> (B, L, d_model).

    ``state``: optional dict(conv (B, d_conv-1, din), ssm (B, din, ds)).
    """
    m = cfg.mamba
    constrain = constrain or (lambda a, spec: a)
    B, L, _ = x.shape
    din = cfg.d_inner
    xz = jnp.einsum("bld,dk->blk", x, params["in_proj"])
    xz = constrain(xz, ("batch", None, "model"))  # keep din TP-sharded
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    xs = jax.nn.silu(_causal_conv(params, cfg, xs, conv_state))
    xs = constrain(xs, ("batch", None, "model"))
    dt, Bm, Cm = _ssm_inputs(params, cfg, xs)
    dt = constrain(dt, ("batch", None, "model"))
    A = -jnp.exp(params["A_log"])  # (din, ds)

    if impl == "pallas":
        from repro.kernels import ops
        h0 = state["ssm"] if state is not None else None
        y, h_last = ops.ssm_scan(xs.astype(jnp.float32), dt, A, Bm, Cm, h0=h0)
    else:
        y, h_last = ssm_scan_ref(xs.astype(jnp.float32), dt, A, Bm, Cm,
                                 chunk=chunk,
                                 h0=state["ssm"] if state is not None
                                 else None)
    y = y + xs.astype(jnp.float32) * params["D"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bld,dk->blk", y, params["out_proj"])
    if return_state:
        tail = xz[:, L - (m.d_conv - 1):, :din] if L >= m.d_conv - 1 else None
        new_state = {
            "conv": _conv_tail(params, cfg, state, xz[..., :din]),
            "ssm": h_last,
        }
        return out, new_state
    return out


def _conv_tail(params, cfg, state, xs_raw):
    """Last (d_conv - 1) pre-activation conv inputs, for decode continuity."""
    m = cfg.mamba
    k = m.d_conv - 1
    B, L, din = xs_raw.shape
    if state is not None:
        full = jnp.concatenate([state["conv"].astype(xs_raw.dtype), xs_raw],
                               axis=1)
    else:
        full = jnp.pad(xs_raw, ((0, 0), (k, 0), (0, 0)))
    return full[:, full.shape[1] - k:]


def ssm_scan_ref(xs, dt, A, Bm, Cm, *, chunk: int = 256, h0=None):
    """Chunked associative scan for h_t = a_t h_{t-1} + b_t; y_t = C_t.h_t.

    xs/dt (B,L,din) f32; A (din,ds); Bm/Cm (B,L,ds).
    Returns y (B,L,din) f32 and final state (B,din,ds).

    The chunk body is jax.checkpoint'ed: scan-AD then saves only the
    per-chunk carry h (B,din,ds — tiny) instead of the (B,c,din,ds)
    prefix-product tensors for EVERY chunk, which at jamba train scale
    is ~8.6 GB/chip/layer (dry-run §Perf log).
    """
    B, L, din = xs.shape
    ds = A.shape[1]
    c = min(chunk, L)
    while L % c:
        c -= 1
    nc = L // c

    def reshape(t):
        return t.reshape(B, nc, c, *t.shape[2:]).transpose(1, 0, 2,
                                                           *range(3, t.ndim + 1))

    xs_c, dt_c, B_c, C_c = map(reshape, (xs, dt, Bm, Cm))
    h_init = h0.astype(jnp.float32) if h0 is not None else \
        jnp.zeros((B, din, ds), jnp.float32)

    @jax.checkpoint
    def chunk_body(h, args):
        xc, dc, bc, cc = args  # (B,c,din), (B,c,din), (B,c,ds), (B,c,ds)
        a = jnp.exp(dc[..., None] * A)            # (B,c,din,ds)
        b = (dc * xc)[..., None] * bc[:, :, None]  # (B,c,din,ds)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        A_pref, B_pref = jax.lax.associative_scan(combine, (a, b), axis=1)
        h_t = A_pref * h[:, None] + B_pref        # (B,c,din,ds)
        y = jnp.einsum("bcds,bcs->bcd", h_t, cc)
        return h_t[:, -1], y

    h_last, ys = jax.lax.scan(chunk_body, h_init, (xs_c, dt_c, B_c, C_c))
    y = ys.transpose(1, 0, 2, 3).reshape(B, L, din)
    return y, h_last


def mamba_decode_step(params, cfg, x, state):
    """Single-token decode.  x (B, 1, d); state {conv (B,k,din), ssm}."""
    m = cfg.mamba
    B = x.shape[0]
    din = cfg.d_inner
    xz = jnp.einsum("bld,dk->blk", x, params["in_proj"])
    xs_raw, z = jnp.split(xz, 2, axis=-1)          # (B,1,din)
    conv_in = jnp.concatenate([state["conv"].astype(x.dtype), xs_raw], axis=1)
    w = params["conv_w"]
    xs = sum(conv_in[:, i] * w[i] for i in range(m.d_conv)) + params["conv_b"]
    xs = jax.nn.silu(xs)[:, None]                   # (B,1,din)
    dt, Bm, Cm = _ssm_inputs(params, cfg, xs)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt[:, 0, :, None] * A)              # (B,din,ds)
    b = (dt[:, 0] * xs[:, 0].astype(jnp.float32))[..., None] * \
        Bm[:, 0, None]
    h = a * state["ssm"] + b
    y = jnp.einsum("bds,bs->bd", h, Cm[:, 0])
    y = y + xs[:, 0].astype(jnp.float32) * params["D"]
    y = y[:, None].astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bld,dk->blk", y, params["out_proj"])
    new_state = {"conv": conv_in[:, 1:], "ssm": h}
    return out, new_state
