"""Mixture-of-Experts FFN with grouped top-k dispatch (T5X/Mesh-style).

Tokens are processed in groups of ``group_size``; per group each expert has
capacity ``ceil(g * top_k / E * capacity_factor)``.  Dispatch/combine are
one-hot einsums — fully static shapes, shardable under GSPMD.

Expert partitioning:
  * ``ep`` (experts % tp == 0): expert dim sharded over the model axis;
    GSPMD materializes the token all-to-all at the dispatch einsum.
  * ``tp`` (else, e.g. Mixtral 8e over 16 chips): every expert's hidden dim
    sharded over the model axis (pure tensor parallelism).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import ParamBuilder, act_fn


def moe_partition(cfg, tp: int) -> str:
    """'ep': experts sharded over the data axis (GShard all-to-all
    dataflow) + hidden over model.  'tp': experts replicated over data
    with d FSDP-sharded (contracted dim -> cheap per-layer weight
    all-gather) + hidden over model — used when E doesn't divide the
    data axis (e.g. Mixtral's 8 experts on a 16-wide axis)."""
    m = cfg.moe
    if m.partition != "auto":
        return m.partition
    return "ep" if m.num_experts % tp == 0 else "tp"


def init_moe(b: ParamBuilder, cfg, tp: int):
    m = cfg.moe
    d, E, f = cfg.d_model, m.num_experts, m.d_ff_expert
    if moe_partition(cfg, tp) == "ep":
        w_spec_in = ("expert", None, "model")
        w_spec_out = ("expert", "model", None)
    else:
        w_spec_in = (None, "data", "model")
        w_spec_out = (None, "model", "data")
    b.param("router", (d, E), (None, None))
    b.param("w_gate", (E, d, f), w_spec_in)
    b.param("w_in", (E, d, f), w_spec_in)
    b.param("w_out", (E, f, d), w_spec_out)


def moe_ffn(params, cfg, x, *, group_size: int = 256, constrain=None,
            dropless: bool = False, inference: bool = False):
    """x (B, S, d) -> (B, S, d).

    Capacity policy (a dropped token corrupts *generation*, but is a
    mild regularizer in *training* — Switch):
      * dropless=True   — C = group size, exact; used for decode and any
        small-group path (cheap there).
      * inference=True  — serving prefill: capacity factor boosted to
        >= 2.0 (P(drop) is ~4-sigma-rare at group>=256) and exact
        dropless for small groups.  Exact sort-based dropless dispatch
        is future kernel work (DESIGN.md §8).
      * default         — training: cfg capacity_factor (1.25).
    """
    m = cfg.moe
    act = act_fn(cfg.act)
    constrain = constrain or (lambda a, spec: a)
    B, S, d = x.shape
    T = B * S
    g = min(group_size, T)
    while T % g:
        g -= 1
    N = T // g
    E, k = m.num_experts, m.top_k
    if inference and g <= 64:
        dropless = True
    if dropless:
        C = g
    else:
        cf = max(m.capacity_factor, 2.0) if inference else m.capacity_factor
        C = max(1, math.ceil(g * k / E * cf))

    xg = x.reshape(N, g, d)
    logits = jnp.einsum("ngd,de->nge", xg, params["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_k, idx_k = jax.lax.top_k(probs, k)          # (N,g,k)
    gate_k = gate_k / jnp.clip(gate_k.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's capacity buffer
    onehot_e = jax.nn.one_hot(idx_k, E, dtype=jnp.float32)   # (N,g,k,E)
    flat = onehot_e.reshape(N, g * k, E)
    pos = jnp.cumsum(flat, axis=1) - 1.0                      # (N,g*k,E)
    pos = pos.reshape(N, g, k, E)
    in_cap = (pos < C) & (onehot_e > 0)
    pos_cap = jnp.clip(pos, 0, C - 1).astype(jnp.int32)
    onehot_c = jax.nn.one_hot(pos_cap, C, dtype=jnp.float32)  # (N,g,k,E,C)
    combine = jnp.einsum("ngk,ngke,ngkec->ngec",
                         gate_k.astype(jnp.float32),
                         (onehot_e * in_cap).astype(jnp.float32), onehot_c)
    dispatch = (combine > 0).astype(x.dtype)                  # (N,g,E,C)

    ep = (m.num_experts % 16 == 0 if m.partition == "auto"
          else m.partition == "ep")
    if ep:
        # expert-space layout: e sharded ("expert" -> data axis), token
        # d sharded over model (the capacity buffers stay 1/(ep*tp)
        # sized), n replicated — the n@data -> e@data reshard IS the
        # GShard dispatch all-to-all.
        in_spec = (None, "expert", None, "model")
        h_spec = (None, "expert", None, "model")
    else:
        # 'tp' layout: experts replicated, tokens stay data-sharded,
        # hidden on model; expert weights FSDP-gathered per layer.
        in_spec = ("batch", None, None, None)
        h_spec = ("batch", None, None, "model")
    # dispatch: compute locally in token space (n@data), THEN reshard to
    # expert space (e@data) — the back-to-back constraints force GSPMD to
    # lower the reshard as an all-to-all moving 1/|data| of the tokens;
    # constraining only the einsum output lets it all-gather ALL tokens
    # to every chip in f32 instead (2 GB/chip at jamba scale, §Perf log).
    expert_in = jnp.einsum("ngec,ngd->necd", dispatch, xg)
    expert_in = constrain(expert_in, ("batch", None, None, None))
    expert_in = constrain(expert_in, in_spec)
    h = act(jnp.einsum("necd,edf->necf", expert_in, params["w_gate"])) * \
        jnp.einsum("necd,edf->necf", expert_in, params["w_in"])
    h = constrain(h, h_spec)
    out = jnp.einsum("necf,efd->necd", h, params["w_out"])
    out = constrain(out, in_spec)
    out = constrain(out, ("batch", None, None, None))  # combine: back to
    y = jnp.einsum("ngec,necd->ngd", combine.astype(x.dtype), out)
    y = constrain(y, ("batch", None, None))            # token space
    return y.reshape(B, S, d)


def init_dense_ffn(b: ParamBuilder, cfg):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.ffn_glu:
        b.param("w_gate", (d, f), (None, "model"))
    b.param("w_in", (d, f), (None, "model"))
    b.param("w_out", (f, d), ("model", None))


def dense_ffn(params, cfg, x, constrain=None):
    act = act_fn(cfg.act)
    constrain = constrain or (lambda a, spec: a)
    if cfg.ffn_glu:
        h = act(jnp.einsum("bsd,df->bsf", x, params["w_gate"])) * \
            jnp.einsum("bsd,df->bsf", x, params["w_in"])
    else:
        h = act(jnp.einsum("bsd,df->bsf", x, params["w_in"]))
    # pin the hidden to TP-sharded: left to itself GSPMD sometimes picks
    # full-f activations + replicated dW (dry-run §Perf log)
    h = constrain(h, ("batch", None, "model"))
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"])
