"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM.

mLSTM — pre-up-projection block (proj factor 2).  Training/prefill uses the
*chunkwise-recurrent* formulation: within a chunk the gated attention-like
quadratic form is evaluated in parallel; the stabilized matrix state
(C, n, m) is carried across chunks with ``lax.scan``.  Decode is the O(1)
recurrent update.  This is the linear-cost analogue of the paper's parallel
form and shares its numerics (exp input gate, sigmoid-in-log-space forget
gate, max-stabilizer m).

sLSTM — scalar memory with head-block-diagonal recurrent connections; it is
inherently sequential (h_{t-1} feeds the gates), so prefill is a
``lax.scan`` over tokens.  Post-up-projection GLU (proj factor 4/3) follows
the cell, per the xLSTM paper.

States replace the KV cache for these layers and flow through the same
decode-owned allocation protocol (DESIGN.md §5).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import ParamBuilder

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg):
    x = cfg.xlstm
    din = int(x.proj_factor * cfg.d_model)
    H = x.num_heads
    # pad inner dim to a multiple of heads
    din = int(math.ceil(din / H) * H)
    return din, H, din // H


def init_mlstm(b: ParamBuilder, cfg):
    d = cfg.d_model
    din, H, _ = _mlstm_dims(cfg)
    b.param("w_up", (d, 2 * din), (None, "model"))
    b.param("wq", (din, din), (None, "model"))
    b.param("wk", (din, din), (None, "model"))
    b.param("wv", (din, din), (None, "model"))
    # per-head scalar gates from the pre-projection features
    b.param("w_i", (din, H), (None, None))
    b.param("b_i", (H,), (None,), init="zeros")
    b.param("w_f", (din, H), (None, None))
    b.param("b_f", (H,), (None,),
            init=lambda rng, shape: jnp.full(shape, 3.0, jnp.float32))
    b.param("w_down", (din, d), ("model", None))


def mlstm_init_state(cfg, batch: int, dtype=jnp.float32):
    din, H, Dh = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, H, Dh, Dh), dtype),
        "n": jnp.zeros((batch, H, Dh), dtype),
        "m": jnp.full((batch, H), NEG_INF, dtype),
    }


def _mlstm_qkvgates(params, cfg, x):
    """x (B,L,d) -> q,k,v (B,L,H,Dh); log_i, log_f (B,L,H) f32."""
    din, H, Dh = _mlstm_dims(cfg)
    B, L, _ = x.shape
    up = jnp.einsum("bld,dk->blk", x, params["w_up"])
    xs, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("blk,kj->blj", xs, params["wq"]).reshape(B, L, H, Dh)
    k = jnp.einsum("blk,kj->blj", xs, params["wk"]).reshape(B, L, H, Dh)
    k = k / (Dh ** 0.5)
    v = jnp.einsum("blk,kj->blj", xs, params["wv"]).reshape(B, L, H, Dh)
    xs32 = xs.astype(jnp.float32)
    log_i = jnp.einsum("blk,kh->blh", xs32,
                       params["w_i"].astype(jnp.float32)) + params["b_i"]
    pre_f = jnp.einsum("blk,kh->blh", xs32,
                       params["w_f"].astype(jnp.float32)) + params["b_f"]
    log_f = jax.nn.log_sigmoid(pre_f)
    return q, k, v, log_i, log_f, z


def mlstm_chunk_scan(q, k, v, log_i, log_f, state, *, chunk: int = 128):
    """Chunkwise-recurrent mLSTM.  q/k/v (B,L,H,Dh), gates (B,L,H) f32.

    Returns h (B,L,H,Dh) and the final (C, n, m) state.
    """
    B, L, H, Dh = q.shape
    c = min(chunk, L)
    while L % c:
        c -= 1
    nc = L // c

    def chunked(t):
        return t.reshape(B, nc, c, *t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1))

    qc, kc, vc = map(chunked, (q, k, v))
    lic, lfc = map(chunked, (log_i, log_f))

    @jax.checkpoint
    def body(carry, args):
        C0, n0, m0 = carry
        qt, kt, vt, li, lf = args          # (B,c,H,*)
        qt32 = qt.astype(jnp.float32)
        kt32 = kt.astype(jnp.float32)
        vt32 = vt.astype(jnp.float32)
        F = jnp.cumsum(lf, axis=1)          # (B,c,H) inclusive log-f prefix
        # intra-chunk decay matrix D[t,s] = F_t - F_s + log i_s for s<=t
        Dmat = (F[:, :, None] - F[:, None, :] + li[:, None, :, :])
        tri = jnp.tril(jnp.ones((c, c), bool))
        Dmat = jnp.where(tri[None, :, :, None], Dmat, NEG_INF)  # (B,t,s,H)
        m_intra = jnp.max(Dmat, axis=2)                  # (B,c,H)
        m_inter = F + m0[:, None]                        # (B,c,H)
        m_t = jnp.maximum(m_intra, m_inter)
        w_intra = jnp.exp(Dmat - m_t[:, :, None])        # (B,t,s,H)
        w_inter = jnp.exp(m_inter - m_t)                 # (B,c,H)
        scores = jnp.einsum("bthd,bshd->btsh", qt32, kt32)
        num = jnp.einsum("btsh,btsh,bshd->bthd", scores, w_intra, vt32)
        num += w_inter[..., None] * jnp.einsum("bthd,bhde->bthe", qt32, C0)
        den = jnp.einsum("btsh,btsh->bth", scores, w_intra)
        den += w_inter * jnp.einsum("bthd,bhd->bth", qt32, n0)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # ---- state to end of chunk -------------------------------------
        Fc = F[:, -1]                                     # (B,H)
        decay_s = Fc[:, None] - F + li                    # (B,c,H)
        m_new = jnp.maximum(Fc + m0, jnp.max(decay_s, axis=1))
        w_s = jnp.exp(decay_s - m_new[:, None])           # (B,c,H)
        w_0 = jnp.exp(Fc + m0 - m_new)                    # (B,H)
        C_new = w_0[..., None, None] * C0 + \
            jnp.einsum("bsh,bshd,bshe->bhde", w_s, kt32, vt32)
        n_new = w_0[..., None] * n0 + jnp.einsum("bsh,bshd->bhd", w_s, kt32)
        return (C_new, n_new, m_new), h

    init = (state["C"].astype(jnp.float32), state["n"].astype(jnp.float32),
            state["m"].astype(jnp.float32))
    (C, n, m), hs = jax.lax.scan(body, init, (qc, kc, vc, lic, lfc))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, L, H, Dh)
    return h, {"C": C, "n": n, "m": m}


def mlstm_forward(params, cfg, x, *, state=None, return_state=False,
                  chunk: int = 128):
    B, L, _ = x.shape
    din, H, Dh = _mlstm_dims(cfg)
    q, k, v, log_i, log_f, z = _mlstm_qkvgates(params, cfg, x)
    st = state if state is not None else mlstm_init_state(cfg, B)
    h, new_state = mlstm_chunk_scan(q, k, v, log_i, log_f, st, chunk=chunk)
    h = h.reshape(B, L, din).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("blk,kd->bld", h, params["w_down"])
    if return_state:
        return out, new_state
    return out


def mlstm_decode_step(params, cfg, x, state):
    """x (B,1,d) -> (out (B,1,d), state).  O(1) recurrent update."""
    B = x.shape[0]
    din, H, Dh = _mlstm_dims(cfg)
    q, k, v, log_i, log_f, z = _mlstm_qkvgates(params, cfg, x)
    q1, k1, v1 = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    li, lf = log_i[:, 0], log_f[:, 0]                    # (B,H)
    m0, C0, n0 = state["m"], state["C"], state["n"]
    m_t = jnp.maximum(lf + m0, li)
    fp = jnp.exp(lf + m0 - m_t)
    ip = jnp.exp(li - m_t)
    C = fp[..., None, None] * C0 + \
        ip[..., None, None] * jnp.einsum("bhd,bhe->bhde", k1, v1)
    n = fp[..., None] * n0 + ip[..., None] * k1
    num = jnp.einsum("bhd,bhde->bhe", q1, C)
    den = jnp.einsum("bhd,bhd->bh", q1, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
    h = h.reshape(B, 1, din).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("blk,kd->bld", h, params["w_down"])
    return out, {"C": C, "n": n, "m": m_t}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def _slstm_dims(cfg):
    H = cfg.xlstm.num_heads
    d = cfg.d_model
    assert d % H == 0
    return H, d // H


def init_slstm(b: ParamBuilder, cfg):
    d = cfg.d_model
    H, Dh = _slstm_dims(cfg)
    for g in ("z", "i", "f", "o"):
        b.param(f"w_{g}", (d, d), (None, "model"))
        # head-block-diagonal recurrent weights
        b.param(f"r_{g}", (H, Dh, Dh), (None, None, None),
                scale=1.0 / math.sqrt(Dh))
        b.param(f"b_{g}", (d,), (None,),
                init="zeros" if g != "f" else
                (lambda rng, shape: jnp.full(shape, 3.0, jnp.float32)))
    # post-up-projection GLU (factor 4/3)
    f = int(math.ceil(4 * d / 3 / 64) * 64)
    b.param("up_gate", (d, f), (None, "model"))
    b.param("up", (d, f), (None, "model"))
    b.param("down", (f, d), ("model", None))


def slstm_init_state(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), dtype),
        "n": jnp.zeros((batch, d), dtype),
        "h": jnp.zeros((batch, d), dtype),
        "m": jnp.full((batch, d), NEG_INF, dtype),
    }


def _slstm_cell(params, cfg, xt, st, wx=None):
    """One step.  xt (B,d) f32; state leaves (B,d) f32.

    ``wx``: precomputed input projections {g: (B,d)} — the W_g·x_t terms
    are NOT recurrent and must be batched outside the token scan: inside
    it, their weight-gradient all-reduce runs once PER TOKEN per layer
    (360 GB/chip/step at xlstm/train_4k, §Perf log)."""
    H, Dh = _slstm_dims(cfg)
    B, d = xt.shape
    hprev = st["h"].reshape(B, H, Dh)

    def gate(g):
        w = wx[g] if wx is not None else \
            xt @ params[f"w_{g}"].astype(jnp.float32)
        rh = jnp.einsum("bhd,hde->bhe", hprev,
                        params[f"r_{g}"].astype(jnp.float32)).reshape(B, d)
        return w + rh + params[f"b_{g}"].astype(jnp.float32)

    z = jnp.tanh(gate("z"))
    log_i = gate("i")
    log_f = jax.nn.log_sigmoid(gate("f"))
    o = jax.nn.sigmoid(gate("o"))
    m_t = jnp.maximum(log_f + st["m"], log_i)
    fp = jnp.exp(log_f + st["m"] - m_t)
    ip = jnp.exp(log_i - m_t)
    c = fp * st["c"] + ip * z
    n = fp * st["n"] + ip
    h = o * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h, "m": m_t}


def slstm_forward(params, cfg, x, *, state=None, return_state=False,
                  chunk: int = 64):
    """x (B,L,d).  Sequential over tokens (inherent recurrence), but:
    input projections are batched OUTSIDE the scan, and the scan runs in
    checkpointed chunks so the recurrent-weight grad reduction happens
    per chunk, not per token."""
    B, L, d = x.shape
    st = state if state is not None else slstm_init_state(cfg, B)
    st = {k: v.astype(jnp.float32) for k, v in st.items()}
    x32 = x.astype(jnp.float32)
    # batched, non-recurrent input projections (L, B, d) per gate
    wx_all = {g: jnp.einsum("bld,de->lbe", x32,
                            params[f"w_{g}"].astype(jnp.float32))
              for g in ("z", "i", "f", "o")}

    c = min(chunk, L)
    while L % c:
        c -= 1
    nc = L // c

    def tok_body(s, wx_t):
        s2 = _slstm_cell(params, cfg, s["h"], s, wx=wx_t)
        return s2, s2["h"]

    @jax.checkpoint
    def chunk_body(s, wx_c):
        return jax.lax.scan(tok_body, s, wx_c)

    wx_chunks = jax.tree.map(
        lambda t: t.reshape(nc, c, B, d), wx_all)
    st, hs = jax.lax.scan(chunk_body, st, wx_chunks)
    h = hs.reshape(L, B, d).transpose(1, 0, 2).astype(x.dtype)  # (B,L,d)
    y = jax.nn.silu(jnp.einsum("bld,df->blf", h, params["up_gate"])) * \
        jnp.einsum("bld,df->blf", h, params["up"])
    out = jnp.einsum("blf,fd->bld", y, params["down"])
    if return_state:
        return out, st
    return out


def slstm_decode_step(params, cfg, x, state):
    """x (B,1,d)."""
    st = {k: v.astype(jnp.float32) for k, v in state.items()}
    s2 = _slstm_cell(params, cfg, x[:, 0].astype(jnp.float32), st)
    h = s2["h"][:, None].astype(x.dtype)
    y = jax.nn.silu(jnp.einsum("bld,df->blf", h, params["up_gate"])) * \
        jnp.einsum("bld,df->blf", h, params["up"])
    out = jnp.einsum("blf,fd->bld", y, params["down"])
    return out, s2
