"""Primitive layers: norms, dense, embeddings, RoPE / M-RoPE, activations.

All parameter creation goes through a ``ParamBuilder`` so that every leaf is
born with a logical PartitionSpec; the spec tree always matches the param
tree structurally (asserted in tests).

Logical sharding axes used below (translated to mesh axes in sharding.py):
  "model"  -> tensor-parallel axis
  "data"   -> ZeRO / batch axis (params: only opt-state dim0)
  None     -> replicated
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


class ParamBuilder:
    """Builds a params pytree and a parallel logical-spec pytree."""

    def __init__(self, rng: jax.Array, dtype=jnp.bfloat16):
        self._rng = rng
        self.dtype = dtype
        self.params: dict = {}
        self.specs: dict = {}

    def _next_rng(self):
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def scope(self, name: str) -> "ParamBuilder":
        child = ParamBuilder.__new__(ParamBuilder)
        child._rng = self._next_rng()
        child.dtype = self.dtype
        child.params = self.params.setdefault(name, {})
        child.specs = self.specs.setdefault(name, {})
        return child

    def param(self, name: str, shape, spec, init="normal", scale=None,
              dtype=None):
        dtype = dtype or self.dtype
        if init == "normal":
            scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
            arr = (jax.random.normal(self._next_rng(), shape, jnp.float32)
                   * scale).astype(dtype)
        elif init == "zeros":
            arr = jnp.zeros(shape, dtype)
        elif init == "ones":
            arr = jnp.ones(shape, dtype)
        elif callable(init):
            arr = init(self._next_rng(), shape).astype(dtype)
        else:
            raise ValueError(init)
        self.params[name] = arr
        self.specs[name] = spec
        return arr


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


@jax.custom_vjp
def grad_barrier(xs):
    """``optimization_barrier`` with a straight-through gradient.

    ``jax.lax.optimization_barrier`` has no differentiation rule; the
    scan bodies barrier (layer_params, carry) to stop LICM hoisting f32
    upcasts of the whole stacked weights out of the loop, and that sits
    on the grad path of every train step.  The barrier is semantically
    the identity, so the VJP is the identity too — the CSE/LICM-blocking
    effect is preserved on the forward (primal) computation.
    """
    return jax.lax.optimization_barrier(xs)


def _grad_barrier_fwd(xs):
    return jax.lax.optimization_barrier(xs), None


def _grad_barrier_bwd(_, cts):
    return (cts,)


grad_barrier.defvjp(_grad_barrier_fwd, _grad_barrier_bwd)


def rmsnorm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * w


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_cos_sin(positions, head_dim: int, theta: float, dtype=jnp.float32):
    """positions (..., S) -> cos/sin (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x (..., S, H, D); cos/sin (..., S, D/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # add head dim
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def mrope_cos_sin(positions3, head_dim: int, theta: float,
                  sections=(2, 1, 1), dtype=jnp.float32):
    """M-RoPE (Qwen2-VL): positions3 (..., S, 3) = (t, h, w) ids.

    The rotary half-dim is split into `sections` (proportional) chunks; each
    chunk rotates with its own position stream.  For text tokens the three
    streams coincide and this reduces to standard RoPE.
    """
    half = head_dim // 2
    tot = sum(sections)
    sizes = [half * s // tot for s in sections]
    sizes[-1] = half - sum(sizes[:-1])
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    coss, sins = [], []
    off = 0
    for i, sz in enumerate(sizes):
        pos = positions3[..., i]
        ang = pos[..., None].astype(jnp.float32) * freqs[off:off + sz]
        coss.append(jnp.cos(ang))
        sins.append(jnp.sin(ang))
        off += sz
    return (jnp.concatenate(coss, -1).astype(dtype),
            jnp.concatenate(sins, -1).astype(dtype))


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def init_embed(b: ParamBuilder, cfg):
    eb = b.scope("embed")
    eb.param("tok", (cfg.vocab_padded, cfg.d_model), ("model", None),
             scale=1.0)
    if not cfg.tie_embeddings:
        hb = b.scope("lm_head")
        hb.param("w", (cfg.d_model, cfg.vocab_padded), (None, "model"))
    fb = b.scope("final_norm")
    fb.param("w", (cfg.d_model,), (None,), init="ones")


def embed_tokens(params, cfg, tokens):
    return jnp.take(params["embed"]["tok"], tokens, axis=0)


def lm_logits(params, cfg, x):
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].T
    else:
        w = params["lm_head"]["w"]
    x = rmsnorm(x, params["final_norm"]["w"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", x, w)
