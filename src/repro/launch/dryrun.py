import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:  jit(step, in_shardings=..., donate=...)
                    .lower(**ShapeDtypeStruct stand-ins)
                    .compile()
then print memory_analysis() (fits 16 GB/chip?) and cost_analysis()
(FLOPs/bytes for §Roofline), plus the parsed collective-byte breakdown.

Usage:
    python -m repro.launch.dryrun --arch llama3-70b --shape decode_32k
    python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init) — 512 placeholder CPU devices back the
16x16 (single-pod) and 2x16x16 (multi-pod) meshes.  Nothing here
allocates a real buffer: params/caches enter as ShapeDtypeStructs.
"""
import argparse
import dataclasses
import json
import sys
import time

import jax  # noqa: F401  (must initialize under the XLA_FLAGS above)

from repro.config import SHAPES, get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze
from repro.launch.steps import build_cell, cells_for_arch


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             verbose: bool = True, **cell_kw):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.size
    t0 = time.time()
    cell = build_cell(cfg, shape, mesh, **cell_kw)
    with mesh:
        lowered = cell.lower()
        compiled = lowered.compile()
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    terms = analyze(compiled, cfg, shape, mesh_name, chips)
    if verbose:
        print(f"== {arch} x {shape_name} x {mesh_name} "
              f"(compile {dt:.1f}s)")
        print(f"   memory_analysis: {mem}")
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        print(f"   cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
        tc, tm, tl = terms.terms()
        print(f"   roofline: compute={tc*1e3:.2f}ms memory={tm*1e3:.2f}ms "
              f"collective={tl*1e3:.2f}ms -> {terms.bottleneck}-bound; "
              f"useful-FLOPs={terms.useful_flops_ratio:.2f} "
              f"peak_mem/chip={terms.peak_mem_per_chip/2**30:.2f}GiB")
        print(f"   collectives: " + ", ".join(
            f"{k}={v/2**20:.0f}MiB" for k, v in
            sorted(terms.coll_by_op.items())) if terms.coll_by_op
            else "   collectives: none")
    return terms


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, choices=list_archs() + [None])
    p.add_argument("--shape", default=None,
                   choices=list(SHAPES) + [None])
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--json", default=None)
    args = p.parse_args(argv)

    results = []
    failures = []
    if args.all:
        archs = list_archs()
    elif args.arch:
        archs = [args.arch]
    else:
        p.error("--arch or --all required")
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([SHAPES[args.shape]] if args.shape
                  else cells_for_arch(cfg))
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_cell(arch, shape.name, mp))
                except Exception as e:  # noqa: BLE001 - report & continue
                    failures.append((arch, shape.name, mp, repr(e)))
                    print(f"!! FAILED {arch} x {shape.name} "
                          f"(multi_pod={mp}): {e}", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump([dataclasses.asdict(r) for r in results], f,
                      indent=1)
    print(f"\n{len(results)} cells compiled, {len(failures)} failed")
    for f_ in failures:
        print("  FAIL:", *f_[:3])
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
