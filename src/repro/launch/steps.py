"""Cell builders: (arch x input-shape x mesh) -> jitted step + arg specs.

Every dry-run/launch entry point goes through ``build_cell``; it returns
the step function, ShapeDtypeStruct stand-ins for all inputs (no device
allocation — the shannon/kernels pattern), and the in/out shardings.

Shapes lower:
  train_4k     -> train_step(state, batch)       (donates state)
  prefill_32k  -> prefill_step(params, inputs, positions)
  decode_32k   -> serve_step(params, inputs, positions, cache, seq_lens)
  long_500k    -> serve_step with context-parallel KV (seq->data axis)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.config import SHAPES, ModelConfig, ShapeConfig
from repro.models.transformer import (cache_specs, decode_forward, forward,
                                      init_cache, init_model)
from repro.sharding import (ShardingRules, make_constrain, param_sharding,
                            rules_for_mesh, spec_to_pspec)
from repro.training.optimizer import OptConfig
from repro.training.train_lib import (TrainState, make_train_step,
                                      train_state_specs)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    step_fn: Any
    args: tuple              # ShapeDtypeStruct pytrees
    in_shardings: tuple
    donate_argnums: tuple = ()

    def jitted(self):
        return jax.jit(self.step_fn, in_shardings=self.in_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jitted().lower(*self.args)


SMALL_MODEL_PARAMS = 2_000_000_000


def _small_dp_only(cfg: Optional[ModelConfig], shape: ShapeConfig) -> bool:
    """§Perf hillclimb #3: sub-2B models (xlstm-125m) are pathologically
    over-sharded at TP=16 (96-wide matmul shards).  Replicate the
    weights (250 MB) and run pure DP: 2x prefill MFU, zero serving
    collectives, and — after hoisting the sLSTM input projections out
    of its token scan (whose in-loop dW all-reduce initially made this
    look like a regression, see §Perf iteration 3) — a 1.34x faster
    train step than TP-16 as well."""
    return (cfg is not None and cfg.param_count() < SMALL_MODEL_PARAMS)


def _rules(mesh: Mesh, shape: ShapeConfig,
           cfg: Optional[ModelConfig] = None) -> ShardingRules:
    rules = rules_for_mesh(mesh)
    if _small_dp_only(cfg, shape):
        axes = (("pod", "data", "model") if "pod" in mesh.axis_names
                else ("data", "model"))
        if shape.global_batch % mesh.size:
            axes = axes[:-1]
        return dataclasses.replace(rules, model=None, expert=None,
                                   data=None, batch=axes)
    if shape.kind == "long_decode":
        # context parallelism: shard the KV/cache sequence dim over the
        # (otherwise idle at batch=1) data axis
        rules = dataclasses.replace(rules, seq="data")
    elif shape.kind == "train":
        # sequence parallelism: inter-block residuals (and their remat
        # checkpoints) shard S over the TP axis
        rules = dataclasses.replace(rules, seq="model")
    return rules


def _tp_for(cfg: ModelConfig, mesh: Mesh,
            shape: Optional[ShapeConfig] = None) -> int:
    """Effective TP degree: 1 under the small-model DP-only serving
    policy (no head/vocab padding, no TP collectives)."""
    if shape is not None and _small_dp_only(cfg, shape):
        return 1
    return mesh.shape["model"]


def _serve_fsdp(cfg: ModelConfig, mesh: Mesh) -> bool:
    """§Perf hillclimb #1: FSDP-sharded weights force a full weight
    all-gather EVERY decode token (llama decode_32k: 16.5 GB/chip/step,
    0.33 s of ICI time vs a 46 ms memory floor).  Serve TP-only whenever
    the per-chip TP shard fits in HBM with room for KV."""
    from repro.perfmodel.hw import TPU_V5E
    tp = mesh.shape["model"]
    per_chip = cfg.param_count() * 2 / tp
    return per_chip > 0.75 * TPU_V5E.hbm_bytes


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def _input_tokens(cfg: ModelConfig, B: int, S: int):
    if cfg.frontend == "embed_stub":
        return _sds((B, S, cfg.d_model), cfg.dtype)
    return _sds((B, S), "int32")


def _positions(cfg: ModelConfig, B: int, S: int):
    if cfg.rope_type == "mrope":
        return _sds((B, S, 3), "int32")
    return _sds((B, S), "int32")


def _input_sharding(cfg, mesh, rules, sds, batch_axes):
    return NamedSharding(mesh, spec_to_pspec(batch_axes, mesh, rules,
                                             sds.shape))


def input_specs(arch_cfg: ModelConfig, shape: ShapeConfig):
    """Public helper: ShapeDtypeStructs for every model input of a cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {
            "inputs": _input_tokens(arch_cfg, B, S),
            "labels": _sds((B, S), "int32"),
            "positions": _positions(arch_cfg, B, S),
        }
    if shape.kind == "prefill":
        return {
            "inputs": _input_tokens(arch_cfg, B, S),
            "positions": _positions(arch_cfg, B, S),
        }
    # decode / long_decode: one new token against an S-token cache
    return {
        "inputs": _input_tokens(arch_cfg, B, 1),
        "positions": _positions(arch_cfg, B, 1),
        "seq_lens": _sds((B,), "int32"),
    }


# ---------------------------------------------------------------------------
# Cells
# ---------------------------------------------------------------------------


def build_train_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     *, fsdp: bool = True, impl: str = "ref") -> Cell:
    tp = _tp_for(cfg, mesh, shape)
    rules = _rules(mesh, shape, cfg)
    constrain = make_constrain(mesh, rules)
    # bf16 moments AND bf16 gradient accumulation for the archs whose
    # f32 state would not fit 16 GB/chip (config-recorded deployment plan)
    opt = OptConfig(moment_dtype=cfg.opt_dtype,
                    grad_accum_dtype=cfg.opt_dtype)
    # microbatch count adapts to the mesh: per-microbatch batch is kept
    # at the minimum that still shards over all data-parallel rows
    # (the full mesh under the small-model DP-only policy)
    dp_total = mesh.size // tp
    mb = max(1, min(cfg.train_microbatches,
                    shape.global_batch // dp_total))

    rng = jax.random.PRNGKey(0)
    closure = {}

    def init(r):
        p, s = init_model(r, cfg, tp)
        closure["specs"] = s
        from repro.training.optimizer import adamw_init
        return TrainState(p, adamw_init(p, opt), jnp.zeros((), jnp.int32))

    state_sds = jax.eval_shape(init, rng)
    spec_state = train_state_specs(closure["specs"])
    state_shardings = param_sharding(spec_state, state_sds, mesh,
                                     rules=rules, fsdp=fsdp)
    step = make_train_step(cfg, opt, tp, microbatches=mb, impl=impl,
                           constrain=constrain, remat=True,
                           grad_shardings=state_shardings.params)

    ins = input_specs(cfg, shape)
    batch_sds = {"inputs": ins["inputs"], "labels": ins["labels"],
                 "positions": ins["positions"]}
    bsh = {
        "inputs": _input_sharding(cfg, mesh, rules, ins["inputs"],
                                  ("batch",) + (None,) *
                                  (len(ins["inputs"].shape) - 1)),
        "labels": _input_sharding(cfg, mesh, rules, ins["labels"],
                                  ("batch", None)),
        "positions": _input_sharding(cfg, mesh, rules, ins["positions"],
                                     ("batch",) + (None,) *
                                     (len(ins["positions"].shape) - 1)),
    }
    return Cell(cfg.name, shape.name, step, (state_sds, batch_sds),
                (state_shardings, bsh), donate_argnums=(0,))


def _param_setup(cfg, mesh, rules, fsdp, shape=None):
    tp = _tp_for(cfg, mesh, shape)
    rng = jax.random.PRNGKey(0)
    closure = {}

    def init(r):
        p, s = init_model(r, cfg, tp)
        closure["specs"] = s
        return p

    p_sds = jax.eval_shape(init, rng)
    p_shard = param_sharding(closure["specs"], p_sds, mesh, rules=rules,
                             fsdp=fsdp)
    return tp, p_sds, p_shard


def build_prefill_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       *, fsdp: bool = None, impl: str = "ref") -> Cell:
    if fsdp is None:
        fsdp = _serve_fsdp(cfg, mesh)
    rules = _rules(mesh, shape, cfg)
    constrain = make_constrain(mesh, rules)
    tp, p_sds, p_shard = _param_setup(cfg, mesh, rules, fsdp, shape)

    def prefill_step(params, inputs, positions):
        logits, aux = forward(params, cfg, inputs, positions, tp,
                              impl=impl, return_aux=True,
                              constrain=constrain, last_only=True)
        return logits, aux

    ins = input_specs(cfg, shape)
    ish = {
        "inputs": _input_sharding(cfg, mesh, rules, ins["inputs"],
                                  ("batch",) + (None,) *
                                  (len(ins["inputs"].shape) - 1)),
        "positions": _input_sharding(cfg, mesh, rules, ins["positions"],
                                     ("batch",) + (None,) *
                                     (len(ins["positions"].shape) - 1)),
    }
    return Cell(cfg.name, shape.name, prefill_step,
                (p_sds, ins["inputs"], ins["positions"]),
                (p_shard, ish["inputs"], ish["positions"]))


def build_decode_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      *, fsdp: bool = None, impl: str = "ref") -> Cell:
    if fsdp is None:
        fsdp = _serve_fsdp(cfg, mesh)
    rules = _rules(mesh, shape, cfg)
    constrain = make_constrain(mesh, rules)
    tp, p_sds, p_shard = _param_setup(cfg, mesh, rules, fsdp, shape)
    B, S = shape.global_batch, shape.seq_len

    cache_sds = jax.eval_shape(lambda: init_cache(cfg, B, S, tp))
    c_specs = cache_specs(cfg, tp)
    c_shard = param_sharding(c_specs, cache_sds, mesh, rules=rules,
                             fsdp=False)

    def serve_step(params, inputs, positions, cache, seq_lens):
        logits, new_cache = decode_forward(params, cfg, inputs, positions,
                                           cache, seq_lens, tp, impl=impl,
                                           constrain=constrain)
        return logits, new_cache

    ins = input_specs(cfg, shape)
    ish = {
        "inputs": _input_sharding(cfg, mesh, rules, ins["inputs"],
                                  ("batch",) + (None,) *
                                  (len(ins["inputs"].shape) - 1)),
        "positions": _input_sharding(cfg, mesh, rules, ins["positions"],
                                     ("batch",) + (None,) *
                                     (len(ins["positions"].shape) - 1)),
        "seq_lens": _input_sharding(cfg, mesh, rules, ins["seq_lens"],
                                    ("batch",)),
    }
    return Cell(cfg.name, shape.name, serve_step,
                (p_sds, ins["inputs"], ins["positions"], cache_sds,
                 ins["seq_lens"]),
                (p_shard, ish["inputs"], ish["positions"], c_shard,
                 ish["seq_lens"]),
                donate_argnums=(3,))


def build_fused_pd_cell(cfg: ModelConfig, mesh: Mesh, *,
                        prefill_batch: int = 2, prefill_seq: int = 4096,
                        decode_batch: int = 64, decode_ctx: int = 8192,
                        fsdp: bool = None, impl: str = "ref") -> Cell:
    """The RAPID concurrent step as ONE XLA program: the prefill subgraph
    and the decode subgraph are data-disjoint, so XLA is free to
    interleave decode's HBM-bound attention with prefill's MXU-bound
    GEMMs — the fused-overlap analogue of the paper's two HW queues
    (DESIGN.md §2).  Used by the §Perf hillclimb."""
    if fsdp is None:
        fsdp = _serve_fsdp(cfg, mesh)
    shape = ShapeConfig("fused_pd", decode_ctx, decode_batch, "decode")
    rules = _rules(mesh, shape, cfg)
    constrain = make_constrain(mesh, rules)
    tp, p_sds, p_shard = _param_setup(cfg, mesh, rules, fsdp, shape)
    Bp, Sp, Bd, Sc = prefill_batch, prefill_seq, decode_batch, decode_ctx

    def fused_step(params, p_inputs, p_positions, d_inputs, d_positions,
                   cache, seq_lens):
        p_logits, aux = forward(params, cfg, p_inputs, p_positions, tp,
                                impl=impl, return_aux=True,
                                constrain=constrain, last_only=True)
        d_logits, new_cache = decode_forward(params, cfg, d_inputs,
                                             d_positions, cache, seq_lens,
                                             tp, impl=impl,
                                             constrain=constrain)
        return p_logits, aux, d_logits, new_cache

    cache_sds = jax.eval_shape(lambda: init_cache(cfg, Bd, Sc, tp))
    c_shard = param_sharding(cache_specs(cfg, tp), cache_sds, mesh,
                             rules=rules, fsdp=False)
    args = (p_sds, _input_tokens(cfg, Bp, Sp), _positions(cfg, Bp, Sp),
            _input_tokens(cfg, Bd, 1), _positions(cfg, Bd, 1),
            cache_sds, _sds((Bd,), "int32"))

    def bsh(sds):
        return _input_sharding(cfg, mesh, rules, sds,
                               ("batch",) + (None,) * (len(sds.shape) - 1))

    shardings = (p_shard, bsh(args[1]), bsh(args[2]), bsh(args[3]),
                 bsh(args[4]), c_shard, bsh(args[6]))
    return Cell(cfg.name, "fused_pd", fused_step, args, shardings,
                donate_argnums=(5,))


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               **kw) -> Cell:
    if shape.kind == "train":
        return build_train_cell(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_cell(cfg, shape, mesh, **kw)
    return build_decode_cell(cfg, shape, mesh, **kw)


def cells_for_arch(cfg: ModelConfig):
    """The shape list for an arch: decode/long shapes obey the
    sub-quadratic / family rules (DESIGN.md §5)."""
    shapes = [SHAPES["train_4k"], SHAPES["prefill_32k"],
              SHAPES["decode_32k"]]
    if cfg.sub_quadratic:
        shapes.append(SHAPES["long_500k"])
    return shapes
