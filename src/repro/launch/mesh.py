"""Production meshes.  A FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before any jax initialization."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod: 2 pods = 512 chips.

    Axes: "model" = TP inside a pod (ICI); "data" = DP/FSDP inside a pod
    (ICI); "pod" = outermost DP across pods (DCN) — parameter all-gathers
    never cross the pod boundary (sharding.py rules).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(tp: int = 1):
    """Single-process mesh for CPU examples/tests (1 device)."""
    n = len(jax.devices())
    tp = min(tp, n)
    return jax.make_mesh((n // tp, tp), ("data", "model"))
