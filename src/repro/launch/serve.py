"""Serving launcher: run RAPID / hybrid / disagg on a trace and report
throughput, goodput and tail latencies (the paper's §5 methodology).

    python -m repro.launch.serve --arch llama3-70b --trace lmsys \
        --qps 8 --duration 60 --mode rapid

Multi-replica cluster serving (shared virtual clock, pluggable router):

    python -m repro.launch.serve --arch llama3-70b --trace lmsys \
        --qps 24 --replicas 4 --router least_loaded --mode rapid

``--mix rapid,rapid,hybrid`` overrides ``--mode``/``--replicas`` with an
explicit per-replica engine list; heterogeneous fleets use
``mode:COUNTxCHIPS`` groups with the BucketServe-style router:

    python -m repro.launch.serve --arch llama3-70b --trace loogle \
        --qps 8 --mix rapid:2x16,rapid:1x32 --router bucketed \
        --admission --rebalance

``--admission`` enables KV-aware admission control (queue/redirect/
reject arrivals that would overflow a replica's block pool — for disagg
replicas the transient prefill pool is projected too);
``--rebalance`` enables the cross-replica preemption/migration tick.

``--scale-policy`` turns on SLO-driven autoscaling: ``reactive`` is the
trailing TTFT-attainment window, ``projection`` forecasts TTFT/ITL from
each replica's live load via the perfmodel and scales before violations
happen — including growing a disagg replica's prefill and decode chip
pools independently.  Per-pool fleet shapes use ``mode:COUNTxP+D``:

    python -m repro.launch.serve --arch llama3-70b --trace lmsys \
        --qps 16 --mix disagg:2x12+20 --scale-policy projection \
        --max-replicas 4

``--serve http`` starts the online gateway instead of replaying a
trace: an asyncio front-end with admission, routing, heartbeat health
checks and crash failover, streaming each request's typed event stream
as JSON lines (serving/gateway.py + serving/http.py):

    python -m repro.launch.serve --arch llama3-70b --mode rapid \
        --replicas 2 --serve http --port 8080
    curl -N -X POST http://127.0.0.1:8080/v1/generate \
        -d '{"prompt_len": 512, "max_new_tokens": 64}'

Engine logic is real; step durations come from the calibrated TPU-v5e
perfmodel (this container has no accelerator — DESIGN.md §6).  Use
examples/serve_real.py for actual on-CPU token generation with a
reduced model.
"""
from __future__ import annotations

import argparse
import copy
import json

from repro.config import SLOConfig, ServeConfig, get_config, list_archs
from repro.core import make_engine
from repro.serving import (ROUTERS, TRACES, AdmissionPolicy,
                           ProjectionPolicy, RebalancePolicy, ScalePolicy,
                           StreamMetrics, diurnal_rate, flash_crowd_rate,
                           generate_multiclass_trace, generate_trace,
                           parse_mix, run_fleet)


def _serve_config(mode: str, chips: int, slo: SLOConfig, chunk: int,
                  max_slots: int) -> ServeConfig:
    return ServeConfig(mode=mode, chips=chips, slo=slo,
                       chunk_size=chunk,
                       disagg_split=(chips // 2, chips // 2),
                       max_batch_slots=max_slots)


def run_one(arch: str, mode: str, trace: str, qps: float, duration: float,
            chips: int, slo_itl_ms: float, chunk: int = 512,
            seed: int = 0, max_slots: int = 128):
    cfg = get_config(arch)
    slo = SLOConfig(itl_ms=slo_itl_ms)
    serve = _serve_config(mode, chips, slo, chunk, max_slots)
    reqs = generate_trace(TRACES[trace], qps=qps, duration_s=duration,
                          seed=seed)
    eng = make_engine(mode, cfg, serve)
    # API v2: consume the event stream instead of scraping records()
    metrics = StreamMetrics()
    eng.subscribe(metrics)
    eng.enqueue([copy.deepcopy(r) for r in reqs])
    eng.loop.run()
    span = eng.loop.now if eng.loop.now > 0 else 1.0
    return metrics.summarize(slo, span)


def _workload_requests(workload: str, trace: str, qps: float,
                       duration: float, seed: int, arrival: str):
    """Single-class trace, or the multi-tenant mix (SLO classes +
    multi-turn sessions from serving/workloads.py), under a flat /
    diurnal / flash-crowd arrival process."""
    if workload == "trace":
        return generate_trace(TRACES[trace], qps=qps, duration_s=duration,
                              seed=seed)
    rate_fn = None
    if arrival == "diurnal":
        rate_fn = diurnal_rate(qps, amplitude=0.5, period_s=duration / 2)
    elif arrival == "flash":
        rate_fn = flash_crowd_rate(qps, 3.0 * qps, duration * 0.4,
                                   duration * 0.6)
    return generate_multiclass_trace(qps=qps, duration_s=duration,
                                     seed=seed, rate_fn=rate_fn)


def run_cluster(arch: str, modes, router: str, trace: str, qps: float,
                duration: float, chips: int, slo_itl_ms: float,
                chunk: int = 512, seed: int = 0, max_slots: int = 128,
                admission: AdmissionPolicy = None,
                rebalance: RebalancePolicy = None, scale=None,
                workload: str = "trace", arrival: str = "flat",
                session_affinity: bool = False):
    """Run a trace against an N-replica cluster; returns the fleet/per-
    replica summary dict from ``fleet_summarize`` plus the fleet span."""
    cfg = get_config(arch)
    slo = SLOConfig(itl_ms=slo_itl_ms)
    mode0 = modes[0] if isinstance(modes[0], str) else modes[0].mode
    serve = _serve_config(mode0, chips, slo, chunk, max_slots)
    reqs = _workload_requests(workload, trace, qps, duration, seed, arrival)
    out, cluster = run_fleet(cfg, serve, modes, router, reqs,
                             admission=admission, rebalance=rebalance,
                             scale=scale, session_affinity=session_affinity)
    out["router"] = router
    if scale is not None:
        out["scale_events"] = list(cluster._scale_events)
    return out


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama3-70b", choices=list_archs())
    p.add_argument("--mode", default="rapid",
                   choices=["rapid", "hybrid", "disagg", "all"])
    p.add_argument("--trace", default="lmsys", choices=list(TRACES))
    p.add_argument("--qps", type=float, default=8.0)
    p.add_argument("--duration", type=float, default=60.0)
    p.add_argument("--chips", type=int, default=32,
                   help="chips per serving replica")
    p.add_argument("--slo-itl-ms", type=float, default=100.0)
    p.add_argument("--chunk", type=int, default=512)
    p.add_argument("--replicas", type=int, default=1)
    p.add_argument("--router", default="least_loaded",
                   choices=sorted(ROUTERS))
    p.add_argument("--mix", default=None,
                   help="comma-separated per-replica engine modes, e.g. "
                        "'rapid,rapid,hybrid', or heterogeneous "
                        "'mode:COUNTxCHIPS' groups like 'rapid:2x16,"
                        "hybrid:1x32' (overrides --mode/--replicas)")
    p.add_argument("--workload", default="trace",
                   choices=["trace", "multiclass"],
                   help="'multiclass' replaces the single-class --trace "
                        "with the multi-tenant mix (interactive sessions "
                        "+ batch + best_effort, serving/workloads.py)")
    p.add_argument("--arrival", default="flat",
                   choices=["flat", "diurnal", "flash"],
                   help="arrival process for --workload multiclass")
    p.add_argument("--session-affinity", action="store_true",
                   help="route a session's turns to the replica parking "
                        "its prefix KV (prefix-cache hits)")
    p.add_argument("--admission", action="store_true",
                   help="KV-aware admission control at the cluster")
    p.add_argument("--class-aware-admission", action="store_true",
                   help="class-ordered admission headroom: sheds "
                        "best_effort first, never interactive (implies "
                        "--admission)")
    p.add_argument("--kv-headroom", type=float, default=0.9,
                   help="admission: max projected pool occupancy")
    p.add_argument("--admission-max-wait", type=float, default=60.0,
                   help="admission: queueing deadline before rejection (s)")
    p.add_argument("--rebalance", action="store_true",
                   help="cross-replica preemption/migration tick")
    p.add_argument("--scale-policy", default=None,
                   choices=["reactive", "projection"],
                   help="SLO-driven autoscaling: 'reactive' trailing "
                        "TTFT-attainment window, 'projection' perfmodel "
                        "forecasts incl. independent disagg P/D pool "
                        "scaling")
    p.add_argument("--min-replicas", type=int, default=1)
    p.add_argument("--max-replicas", type=int, default=4)
    p.add_argument("--serve", default="offline",
                   choices=["offline", "http"],
                   help="'http' starts the online gateway (streaming "
                        "NDJSON API, heartbeats, crash failover) instead "
                        "of replaying a trace offline")
    p.add_argument("--checkpoint-interval", type=int, default=0,
                   help="gateway KV snapshot period in generated tokens "
                        "(0 disables; crash failover then re-prefills "
                        "from scratch)")
    p.add_argument("--max-retries", type=int, default=2,
                   help="failover re-dispatches per request before the "
                        "terminal worker_lost rejection")
    p.add_argument("--retry-backoff", type=float, default=0.05,
                   help="base seconds of the exponential failover "
                        "backoff (doubles per retry, capped at 2 s)")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8080)
    p.add_argument("--json", default=None)
    args = p.parse_args(argv)

    if args.serve == "http":
        from repro.serving import (Gateway, GatewayPolicy, RealTimeClock,
                                   RetryPolicy, run_http)
        if args.mode == "all" and not args.mix:
            p.error("--serve http needs a concrete fleet; use --mode or "
                    "--mix, not --mode all")
        mix = parse_mix(args.mix) if args.mix \
            else [args.mode] * args.replicas
        modes = [m if isinstance(m, str) else m.mode for m in mix]
        cfg = get_config(args.arch)
        slo = SLOConfig(itl_ms=args.slo_itl_ms)
        serve = _serve_config(modes[0], args.chips, slo, args.chunk, 128)
        admission = AdmissionPolicy(
            kv_headroom=args.kv_headroom,
            max_wait_s=args.admission_max_wait,
            class_aware=args.class_aware_admission)
        gw = Gateway(cfg, serve, modes=modes, router=args.router,
                     clock=RealTimeClock(), admission=admission,
                     session_affinity=args.session_affinity,
                     policy=GatewayPolicy(
                         checkpoint_interval=args.checkpoint_interval,
                         max_retries=args.max_retries),
                     retry=RetryPolicy(
                         max_retries=args.max_retries,
                         backoff_base_s=args.retry_backoff))
        run_http(gw, host=args.host, port=args.port)
        return 0

    out = {}
    if args.mix or args.replicas > 1 or args.admission or \
            args.class_aware_admission or args.rebalance or \
            args.scale_policy or args.workload != "trace" or \
            args.session_affinity:
        if args.mode == "all" and not args.mix:
            p.error("--mode all cannot combine with --replicas; use "
                    "--mix rapid,hybrid,disagg to build a mixed fleet")
        mix = parse_mix(args.mix) if args.mix \
            else [args.mode] * args.replicas
        admission = AdmissionPolicy(
            kv_headroom=args.kv_headroom,
            max_wait_s=args.admission_max_wait,
            class_aware=args.class_aware_admission) \
            if args.admission or args.class_aware_admission else None
        rebalance = RebalancePolicy() if args.rebalance else None
        scale = None
        if args.scale_policy == "reactive":
            scale = ScalePolicy(min_replicas=args.min_replicas,
                                max_replicas=args.max_replicas)
        elif args.scale_policy == "projection":
            scale = ProjectionPolicy(min_replicas=args.min_replicas,
                                     max_replicas=args.max_replicas)
        res = run_cluster(args.arch, mix, args.router, args.trace,
                          args.qps, args.duration, args.chips,
                          args.slo_itl_ms, args.chunk,
                          admission=admission, rebalance=rebalance,
                          scale=scale, workload=args.workload,
                          arrival=args.arrival,
                          session_affinity=args.session_affinity)
        out["cluster"] = res
        f = res["fleet"]
        names = [m if isinstance(m, str)
                 else (f"{m.mode}x{m.chips}" if m.chips else m.mode)
                 for m in mix]
        print(f"cluster[{'+'.join(names)} | {args.router}] "
              f"thpt={f['throughput_tok_s']:9.1f} tok/s  "
              f"goodput={f['goodput_req_s']:6.2f} req/s  "
              f"ttft_p99={f['ttft_p99_s']:7.2f}s  "
              f"slo_ok={f['slo_attainment'] * 100:5.1f}%  "
              f"rej={f['rejected']}  migr={f['migrations']}")
        if res.get("admission"):
            print(f"  admission: {res['admission']}")
        if res.get("scale_events"):
            ups = sum(1 for _, a, _ in res["scale_events"] if a == "up")
            pools = sum(1 for _, a, _ in res["scale_events"]
                        if a.startswith("pool_"))
            print(f"  scaling[{args.scale_policy}]: {ups} replica "
                  f"add(s), {pools} independent pool grow(s)")
        for name, s in res["per_replica"].items():
            print(f"  {name:10s} n={s['requests']:4d}  "
                  f"thpt={s['throughput_tok_s']:9.1f} tok/s  "
                  f"ttft_p95={s['ttft_p95_s']:7.2f}s")
        if args.workload == "multiclass":
            for name, s in res["per_class"].items():
                print(f"  class {name:12s} n={s['requests']:4d}  "
                      f"goodput={s['goodput_req_s']:6.2f} req/s  "
                      f"slo_ok={s['slo_attainment'] * 100:5.1f}%  "
                      f"rej={s['rejected']}")
    else:
        modes = (["rapid", "hybrid", "disagg"] if args.mode == "all"
                 else [args.mode])
        for mode in modes:
            s = run_one(args.arch, mode, args.trace, args.qps,
                        args.duration, args.chips, args.slo_itl_ms,
                        args.chunk)
            out[mode] = s
            print(f"{mode:7s} thpt={s['throughput_tok_s']:9.1f} tok/s  "
                  f"goodput={s['goodput_req_s']:6.2f} req/s  "
                  f"ttft_p95={s['ttft_p95_s']:7.2f}s  "
                  f"itl_p95={s['itl_p95_s'] * 1e3:6.0f}ms  "
                  f"slo_ok={s['slo_attainment'] * 100:5.1f}%")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
