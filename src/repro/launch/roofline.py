"""Roofline terms from a compiled dry-run artifact (EXPERIMENTS.md §Roofline).

    compute term    = HLO_FLOPs  / (chips x 197e12)
    memory term     = HLO_bytes  / (chips x 819e9)
    collective term = coll_bytes / (chips x 50e9)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-
program, all chips).  Collective bytes are NOT in cost_analysis: we parse
the post-SPMD optimized HLO (``compiled.as_text()``) and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  Byte conventions (ring algorithms on a per-chip
basis): all-reduce counts 2x its operand (reduce-scatter + all-gather
phases), all-gather counts its *result*, reduce-scatter and all-to-all
their operand, collective-permute its operand.  Collectives whose
replica_groups span pods are charged to DCN (reported separately).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.perfmodel.hw import TPU_V5E, HardwareSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)")
_WHILE_RE = re.compile(
    r"while\([^)]*\),\s*condition=%([\w\.\-]+),\s*body=%([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_CALL_RE = re.compile(
    r"(?:call|conditional)\([^)]*\).*?to_apply=%([\w\.\-]+)")


def _computations(hlo_text: str):
    """Split the module into {computation_name: body_text}.

    A computation definition is a top-level (unindented) line starting
    with '%name (' or 'ENTRY %name (' and ending with '{'; its body runs
    to the matching top-level '}'."""
    comps = {}
    name, buf = None, []
    for line in hlo_text.splitlines():
        starts_def = (not line.startswith(" ") and
                      line.rstrip().endswith("{") and "->" in line and
                      (line.startswith("%") or line.startswith("ENTRY")))
        if starts_def:
            m = _COMP_RE.match(line)
            if name is not None:
                comps[name] = "\n".join(buf)
            name, buf = (m.group(1) if m else None), []
        elif line.strip() == "}" and not line.startswith("  "):
            if name is not None:
                comps[name] = "\n".join(buf)
            name, buf = None, []
        elif name is not None:
            buf.append(line)
    if name is not None:
        comps[name] = "\n".join(buf)
    return comps


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum collective payload bytes by op kind from optimized HLO text.

    LOOP-AWARE: a collective inside a ``while`` body executes once per
    iteration; bodies are weighted by XLA's known_trip_count annotation
    (nested loops multiply).  Without this, scan-over-layers /
    grad-accumulation programs under-count collectives by 10-100x.
    """
    comps = _computations(hlo_text)
    # body -> trip count, and caller edges (which computation contains
    # the while/call that invokes each body)
    multiplier: Dict[str, float] = {}
    edges: Dict[str, list] = {}
    for cname, body in comps.items():
        for line in body.splitlines():
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                trips = float(tm.group(1)) if tm else 1.0
                for callee in (wm.group(1), wm.group(2)):
                    edges.setdefault(cname, []).append((callee, trips))
            else:
                cm = _CALL_RE.search(line)
                if cm:
                    edges.setdefault(cname, []).append((cm.group(1), 1.0))

    # propagate multipliers from every root (computations nobody calls)
    called = {callee for lst in edges.values() for callee, _ in lst}
    roots = [c for c in comps if c not in called]
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    stack = [(r, 1.0) for r in roots]
    seen_depth = 0
    while stack and seen_depth < 1_000_000:
        seen_depth += 1
        cname, m = stack.pop()
        if m <= mult.get(cname, 0.0) and mult.get(cname, 0.0) > 0:
            continue
        mult[cname] = max(mult.get(cname, 0.0), m)
        for callee, trips in edges.get(cname, []):
            stack.append((callee, m * trips))

    out: Dict[str, float] = {}
    for cname, body in comps.items():
        m = max(mult.get(cname, 1.0), 1.0)
        for line in body.splitlines():
            cm = _COLL_RE.search(line)
            if not cm:
                continue
            type_str, op = cm.group(1), cm.group(2)
            nbytes = _shape_bytes(type_str)
            if op == "all-reduce":
                nbytes *= 2                  # RS + AG phases of a ring AR
            out[op] = out.get(op, 0.0) + nbytes * m
    return out


def collective_report(hlo_text: str, top: int = 12):
    """Itemized (bytes x trips) collective list — the §Perf profiling
    view: which collective, in which loop, costs what."""
    comps = _computations(hlo_text)
    multiplier: Dict[str, float] = {}
    edges: Dict[str, list] = {}
    for cname, body in comps.items():
        for line in body.splitlines():
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                trips = float(tm.group(1)) if tm else 1.0
                for callee in (wm.group(1), wm.group(2)):
                    edges.setdefault(cname, []).append((callee, trips))
            else:
                cm = _CALL_RE.search(line)
                if cm:
                    edges.setdefault(cname, []).append((cm.group(1), 1.0))
    called = {callee for lst in edges.values() for callee, _ in lst}
    mult: Dict[str, float] = {}
    stack = [(c, 1.0) for c in comps if c not in called]
    n = 0
    while stack and n < 1_000_000:
        n += 1
        cname, m = stack.pop()
        if m <= mult.get(cname, 0.0):
            continue
        mult[cname] = m
        for callee, trips in edges.get(cname, []):
            stack.append((callee, m * trips))
    items = []
    for cname, body in comps.items():
        m = max(mult.get(cname, 1.0), 1.0)
        for line in body.splitlines():
            cm = _COLL_RE.search(line)
            if not cm:
                continue
            b = _shape_bytes(cm.group(1))
            if cm.group(2) == "all-reduce":
                b *= 2
            items.append((b * m, cm.group(2), cm.group(1)[:50], m, cname[:40]))
    items.sort(key=lambda t: -t[0])
    return items[:top]


@dataclasses.dataclass
class RooflineTerms:
    """cost_analysis() on this backend reports PER-DEVICE flops/bytes
    (verified by a controlled sharded-matmul probe); fields below store
    per-device values, terms() therefore divides by per-chip peaks only.
    Collective bytes from the SPMD module are likewise per-chip."""
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                        # per device
    hbm_bytes: float                    # per device
    coll_bytes: float                   # per device
    coll_by_op: Dict[str, float]
    model_flops: float                  # whole-model (all chips)
    peak_mem_per_chip: float = 0.0

    def terms(self, hw: HardwareSpec = TPU_V5E):
        t_compute = self.flops / hw.peak_flops
        t_mem = self.hbm_bytes / hw.hbm_bw
        t_coll = self.coll_bytes / hw.ici_bw
        return t_compute, t_mem, t_coll

    @property
    def total_flops(self) -> float:
        return self.flops * self.chips

    @property
    def bottleneck(self) -> str:
        tc, tm, tl = self.terms()
        return ["compute", "memory", "collective"][
            [tc, tm, tl].index(max(tc, tm, tl))]

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / compiled HLO FLOPs — remat/padding/redundancy."""
        return self.model_flops / self.total_flops if self.flops else 0.0

    def roofline_fraction(self, hw: HardwareSpec = TPU_V5E) -> float:
        """MFU-style: time the model's useful FLOPs would take at peak /
        the modeled step time.  For memory/collective-bound steps this is
        honestly low — §Perf tracks the dominant term separately."""
        tc, tm, tl = self.terms(hw)
        t_step = max(tc, tm) + tl
        t_bound = self.model_flops / (self.chips * hw.peak_flops)
        return min(1.0, t_bound / max(t_step, 1e-12))


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS baseline: 6*N_active*D trained tokens, or 2*N_active*D
    inferred tokens (+ attention context reads are not counted — this is
    the deliberately-conservative 'useful work' yardstick)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # one token per sequence


def analyze(compiled, cfg, shape, mesh_name: str, chips: int,
            arch: Optional[str] = None) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):   # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    mem = 0.0
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "temp_size_in_bytes", 0) +
                    getattr(ma, "argument_size_in_bytes", 0) +
                    getattr(ma, "output_size_in_bytes", 0) -
                    getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return RooflineTerms(
        arch=arch or cfg.name, shape=shape.name, mesh=mesh_name,
        chips=chips, flops=flops, hbm_bytes=hbm,
        coll_bytes=sum(coll.values()), coll_by_op=coll,
        model_flops=model_flops_for(cfg, shape),
        peak_mem_per_chip=mem / max(chips, 1))
