"""Training launcher.

Two modes:
  * real run (CPU example / TPU deployment):  --arch <id> --reduced
    trains the reduced config on synthetic data with checkpoint/restart.
  * production lowering: --arch <id> --dryrun lowers+compiles train_4k
    on the production mesh (see dryrun.py for the full sweep).

    python -m repro.launch.train --arch granite-8b --reduced --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import get_reduced_config, list_archs
from repro.data import TokenPipeline
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import OptConfig
from repro.training.resilience import TrainingSupervisor
from repro.training.train_lib import init_train_state, make_train_step


def make_batch(pipe: TokenPipeline, cfg, seq_len: int):
    x, y = pipe.next_batch()
    B, S = x.shape
    if cfg.rope_type == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, 3))
    else:
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.frontend == "embed_stub":
        # modality stub: pseudo-embeddings derived from the token ids
        rng = jax.random.fold_in(jax.random.PRNGKey(7), int(x[0, 0]))
        inputs = jax.random.normal(rng, (B, S, cfg.d_model),
                                   jnp.float32).astype(cfg.dtype)
    else:
        inputs = jnp.asarray(x)
    return {"inputs": inputs, "labels": jnp.asarray(y), "positions": pos}


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True, choices=list_archs())
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=64)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--microbatches", type=int, default=2)
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=20)
    p.add_argument("--compress-grads", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_reduced_config(args.arch)
    opt = OptConfig(lr=args.lr, warmup_steps=5,
                    stable_steps=max(10, args.steps), decay_steps=10)
    state = init_train_state(jax.random.PRNGKey(args.seed), cfg, opt)
    step_fn = jax.jit(make_train_step(
        cfg, opt, microbatches=args.microbatches,
        compress_grads=args.compress_grads))
    pipe = TokenPipeline(cfg.vocab_size, args.batch, args.seq_len,
                         seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    sup = TrainingSupervisor(step_fn, ckpt, ckpt_every=args.ckpt_every)

    print(f"training {args.arch} (reduced: {cfg.num_layers}L "
          f"d={cfg.d_model}) for {args.steps} steps")
    t0 = time.time()
    batches = (make_batch(pipe, cfg, args.seq_len)
               for _ in range(args.steps))
    state = sup.run(state, batches)
    losses = [e["loss"] for e in sup.log if e["event"] == "step"]
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} in "
          f"{time.time() - t0:.0f}s ({len(losses)} steps, "
          f"{sup.restarts} restarts)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
