"""Paged KV-cache block allocator — decode-owned (paper §4.5.1, Fig 4).

The paper's central lock-free protocol: only the *decode* process runs the
KV cache manager.  Prompt block counts are computable from the context
length, so on request arrival the decode side allocates the prompt's
blocks and hands the block IDs to prefill; prefill fills them and sends a
notification back — no KV transfer, no locks, single owner.

``BlockAllocator`` is the page-pool (vLLM PagedAttention-style);
``KVCacheManager`` layers request lifecycle on top: allocate-for-prompt,
append-slot during decode, free on completion/preemption, plus occupancy
accounting used by the §5.4 memory-utilization benchmark and by engine
admission control.

Session prefix cache (first step toward radix-style prefix caching):
when a request carries a ``session_id``, its KV can be *parked* on
completion (``release_to_session``) instead of freed — up to a
``session_cache_blocks`` budget, LRU-evicted.  The session's next turn
then *adopts* the parked pages for its shared prefix
(``allocate_prompt(..., session_id=, max_prefix=)``) and only prefills
the new suffix.  Parked pages are always reclaimable: admission counts
them in ``available_blocks`` and allocation evicts LRU sessions before
ever raising ``OutOfBlocks``, so caching can delay no request.  With the
budget at 0 (or no session ids in the trace) every path below reduces
exactly to the legacy free/alloc behaviour.

Device-side layout (consumed by kernels/paged_attention.py):
    k_pages, v_pages : (num_blocks, page_size, kv_heads, head_dim)
    block_tables     : (max_requests, max_blocks_per_seq) int32
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional


class OutOfBlocks(Exception):
    """Raised when the pool cannot satisfy an allocation (triggers
    engine-level preemption or admission back-pressure)."""


@dataclasses.dataclass(frozen=True, slots=True)
class KVCheckpoint:
    """A durable snapshot of a running request's KV cache, parked off the
    serving replica (gateway / peer worker).  ``generated`` is the number
    of output tokens covered; ``kv_tokens`` the context tokens whose KV
    the snapshot holds (= original prompt + generated - 1: the first
    output token comes from prefill, each decode step appends one KV
    entry before emitting)."""
    rid: int
    generated: int
    kv_tokens: int
    t: float                 # commit time (copy finished)


class CheckpointStore:
    """Gateway-side parking lot for request KV checkpoints.

    Newest-wins per request; a ``budget_blocks`` cap (0 = unbounded)
    models the host/peer memory actually reserved for recovery — when a
    new snapshot would exceed it, *oldest-commit-first* entries of other
    requests are evicted (their requests silently fall back to re-prefill
    failover), and a snapshot too large for the whole budget is refused.
    """

    def __init__(self, page_size: int, budget_blocks: int = 0):
        self.page_size = page_size
        self.budget_blocks = budget_blocks
        self._by_rid: "collections.OrderedDict[int, KVCheckpoint]" = \
            collections.OrderedDict()
        self.taken = 0           # snapshots committed
        self.evicted = 0         # snapshots dropped for budget
        self.refused = 0         # snapshots larger than the whole budget

    def _pages(self, ckpt: KVCheckpoint) -> int:
        return kv_pages_for(ckpt.kv_tokens, self.page_size)

    @property
    def blocks(self) -> int:
        return sum(self._pages(c) for c in self._by_rid.values())

    def __len__(self) -> int:
        return len(self._by_rid)

    def put(self, ckpt: KVCheckpoint) -> bool:
        """Commit a snapshot (replaces any older one for the same rid).
        Returns False when the snapshot alone exceeds the budget."""
        need = self._pages(ckpt)
        if self.budget_blocks and need > self.budget_blocks:
            self.refused += 1
            return False
        self._by_rid.pop(ckpt.rid, None)
        if self.budget_blocks:
            while self._by_rid and self.blocks + need > self.budget_blocks:
                self._by_rid.popitem(last=False)     # oldest commit first
                self.evicted += 1
        self._by_rid[ckpt.rid] = ckpt
        self.taken += 1
        return True

    def get(self, rid: int) -> Optional[KVCheckpoint]:
        return self._by_rid.get(rid)

    def drop(self, rid: int) -> None:
        self._by_rid.pop(rid, None)


def kv_pages_for(num_tokens: int, page_size: int) -> int:
    return -(-num_tokens // page_size)


def paged_cache_shape(cfg, num_blocks: int, page_size: int, tp: int = 1):
    return (num_blocks, page_size, cfg.kv_heads_padded(tp), cfg.head_dim)


class BlockAllocator:
    """Free-list page pool.  O(1) alloc/free, LIFO reuse for locality."""

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return self.num_blocks - len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise OutOfBlocks(f"need {n}, have {len(self._free)}")
        out = self._free[-n:][::-1]
        del self._free[-n:]
        return out

    def free(self, blocks: List[int]) -> None:
        self._free.extend(reversed(blocks))
        assert len(self._free) <= self.num_blocks

    def grow(self, extra_blocks: int) -> None:
        """Append ``extra_blocks`` fresh pages to the pool (runtime pool
        scaling — e.g. the cluster autoscaler adding chips to a disagg
        prefill pool).  Pools only grow: shrinking would require evicting
        live KV out from under running requests."""
        if extra_blocks < 0:
            raise ValueError("block pools only grow; cannot shrink by "
                             f"{-extra_blocks} blocks")
        start = self.num_blocks
        self.num_blocks += extra_blocks
        self._free.extend(range(self.num_blocks - 1, start - 1, -1))


@dataclasses.dataclass
class _SeqAlloc:
    blocks: List[int]
    num_tokens: int          # tokens with cache entries (prompt + generated)
    page_size: int

    @property
    def capacity(self) -> int:
        return len(self.blocks) * self.page_size


class KVCacheManager:
    """Decode-owned per-request block bookkeeping (single owner => no
    locks; the prefill side only ever *reads* block IDs it was handed)."""

    def __init__(self, num_blocks: int, page_size: int,
                 session_cache_blocks: int = 0):
        self.allocator = BlockAllocator(num_blocks)
        self.page_size = page_size
        self._seqs: Dict[int, _SeqAlloc] = {}
        # parked per-session prefix KV, LRU-ordered (oldest first)
        self.session_cache_blocks = session_cache_blocks
        self._sessions: "collections.OrderedDict[str, _SeqAlloc]" = \
            collections.OrderedDict()
        self._session_block_count = 0
        # checkpoint restores staged by the gateway: rid -> context tokens
        # whose KV is being copied in from a parked snapshot (consumed at
        # allocate_prompt; compute for those tokens is skipped)
        self._staged_restores: Dict[int, int] = {}

    # -- session prefix cache ------------------------------------------------
    @property
    def session_blocks(self) -> int:
        """Blocks parked for finished sessions — allocated, but
        reclaimable at any time (LRU) by ``allocate_prompt``."""
        return self._session_block_count

    @property
    def available_blocks(self) -> int:
        """Free blocks plus reclaimable session-parked blocks — the
        quantity admission must project against (identical to
        ``allocator.free_count`` when no sessions are parked)."""
        return self.allocator.free_count + self._session_block_count

    def session_tokens(self, session_id: str) -> int:
        entry = self._sessions.get(session_id)
        return entry.num_tokens if entry is not None else 0

    def session_hit_tokens(self, session_id: Optional[str],
                           prompt_len: int, max_prefix: int) -> int:
        """Prefix tokens the next turn may actually skip: bounded by what
        is resident, by the caller's claimed shared prefix, and by
        ``prompt_len - 1`` (at least one token must be prefilled so the
        step produces the first output token)."""
        if session_id is None or max_prefix <= 0:
            return 0
        return max(0, min(max_prefix, self.session_tokens(session_id),
                          prompt_len - 1))

    def drop_session(self, session_id: str) -> None:
        """Invalidate a session's parked prefix (e.g. the cluster
        migrated the session to another replica)."""
        entry = self._sessions.pop(session_id, None)
        if entry is not None:
            self._session_block_count -= len(entry.blocks)
            self.allocator.free(entry.blocks)

    def release_to_session(self, rid: int, session_id: str) -> bool:
        """Park a finishing request's KV for its session instead of
        freeing it.  Returns True when parked; falls back to a plain
        ``free`` (returns False) when the budget is 0 or the entry alone
        exceeds it.  Evicts LRU sessions to stay within budget."""
        seq = self._seqs.pop(rid)
        if not 0 < len(seq.blocks) <= self.session_cache_blocks:
            self.allocator.free(seq.blocks)
            return False
        old = self._sessions.pop(session_id, None)
        if old is not None:
            self._session_block_count -= len(old.blocks)
            self.allocator.free(old.blocks)
        self._sessions[session_id] = seq
        self._session_block_count += len(seq.blocks)
        while self._session_block_count > self.session_cache_blocks:
            _, evicted = self._sessions.popitem(last=False)
            self._session_block_count -= len(evicted.blocks)
            self.allocator.free(evicted.blocks)
        return True

    def _alloc_evicting(self, n: int) -> List[int]:
        """Allocate ``n`` blocks, reclaiming LRU session prefixes as
        needed — parked KV can never starve live work."""
        if n <= 0:
            return []
        while n > self.allocator.free_count and self._sessions:
            _, evicted = self._sessions.popitem(last=False)
            self._session_block_count -= len(evicted.blocks)
            self.allocator.free(evicted.blocks)
        return self.allocator.alloc(n)

    # -- checkpoint restore staging (gateway failover) ----------------------
    def stage_restore(self, rid: int, kv_tokens: int) -> None:
        """Announce that ``kv_tokens`` context tokens of KV for ``rid``
        are being restored from a parked checkpoint: the next
        ``allocate_prompt(rid, ...)`` still claims the full page count
        (restored KV occupies real pages) but the engine skips prefill
        compute for the restored prefix (``restore_hit_tokens``)."""
        if kv_tokens > 0:
            self._staged_restores[rid] = kv_tokens

    def restore_hit_tokens(self, rid: int, prompt_len: int) -> int:
        """Prefix tokens a staged restore lets ``rid`` skip — same
        ``prompt_len - 1`` bound as the session cache (one token must be
        prefilled so the step emits the first output token)."""
        staged = self._staged_restores.get(rid, 0)
        if staged <= 0:
            return 0
        return max(0, min(staged, prompt_len - 1))

    def clear_restore(self, rid: int) -> None:
        self._staged_restores.pop(rid, None)

    # -- Fig 4 step 2: decode allocates the prompt's blocks ----------------
    def pages_needed(self, prompt_len: int,
                     session_id: Optional[str] = None,
                     max_prefix: int = 0) -> int:
        """Pages ``allocate_prompt`` would newly claim, net of pages
        adopted from the session's parked prefix (pure projection)."""
        total = kv_pages_for(prompt_len, self.page_size)
        hit = self.session_hit_tokens(session_id, prompt_len, max_prefix)
        if hit <= 0:
            return total
        entry = self._sessions[session_id]
        adopted = min(kv_pages_for(hit, self.page_size),
                      len(entry.blocks), total)
        return total - adopted

    def allocate_prompt(self, rid: int, prompt_len: int,
                        session_id: Optional[str] = None,
                        max_prefix: int = 0) -> List[int]:
        if rid in self._seqs:
            raise ValueError(f"request {rid} already allocated")
        total = kv_pages_for(prompt_len, self.page_size)
        adopted: List[int] = []
        hit = self.session_hit_tokens(session_id, prompt_len, max_prefix)
        if hit > 0:
            entry = self._sessions.pop(session_id)
            self._session_block_count -= len(entry.blocks)
            keep = min(kv_pages_for(hit, self.page_size),
                       len(entry.blocks), total)
            adopted = entry.blocks[:keep]
            if entry.blocks[keep:]:
                self.allocator.free(entry.blocks[keep:])
        try:
            blocks = adopted + self._alloc_evicting(total - len(adopted))
        except OutOfBlocks:
            if adopted:
                self.allocator.free(adopted)
            raise
        self._seqs[rid] = _SeqAlloc(blocks, prompt_len, self.page_size)
        self._staged_restores.pop(rid, None)     # restore consumed
        return blocks

    def can_allocate(self, prompt_len: int) -> bool:
        return kv_pages_for(prompt_len, self.page_size) <= \
            self.allocator.free_count

    # -- decode step: one new token per running request ---------------------
    def append_token(self, rid: int) -> Optional[int]:
        """Returns a newly-allocated block id when a page boundary is
        crossed, else None."""
        seq = self._seqs[rid]
        new_block = None
        if seq.num_tokens + 1 > seq.capacity:
            new_block = self.allocator.alloc(1)[0]
            seq.blocks.append(new_block)
        seq.num_tokens += 1
        return new_block

    def free(self, rid: int) -> None:
        seq = self._seqs.pop(rid)
        self.allocator.free(seq.blocks)

    def preempt(self, rid: int) -> int:
        """Free a request's blocks (victim of preemption); returns the
        number of tokens whose KV must be recomputed on resume."""
        seq = self._seqs[rid]
        tokens = seq.num_tokens
        self.free(rid)
        return tokens

    def grow(self, extra_blocks: int) -> None:
        """Runtime pool expansion (see ``BlockAllocator.grow``)."""
        self.allocator.grow(extra_blocks)

    # -- accounting ---------------------------------------------------------
    def blocks_of(self, rid: int) -> List[int]:
        return list(self._seqs[rid].blocks)

    def tokens_of(self, rid: int) -> int:
        return self._seqs[rid].num_tokens

    @property
    def num_requests(self) -> int:
        return len(self._seqs)

    @property
    def utilization(self) -> float:
        """Fraction of the pool holding live KV (paper §5.4 metric)."""
        if self.allocator.num_blocks == 0:
            return 0.0
        return self.allocator.used_count / self.allocator.num_blocks

    @property
    def token_occupancy(self) -> float:
        """Live tokens / pool token capacity — excludes page-tail waste."""
        cap = self.allocator.num_blocks * self.page_size
        live = sum(s.num_tokens for s in self._seqs.values())
        return live / cap if cap else 0.0
