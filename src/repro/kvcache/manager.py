"""Paged KV-cache block allocator — decode-owned (paper §4.5.1, Fig 4).

The paper's central lock-free protocol: only the *decode* process runs the
KV cache manager.  Prompt block counts are computable from the context
length, so on request arrival the decode side allocates the prompt's
blocks and hands the block IDs to prefill; prefill fills them and sends a
notification back — no KV transfer, no locks, single owner.

``BlockAllocator`` is the page-pool (vLLM PagedAttention-style);
``KVCacheManager`` layers request lifecycle on top: allocate-for-prompt,
append-slot during decode, free on completion/preemption, plus occupancy
accounting used by the §5.4 memory-utilization benchmark and by engine
admission control.

Device-side layout (consumed by kernels/paged_attention.py):
    k_pages, v_pages : (num_blocks, page_size, kv_heads, head_dim)
    block_tables     : (max_requests, max_blocks_per_seq) int32
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


class OutOfBlocks(Exception):
    """Raised when the pool cannot satisfy an allocation (triggers
    engine-level preemption or admission back-pressure)."""


def kv_pages_for(num_tokens: int, page_size: int) -> int:
    return -(-num_tokens // page_size)


def paged_cache_shape(cfg, num_blocks: int, page_size: int, tp: int = 1):
    return (num_blocks, page_size, cfg.kv_heads_padded(tp), cfg.head_dim)


class BlockAllocator:
    """Free-list page pool.  O(1) alloc/free, LIFO reuse for locality."""

    def __init__(self, num_blocks: int):
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return self.num_blocks - len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise OutOfBlocks(f"need {n}, have {len(self._free)}")
        out = self._free[-n:][::-1]
        del self._free[-n:]
        return out

    def free(self, blocks: List[int]) -> None:
        self._free.extend(reversed(blocks))
        assert len(self._free) <= self.num_blocks

    def grow(self, extra_blocks: int) -> None:
        """Append ``extra_blocks`` fresh pages to the pool (runtime pool
        scaling — e.g. the cluster autoscaler adding chips to a disagg
        prefill pool).  Pools only grow: shrinking would require evicting
        live KV out from under running requests."""
        if extra_blocks < 0:
            raise ValueError("block pools only grow; cannot shrink by "
                             f"{-extra_blocks} blocks")
        start = self.num_blocks
        self.num_blocks += extra_blocks
        self._free.extend(range(self.num_blocks - 1, start - 1, -1))


@dataclasses.dataclass
class _SeqAlloc:
    blocks: List[int]
    num_tokens: int          # tokens with cache entries (prompt + generated)
    page_size: int

    @property
    def capacity(self) -> int:
        return len(self.blocks) * self.page_size


class KVCacheManager:
    """Decode-owned per-request block bookkeeping (single owner => no
    locks; the prefill side only ever *reads* block IDs it was handed)."""

    def __init__(self, num_blocks: int, page_size: int):
        self.allocator = BlockAllocator(num_blocks)
        self.page_size = page_size
        self._seqs: Dict[int, _SeqAlloc] = {}

    # -- Fig 4 step 2: decode allocates the prompt's blocks ----------------
    def allocate_prompt(self, rid: int, prompt_len: int) -> List[int]:
        if rid in self._seqs:
            raise ValueError(f"request {rid} already allocated")
        n = kv_pages_for(prompt_len, self.page_size)
        blocks = self.allocator.alloc(n)
        self._seqs[rid] = _SeqAlloc(blocks, prompt_len, self.page_size)
        return blocks

    def can_allocate(self, prompt_len: int) -> bool:
        return kv_pages_for(prompt_len, self.page_size) <= \
            self.allocator.free_count

    # -- decode step: one new token per running request ---------------------
    def append_token(self, rid: int) -> Optional[int]:
        """Returns a newly-allocated block id when a page boundary is
        crossed, else None."""
        seq = self._seqs[rid]
        new_block = None
        if seq.num_tokens + 1 > seq.capacity:
            new_block = self.allocator.alloc(1)[0]
            seq.blocks.append(new_block)
        seq.num_tokens += 1
        return new_block

    def free(self, rid: int) -> None:
        seq = self._seqs.pop(rid)
        self.allocator.free(seq.blocks)

    def preempt(self, rid: int) -> int:
        """Free a request's blocks (victim of preemption); returns the
        number of tokens whose KV must be recomputed on resume."""
        seq = self._seqs[rid]
        tokens = seq.num_tokens
        self.free(rid)
        return tokens

    def grow(self, extra_blocks: int) -> None:
        """Runtime pool expansion (see ``BlockAllocator.grow``)."""
        self.allocator.grow(extra_blocks)

    # -- accounting ---------------------------------------------------------
    def blocks_of(self, rid: int) -> List[int]:
        return list(self._seqs[rid].blocks)

    def tokens_of(self, rid: int) -> int:
        return self._seqs[rid].num_tokens

    @property
    def num_requests(self) -> int:
        return len(self._seqs)

    @property
    def utilization(self) -> float:
        """Fraction of the pool holding live KV (paper §5.4 metric)."""
        if self.allocator.num_blocks == 0:
            return 0.0
        return self.allocator.used_count / self.allocator.num_blocks

    @property
    def token_occupancy(self) -> float:
        """Live tokens / pool token capacity — excludes page-tail waste."""
        cap = self.allocator.num_blocks * self.page_size
        live = sum(s.num_tokens for s in self._seqs.values())
        return live / cap if cap else 0.0
