from repro.kvcache.manager import (  # noqa: F401
    BlockAllocator, CheckpointStore, KVCacheManager, KVCheckpoint,
    OutOfBlocks, kv_pages_for, paged_cache_shape,
)
