from repro.data.pipeline import PipelineState, TokenPipeline  # noqa: F401
