from repro.data.pipeline import TokenPipeline, PipelineState  # noqa: F401
