"""Deterministic, checkpointable synthetic-token data pipeline.

A real deployment would stream tokenized shards; here the source is a
counter-seeded PRNG so that (a) every batch is reproducible from the
pipeline state alone, (b) restore(state) resumes the exact stream —
asserted in tests (fault-tolerance depends on it: after checkpoint
restart the data pipeline must not replay or skip batches).

Structured statistics (Zipfian token marginals + Markov repetition) make
the LM loss actually *descend* on this stream, so the end-to-end training
example shows learning, not noise.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineState:
    step: int
    seed: int


class TokenPipeline:
    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, repeat_p: float = 0.3,
                 zipf_a: float = 1.3):
        self.vocab = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.repeat_p = repeat_p
        self.zipf_a = zipf_a
        self._step = 0
        # fixed Zipf marginal over the vocab
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = ranks ** (-zipf_a)
        self._marginal = p / p.sum()

    @property
    def state(self) -> PipelineState:
        return PipelineState(self._step, self.seed)

    def restore(self, state: PipelineState) -> None:
        assert state.seed == self.seed, "pipeline seed mismatch"
        self._step = state.step

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence((self.seed, step)))

    def next_batch(self) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (tokens (B,S+1) int32 -> inputs/labels split upstream)."""
        rng = self._rng(self._step)
        self._step += 1
        B, S = self.batch, self.seq_len + 1
        toks = rng.choice(self.vocab, size=(B, S), p=self._marginal)
        # Markov repetition: with prob repeat_p copy the previous token
        rep = rng.random((B, S)) < self.repeat_p
        for t in range(1, S):
            toks[:, t] = np.where(rep[:, t], toks[:, t - 1], toks[:, t])
        return toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            yield self.next_batch()
