"""Logical-axis -> mesh-axis translation (GSPMD/pjit substrate).

Params and activations carry *logical* axis names ("model", "batch",
"model_ep", None).  A ``ShardingRules`` maps logical names to mesh axes for
a given mesh topology; FSDP additionally shards one replicated dim of each
large weight over the data axis (ZeRO-3-style parameter sharding, needed
for the >=70B-class archs to fit 16 GB/chip — DESIGN.md §4).

Single pod : mesh ("data", "model") = (16, 16)
Multi pod  : mesh ("pod", "data", "model") = (2, 16, 16); "pod" is the
             outermost data-parallel axis (DCN), TP stays inside a pod
             (ICI), FSDP param sharding stays inside a pod so parameter
             all-gathers never cross DCN.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    model: Union[str, tuple, None] = "model"
    batch: Union[str, tuple, None] = "data"       # activations / tokens
    data: Union[str, tuple, None] = "data"        # param FSDP dim
    seq: Union[str, tuple, None] = None           # sequence parallelism
    # expert parallelism: the expert dim lives on the DATA axis (GShard
    # layout — dispatch/combine lower to all-to-alls between the token
    # sharding n@data and the expert sharding e@data).  Putting experts
    # or their hidden dim on "data" via FSDP instead forces GSPMD to
    # all-gather the token-capacity tensors (+7 GB/chip at jamba scale,
    # dry-run buffer dump — EXPERIMENTS.md §Perf).
    expert: Union[str, tuple, None] = "data"

    def resolve(self, name):
        if name is None:
            return None
        return getattr(self, name)


def rules_for_mesh(mesh: Mesh) -> ShardingRules:
    if "pod" in mesh.axis_names:
        return ShardingRules(batch=("pod", "data"))
    return ShardingRules()


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def spec_to_pspec(spec, mesh: Mesh, rules: ShardingRules,
                  shape=None) -> P:
    """Translate a logical spec tuple to a PartitionSpec.

    Drops shardings that do not divide the dim evenly (with ``shape``)
    rather than failing — the caller's roofline accounting still sees the
    padded/logical sizes via the config.
    """
    out = []
    for i, name in enumerate(spec):
        axes = rules.resolve(name)
        if axes is not None and shape is not None:
            if shape[i] % _axis_size(mesh, axes):
                axes = None
        out.append(axes)
    return P(*out)


def _fsdp_spec(spec, shape, mesh: Mesh, rules: ShardingRules,
               min_size: int = 1 << 20):
    """Shard the first replicated dim over the data axis when it divides.

    Only applied to weights with >= min_size elements — biases and norm
    scales stay replicated (tiny, and odd dims).  Leaves that already
    consume the data axis ("expert"/"data" logical names) are skipped.
    """
    if shape is None or int(np.prod(shape)) < min_size:
        return spec
    if any(s in ("data", "expert") for s in spec):
        return spec
    dp = _axis_size(mesh, rules.data)
    if dp == 1:
        return spec
    spec = list(spec)
    # prefer the largest eligible dim (cheapest all-gather layout)
    cand = [i for i, name in enumerate(spec)
            if name is None and shape[i] % dp == 0]
    if not cand:
        return spec
    best = max(cand, key=lambda i: shape[i])
    spec[best] = "data"
    return tuple(spec)


def param_sharding(specs, shapes, mesh: Mesh, *,
                   rules: Optional[ShardingRules] = None,
                   fsdp: bool = False):
    """Tree of NamedShardings for a (specs, shapes) pair of trees."""
    rules = rules or rules_for_mesh(mesh)

    def one(spec, shp):
        shape = shp.shape if hasattr(shp, "shape") else tuple(shp)
        s = tuple(spec)
        if fsdp:
            s = _fsdp_spec(s, shape, mesh, rules)
        return NamedSharding(mesh, spec_to_pspec(s, mesh, rules, shape))

    return jax.tree.map(one, specs, shapes,
                        is_leaf=lambda s: isinstance(s, tuple))


def input_sharding(mesh: Mesh, *logical_axes,
                   rules: Optional[ShardingRules] = None):
    rules = rules or rules_for_mesh(mesh)
    return NamedSharding(mesh, spec_to_pspec(logical_axes, mesh, rules))


def make_constrain(mesh: Mesh, rules: Optional[ShardingRules] = None):
    """Returns constrain(a, logical_spec) for use inside jitted fns."""
    rules = rules or rules_for_mesh(mesh)

    def constrain(a, spec):
        pspec = spec_to_pspec(tuple(spec), mesh, rules, a.shape)
        return jax.lax.with_sharding_constraint(a, NamedSharding(mesh, pspec))

    return constrain


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
