"""Hardware constants.  TPU v5e is the target part (per task spec):
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI, 16 GB HBM."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float          # bf16 FLOP/s per chip
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per link
    dcn_bw: float              # bytes/s per chip, cross-pod
    hbm_bytes: int             # capacity per chip
    # host-side per-step scheduling cost (hidden under async scheduling)
    sched_overhead_s: float = 2e-3
    # device-side per-program dispatch latency
    launch_overhead_s: float = 50e-6

    @property
    def balance(self) -> float:
        """Machine balance: FLOPs per byte at the roofline ridge."""
        return self.peak_flops / self.hbm_bw


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    dcn_bw=25e9,
    hbm_bytes=16 * 1024 ** 3,
)
