"""P/D co-residency timing model — the TPU analogue of CU masking.

On TPU there is no spatial CU partition; the RAPID adaptation exposes the
same control variable f_d (decode's share of issue capacity) through
(a) grid-slot partitioning in the unified Pallas step and (b) the
token-budget knob (DESIGN.md §2).  This module turns (StepCost, f) pairs
into durations, modeling:

  * compute scaling    — a phase holding fraction f of issue capacity runs
    its compute-bound portion at f * peak (paper Fig 3a: prefill perf is
    proportional to CUs).
  * memory insensitivity — the bandwidth-bound portion is unaffected by f
    until f is tiny (Fig 3b: decode holds perf down to 40-50% CUs).
  * memory-subsystem interference (§3.4) — co-resident phases degrade each
    other's HBM term by ~2% (prefill) and 2-5% (decode); no partitioning
    mechanism exists for it, matching the paper.
  * overallocation (Fig 6c / Fig 7) — both phases claim f=1 and share by
    occupancy demand: each phase's share is proportional to its standalone
    compute-utilization, so a small decode batch (low demand u) coexists
    almost freely, while a large one degrades toward a 1/2 split — this
    reproduces Fig 7's P100-D100 curve crossing the SLO as batch grows,
    with no fitted constants beyond the §3.4 interference percentages.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np

from repro.perfmodel import batch as _batch
# §3.4 memory-subsystem interference constants live in the formula
# layer (perfmodel.batch); re-exported here under their historical names
from repro.perfmodel.batch import (MEM_INTERFERENCE_DECODE,
                                   MEM_INTERFERENCE_PREFILL)
from repro.perfmodel.costs import StepCost
from repro.perfmodel.hw import HardwareSpec


def phase_time(cost: StepCost, hw: HardwareSpec, chips: int,
               f: float = 1.0, mem_interference: float = 0.0,
               bw_share: float = 1.0) -> float:
    """Duration of one phase step given issue-capacity fraction f."""
    if cost.flops == 0 and cost.hbm_bytes == 0:
        return 0.0
    t_compute = cost.flops / (chips * hw.peak_flops * max(f, 1e-3))
    t_mem = cost.hbm_bytes * (1.0 + mem_interference) / \
        (chips * hw.hbm_bw * bw_share)
    t_coll = cost.coll_bytes / hw.ici_bw
    return max(t_compute, t_mem) + t_coll + hw.launch_overhead_s


def compute_utilization(cost: StepCost, hw: HardwareSpec,
                        chips: int) -> float:
    """Standalone occupancy demand u in [0, 1]: fraction of issue capacity
    the phase can actually use while bandwidth-bound."""
    t_c = cost.flops / (chips * hw.peak_flops)
    t_m = cost.hbm_bytes / (chips * hw.hbm_bw)
    t_coll = cost.coll_bytes / hw.ici_bw
    denom = max(t_m, t_c) + t_coll
    if denom <= 0:
        return 0.0
    return min(1.0, t_c / denom)


@dataclasses.dataclass(frozen=True)
class OverlapResult:
    t_prefill: float
    t_decode: float
    f_prefill: float
    f_decode: float
    mode: str            # "overalloc" | "distinct" | "solo"


def overlapped_times(p_cost: Optional[StepCost], d_cost: Optional[StepCost],
                     hw: HardwareSpec, chips: int, *,
                     f_decode: Optional[float] = None) -> OverlapResult:
    """Durations for co-resident prefill/decode steps.

    f_decode=None -> overallocation (both claim the whole chip, shares
    set by occupancy demand).  Otherwise a distinct split: decode gets
    f_decode, prefill gets 1 - f_decode (the profiled CU-mask analogue).
    """
    if d_cost is None and p_cost is None:
        return OverlapResult(0.0, 0.0, 0.0, 0.0, "solo")
    if d_cost is None:
        return OverlapResult(
            phase_time(p_cost, hw, chips), 0.0, 1.0, 0.0, "solo")
    if p_cost is None:
        return OverlapResult(
            0.0, phase_time(d_cost, hw, chips), 0.0, 1.0, "solo")

    if f_decode is None:
        # Overallocation: issue-capacity shares proportional to demand.
        u_d = compute_utilization(d_cost, hw, chips)
        u_p = compute_utilization(p_cost, hw, chips)
        share_d = u_d / max(u_d + u_p, 1e-9)
        share_p = 1.0 - share_d
        t_d = phase_time(d_cost, hw, chips, f=max(share_d, 1e-3),
                         mem_interference=MEM_INTERFERENCE_DECODE)
        t_p = phase_time(p_cost, hw, chips, f=max(share_p, 1e-3),
                         mem_interference=MEM_INTERFERENCE_PREFILL)
        return OverlapResult(t_p, t_d, share_p, share_d, "overalloc")

    f_d = min(max(f_decode, 0.05), 0.95)
    f_p = 1.0 - f_d
    t_d = phase_time(d_cost, hw, chips, f=f_d,
                     mem_interference=MEM_INTERFERENCE_DECODE)
    t_p = phase_time(p_cost, hw, chips, f=f_p,
                     mem_interference=MEM_INTERFERENCE_PREFILL)
    return OverlapResult(t_p, t_d, f_p, f_d, "distinct")


@functools.lru_cache(maxsize=65536)
def forecast_phase_times(p_cost: Optional[StepCost],
                         d_cost: Optional[StepCost], hw: HardwareSpec,
                         chips_p: int, chips_d: int, *,
                         colocated: bool = True,
                         f_decode: Optional[float] = None
                         ) -> "tuple[float, float]":
    """Projected ``(t_prefill, t_decode)`` for a replica's current load —
    the primitive behind projection-driven cluster decisions (autoscaler,
    admission).  Colocated replicas couple the two phases through
    ``overlapped_times`` on the shared chip group; split-pool (disagg)
    replicas run each phase at its own pool's ``phase_time`` with no
    cross-phase interference (§3.2: the pools share nothing but the
    transfer link).

    Memoized: the projection autoscaler and admission controller call
    this with the same (cost, chips) operating points tick after tick
    whenever the fleet state is unchanged; caching returns the identical
    tuple without re-running the overlap model.

    N=1 view of ``batch.forecast_phase_times`` — the fleet tick prices
    all replicas through the batched overlap model in one call, and this
    view guarantees the scalar path computes the exact same formula."""
    pb, _ = _batch.pack_costs((p_cost,))
    db, _ = _batch.pack_costs((d_cost,))
    t_p, t_d = _batch.forecast_phase_times(
        pb, db, hw, chips_p, chips_d, colocated=colocated,
        p_mask=p_cost is not None, d_mask=d_cost is not None,
        f_decode=np.nan if f_decode is None else f_decode)
    return float(t_p[0]), float(t_d[0])
