from repro.perfmodel import batch  # noqa: F401
from repro.perfmodel.batch import StepCostBatch  # noqa: F401
from repro.perfmodel.costs import (  # noqa: F401
    StepCost, cache_stats, decode_cost, kv_read_bytes,
    model_flops_per_token, prefill_cost, weight_bytes,
)
from repro.perfmodel.hw import TPU_V5E, HardwareSpec  # noqa: F401
from repro.perfmodel.interference import (  # noqa: F401
    OverlapResult, forecast_phase_times, overlapped_times, phase_time,
)
