from repro.perfmodel.hw import TPU_V5E, HardwareSpec  # noqa: F401
from repro.perfmodel.costs import (  # noqa: F401
    StepCost, prefill_cost, decode_cost, model_flops_per_token,
    weight_bytes, kv_read_bytes,
)
from repro.perfmodel.interference import (  # noqa: F401
    phase_time, overlapped_times, OverlapResult,
)
