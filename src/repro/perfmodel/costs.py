"""Analytic per-step FLOP / HBM-byte / collective-byte counts.

These drive the discrete-event simulator's step durations (the container
has no TPU).  The same three terms are independently derived from the
*compiled* HLO by launch/roofline.py for EXPERIMENTS.md §Roofline; tests
assert the analytic and HLO-derived FLOP counts agree within tolerance,
which keeps the simulator honest.

All pricing functions are pure in their arguments, so the step-cost
entry points are memoized (``functools.lru_cache``) on their exact
operating points: the projection autoscaler re-prices identical
``LoadSnapshot``s every tick, the SLO-aware router re-prices repeated
(backlog, batch) pairs per arrival, and hybrid chunk boundaries land on
quantized (chunk, ctx) points — all of which now hit the cache instead
of re-walking the layer pattern.  Cached values are the *same* objects,
so memoization can never change simulator behavior, only its cost.

Conventions:
  * matmul FLOPs = 2*M*N*K;   causal attention scores halved.
  * weights are streamed from HBM once per step (valid for serving batch
    sizes; prefill is compute-bound anyway so its byte term rarely binds).
  * TP collectives: 2 all-reduces per block over the activation slab,
    ring cost 2*(tp-1)/tp of the payload per chip.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class StepCost:
    flops: float          # total FLOPs for the step (all chips)
    hbm_bytes: float      # HBM traffic per chip-group, summed over chips
    coll_bytes: float     # per-chip collective payload bytes

    def __add__(self, other: "StepCost") -> "StepCost":
        return StepCost(self.flops + other.flops,
                        self.hbm_bytes + other.hbm_bytes,
                        self.coll_bytes + other.coll_bytes)

    def scale(self, k: float) -> "StepCost":
        return StepCost(self.flops * k, self.hbm_bytes * k,
                        self.coll_bytes * k)


ZERO_COST = StepCost(0.0, 0.0, 0.0)


def model_flops_per_token(cfg) -> float:
    """6*N_active per trained token; 2*N_active per inferred token is
    obtained by scaling."""
    return 6.0 * cfg.active_param_count()


@functools.lru_cache(maxsize=None)
def weight_bytes(cfg, dtype_bytes: int = 2) -> float:
    """Bytes of weights streamed per step (MoE: only routed experts are
    read in expectation when the batch is small; we charge min(full,
    per-token-active * tokens) at the call sites)."""
    return cfg.param_count() * dtype_bytes


@functools.lru_cache(maxsize=65536)
def active_weight_bytes(cfg, tokens: int, dtype_bytes: int = 2) -> float:
    """Expected weight bytes touched by `tokens` tokens in one step.

    Dense: all weights.  MoE: each token touches top_k experts; with E
    experts the expected fraction of expert weights touched is
    1-(1-k/E)^tokens, capped at 1.
    """
    if cfg.moe is None:
        return cfg.param_count() * dtype_bytes
    total = cfg.param_count()
    moe_layers = sum(1 for i in range(cfg.num_layers)
                     if cfg.ffn_at(i) == "moe")
    glu = 3
    expert_params = moe_layers * cfg.moe.num_experts * glu * \
        cfg.d_model * cfg.moe.d_ff_expert
    rest = total - expert_params
    p_touch = 1.0 - (1.0 - cfg.moe.top_k / cfg.moe.num_experts) ** tokens
    return (rest + expert_params * min(1.0, p_touch)) * dtype_bytes


def kv_read_bytes(cfg, context_tokens: float, dtype_bytes: int = 2) -> float:
    """KV bytes read for one query token against `context_tokens` cache."""
    per_tok = cfg.kv_bytes_per_token(dtype_bytes)
    if cfg.sliding_window:
        context_tokens = min(context_tokens, cfg.sliding_window)
    return per_tok * context_tokens


def _attn_flops(cfg, q_tokens: float, ctx_tokens: float,
                causal_half: bool) -> float:
    """Score + AV FLOPs across attention layers for q_tokens queries
    attending to ctx_tokens keys (per sequence averages are fine)."""
    if cfg.sliding_window:
        ctx_tokens = min(ctx_tokens, cfg.sliding_window)
    per_layer = 2 * 2 * q_tokens * ctx_tokens * cfg.num_heads * cfg.head_dim
    if causal_half:
        per_layer *= 0.5
    return per_layer * cfg.attn_layer_count


def _ssm_flops(cfg, tokens: float) -> float:
    """Selective-scan / xLSTM recurrence FLOPs (non-matmul part)."""
    if not any(m in ("mamba", "mlstm", "slstm")
               for m in cfg.layer_pattern):
        return 0.0    # pure-attention arch: skip the per-layer walk
    total = 0.0
    for i in range(cfg.num_layers):
        mx = cfg.mixer_at(i)
        if mx == "mamba":
            m = cfg.mamba
            total += 9.0 * tokens * cfg.d_inner * m.d_state
        elif mx == "mlstm":
            x = cfg.xlstm
            din = int(x.proj_factor * cfg.d_model)
            dh = din // x.num_heads
            total += 8.0 * tokens * din * dh
        elif mx == "slstm":
            total += 10.0 * tokens * cfg.d_model
    return total


def _tp_collective_bytes(cfg, tokens: float, tp: int,
                         dtype_bytes: int = 2) -> float:
    """2 all-reduces per block of the (tokens, d_model) slab."""
    if tp <= 1:
        return 0.0
    payload = tokens * cfg.d_model * dtype_bytes
    ring = 2.0 * (tp - 1) / tp
    return 2.0 * cfg.num_layers * payload * ring


def prefill_cost(cfg, seq_lens: Sequence[int], tp: int = 1,
                 dtype_bytes: int = 2) -> StepCost:
    """One prefill step over whole prompts (RAPID: no chunking)."""
    return _prefill_cost(cfg, tuple(seq_lens), tp, dtype_bytes)


@functools.lru_cache(maxsize=65536)
def _prefill_cost(cfg, seq_lens: tuple, tp: int,
                  dtype_bytes: int) -> StepCost:
    T = float(sum(seq_lens))
    if T == 0:
        return ZERO_COST
    n_active = cfg.active_param_count()
    flops = 2.0 * n_active * T + \
        (sum(_attn_flops(cfg, s, s, True) for s in seq_lens)
         if cfg.attn_layer_count else 0.0) + _ssm_flops(cfg, T)
    bytes_ = active_weight_bytes(cfg, int(T), dtype_bytes)
    bytes_ += 2.0 * T * cfg.kv_bytes_per_token(dtype_bytes)  # KV write+read
    bytes_ += 4.0 * T * cfg.d_model * dtype_bytes            # act traffic
    coll = _tp_collective_bytes(cfg, T, tp, dtype_bytes) / max(tp, 1)
    return StepCost(flops, bytes_, coll)


@functools.lru_cache(maxsize=65536)
def chunk_prefill_cost(cfg, chunk_tokens: int, ctx_so_far: int,
                       tp: int = 1, dtype_bytes: int = 2) -> StepCost:
    """One chunk of a chunked prefill: chunk_tokens queries attend to
    (ctx_so_far + chunk) keys — the repeated KV re-read is the chunking
    overhead the paper quantifies in §3.1."""
    T = float(chunk_tokens)
    n_active = cfg.active_param_count()
    flops = 2.0 * n_active * T + \
        _attn_flops(cfg, T, ctx_so_far + T / 2, False) + _ssm_flops(cfg, T)
    bytes_ = active_weight_bytes(cfg, int(T), dtype_bytes)
    bytes_ += kv_read_bytes(cfg, ctx_so_far, dtype_bytes) * 1.0
    bytes_ += 2.0 * T * cfg.kv_bytes_per_token(dtype_bytes)
    bytes_ += 4.0 * T * cfg.d_model * dtype_bytes
    coll = _tp_collective_bytes(cfg, T, tp, dtype_bytes) / max(tp, 1)
    return StepCost(flops, bytes_, coll)


@functools.lru_cache(maxsize=65536)
def decode_cost(cfg, batch: int, ctx_tokens_total: float, tp: int = 1,
                dtype_bytes: int = 2) -> StepCost:
    """One decode iteration: `batch` single-token queries, total live
    context of ctx_tokens_total across the batch."""
    if batch == 0:
        return ZERO_COST
    B = float(batch)
    n_active = cfg.active_param_count()
    flops = 2.0 * n_active * B
    flops += _attn_flops(cfg, B, ctx_tokens_total / B, False)
    flops += _ssm_flops(cfg, B)
    bytes_ = active_weight_bytes(cfg, batch, dtype_bytes)
    bytes_ += kv_read_bytes(cfg, ctx_tokens_total / B, dtype_bytes) * B
    bytes_ += B * cfg.state_bytes_per_seq(dtype_bytes)
    bytes_ += 4.0 * B * cfg.d_model * dtype_bytes
    coll = _tp_collective_bytes(cfg, B, tp, dtype_bytes) / max(tp, 1)
    return StepCost(flops, bytes_, coll)


def kv_transfer_bytes(cfg, prompt_len: int, dtype_bytes: int = 2) -> float:
    """Disaggregated serving: KV moved prefill->decode instance."""
    return float(prompt_len) * cfg.kv_bytes_per_token(dtype_bytes)


def kv_migration_seconds(cfg, context_tokens: int, link_gbps: float,
                         dtype_bytes: int = 2) -> float:
    """Cross-replica preemption/migration: the victim's live context KV
    shipped over the inter-replica link before it can re-enqueue on the
    destination (the cluster rebalancer charges this on every move of a
    running request)."""
    return kv_transfer_bytes(cfg, context_tokens, dtype_bytes) / \
        max(link_gbps, 1e-9) / 1e9
