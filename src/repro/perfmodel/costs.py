"""Analytic per-step FLOP / HBM-byte / collective-byte counts.

These drive the discrete-event simulator's step durations (the container
has no TPU).  The same three terms are independently derived from the
*compiled* HLO by launch/roofline.py for EXPERIMENTS.md §Roofline; tests
assert the analytic and HLO-derived FLOP counts agree within tolerance,
which keeps the simulator honest.

All pricing functions are pure in their arguments, so the step-cost
entry points are memoized (``functools.lru_cache``) on their exact
operating points: the projection autoscaler re-prices identical
``LoadSnapshot``s every tick, the SLO-aware router re-prices repeated
(backlog, batch) pairs per arrival, and hybrid chunk boundaries land on
quantized (chunk, ctx) points — all of which now hit the cache instead
of re-walking the layer pattern.  Cached values are the *same* objects,
so memoization can never change simulator behavior, only its cost.
All caches carry an explicit ``maxsize`` so a fleet-scale trace cannot
grow them without bound; ``cache_stats()`` surfaces hit/miss counters
(bench_hotpath reports them).

The formula bodies live in ``perfmodel.batch`` (the structure-of-arrays
layer the fleet paths price whole replica sets through); the cached
entry points below are N=1 views over it, so there is one formula, not
two, and the batched and scalar paths are bit-identical by
construction.

Conventions:
  * matmul FLOPs = 2*M*N*K;   causal attention scores halved.
  * weights are streamed from HBM once per step (valid for serving batch
    sizes; prefill is compute-bound anyway so its byte term rarely binds).
  * TP collectives: 2 all-reduces per block over the activation slab,
    ring cost 2*(tp-1)/tp of the payload per chip.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

from repro.perfmodel import batch as _batch


@dataclasses.dataclass(frozen=True)
class StepCost:
    flops: float          # total FLOPs for the step (all chips)
    hbm_bytes: float      # HBM traffic per chip-group, summed over chips
    coll_bytes: float     # per-chip collective payload bytes

    def __add__(self, other: "StepCost") -> "StepCost":
        return StepCost(self.flops + other.flops,
                        self.hbm_bytes + other.hbm_bytes,
                        self.coll_bytes + other.coll_bytes)

    def scale(self, k: float) -> "StepCost":
        return StepCost(self.flops * k, self.hbm_bytes * k,
                        self.coll_bytes * k)


ZERO_COST = StepCost(0.0, 0.0, 0.0)


def model_flops_per_token(cfg) -> float:
    """6*N_active per trained token; 2*N_active per inferred token is
    obtained by scaling."""
    return 6.0 * cfg.active_param_count()


# bounded (was maxsize=None): a handful of (cfg, dtype) pairs exist per
# process, but an unbounded cache is a fleet-scale liability on
# principle — every perfmodel cache now carries an explicit ceiling
@functools.lru_cache(maxsize=1024)
def weight_bytes(cfg, dtype_bytes: int = 2) -> float:
    """Bytes of weights streamed per step (MoE: only routed experts are
    read in expectation when the batch is small; we charge min(full,
    per-token-active * tokens) at the call sites)."""
    return cfg.param_count() * dtype_bytes


@functools.lru_cache(maxsize=65536)
def active_weight_bytes(cfg, tokens: int, dtype_bytes: int = 2) -> float:
    """Expected weight bytes touched by `tokens` tokens in one step.

    Dense: all weights.  MoE: each token touches top_k experts; with E
    experts the expected fraction of expert weights touched is
    1-(1-k/E)^tokens, capped at 1.  (N=1 view of the batched formula.)
    """
    return float(_batch.active_weight_bytes(cfg, (tokens,), dtype_bytes)[0])


def kv_read_bytes(cfg, context_tokens: float, dtype_bytes: int = 2) -> float:
    """KV bytes read for one query token against `context_tokens` cache."""
    per_tok = cfg.kv_bytes_per_token(dtype_bytes)
    if cfg.sliding_window:
        context_tokens = min(context_tokens, cfg.sliding_window)
    return per_tok * context_tokens


def prefill_cost(cfg, seq_lens: Sequence[int], tp: int = 1,
                 dtype_bytes: int = 2) -> StepCost:
    """One prefill step over whole prompts (RAPID: no chunking)."""
    return _prefill_cost(cfg, tuple(seq_lens), tp, dtype_bytes)


@functools.lru_cache(maxsize=65536)
def _prefill_cost(cfg, seq_lens: tuple, tp: int,
                  dtype_bytes: int) -> StepCost:
    if not any(seq_lens):
        return ZERO_COST
    return _batch.prefill_cost(cfg, (seq_lens,), tp, dtype_bytes).item(0)


@functools.lru_cache(maxsize=65536)
def chunk_prefill_cost(cfg, chunk_tokens: int, ctx_so_far: int,
                       tp: int = 1, dtype_bytes: int = 2) -> StepCost:
    """One chunk of a chunked prefill: chunk_tokens queries attend to
    (ctx_so_far + chunk) keys — the repeated KV re-read is the chunking
    overhead the paper quantifies in §3.1."""
    return _batch.chunk_prefill_cost(cfg, (chunk_tokens,), (ctx_so_far,),
                                     tp, dtype_bytes).item(0)


@functools.lru_cache(maxsize=65536)
def decode_cost(cfg, batch: int, ctx_tokens_total: float, tp: int = 1,
                dtype_bytes: int = 2) -> StepCost:
    """One decode iteration: `batch` single-token queries, total live
    context of ctx_tokens_total across the batch."""
    if batch == 0:
        return ZERO_COST
    return _batch.decode_cost(cfg, (batch,), (ctx_tokens_total,),
                              tp, dtype_bytes).item(0)


def kv_transfer_bytes(cfg, prompt_len: int, dtype_bytes: int = 2) -> float:
    """Disaggregated serving: KV moved prefill->decode instance."""
    return float(prompt_len) * cfg.kv_bytes_per_token(dtype_bytes)


def kv_migration_seconds(cfg, context_tokens: int, link_gbps: float,
                         dtype_bytes: int = 2) -> float:
    """Cross-replica preemption/migration: the victim's live context KV
    shipped over the inter-replica link before it can re-enqueue on the
    destination (the cluster rebalancer charges this on every move of a
    running request)."""
    return kv_transfer_bytes(cfg, context_tokens, dtype_bytes) / \
        max(link_gbps, 1e-9) / 1e9


def cache_stats() -> dict:
    """hits/misses/size for every memoized perfmodel entry point —
    bench_hotpath surfaces the per-run deltas so cache behavior stays
    visible at fleet scale (a miss now pays the N=1 batch-layer view)."""
    from repro.perfmodel import interference as _interference
    fns = {
        "prefill_cost": _prefill_cost,
        "chunk_prefill_cost": chunk_prefill_cost,
        "decode_cost": decode_cost,
        "active_weight_bytes": active_weight_bytes,
        "weight_bytes": weight_bytes,
        "forecast_phase_times": _interference.forecast_phase_times,
    }
    return {name: fn.cache_info()._asdict() for name, fn in fns.items()}
