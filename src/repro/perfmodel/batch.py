"""Structure-of-arrays pricing: the perfmodel formulas over numpy.

This module is THE formula layer.  The scalar entry points in
``perfmodel.costs`` (``_prefill_cost`` / ``chunk_prefill_cost`` /
``decode_cost``) and ``perfmodel.interference.forecast_phase_times``
are thin N=1 views over the batched functions here, so there is one
formula, not two — and the fleet-facing consumers (``ProjectionPolicy``,
``SloAwareRouter``, the rebalance cost/benefit gate, ``Executor.
price_batch``) price a whole replica fleet in a handful of array ops
per tick instead of per-replica Python.

Bit-identity contract (load-bearing — the golden parity suite and the
fig8–16 smokes pin simulation outputs, and ``bench_hotpath --fleet``
asserts the batched and scalar cluster paths produce identical traces):

  * every elementwise op (``+ - * /``, ``np.minimum``/``np.maximum``/
    ``np.where``, float64 ``**``) is IEEE-754-identical to the CPython
    float op it replaces, so expressions are kept in the scalar code's
    exact association order;
  * reductions NEVER use ``np.sum`` (pairwise summation reassociates
    for n >= 8): ragged per-entry sums accumulate column-by-column in
    the scalar code's left-to-right order, and integer token totals
    use exact int64 sums;
  * where the scalar code does exact *integer* arithmetic before its
    first float conversion (causal attention FLOPs over int sequence
    lengths, KV read bytes over an int context), the batched path does
    the same product in int64 and converts once, at the same point.

Everything here is plain numpy on float64/int64 and restricted to the
jax-transliterable op set (elementwise arithmetic, ``where``, ``clip``-
style min/max, fixed-trip-count loops over *layers*, never over
entries) — the door to on-accelerator pricing with jax_pallas
(ROADMAP item 1).  No Python loops over batch entries anywhere in the
formula paths.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

# §3.4 memory-subsystem interference (fractional slowdown of the HBM
# term when the other phase is co-resident).  Defined here — the formula
# layer — and re-exported by ``perfmodel.interference`` under the same
# names.
MEM_INTERFERENCE_PREFILL = 0.02
MEM_INTERFERENCE_DECODE = 0.035   # paper: 2-5% avg

_STEP_COST = None


def _step_cost_cls():
    # costs.py imports this module at its own top level, so the scalar
    # StepCost class is resolved lazily (and cached) here
    global _STEP_COST
    if _STEP_COST is None:
        from repro.perfmodel.costs import StepCost
        _STEP_COST = StepCost
    return _STEP_COST


@dataclasses.dataclass(frozen=True)
class StepCostBatch:
    """Array-of-StepCost: one (flops, hbm_bytes, coll_bytes) triple per
    entry, each a float64 ``(n,)`` array.  Entry ``i`` is exactly the
    ``StepCost`` the scalar formulas would have produced for entry
    ``i``'s operating point (see the module docstring's bit-identity
    contract)."""
    flops: np.ndarray
    hbm_bytes: np.ndarray
    coll_bytes: np.ndarray

    def __len__(self) -> int:
        return self.flops.shape[0]

    def item(self, i: int):
        """Entry ``i`` as a scalar ``StepCost``."""
        return _step_cost_cls()(
            float(self.flops[i]), float(self.hbm_bytes[i]),
            float(self.coll_bytes[i]))


def zeros(n: int) -> StepCostBatch:
    return StepCostBatch(np.zeros(n), np.zeros(n), np.zeros(n))


def pack_costs(costs: Sequence[Optional[object]]
               ) -> "tuple[StepCostBatch, np.ndarray]":
    """Pack scalar ``Optional[StepCost]`` entries into a batch plus a
    presence mask.  ``None`` is NOT a zero cost: ``forecast_phase_times``
    applies memory interference to a phase whenever the *other* phase is
    present, even at zero cost — the mask carries that distinction."""
    mask = np.array([c is not None for c in costs], dtype=bool)
    flops = np.array([c.flops if c is not None else 0.0 for c in costs])
    hbm = np.array([c.hbm_bytes if c is not None else 0.0 for c in costs])
    coll = np.array([c.coll_bytes if c is not None else 0.0 for c in costs])
    return StepCostBatch(flops, hbm, coll), mask


# ---------------------------------------------------------------------------
# cost formulas (perfmodel.costs, vectorized)
# ---------------------------------------------------------------------------


def _seq_matrix(seqs) -> np.ndarray:
    """Ragged per-entry sequence lengths as a zero-padded int64 matrix.
    Fast path: the fleet tick and router paths price exactly one
    (backlog) sequence per entry, which maps straight to a column."""
    n = len(seqs)
    first = len(seqs[0]) if n else 0
    if n and all(len(s) == first for s in seqs):
        if first == 0:
            return np.zeros((n, 1), dtype=np.int64)
        return np.asarray(seqs, dtype=np.int64).reshape(n, first)
    width = max((len(s) for s in seqs), default=0)
    mat = np.zeros((n, max(width, 1)), dtype=np.int64)
    for i, s in enumerate(seqs):
        if len(s):
            mat[i, :len(s)] = s
    return mat


def _attn_flops_int(cfg, seq_mat: np.ndarray) -> np.ndarray:
    """Causal attention FLOPs summed over each entry's sequences.

    Mirrors ``sum(_attn_flops(cfg, s, s, True) for s in seq_lens)``:
    the per-sequence product is exact integer arithmetic up to the
    single ``* 0.5`` float conversion, and the per-entry sum runs
    left-to-right (column by column) like Python's ``sum`` — padding
    zeros are exact no-ops on the non-negative partial sums.
    """
    ctx = np.minimum(seq_mat, cfg.sliding_window) if cfg.sliding_window \
        else seq_mat
    prod = 2 * 2 * seq_mat * ctx * cfg.num_heads * cfg.head_dim  # exact i64
    per_seq = prod.astype(np.float64) * 0.5
    per_seq = per_seq * cfg.attn_layer_count
    total = np.zeros(seq_mat.shape[0])
    for k in range(seq_mat.shape[1]):
        total = total + per_seq[:, k]
    return total


def _attn_flops_f(cfg, q_tokens: np.ndarray,
                  ctx_tokens: np.ndarray) -> np.ndarray:
    """Non-causal attention FLOPs for float query/context counts
    (chunked prefill and decode average over the batch)."""
    if cfg.sliding_window:
        ctx_tokens = np.minimum(ctx_tokens, cfg.sliding_window)
    per_layer = 2 * 2 * q_tokens * ctx_tokens * cfg.num_heads * cfg.head_dim
    return per_layer * cfg.attn_layer_count


def _has_ssm(cfg) -> bool:
    # config-static; stashed on the instance like config.py's own
    # derived-property memos (the N=1 views hit this per cache miss)
    v = cfg.__dict__.get("_batch_has_ssm")
    if v is None:
        v = any(m in ("mamba", "mlstm", "slstm") for m in cfg.layer_pattern)
        cfg.__dict__["_batch_has_ssm"] = v
    return v


def _ssm_flops(cfg, tokens: np.ndarray) -> np.ndarray:
    """Selective-scan / xLSTM recurrence FLOPs.  The walk is over the
    *layer pattern* (bounded, config-static) — each layer's term is the
    scalar expression evaluated once and re-added in layer order, which
    reproduces the scalar accumulation bit-for-bit."""
    if not _has_ssm(cfg):
        return np.zeros_like(tokens)
    terms = {}
    for mx in set(cfg.layer_pattern):
        if mx == "mamba":
            m = cfg.mamba
            terms[mx] = 9.0 * tokens * cfg.d_inner * m.d_state
        elif mx == "mlstm":
            x = cfg.xlstm
            din = int(x.proj_factor * cfg.d_model)
            dh = din // x.num_heads
            terms[mx] = 8.0 * tokens * din * dh
        elif mx == "slstm":
            terms[mx] = 10.0 * tokens * cfg.d_model
    total = np.zeros_like(tokens)
    for i in range(cfg.num_layers):
        t = terms.get(cfg.mixer_at(i))
        if t is not None:
            total = total + t
    return total


def _tp_collective_bytes(cfg, tokens: np.ndarray, tp,
                         dtype_bytes: int) -> np.ndarray:
    if not isinstance(tp, np.ndarray):
        # scalar tp (the executor and N=1-view path): same arithmetic on
        # Python floats — IEEE-identical, ~half the ufunc dispatches
        if tp <= 1:
            return np.zeros_like(tokens, dtype=np.float64)
        payload = tokens * cfg.d_model * dtype_bytes
        ring = 2.0 * (tp - 1) / tp
        return 2.0 * cfg.num_layers * payload * ring
    gt1 = tp > 1
    tp_safe = np.where(gt1, tp, 2)
    payload = tokens * cfg.d_model * dtype_bytes
    ring = 2.0 * (tp_safe - 1) / tp_safe
    out = 2.0 * cfg.num_layers * payload * ring
    return np.where(gt1, out, 0.0)


def active_weight_bytes(cfg, tokens, dtype_bytes: int = 2) -> np.ndarray:
    """Vectorized ``costs.active_weight_bytes`` over int64 token counts."""
    tokens = np.asarray(tokens, dtype=np.int64)
    if cfg.moe is None:
        return np.full(tokens.shape, float(cfg.param_count() * dtype_bytes))
    split = cfg.__dict__.get("_batch_moe_split")
    if split is None:
        moe_layers = sum(1 for i in range(cfg.num_layers)
                         if cfg.ffn_at(i) == "moe")
        glu = 3
        expert_params = moe_layers * cfg.moe.num_experts * glu * \
            cfg.d_model * cfg.moe.d_ff_expert
        split = (cfg.param_count() - expert_params, expert_params)
        cfg.__dict__["_batch_moe_split"] = split
    rest, expert_params = split
    p_touch = 1.0 - (1.0 - cfg.moe.top_k / cfg.moe.num_experts) ** tokens
    return (rest + expert_params * np.minimum(1.0, p_touch)) * dtype_bytes


def _kv_read_bytes_f(cfg, context_tokens: np.ndarray,
                     dtype_bytes: int) -> np.ndarray:
    per_tok = cfg.kv_bytes_per_token(dtype_bytes)
    if cfg.sliding_window:
        context_tokens = np.minimum(context_tokens, cfg.sliding_window)
    return per_tok * context_tokens


def _per_chip(coll: np.ndarray, tp) -> np.ndarray:
    """Collective payload per chip: divide by tp (clamped to >= 1)."""
    if not isinstance(tp, np.ndarray):
        return coll / max(tp, 1)
    return coll / np.maximum(tp, 1)


def _mask_cost(nz: np.ndarray, flops, bytes_, coll) -> StepCostBatch:
    if nz.all():          # common case: selecting everything is identity
        return StepCostBatch(flops, bytes_, coll)
    return StepCostBatch(np.where(nz, flops, 0.0),
                         np.where(nz, bytes_, 0.0),
                         np.where(nz, coll, 0.0))


def prefill_cost(cfg, seqs: Sequence[Sequence[int]], tp=1,
                 dtype_bytes: int = 2) -> StepCostBatch:
    """One prefill step per entry over whole prompts.  ``seqs[i]`` is
    entry ``i``'s prompt-length tuple; ``tp`` is an int or per-entry
    int array (the executor passes chips as tp)."""
    seq_mat = _seq_matrix(seqs)
    if isinstance(tp, (list, tuple, np.ndarray)):
        tp = np.asarray(tp, dtype=np.int64)
    t_int = seq_mat.sum(axis=1)          # exact int64 token totals
    t = t_int.astype(np.float64)
    nz = t_int != 0
    n_active = cfg.active_param_count()
    flops = 2.0 * n_active * t
    if cfg.attn_layer_count:
        flops = flops + _attn_flops_int(cfg, seq_mat)
    else:
        flops = flops + 0.0
    if _has_ssm(cfg):
        flops = flops + _ssm_flops(cfg, t)
    bytes_ = active_weight_bytes(cfg, t_int, dtype_bytes)
    bytes_ = bytes_ + 2.0 * t * cfg.kv_bytes_per_token(dtype_bytes)
    bytes_ = bytes_ + 4.0 * t * cfg.d_model * dtype_bytes
    coll = _per_chip(_tp_collective_bytes(cfg, t, tp, dtype_bytes), tp)
    return _mask_cost(nz, flops, bytes_, coll)


def chunk_prefill_cost(cfg, chunk_tokens, ctx_so_far, tp=1,
                       dtype_bytes: int = 2) -> StepCostBatch:
    """One chunk of a chunked prefill per entry: ``chunk_tokens[i]``
    queries attending to ``ctx_so_far[i] + chunk/2`` keys on average."""
    chunk = np.asarray(chunk_tokens, dtype=np.int64)
    ctx_i = np.asarray(ctx_so_far, dtype=np.int64)
    if isinstance(tp, (list, tuple, np.ndarray)):
        tp = np.asarray(tp, dtype=np.int64)
    t = chunk.astype(np.float64)
    n_active = cfg.active_param_count()
    flops = 2.0 * n_active * t
    flops = flops + _attn_flops_f(cfg, t, ctx_i + t / 2)
    if _has_ssm(cfg):
        flops = flops + _ssm_flops(cfg, t)
    bytes_ = active_weight_bytes(cfg, chunk, dtype_bytes)
    # KV re-read of the whole context so far: exact integer product,
    # converted at the scalar code's ``* 1.0``
    ctx_clip = np.minimum(ctx_i, cfg.sliding_window) if cfg.sliding_window \
        else ctx_i
    bytes_ = bytes_ + cfg.kv_bytes_per_token(dtype_bytes) * ctx_clip * 1.0
    bytes_ = bytes_ + 2.0 * t * cfg.kv_bytes_per_token(dtype_bytes)
    bytes_ = bytes_ + 4.0 * t * cfg.d_model * dtype_bytes
    coll = _per_chip(_tp_collective_bytes(cfg, t, tp, dtype_bytes), tp)
    return StepCostBatch(flops, bytes_, coll)


def decode_cost(cfg, batch, ctx_tokens_total, tp=1,
                dtype_bytes: int = 2) -> StepCostBatch:
    """One decode iteration per entry: ``batch[i]`` single-token queries
    over ``ctx_tokens_total[i]`` live context tokens."""
    batch = np.asarray(batch, dtype=np.int64)
    ctx = np.asarray(ctx_tokens_total, dtype=np.float64)
    if isinstance(tp, (list, tuple, np.ndarray)):
        tp = np.asarray(tp, dtype=np.int64)
    nz = batch != 0
    all_nz = bool(nz.all())
    b = batch.astype(np.float64)
    b_safe = b if all_nz else np.where(nz, b, 1.0)
    ctx_per = ctx / b_safe
    n_active = cfg.active_param_count()
    flops = 2.0 * n_active * b
    flops = flops + _attn_flops_f(cfg, b, ctx_per)
    if _has_ssm(cfg):
        flops = flops + _ssm_flops(cfg, b)
    bytes_ = active_weight_bytes(cfg, batch, dtype_bytes)
    bytes_ = bytes_ + _kv_read_bytes_f(cfg, ctx_per, dtype_bytes) * b
    bytes_ = bytes_ + b * cfg.state_bytes_per_seq(dtype_bytes)
    bytes_ = bytes_ + 4.0 * b * cfg.d_model * dtype_bytes
    coll = _per_chip(_tp_collective_bytes(cfg, b, tp, dtype_bytes), tp)
    if all_nz:
        return StepCostBatch(flops, bytes_, coll)
    return _mask_cost(nz, flops, bytes_, coll)


# ---------------------------------------------------------------------------
# interference / forecast (perfmodel.interference, vectorized)
# ---------------------------------------------------------------------------


def phase_time(cost: StepCostBatch, hw, chips, f=1.0,
               mem_interference=0.0, bw_share=1.0) -> np.ndarray:
    """Vectorized ``interference.phase_time``: per-entry duration under
    per-entry issue-capacity fractions / interference terms."""
    if isinstance(chips, (list, tuple, np.ndarray)):
        chips = np.asarray(chips, dtype=np.int64)
    f_c = np.maximum(f, 1e-3) if isinstance(f, np.ndarray) \
        else max(f, 1e-3)
    zero = (cost.flops == 0) & (cost.hbm_bytes == 0)
    t_compute = cost.flops / (chips * hw.peak_flops * f_c)
    t_mem = cost.hbm_bytes * (1.0 + mem_interference) / \
        (chips * hw.hbm_bw * bw_share)
    t_coll = cost.coll_bytes / hw.ici_bw
    t = np.maximum(t_compute, t_mem) + t_coll + hw.launch_overhead_s
    return np.where(zero, 0.0, t)


def compute_utilization(cost: StepCostBatch, hw, chips) -> np.ndarray:
    """Vectorized ``interference.compute_utilization``."""
    if isinstance(chips, (list, tuple, np.ndarray)):
        chips = np.asarray(chips, dtype=np.int64)
    t_c = cost.flops / (chips * hw.peak_flops)
    t_m = cost.hbm_bytes / (chips * hw.hbm_bw)
    t_coll = cost.coll_bytes / hw.ici_bw
    denom = np.maximum(t_m, t_c) + t_coll
    pos = denom > 0
    u = np.minimum(1.0, t_c / np.where(pos, denom, 1.0))
    return np.where(pos, u, 0.0)


def forecast_phase_times(p_cost: StepCostBatch, d_cost: StepCostBatch,
                         hw, chips_p, chips_d, *,
                         colocated, p_mask=None, d_mask=None,
                         f_decode=None) -> "tuple[np.ndarray, np.ndarray]":
    """Vectorized ``interference.forecast_phase_times``: projected
    ``(t_prefill, t_decode)`` arrays for a fleet of replica load points.

    ``p_mask`` / ``d_mask`` mark which entries carry a phase at all
    (the scalar API's ``None`` costs — absence is not zero cost, see
    ``pack_costs``).  ``f_decode`` is a float array where NaN selects
    overallocation (the scalar API's ``None``) and a finite value the
    distinct split; ``colocated`` is a per-entry bool array.  Every
    branch of the scalar overlap model is evaluated elementwise and
    selected with ``np.where``, so each entry gets bit-identical math
    to the scalar path it replaces.
    """
    n = len(p_cost)
    # scalar knobs stay scalar — every op below broadcasts, and the
    # result shape (n,) is pinned by the cost arrays themselves
    if np.ndim(chips_p):
        chips_p = np.broadcast_to(np.asarray(chips_p, dtype=np.int64), (n,))
    if np.ndim(chips_d):
        chips_d = np.broadcast_to(np.asarray(chips_d, dtype=np.int64), (n,))
    if np.ndim(colocated):
        colocated = np.broadcast_to(np.asarray(colocated, dtype=bool), (n,))
    else:
        colocated = bool(colocated)
    pm = True if p_mask is None else p_mask
    dm = True if d_mask is None else d_mask
    if np.ndim(pm) == 0:
        pm = bool(pm)
    if np.ndim(dm) == 0:
        dm = bool(dm)
    if f_decode is None:
        f_decode = np.nan
    elif np.ndim(f_decode):
        f_decode = np.broadcast_to(
            np.asarray(f_decode, dtype=np.float64), (n,))

    with np.errstate(invalid="ignore", divide="ignore"):
        # solo durations (also the non-colocated per-pool path)
        t_p_solo = phase_time(p_cost, hw, chips_p)
        t_d_solo_p = phase_time(d_cost, hw, chips_p)   # colocated, p absent
        t_d_solo_d = phase_time(d_cost, hw, chips_d)   # split decode pool
        # overallocation: shares proportional to standalone demand
        u_d = compute_utilization(d_cost, hw, chips_p)
        u_p = compute_utilization(p_cost, hw, chips_p)
        share_d = u_d / np.maximum(u_d + u_p, 1e-9)
        share_p = 1.0 - share_d
        t_d_ov = phase_time(d_cost, hw, chips_p,
                            f=np.maximum(share_d, 1e-3),
                            mem_interference=MEM_INTERFERENCE_DECODE)
        t_p_ov = phase_time(p_cost, hw, chips_p,
                            f=np.maximum(share_p, 1e-3),
                            mem_interference=MEM_INTERFERENCE_PREFILL)
        # distinct split (NaN f_decode entries resolve to the overalloc
        # branch below; their NaNs are selected away)
        f_d = np.minimum(np.maximum(f_decode, 0.05), 0.95)
        f_p = 1.0 - f_d
        t_d_di = phase_time(d_cost, hw, chips_p, f=f_d,
                            mem_interference=MEM_INTERFERENCE_DECODE)
        t_p_di = phase_time(p_cost, hw, chips_p, f=f_p,
                            mem_interference=MEM_INTERFERENCE_PREFILL)

    both = pm & dm
    distinct = both & ~np.isnan(f_decode)
    coupled_p = np.where(distinct, t_p_di, t_p_ov)
    coupled_d = np.where(distinct, t_d_di, t_d_ov)
    t_p = np.where(colocated & both, coupled_p,
                   np.where(pm, t_p_solo, 0.0))
    t_d = np.where(colocated,
                   np.where(both, coupled_d,
                            np.where(dm, t_d_solo_p, 0.0)),
                   np.where(dm, t_d_solo_d, 0.0))
    return t_p, t_d
