"""Per-replica engine worker for the serving gateway.

A ``ReplicaWorker`` owns one ``Engine`` wrapped in the cluster-layer
``Replica`` record (so the router zoo, admission controller and metrics
all see the shape they already know), forwards the engine's typed event
stream (core/events.py) to the gateway, and sends periodic heartbeats
through the shared clock.  The gateway's registry declares a worker dead
when its heartbeats stop — which is exactly what ``kill()`` does, so a
simulated crash and a real hung process take the same code path.

Lifecycle::

    UP ──start_drain()──▶ DRAINING ──retire()──▶ RETIRED
     └──kill()/timeout──▶ DEAD

``kill()`` models an abrupt crash: the engine is halted in place (its
scheduler is swapped for one that plans nothing; in-flight lane
completions drain harmlessly), heartbeats stop, and every event the
crashed engine still emits is dropped at the forwarding boundary — the
gateway never sees tokens from a zombie.  Crucially ``kill()`` does NOT
flip ``state`` — the worker has crashed but nobody *knows* yet; the
registry's health tick notices the missing heartbeats after
``heartbeat_timeout_s`` and calls ``mark_dead()``, which is when
failover runs.  Recovery is the *gateway's* job (serving/gateway.py
re-submits clones elsewhere); the worker only guarantees the crash is
contained.
"""
from __future__ import annotations

import enum
from typing import Callable

from repro.core.request import Request
from repro.serving.cluster import Replica
from repro.core.queues import IndexedQueue


class WorkerState(enum.Enum):
    UP = "up"
    DRAINING = "draining"   # no new work; finishing what it has
    DEAD = "dead"           # crashed / heartbeat timeout
    RETIRED = "retired"     # drained clean and deregistered


class ReplicaWorker:
    """One engine + its gateway-facing plumbing.

    ``sink(worker, event)`` receives every live engine event (the
    gateway fans these into per-request channels and its fleet metrics
    stream).  Heartbeats are scheduled through ``clock`` and re-armed
    only while ``keep_alive()`` is true, so a simulated run terminates
    once no request remains in flight.
    """

    def __init__(self, wid: int, mode: str, engine, serve,
                 clock, sink: Callable, heartbeat: Callable[[int], None],
                 keep_alive: Callable[[], bool],
                 heartbeat_s: float = 0.5):
        self.wid = wid
        self.state = WorkerState.UP
        self.clock = clock
        self.replica = Replica(idx=wid, mode=mode, engine=engine,
                               serve=serve, assigned=IndexedQueue(
                                   serve.page_size))
        self._sink = sink
        self._heartbeat = heartbeat
        self._keep_alive = keep_alive
        self.heartbeat_s = heartbeat_s
        self._beat_armed = False
        self.crashed = False         # ground truth; state lags detection
        self.death_handled = False   # gateway's failover-ran-once latch
        self._suppressed_beats = 0   # fault injection: heartbeat flap
        engine.subscribe(self._forward)

    # -- identity / views ---------------------------------------------------

    @property
    def mode(self) -> str:
        return self.replica.mode

    @property
    def name(self) -> str:
        return f"{self.replica.mode}-{self.wid}"

    @property
    def engine(self):
        return self.replica.engine

    def idle(self) -> bool:
        """Nothing queued, running, or mid-step on any lane."""
        eng = self.engine
        return (len(eng.running) == 0
                and all(len(q) == 0 for q in eng.queues.values())
                and not eng.prefill_busy and not eng.decode_busy
                and not eng.busy)

    # -- event forwarding ---------------------------------------------------

    def _forward(self, ev) -> None:
        # a crashed engine's in-flight lane completions may still emit;
        # drop them here so the gateway never streams zombie tokens
        if self.crashed or self.state is WorkerState.DEAD:
            return
        self._sink(self, ev)

    # -- request plumbing ---------------------------------------------------

    def submit(self, r: Request) -> None:
        self.replica.assigned.append(r)
        self.engine.submit(r)

    def evict(self, r: Request) -> bool:
        """Targeted removal (slow-consumer backpressure).  False when the
        request is pinned inside an in-flight lane step — the caller
        retries after the step completes."""
        ok = self.engine.evict_request(r)
        if ok and r in self.replica.assigned:
            self.replica.assigned.remove(r)
        return ok

    # -- lifecycle ----------------------------------------------------------

    def kill(self) -> None:
        """Abrupt crash: halt the engine and go silent.  ``state`` is
        NOT flipped — detection (and failover) waits for the registry's
        heartbeat timeout, like a real hung process."""
        if self.crashed or self.state in (WorkerState.DEAD,
                                          WorkerState.RETIRED):
            return
        self.crashed = True
        self.engine.halt()

    def mark_dead(self) -> None:
        """Registry verdict after missed heartbeats: the worker is gone
        for routing purposes and the gateway's failover may run."""
        if self.state in (WorkerState.DEAD, WorkerState.RETIRED):
            return
        self.crashed = True
        self.state = WorkerState.DEAD
        self.replica.routable = False
        self.engine.halt()

    def start_drain(self) -> None:
        """Stop accepting new work; existing requests run to completion
        (the gateway migrates what it can to other workers first)."""
        if self.state is WorkerState.UP:
            self.state = WorkerState.DRAINING
            self.replica.routable = False

    def retire(self) -> None:
        if self.state is WorkerState.DRAINING:
            self.state = WorkerState.RETIRED

    # -- heartbeats ---------------------------------------------------------

    def ensure_beat(self) -> None:
        """Arm the periodic heartbeat if it is not already scheduled."""
        if not self._beat_armed:
            self._beat_armed = True
            self.clock.after(self.heartbeat_s, self._beat)

    def suppress_beats(self, n: int) -> None:
        """Fault injection: swallow the next ``n`` heartbeats while the
        worker keeps running (GC pause / network flap).  If ``n *
        heartbeat_s`` stays under the registry's ``heartbeat_timeout_s``
        the flap must be invisible — no failover (pinned in
        tests/test_gateway_churn.py)."""
        self._suppressed_beats = max(self._suppressed_beats, n)

    def _beat(self) -> None:
        self._beat_armed = False
        if self.crashed or self.state in (WorkerState.DEAD,
                                          WorkerState.RETIRED):
            return                      # crashed workers fall silent
        if self._suppressed_beats > 0:
            self._suppressed_beats -= 1     # flapping: alive but silent
        else:
            self._heartbeat(self.wid)
        if self._keep_alive():
            self.ensure_beat()
