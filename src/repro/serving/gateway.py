"""Online serving gateway: admission, routing, streaming, failover.

The gateway is the asyncio-shaped front-end the paper's serving stack
has been building toward: it owns cluster-side admission
(serving/admission.py) and routing (the serving/cluster.py router zoo,
including session affinity), keeps a registry of per-replica engine
workers with heartbeat health checks, and forwards each engine's typed
event stream (core/events.py) into bounded per-request channels — the
same events serialize as JSON lines for the HTTP surface
(serving/http.py), so the PR-3 event stream IS the wire format.

Everything is scheduled through a *clock* (serving/clock.py): under the
simulated ``EventLoop`` the whole gateway — heartbeats, crash
detection, failover, drains, backpressure — runs deterministically in
CI with no sockets or sleeps; under ``RealTimeClock`` the same code
serves real HTTP clients.

Churn semantics (tests/test_gateway_churn.py):

  * **Worker crash.**  ``kill_worker`` halts the engine and stops its
    heartbeats; the registry declares it dead after
    ``heartbeat_timeout_s`` and the gateway re-submits every in-flight
    request as a fresh clone on a healthy worker (re-prefill from
    scratch; the session prefix may shortcut it on a session-affine
    worker).  The per-request channel dedupes the replayed token
    indices, so a consumer sees one contiguous stream; ``retries`` on
    the final record counts the failovers.  When retries are exhausted
    or no healthy worker remains, the request ends with a typed
    ``RejectedEvent(reason="worker_lost")`` — accepted requests never
    vanish silently.
  * **Rolling upgrade.**  ``drain_worker`` stops routing to a worker,
    migrates its queued (KV-free) requests away via the existing
    migration machinery, lets in-flight decodes finish in place, then
    retires and deregisters it.  ``rolling_upgrade`` chains
    add-replacement → drain-old across the fleet, one worker at a time.
  * **Slow consumer.**  A per-request channel that fills to
    ``stream_buffer`` pauses *its own* request — the gateway evicts it
    from its engine (freeing KV for everyone else) and re-admits it
    when the consumer drains.  Other streams are unaffected.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Dict, List, Optional, Set

from repro.core.events import (CancelledEvent, EventStream, FinishedEvent,
                               PhaseEvent, RejectedEvent, TERMINAL_EVENTS,
                               TokenEvent)
from repro.core.request import Request, State
from repro.kvcache import CheckpointStore, KVCheckpoint
from repro.perfmodel.costs import kv_migration_seconds
from repro.perfmodel.hw import TPU_V5E, HardwareSpec
from repro.serving.admission import AdmissionController, AdmissionPolicy
from repro.serving.cluster import make_router
from repro.serving.faults import RetryPolicy
from repro.serving.metrics import (RequestRecord, StreamMetrics,
                                   fleet_summarize)
from repro.serving.sim import EventLoop
from repro.serving.worker import ReplicaWorker, WorkerState


@dataclasses.dataclass(frozen=True)
class GatewayPolicy:
    """Gateway-level knobs (admission knobs live in AdmissionPolicy).

    ``heartbeat_timeout_s`` should exceed ``heartbeat_s`` by a safety
    factor (default ~3.5 beats) so one delayed beat never triggers a
    spurious failover.  ``stream_buffer`` bounds each request's channel;
    a consumer that falls that far behind gets its request evicted from
    the engine (backpressure) until it drains below
    ``stream_buffer * resume_frac``.

    ``checkpoint_interval`` > 0 enables crash-consistent KV recovery:
    every that-many delivered tokens the gateway snapshots the request's
    KV off the worker (the copy is costed with the perfmodel's
    ``kv_migration_seconds`` at ``checkpoint_gbps``, defaulting to the
    serve config's ``kv_transfer_gbps``), and crash failover resumes
    from the newest snapshot instead of re-prefilling — re-computing at
    most ``checkpoint_interval`` tokens.  ``checkpoint_store_blocks``
    caps the parked-KV budget (0 = unbounded)."""
    heartbeat_s: float = 0.5
    heartbeat_timeout_s: float = 1.75
    health_check_s: float = 0.5
    drain_check_s: float = 0.25
    stream_buffer: int = 64
    resume_frac: float = 0.5
    max_retries: int = 2
    evict_retry_s: float = 0.05     # re-try eviction pinned mid-step
    checkpoint_interval: int = 0    # tokens between KV snapshots (0=off)
    checkpoint_gbps: float = 0.0    # snapshot link speed (0 => serve cfg)
    checkpoint_store_blocks: int = 0    # parked-KV budget (0 = unbounded)


class RequestChannel:
    """Bounded per-request event channel between a worker and a consumer.

    ``offer`` is the producer side (gateway); it **dedupes token
    replays** — after a crash failover the clone re-generates tokens
    from index 0, and only the first occurrence of each index passes —
    so consumers always see one contiguous token stream per request.

    Consumption is either *inline* (a ``consumer`` callable invoked at
    offer time — no buffering, used by the simulated trace driver) or
    *pulled* (``take``/``drain`` on the internal deque, used by the HTTP
    server; ``notify`` pokes the async waiter).  When the buffer
    reaches ``capacity`` the channel flags itself paused and tells the
    gateway via ``on_pause``; draining below ``resume_at`` fires
    ``on_resume``.  Terminal events are always accepted — capacity is a
    backpressure watermark, not a hard drop."""

    def __init__(self, rid: int, capacity: int = 64,
                 resume_at: Optional[int] = None,
                 consumer: Optional[Callable] = None,
                 notify: Optional[Callable[[], None]] = None,
                 on_pause: Optional[Callable[[int], None]] = None,
                 on_resume: Optional[Callable[[int], None]] = None):
        self.rid = rid
        self.capacity = capacity
        self.resume_at = capacity // 2 if resume_at is None else resume_at
        self._consumer = consumer
        self._notify = notify
        self._on_pause = on_pause
        self._on_resume = on_resume
        self.buf: collections.deque = collections.deque()
        self.next_index = 0          # next un-seen token index
        self.closed = False          # terminal event passed through
        self.paused = False
        self.stalled = False         # fault injection: consumer wedged
        self.dup_tokens = 0          # replayed indices suppressed (failover)
        self.gap_tokens = 0          # ahead-of-stream indices (wire loss)

    def offer(self, ev) -> bool:
        """Deliver ``ev``; False when it was a duplicate (replayed token
        index) or the channel already closed."""
        if self.closed:
            return False
        if isinstance(ev, TokenEvent):
            if ev.index != self.next_index:
                # replayed (failover) or out of order; the split counter
                # is the recovery cost metric: dup_tokens is exactly the
                # tokens the failover re-computed for this request
                if ev.index < self.next_index:
                    self.dup_tokens += 1
                else:
                    self.gap_tokens += 1
                return False
            self.next_index += 1
        if isinstance(ev, TERMINAL_EVENTS):
            self.closed = True
        if self._consumer is not None and not self.stalled:
            self._consumer(ev)
            return True
        self.buf.append(ev)
        if self._notify is not None:
            self._notify()
        if (not self.closed and not self.paused
                and len(self.buf) >= self.capacity):
            self.paused = True
            if self._on_pause is not None:
                self._on_pause(self.rid)
        return True

    def take(self):
        """Pop the oldest buffered event (None when empty)."""
        ev = self.buf.popleft() if self.buf else None
        self._maybe_resume()
        return ev

    def drain(self) -> List:
        out = list(self.buf)
        self.buf.clear()
        self._maybe_resume()
        return out

    def stall(self) -> None:
        """Fault injection: wedge the consumer — even inline consumers
        start buffering, so the backpressure watermark (pause/evict)
        engages exactly as for a genuinely slow reader."""
        self.stalled = True

    def unstall(self) -> None:
        """Un-wedge: flush everything buffered during the stall to the
        inline consumer (pull-mode consumers drain themselves)."""
        self.stalled = False
        if self._consumer is not None:
            while self.buf:
                self._consumer(self.buf.popleft())
        self._maybe_resume()

    def _maybe_resume(self) -> None:
        if self.paused and len(self.buf) <= self.resume_at:
            self.paused = False
            if self._on_resume is not None:
                self._on_resume(self.rid)

    @property
    def done(self) -> bool:
        """Closed AND fully consumed."""
        return self.closed and not self.buf

    def __len__(self) -> int:
        return len(self.buf)


class WorkerRegistry:
    """Tracks workers, their heartbeats, and declares the silent dead.

    ``replicas`` is the live ``Replica`` list the router binds to (same
    contract as ``Cluster.replicas`` — later registrations are visible).
    The periodic health tick compares each worker's last heartbeat
    against ``heartbeat_timeout_s``; a crashed worker stops beating
    (``ReplicaWorker.kill``) and is marked dead here, which triggers the
    gateway's failover exactly once per death."""

    def __init__(self, clock, policy: GatewayPolicy,
                 on_death: Callable[[ReplicaWorker], None],
                 keep_alive: Callable[[], bool]):
        self.clock = clock
        self.policy = policy
        self.workers: Dict[int, ReplicaWorker] = {}
        self.replicas: List = []     # router-facing live list
        self.last_beat: Dict[int, float] = {}
        self._on_death = on_death
        self._keep_alive = keep_alive
        self._tick_armed = False
        self.fenced_beats = 0        # beats refused from dead/unknown wids

    def register(self, w: ReplicaWorker) -> None:
        self.workers[w.wid] = w
        self.replicas.append(w.replica)
        self.last_beat[w.wid] = self.clock.now
        w.ensure_beat()
        self.ensure_tick()

    def deregister(self, wid: int) -> None:
        w = self.workers.pop(wid, None)
        if w is not None:
            if w.replica in self.replicas:
                self.replicas.remove(w.replica)
            self.last_beat.pop(wid, None)

    def heartbeat(self, wid: int) -> None:
        """Record a beat — unless the sender was already declared dead
        (or never registered).  Fencing: a worker that went silent past
        ``heartbeat_timeout_s`` had its requests failed over; letting a
        late beat resurrect it would double-serve them.  A fenced worker
        can only rejoin as a *fresh* worker via ``add_worker``."""
        w = self.workers.get(wid)
        if w is None or w.state in (WorkerState.DEAD, WorkerState.RETIRED):
            self.fenced_beats += 1
            return
        self.last_beat[wid] = self.clock.now

    def healthy(self) -> List[ReplicaWorker]:
        return [w for w in self.workers.values()
                if w.state is WorkerState.UP and not w.crashed]

    # -- periodic health check ----------------------------------------------

    def ensure_tick(self) -> None:
        if not self._tick_armed:
            self._tick_armed = True
            self.clock.after(self.policy.health_check_s, self._health_tick)

    def _health_tick(self) -> None:
        self._tick_armed = False
        now = self.clock.now
        for w in list(self.workers.values()):
            if (w.state in (WorkerState.UP, WorkerState.DRAINING)
                    and now - self.last_beat.get(w.wid, now)
                    > self.policy.heartbeat_timeout_s):
                w.mark_dead()
        for w in list(self.workers.values()):
            if w.state is WorkerState.DEAD and not w.death_handled:
                w.death_handled = True
                if w.replica in self.replicas:
                    self.replicas.remove(w.replica)
                self._on_death(w)
        if self._keep_alive():
            self.ensure_tick()

    def resume_ticks(self) -> None:
        """Re-arm heartbeats + health tick after a simulated idle gap.

        The virtual clock may have jumped far past every stale beat
        while the gateway was idle (ticks stop re-arming when nothing is
        in flight); granting each live worker one fresh beat prevents
        the entire fleet being declared dead on the first tick back."""
        now = self.clock.now
        for w in self.workers.values():
            if w.state in (WorkerState.UP, WorkerState.DRAINING):
                self.last_beat[w.wid] = now
                w.ensure_beat()
        self.ensure_tick()


@dataclasses.dataclass
class _RequestState:
    """Gateway-side bookkeeping for one live request."""
    request: Request
    channel: RequestChannel
    worker: Optional[ReplicaWorker] = None
    orig_prefix: int = 0         # trace's optimistic cached_prefix_len
    paused: bool = False         # consumer fell behind
    evicted: bool = False        # removed from its engine while paused
    orig_prompt: int = 0         # original prompt_len (clones may extend)
    orig_max_new: int = 0        # original max_new_tokens budget
    token_base: int = 0          # absolute index of the clone's token 0
    ckpt_inflight: bool = False  # a snapshot copy is on the wire
    resume_ckpt: Optional[KVCheckpoint] = None   # stage at next dispatch


class Gateway:
    """The serving front-end.  See module docstring for semantics."""

    def __init__(self, cfg, serve, modes=(), router: str = "least_loaded",
                 hw: HardwareSpec = TPU_V5E, clock=None,
                 policy: Optional[GatewayPolicy] = None,
                 admission: Optional[AdmissionPolicy] = None,
                 session_affinity: bool = True,
                 retry: Optional[RetryPolicy] = None):
        self.cfg = cfg
        self.serve = serve
        self.hw = hw
        self.clock = clock if clock is not None else EventLoop()
        self.policy = policy if policy is not None else GatewayPolicy()
        self.retry = retry if retry is not None else \
            RetryPolicy(max_retries=self.policy.max_retries)
        self.router = make_router(router, cfg, serve, hw)
        self.admission = AdmissionController(
            admission if admission is not None else AdmissionPolicy())
        self.session_affinity = session_affinity
        self.stream = EventStream()          # fleet-wide, deduped
        self.metrics = StreamMetrics()
        self.stream.subscribe(self.metrics)
        self.registry = WorkerRegistry(self.clock, self.policy,
                                       on_death=self._on_worker_death,
                                       keep_alive=self._keep_alive)
        self.router.bind(self.registry.replicas)
        self._live: Dict[int, _RequestState] = {}
        self._paused: Set[int] = set()
        self._session_home: Dict[str, int] = {}
        self._next_wid = 0
        self._next_rid = 0
        self._submitted = 0
        self._expected = 0           # serve_trace() arrivals not yet in
        self.migrations = 0
        self.checkpoints = CheckpointStore(
            serve.page_size, self.policy.checkpoint_store_blocks)
        self.resumes = 0             # failovers restored from a snapshot
        self.replayed_tokens = 0     # tokens re-computed across failovers
        self.cancellations = 0
        self._wire_taps: List[Callable] = []     # fault injection hooks
        self._t0: Optional[float] = None
        self._idle = False           # ticks disarmed; resume on submit
        for m in modes:
            self.add_worker(m)

    # -- fleet management ---------------------------------------------------

    def add_worker(self, mode: str, serve=None) -> ReplicaWorker:
        from repro.core.engines import make_engine   # break import cycle
        sv = serve if serve is not None else self.serve
        wid = self._next_wid
        self._next_wid += 1
        engine = make_engine(mode, self.cfg, sv, self.hw, loop=self.clock)
        w = ReplicaWorker(wid, mode, engine, sv, self.clock,
                          sink=self._on_worker_event,
                          heartbeat=self.registry.heartbeat,
                          keep_alive=self._keep_alive,
                          heartbeat_s=self.policy.heartbeat_s)
        self.registry.register(w)
        return w

    def kill_worker(self, wid: int) -> None:
        """Simulate an abrupt crash: the engine halts and heartbeats
        stop.  Failover happens when the health tick detects the
        silence, ``heartbeat_timeout_s`` later — not instantly.
        Killing an unknown or already-dead worker is a no-op (fault
        plans may race a scripted crash against a real death)."""
        w = self.registry.workers.get(wid)
        if w is not None:
            w.kill()

    def next_rid(self) -> int:
        self._next_rid += 1
        return self._next_rid - 1

    # -- request intake -----------------------------------------------------

    def submit(self, r: Request,
               consumer: Optional[Callable] = None,
               notify: Optional[Callable[[], None]] = None
               ) -> RequestChannel:
        """Accept a request; returns its event channel.  ``consumer``
        makes delivery inline (no backpressure); otherwise events buffer
        for ``take()``/``drain()`` with ``notify`` poked per event."""
        if self._t0 is None:
            self._t0 = min(self.clock.now, r.arrival)
        self._next_rid = max(self._next_rid, r.rid + 1)
        ch = RequestChannel(r.rid, capacity=self.policy.stream_buffer,
                            resume_at=int(self.policy.stream_buffer
                                          * self.policy.resume_frac),
                            consumer=consumer, notify=notify,
                            on_pause=self._channel_pause,
                            on_resume=self._channel_resume)
        st = _RequestState(request=r, channel=ch,
                           orig_prefix=r.cached_prefix_len,
                           orig_prompt=r.prompt_len,
                           orig_max_new=r.max_new_tokens)
        self._live[r.rid] = st
        self._submitted += 1
        if self._idle:
            # ticks disarmed while the gateway sat idle; grant one grace
            # beat so the fleet is not declared dead for time that
            # passed with nothing to do
            self._idle = False
            self.registry.resume_ticks()
        self._admit(st)
        return ch

    def _admit(self, st: _RequestState) -> None:
        r = st.request
        healthy = self.registry.healthy()
        if not healthy:
            self._reject(st, "worker_lost")
            return
        verdict, fit, reason = self.admission.decide(
            r, [w.replica for w in healthy], self.clock.now)
        if verdict == "reject":
            self._reject(st, reason)
        elif verdict == "wait":
            rid = r.rid
            self.clock.after(self.admission.policy.retry_s,
                             lambda: self._readmit(rid))
        else:
            fitw = [self.registry.workers[rep.idx] for rep in fit
                    if rep.idx in self.registry.workers]
            self._dispatch(st, self._choose(r, fitw or healthy))

    def _readmit(self, rid: int) -> None:
        st = self._live.get(rid)
        if st is not None and st.worker is None:
            self._admit(st)

    def _choose(self, r: Request,
                candidates: List[ReplicaWorker]) -> ReplicaWorker:
        if self.session_affinity and r.session_id is not None:
            home = self._session_home.get(r.session_id)
            for w in candidates:
                if w.wid == home:
                    return w
        idx = self.router.choose(r, [w.replica for w in candidates])
        w = candidates[idx]
        if self.session_affinity and r.session_id is not None:
            self._session_home[r.session_id] = w.wid
        return w

    def _dispatch(self, st: _RequestState, w: ReplicaWorker) -> None:
        st.worker = w
        w.submit(st.request)

    # -- event fan-in -------------------------------------------------------

    def add_wire_tap(self, fn: Callable) -> None:
        """Fault-injection hook on the worker→gateway event wire:
        ``fn(worker, event)`` returns the event (possibly mutated) to
        pass it on, or None to drop the line."""
        self._wire_taps.append(fn)

    def remove_wire_tap(self, fn: Callable) -> None:
        if fn in self._wire_taps:
            self._wire_taps.remove(fn)

    def _on_worker_event(self, w: ReplicaWorker, ev) -> None:
        st = self._live.get(ev.rid)
        if st is None or st.worker is not w:
            return                   # stale worker / already terminal
        for tap in list(self._wire_taps):
            ev = tap(w, ev)
            if ev is None:
                return               # injected wire drop
        if st.token_base:
            ev = self._rebase(st, ev)
        if st.channel.offer(ev):     # False => deduped replay
            self.stream.emit(ev)
            if isinstance(ev, TokenEvent):
                self._maybe_checkpoint(st, ev)
        if isinstance(ev, TERMINAL_EVENTS):
            self._finish(st)

    def _rebase(self, st: _RequestState, ev):
        """Translate a resumed clone's events into the request's
        absolute coordinates: the clone's token 0 is really token
        ``token_base``, and its (extended) prompt is really the original
        prompt plus the restored output prefix."""
        base = st.token_base
        if isinstance(ev, TokenEvent):
            return dataclasses.replace(ev, index=ev.index + base)
        if isinstance(ev, (FinishedEvent, RejectedEvent)):
            return dataclasses.replace(ev, output_len=ev.output_len + base,
                                       prompt_len=st.orig_prompt)
        return ev

    # -- KV checkpointing ---------------------------------------------------

    def _ckpt_seconds(self, kv_tokens: int) -> float:
        gbps = self.policy.checkpoint_gbps or self.serve.kv_transfer_gbps
        return kv_migration_seconds(self.cfg, kv_tokens, gbps)

    def _maybe_checkpoint(self, st: _RequestState, ev: TokenEvent) -> None:
        """Kick off an async KV snapshot every ``checkpoint_interval``
        delivered tokens.  The copy takes perfmodel transfer time; it
        only commits if the source worker is still alive when it ends —
        an in-flight copy dies with its worker (crash consistency)."""
        interval = self.policy.checkpoint_interval
        if interval <= 0 or st.ckpt_inflight:
            return
        g = ev.index + 1             # absolute tokens delivered so far
        if g % interval != 0:
            return
        w = st.worker
        if w is None or w.crashed:
            return
        rid = st.request.rid
        kv_tokens = st.orig_prompt + g - 1   # prompt KV + decode appends
        st.ckpt_inflight = True
        self.clock.after(
            self._ckpt_seconds(kv_tokens),
            lambda: self._commit_checkpoint(rid, w, g, kv_tokens))

    def _commit_checkpoint(self, rid: int, src: ReplicaWorker,
                           g: int, kv_tokens: int) -> None:
        st = self._live.get(rid)
        if st is not None:
            st.ckpt_inflight = False
        if st is None or st.worker is not src:
            return                   # finished / failed over mid-copy
        if src.crashed or src.state in (WorkerState.DEAD,
                                        WorkerState.RETIRED):
            return                   # source died mid-copy: not durable
        ok = self.checkpoints.put(KVCheckpoint(
            rid=rid, generated=g, kv_tokens=kv_tokens, t=self.clock.now))
        if ok:
            ev = PhaseEvent(rid, self.clock.now, "checkpoint")
            if st.channel.offer(ev):
                self.stream.emit(ev)

    def _reject(self, st: _RequestState, reason: str) -> None:
        r = st.request
        ev = RejectedEvent(rid=r.rid, t=self.clock.now, arrival=r.arrival,
                           prompt_len=r.prompt_len, reason=reason,
                           output_len=st.channel.next_index,
                           preemptions=r.preemptions,
                           slo_class=r.slo_class, retries=r.retries)
        st.channel.offer(ev)
        self.stream.emit(ev)
        self._finish(st)

    def _finish(self, st: _RequestState) -> None:
        rid = st.request.rid
        self._live.pop(rid, None)
        self._paused.discard(rid)
        self.replayed_tokens += st.channel.dup_tokens
        self.checkpoints.drop(rid)       # parked KV freed immediately
        st.resume_ckpt = None
        if st.worker is not None:
            st.worker.engine.kv.clear_restore(rid)

    # -- crash failover -----------------------------------------------------

    def _on_worker_death(self, w: ReplicaWorker) -> None:
        """Re-home every request that was on ``w`` when it died.  With a
        parked checkpoint the clone *resumes* (restored KV, bounded
        replay); otherwise it re-prefills from scratch.  Re-dispatch is
        delayed by the retry policy's backoff plus the snapshot restore
        transfer time."""
        for st in [s for s in self._live.values() if s.worker is w]:
            r = st.request
            if r in w.replica.assigned:
                w.replica.assigned.remove(r)
            if st.evicted:
                st.worker = None     # resume will route it fresh
                continue
            ckpt = self.checkpoints.get(r.rid)
            clone = self._clone_for_retry(st, ckpt)
            st.request = clone
            st.resume_ckpt = ckpt
            st.token_base = ckpt.generated if ckpt is not None else 0
            healthy = [x for x in self.registry.healthy()
                       if x.wid != w.wid]
            if clone.retries > self.retry.max_retries or not healthy:
                st.resume_ckpt = None
                self._reject(st, "worker_lost")
                continue
            if st.paused:
                st.evicted = True    # hold until the consumer drains
                st.worker = None
                continue
            st.worker = None
            delay = self.retry.delay(clone.retries)
            if ckpt is not None:
                delay += self._ckpt_seconds(ckpt.kv_tokens)
            rid = r.rid
            self.clock.after(delay, lambda rid=rid: self._redispatch(rid))

    def _redispatch(self, rid: int) -> None:
        """Backoff expired: place the failover clone on a healthy
        worker (health may have changed while we waited)."""
        st = self._live.get(rid)
        if st is None or st.worker is not None or st.paused or st.evicted:
            return
        healthy = self.registry.healthy()
        if not healthy:
            self._reject(st, "worker_lost")
            return
        self._dispatch_fresh(st, self._choose(st.request, healthy))

    def _dispatch_fresh(self, st: _RequestState, w: ReplicaWorker) -> None:
        """Dispatch after a failover/eviction gap: stage the pending
        checkpoint restore (if any) on the target's KV manager so its
        admission clamp skips prefill compute for the restored context."""
        ckpt, st.resume_ckpt = st.resume_ckpt, None
        if ckpt is not None:
            if getattr(w.engine.scheduler, "prefill_route", "join") \
                    == "join":
                w.engine.kv.stage_restore(st.request.rid, ckpt.kv_tokens)
            # transfer-route (disagg) targets re-prefill the extended
            # context instead: their prefill pool never holds restored
            # KV (same rule as the session cache) — still strictly
            # cheaper than re-decoding token by token
            self.resumes += 1
            ev = PhaseEvent(st.request.rid, self.clock.now, "resume")
            if st.channel.offer(ev):
                self.stream.emit(ev)
        self._dispatch(st, w)

    def _clone_for_retry(self, st: _RequestState,
                         ckpt: Optional[KVCheckpoint] = None) -> Request:
        """A fresh copy for re-submission.  Without a checkpoint,
        token/prefill progress resets (the new worker re-prefills from
        scratch; a session-affine target may shortcut via its parked
        prefix).  With one, the restored context becomes the clone's
        "prompt" (original prompt + ``generated`` output tokens — same
        shape as preemption's recompute-on-resume) and the token budget
        shrinks by what the snapshot already covers; the gateway rebases
        the clone's token indices by ``token_base`` so the channel's
        index dedupe bounds the visible replay to the tokens generated
        after the snapshot.  Identity and accounting carry over."""
        r = st.request
        if ckpt is None:
            c = Request(rid=r.rid, arrival=r.arrival,
                        prompt_len=st.orig_prompt,
                        max_new_tokens=st.orig_max_new,
                        slo_class=r.slo_class, session_id=r.session_id,
                        cached_prefix_len=st.orig_prefix)
        else:
            c = Request(rid=r.rid, arrival=r.arrival,
                        prompt_len=st.orig_prompt + ckpt.generated,
                        max_new_tokens=max(
                            st.orig_max_new - ckpt.generated, 1),
                        slo_class=r.slo_class, session_id=r.session_id,
                        cached_prefix_len=0)
        c.preemptions = r.preemptions
        c.truncated = r.truncated
        c.retries = r.retries + 1
        return c

    # -- client cancellation ------------------------------------------------

    def cancel(self, rid: int, reason: str = "client_cancel") -> bool:
        """Explicit client cancel / disconnect: emit the terminal
        ``CancelledEvent`` immediately, free the parked checkpoint, and
        reap the engine slot — no waiting out the slow-consumer eviction
        path.  Returns False when the request is not live (already
        terminal or never submitted)."""
        st = self._live.get(rid)
        if st is None:
            return False
        r = st.request
        w, evicted = st.worker, st.evicted
        ev = CancelledEvent(rid=rid, t=self.clock.now, arrival=r.arrival,
                            prompt_len=st.orig_prompt,
                            output_len=st.channel.next_index,
                            preemptions=r.preemptions,
                            slo_class=r.slo_class, retries=r.retries,
                            reason=reason)
        st.channel.offer(ev)
        self.stream.emit(ev)
        self.cancellations += 1
        self._finish(st)
        if w is not None and not evicted:
            self._reap(w, r)
        return True

    def _reap(self, w: ReplicaWorker, r: Request) -> None:
        """Free a cancelled request's engine slot, retrying while it is
        pinned inside an in-flight lane step.  Stops when the worker is
        gone (its KV died with it) or the request reached a terminal
        engine state on its own."""
        if w.crashed or w.state in (WorkerState.DEAD, WorkerState.RETIRED):
            return
        if r.state in (State.FINISHED, State.REJECTED):
            return
        if not w.evict(r):
            self.clock.after(self.policy.evict_retry_s,
                             lambda: self._reap(w, r))

    # -- slow-consumer backpressure -----------------------------------------

    def _channel_pause(self, rid: int) -> None:
        st = self._live.get(rid)
        if st is None or st.paused:
            return
        st.paused = True
        self._paused.add(rid)
        # deferred: pause fires from inside offer(), i.e. mid-engine-step
        # — mutating engine containers re-entrantly would corrupt the
        # very iteration that emitted the event
        self.clock.after(0, lambda: self._do_pause(rid))

    def _do_pause(self, rid: int) -> None:
        st = self._live.get(rid)
        if st is None or not st.paused or st.evicted:
            return
        w = st.worker
        if w is None or w.state is not WorkerState.UP:
            return                   # drain/death paths own it now
        if w.evict(st.request):
            st.evicted = True
            w.engine.kv.clear_restore(rid)   # unconsumed restore staging
        else:                        # pinned inside an in-flight step
            self.clock.after(self.policy.evict_retry_s,
                             lambda: self._do_pause(rid))

    def _channel_resume(self, rid: int) -> None:
        st = self._live.get(rid)
        if st is None or not st.paused:
            return
        st.paused = False
        self._paused.discard(rid)
        self.registry.resume_ticks()
        self.clock.after(0, lambda: self._do_resume(rid))

    def _do_resume(self, rid: int) -> None:
        st = self._live.get(rid)
        if st is None or st.paused or not st.evicted:
            return
        st.evicted = False
        w = st.worker
        if w is None or w.state is not WorkerState.UP:
            healthy = self.registry.healthy()
            if not healthy:
                self._reject(st, "worker_lost")
                return
            w = self._choose(st.request, healthy)
        self._dispatch_fresh(st, w)

    # -- drain / rolling upgrade --------------------------------------------

    def drain_worker(self, wid: int,
                     on_retired: Optional[Callable[[], None]] = None
                     ) -> None:
        """Stop routing to ``wid``, migrate its queued (KV-free) work to
        healthy peers, let in-flight decodes finish in place, then
        retire + deregister it.  ``on_retired`` fires once it is gone."""
        w = self.registry.workers[wid]
        w.start_drain()
        while True:
            targets = [x for x in self.registry.healthy() if x.wid != wid]
            if not targets:
                break
            cand = w.engine.migration_candidate()
            if cand is None or cand[1]:      # has_kv: finish in place
                break
            got = w.engine.evict_for_migration()
            if got is None:
                break
            r, _ = got
            if r in w.replica.assigned:
                w.replica.assigned.remove(r)
            self.migrations += 1
            st = self._live.get(r.rid)
            target = self._choose(r, targets)
            if st is not None and st.request is r:
                self._dispatch(st, target)
            else:
                target.submit(r)
        self._drain_tick(wid, on_retired)

    def _drain_tick(self, wid: int,
                    on_retired: Optional[Callable[[], None]]) -> None:
        w = self.registry.workers.get(wid)
        if w is None or w.state is not WorkerState.DRAINING:
            return
        busy = any(s.worker is w and not s.evicted
                   for s in self._live.values())
        if w.idle() and not busy:
            w.retire()
            self.registry.deregister(wid)
            if on_retired is not None:
                on_retired()
            return
        self.clock.after(self.policy.drain_check_s,
                         lambda: self._drain_tick(wid, on_retired))

    def rolling_upgrade(self,
                        on_done: Optional[Callable[[], None]] = None
                        ) -> None:
        """Replace every UP worker one at a time: add a fresh worker of
        the same mode, drain the old one, move on when it retires."""
        targets = [w.wid for w in self.registry.workers.values()
                   if w.state is WorkerState.UP]

        def step(i: int) -> None:
            if i >= len(targets):
                if on_done is not None:
                    on_done()
                return
            old = self.registry.workers[targets[i]]
            self.add_worker(old.mode, serve=old.replica.serve)
            self.drain_worker(old.wid, on_retired=lambda: step(i + 1))

        step(0)

    # -- liveness (simulated clock) -----------------------------------------

    def _keep_alive(self) -> bool:
        """Whether periodic ticks should re-arm.  On the real clock,
        always; on the virtual clock only while work is pending —
        otherwise ``EventLoop.run()`` would never drain its heap."""
        if not self.clock.virtual:
            return True
        if self._submitted < self._expected:
            return True
        alive = len(self._live) - len(self._paused) > 0
        if not alive:
            self._idle = True
        return alive

    def serve_trace(self, requests) -> tuple:
        """Drive a full trace on the simulated clock; returns
        ``(records, span_s)``.  Each request gets an inline discard
        consumer (no backpressure) — churn tests that want buffered
        channels submit requests themselves."""
        self._expected += len(requests)
        for r in requests:
            self.clock.at(r.arrival, lambda r=r: self.submit(
                r, consumer=lambda ev: None))
        self.clock.run()
        return self.metrics.records, self.span()

    # -- observability ------------------------------------------------------

    def span(self) -> float:
        t0 = self._t0 if self._t0 is not None else self.clock.now
        return max(self.clock.now - t0, 1e-9)

    def health(self) -> Dict[str, object]:
        workers = {w.name: w.state.value
                   for w in self.registry.workers.values()}
        return {"status": "ok" if self.registry.healthy() else "degraded",
                "workers": workers,
                "live_requests": len(self._live),
                "paused_streams": len(self._paused)}

    def metrics_summary(self) -> Dict[str, object]:
        per = {w.name: [RequestRecord.from_request(r)
                        for r in w.replica.assigned]
               for w in self.registry.workers.values()
               if w.state is not WorkerState.DEAD}
        summary = fleet_summarize(per, self.serve.slo, self.span(),
                                  fleet_records=self.metrics.records,
                                  loop_stats=self.clock.stats)
        summary["fleet"]["migrations"] = self.migrations
        summary["fleet"]["checkpoints"] = self.checkpoints.taken
        summary["fleet"]["resumes"] = self.resumes
        summary["fleet"]["replayed_tokens"] = self.replayed_tokens
        summary["fleet"]["cancelled"] = self.cancellations
        summary["fleet"]["fenced_beats"] = self.registry.fenced_beats
        summary["admission"] = dict(self.admission.stats)
        return summary
