"""TTFT / ITL / throughput / goodput metrics (paper §5.2-§5.3).

SLO attainment (paper's definition):
  * ITL : the request's p95 inter-token latency must not exceed itl_ms.
  * TTFT: length-dependent ceiling — prompts of 0-1000 tokens within 1 s,
          1000-2000 within 2 s, proportionally thereafter.

goodput        = SLO-satisfying requests completed per second (both SLOs)
itl_goodput    = same with only the ITL constraint (paper Fig 10)

Serving API v2: ``StreamMetrics`` assembles the same ``RequestRecord``s
incrementally from the typed engine/cluster event stream
(core/events.py) — the replacement for scraping ``records()`` after a
blocking ``run()``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.config import SLOConfig
from repro.core.events import (CancelledEvent, FinishedEvent, RejectedEvent,
                               TokenEvent)
from repro.core.request import Request, State


def percentile_linear(vals: Sequence[float], q: float) -> float:
    """Scalar ``np.percentile(vals, q)`` (default linear interpolation),
    bit-identical to numpy on float64 inputs but without the per-call
    array/ufunc machinery — this runs once per *finished request* (both
    record-assembly paths), where numpy's constant overhead dominated
    the whole metrics pipeline.  Replicates numpy's ``_lerp`` exactly,
    including the ``gamma >= 0.5`` symmetric form (golden parity asserts
    the results stay bit-equal to the recorded traces)."""
    a = sorted(vals)
    n = len(a)
    if n == 1:
        return float(a[0])
    vi = (q / 100.0) * (n - 1)
    lo = math.floor(vi)
    gamma = vi - lo
    lo = int(lo)
    hi = lo + 1 if lo + 1 < n else n - 1
    x, y = float(a[lo]), float(a[hi])
    diff = y - x
    if gamma >= 0.5:
        return y - diff * (1.0 - gamma)
    return x + diff * gamma


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    rid: int
    arrival: float
    prompt_len: int
    output_len: int
    ttft: Optional[float]
    itl_p95: Optional[float]
    finish: Optional[float]
    preemptions: int = 0
    rejected: bool = False
    slo_class: str = "interactive"
    reject_reason: Optional[str] = None
    retries: int = 0          # gateway failovers after worker crashes
    truncated: bool = False   # admission capped max_new_tokens to fit
    cancelled: bool = False   # client cancel / disconnect mid-stream

    @classmethod
    def from_request(cls, r: Request) -> "RequestRecord":
        itls = r.itls
        return cls(
            rid=r.rid, arrival=r.arrival, prompt_len=r.prompt_len,
            output_len=r.tokens_generated, ttft=r.ttft,
            itl_p95=percentile_linear(itls, 95) if itls else None,
            finish=r.t_finish, preemptions=r.preemptions,
            rejected=r.state is State.REJECTED,
            slo_class=r.slo_class, reject_reason=r.reject_reason,
            retries=r.retries, truncated=r.truncated)


class StreamMetrics:
    """Event-stream consumer that assembles ``RequestRecord``s live.

    Subscribe one to an engine or cluster (``engine.subscribe(metrics)``)
    and it folds ``TokenEvent``s into per-request timelines, sealing a
    record at each ``FinishedEvent`` / ``RejectedEvent`` — no post-hoc
    ``records()`` scraping.  ``records`` accumulates in terminal-event
    order (chronological under the shared virtual clock);
    ``finished_since(t)`` serves windowed consumers like the autoscaler.
    """

    def __init__(self):
        self._token_times: Dict[int, List[float]] = {}
        self.records: List[RequestRecord] = []
        self.finished: List[RequestRecord] = []   # finish-ordered subset

    def __call__(self, ev) -> None:
        if isinstance(ev, TokenEvent):
            # hot path: one call per generated token — avoid setdefault's
            # unconditional empty-list allocation on every hit
            times = self._token_times.get(ev.rid)
            if times is None:
                self._token_times[ev.rid] = [ev.t]
            else:
                times.append(ev.t)
        elif isinstance(ev, FinishedEvent):
            ts = self._token_times.pop(ev.rid, [])
            itls = [b - a for a, b in zip(ts, ts[1:])]
            rec = RequestRecord(
                rid=ev.rid, arrival=ev.arrival, prompt_len=ev.prompt_len,
                output_len=ev.output_len,
                ttft=ts[0] - ev.arrival if ts else None,
                itl_p95=percentile_linear(itls, 95) if itls else None,
                finish=ev.t, preemptions=ev.preemptions, rejected=False,
                slo_class=ev.slo_class, retries=ev.retries,
                truncated=ev.truncated)
            self.records.append(rec)
            self.finished.append(rec)
        elif isinstance(ev, RejectedEvent):
            self._token_times.pop(ev.rid, None)
            self.records.append(RequestRecord(
                rid=ev.rid, arrival=ev.arrival, prompt_len=ev.prompt_len,
                output_len=ev.output_len, ttft=None, itl_p95=None,
                finish=None, preemptions=ev.preemptions, rejected=True,
                slo_class=ev.slo_class, reject_reason=ev.reason,
                retries=ev.retries))
        elif isinstance(ev, CancelledEvent):
            # terminal but neither success nor rejection: the partial
            # stream the client walked away from.  finish=None keeps it
            # out of completion/goodput; TTFT/ITL reflect what it saw.
            ts = self._token_times.pop(ev.rid, [])
            itls = [b - a for a, b in zip(ts, ts[1:])]
            self.records.append(RequestRecord(
                rid=ev.rid, arrival=ev.arrival, prompt_len=ev.prompt_len,
                output_len=ev.output_len,
                ttft=ts[0] - ev.arrival if ts else None,
                itl_p95=percentile_linear(itls, 95) if itls else None,
                finish=None, preemptions=ev.preemptions, rejected=False,
                slo_class=ev.slo_class, retries=ev.retries,
                cancelled=True))

    def finished_since(self, t_lo: float) -> List[RequestRecord]:
        """Records that finished at or after ``t_lo`` (windowed view)."""
        out: List[RequestRecord] = []
        for rec in reversed(self.finished):
            if rec.finish < t_lo:
                break
            out.append(rec)
        return out

    def summarize(self, slo: SLOConfig, span_s: float) -> Dict[str, float]:
        return summarize(self.records, slo, span_s)


def records_from_events(events: Iterable) -> List[RequestRecord]:
    """Replay a recorded event stream into ``RequestRecord``s."""
    metrics = StreamMetrics()
    for ev in events:
        metrics(ev)
    return metrics.records


def ttft_ceiling(prompt_len: int, slo: SLOConfig) -> float:
    return slo.ttft_base_s * max(
        1, -(-prompt_len // slo.ttft_tokens_per_ceiling))


def meets_itl(rec: RequestRecord, slo: SLOConfig) -> bool:
    if rec.finish is None:
        return False
    return rec.itl_p95 is None or rec.itl_p95 <= slo.itl_ms / 1e3


def meets_ttft(rec: RequestRecord, slo: SLOConfig) -> bool:
    if rec.finish is None or rec.ttft is None:
        return False
    return rec.ttft <= ttft_ceiling(rec.prompt_len, slo)


def _pct(vals: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(vals), q)) if len(vals) else \
        float("nan")


def summarize(records: List[RequestRecord], slo: SLOConfig,
              span_s: float) -> Dict[str, float]:
    done = [r for r in records if r.finish is not None]
    ttfts = [r.ttft for r in done if r.ttft is not None]
    itls = [r.itl_p95 for r in done if r.itl_p95 is not None]
    tokens = sum(r.output_len for r in done)
    ok_both = [r for r in done if meets_itl(r, slo) and meets_ttft(r, slo)]
    ok_itl = [r for r in done if meets_itl(r, slo)]
    return {
        "requests": len(records),
        "completed": len(done),
        "tokens": tokens,
        "throughput_tok_s": tokens / span_s if span_s else 0.0,
        "throughput_req_s": len(done) / span_s if span_s else 0.0,
        "goodput_req_s": len(ok_both) / span_s if span_s else 0.0,
        "itl_goodput_req_s": len(ok_itl) / span_s if span_s else 0.0,
        "slo_attainment": len(ok_both) / len(done) if done else 0.0,
        "rejected": sum(1 for r in records if r.rejected),
        "ttft_p50_s": _pct(ttfts, 50),
        "ttft_p95_s": _pct(ttfts, 95),
        "ttft_p99_s": _pct(ttfts, 99),
        "itl_p50_s": _pct(itls, 50),
        "itl_p95_s": _pct(itls, 95),
        "preemptions": sum(r.preemptions for r in done),
        "retries": sum(r.retries for r in records),
        "truncated": sum(1 for r in done if r.truncated),
        "cancelled": sum(1 for r in records if r.cancelled),
    }


def per_class_summaries(records: List[RequestRecord], slo: SLOConfig,
                        span_s: float,
                        class_slos: Optional[Dict[str, SLOConfig]] = None
                        ) -> Dict[str, Dict[str, float]]:
    """One summary per SLO class present in ``records``, each evaluated
    against that class's OWN SLO (``class_slos`` defaults to
    ``serving.workloads.class_slos()``; the cluster-wide ``slo`` covers
    classes without an entry).  A single-class trace yields one entry."""
    if class_slos is None:
        from repro.serving.workloads import class_slos as _defaults
        class_slos = _defaults()
    by_class: Dict[str, List[RequestRecord]] = {}
    for rec in records:
        by_class.setdefault(rec.slo_class, []).append(rec)
    return {name: summarize(recs, class_slos.get(name, slo), span_s)
            for name, recs in sorted(by_class.items())}


def rejections_by_reason(records: List[RequestRecord]) -> Dict[str, int]:
    """Rejection counts keyed by ``RejectedEvent.reason`` vocabulary."""
    out: Dict[str, int] = {}
    for rec in records:
        if rec.rejected:
            reason = rec.reject_reason or "never_fits"
            out[reason] = out.get(reason, 0) + 1
    return out


def fleet_summarize(per_replica: Dict[str, List[RequestRecord]],
                    slo: SLOConfig, span_s: float,
                    fleet_records: Optional[List[RequestRecord]] = None,
                    class_slos: Optional[Dict[str, SLOConfig]] = None,
                    loop_stats=None) -> Dict[str, object]:
    """Cluster-level aggregation: one fleet-wide summary over the union of
    all replicas' records, plus the per-replica summaries (every replica
    shares the cluster's virtual clock, so one span normalizes all).

    ``fleet_records`` overrides the fleet-wide record set — the stream-
    consuming cluster passes its ``StreamMetrics.records``, which also
    carry cluster-side admission rejections that never reached a
    replica.

    The result additionally carries ``per_class`` (one summary per SLO
    class present, each judged against its own SLO from ``class_slos`` /
    ``serving.workloads``) and, inside ``fleet``,
    ``rejections_by_reason`` (never_fits / kv_headroom / class_shed /
    worker_lost).

    ``loop_stats`` (a ``serving.sim.LoopStats`` or plain dict) surfaces
    event-loop health under ``fleet["loop"]`` — ``dispatched``,
    ``clamped`` (past-due ``EventLoop.at()`` schedules snapped to
    ``now``: a persistent non-zero rate means some component plans
    against a stale clock) and ``peak_heap``."""
    union: List[RequestRecord] = [r for recs in per_replica.values()
                                  for r in recs]
    fleet_recs = union if fleet_records is None else fleet_records
    fleet = summarize(fleet_recs, slo, span_s)
    fleet["replicas"] = len(per_replica)
    counts = {name: len(recs) for name, recs in per_replica.items()}
    fleet["min_replica_share"] = (min(counts.values()) / max(1, len(union))
                                  if counts and union else 0.0)
    fleet["rejections_by_reason"] = rejections_by_reason(fleet_recs)
    if loop_stats is not None:
        fleet["loop"] = loop_stats.as_dict() \
            if hasattr(loop_stats, "as_dict") else dict(loop_stats)
    return {
        "fleet": fleet,
        "per_replica": {name: summarize(recs, slo, span_s)
                        for name, recs in per_replica.items()},
        "per_class": per_class_summaries(fleet_recs, slo, span_s,
                                         class_slos=class_slos),
    }
