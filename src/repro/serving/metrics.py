"""TTFT / ITL / throughput / goodput metrics (paper §5.2-§5.3).

SLO attainment (paper's definition):
  * ITL : the request's p95 inter-token latency must not exceed itl_ms.
  * TTFT: length-dependent ceiling — prompts of 0-1000 tokens within 1 s,
          1000-2000 within 2 s, proportionally thereafter.

goodput        = SLO-satisfying requests completed per second (both SLOs)
itl_goodput    = same with only the ITL constraint (paper Fig 10)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.config import SLOConfig
from repro.core.request import Request, State


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    rid: int
    arrival: float
    prompt_len: int
    output_len: int
    ttft: Optional[float]
    itl_p95: Optional[float]
    finish: Optional[float]
    preemptions: int = 0
    rejected: bool = False

    @classmethod
    def from_request(cls, r: Request) -> "RequestRecord":
        itls = r.itls
        return cls(
            rid=r.rid, arrival=r.arrival, prompt_len=r.prompt_len,
            output_len=r.tokens_generated, ttft=r.ttft,
            itl_p95=float(np.percentile(itls, 95)) if itls else None,
            finish=r.t_finish, preemptions=r.preemptions,
            rejected=r.state is State.REJECTED)


def ttft_ceiling(prompt_len: int, slo: SLOConfig) -> float:
    return slo.ttft_base_s * max(
        1, -(-prompt_len // slo.ttft_tokens_per_ceiling))


def meets_itl(rec: RequestRecord, slo: SLOConfig) -> bool:
    if rec.finish is None:
        return False
    return rec.itl_p95 is None or rec.itl_p95 <= slo.itl_ms / 1e3


def meets_ttft(rec: RequestRecord, slo: SLOConfig) -> bool:
    if rec.finish is None or rec.ttft is None:
        return False
    return rec.ttft <= ttft_ceiling(rec.prompt_len, slo)


def _pct(vals: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(vals), q)) if len(vals) else \
        float("nan")


def summarize(records: List[RequestRecord], slo: SLOConfig,
              span_s: float) -> Dict[str, float]:
    done = [r for r in records if r.finish is not None]
    ttfts = [r.ttft for r in done if r.ttft is not None]
    itls = [r.itl_p95 for r in done if r.itl_p95 is not None]
    tokens = sum(r.output_len for r in done)
    ok_both = [r for r in done if meets_itl(r, slo) and meets_ttft(r, slo)]
    ok_itl = [r for r in done if meets_itl(r, slo)]
    return {
        "requests": len(records),
        "completed": len(done),
        "tokens": tokens,
        "throughput_tok_s": tokens / span_s if span_s else 0.0,
        "throughput_req_s": len(done) / span_s if span_s else 0.0,
        "goodput_req_s": len(ok_both) / span_s if span_s else 0.0,
        "itl_goodput_req_s": len(ok_itl) / span_s if span_s else 0.0,
        "slo_attainment": len(ok_both) / len(done) if done else 0.0,
        "rejected": sum(1 for r in records if r.rejected),
        "ttft_p50_s": _pct(ttfts, 50),
        "ttft_p95_s": _pct(ttfts, 95),
        "ttft_p99_s": _pct(ttfts, 99),
        "itl_p50_s": _pct(itls, 50),
        "itl_p95_s": _pct(itls, 95),
        "preemptions": sum(r.preemptions for r in done),
    }


def fleet_summarize(per_replica: Dict[str, List[RequestRecord]],
                    slo: SLOConfig, span_s: float) -> Dict[str, object]:
    """Cluster-level aggregation: one fleet-wide summary over the union of
    all replicas' records, plus the per-replica summaries (every replica
    shares the cluster's virtual clock, so one span normalizes all)."""
    union: List[RequestRecord] = [r for recs in per_replica.values()
                                  for r in recs]
    fleet = summarize(union, slo, span_s)
    fleet["replicas"] = len(per_replica)
    counts = {name: len(recs) for name, recs in per_replica.items()}
    fleet["min_replica_share"] = (min(counts.values()) / max(1, len(union))
                                  if counts and union else 0.0)
    return {
        "fleet": fleet,
        "per_replica": {name: summarize(recs, slo, span_s)
                        for name, recs in per_replica.items()},
    }
