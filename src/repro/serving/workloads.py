"""Multi-tenant workload classes, sessions, and time-varying arrivals.

Production serving fleets multiplex tenants with very different
contracts over one pool of chips: latency-sensitive chat (tight ITL,
multi-turn sessions whose turns share a growing prefix), throughput
batch jobs (loose deadlines, long documents), and best-effort scavenger
traffic that exists to soak up idle capacity and is the first thing shed
under pressure.  This module defines that taxonomy and the trace
generators that exercise it:

  * ``WORKLOAD_CLASSES`` — the three SLO classes (interactive / batch /
    best_effort), each with its OWN ``SLOConfig``; per-class goodput in
    ``serving.metrics.fleet_summarize`` is judged against these.
  * Multi-turn *session* generation: turn ``k``'s prompt is the full
    conversation context so far (previous prompt + generated reply) plus
    fresh user tokens, and ``cached_prefix_len`` marks the shared prefix
    a session-prefix cache can skip re-prefilling (kvcache/manager.py).
  * Non-homogeneous Poisson arrivals by thinning — ``diurnal_rate``
    (sinusoidal day/night load) and ``flash_crowd_rate`` (step burst),
    layered on the same lognormal length distributions as traces.py.

Everything is deterministic under the seed.  Plain single-class traces
from ``traces.generate_trace`` are the degenerate case: every request
``interactive``, no sessions, homogeneous arrivals.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Mapping, Optional

import numpy as np

from repro.config import SLOConfig
from repro.core.request import Request
from repro.serving.traces import TRACES, TraceSpec, _lognormal_mean


@dataclasses.dataclass(frozen=True)
class WorkloadClass:
    """One tenant class: its SLO contract, length distribution, and (for
    sessionful classes) the multi-turn conversation shape."""
    name: str
    slo: SLOConfig
    trace: str = "lmsys"            # key into traces.TRACES
    sessions: bool = False          # multi-turn with shared prefixes
    mean_turns: float = 4.0         # geometric mean turns per session
    think_time_s: float = 4.0       # user think time between turns
    mean_followup_prompt: int = 256  # fresh tokens per follow-up turn


WORKLOAD_CLASSES: Dict[str, WorkloadClass] = {
    "interactive": WorkloadClass(
        "interactive", SLOConfig(itl_ms=100.0, ttft_base_s=1.0),
        trace="lmsys", sessions=True),
    "batch": WorkloadClass(
        "batch", SLOConfig(itl_ms=250.0, ttft_base_s=5.0),
        trace="arxiv"),
    "best_effort": WorkloadClass(
        "best_effort", SLOConfig(itl_ms=1000.0, ttft_base_s=20.0),
        trace="lmsys"),
}

DEFAULT_MIX: Mapping[str, float] = {
    "interactive": 0.45, "batch": 0.35, "best_effort": 0.20,
}


def class_slos() -> Dict[str, SLOConfig]:
    """Per-class SLOs for ``metrics.per_class_summaries``."""
    return {name: wc.slo for name, wc in WORKLOAD_CLASSES.items()}


# ---------------------------------------------------------------------------
# time-varying arrival processes (non-homogeneous Poisson, by thinning)
# ---------------------------------------------------------------------------


def diurnal_rate(base_qps: float, amplitude: float = 0.5,
                 period_s: float = 120.0,
                 phase_s: float = 0.0) -> Callable[[float], float]:
    """Sinusoidal day/night load: rate(t) = base * (1 + A sin(2πt/T)).
    ``period_s`` defaults short so simulated minutes sweep a full cycle.
    The returned callable carries ``rate_max`` for the thinning bound."""
    if not 0.0 <= amplitude <= 1.0:
        raise ValueError("amplitude must be in [0, 1]")

    def rate(t: float) -> float:
        return base_qps * (1.0 + amplitude * math.sin(
            2.0 * math.pi * (t + phase_s) / period_s))

    rate.rate_max = base_qps * (1.0 + amplitude)
    return rate


def flash_crowd_rate(base_qps: float, peak_qps: float, t_start: float,
                     t_end: float) -> Callable[[float], float]:
    """Step burst: ``peak_qps`` inside [t_start, t_end), base elsewhere."""

    def rate(t: float) -> float:
        return peak_qps if t_start <= t < t_end else base_qps

    rate.rate_max = max(base_qps, peak_qps)
    return rate


def nhpp_arrivals(rate_fn: Callable[[float], float], duration_s: float,
                  rng: np.random.Generator,
                  rate_max: Optional[float] = None) -> List[float]:
    """Arrival times of a non-homogeneous Poisson process on
    [0, duration_s) by Lewis-Shedler thinning: draw candidates at the
    envelope rate, keep each with probability rate(t)/rate_max."""
    if rate_max is None:
        rate_max = getattr(rate_fn, "rate_max", None)
    if rate_max is None or rate_max <= 0:
        raise ValueError("rate_max must be positive (attach .rate_max to "
                         "rate_fn or pass it explicitly)")
    out: List[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate_max)
        if t >= duration_s:
            return out
        if rng.random() * rate_max <= rate_fn(t):
            out.append(t)


# ---------------------------------------------------------------------------
# multi-turn sessions and the multi-class trace builder
# ---------------------------------------------------------------------------


def _session_turns(wc: WorkloadClass, spec: TraceSpec, session_id: str,
                   start: float, duration_s: float,
                   rng: np.random.Generator) -> List[Request]:
    """One session's turn requests (rid=-1; assigned by the caller).

    Turn k's prompt is the whole conversation so far plus fresh user
    tokens; ``cached_prefix_len`` is the prior context — *optimistic*
    (the engine clamps it to what is actually still parked in the
    session-prefix cache at admission)."""
    n_turns = 1 + int(rng.geometric(1.0 / max(1.0, wc.mean_turns)))
    first = int(np.clip(
        _lognormal_mean(rng, spec.mean_prompt, spec.sigma_prompt, 1)[0],
        16, spec.max_prompt))
    out: List[Request] = []
    t, context = start, 0
    for _ in range(n_turns):
        if t >= duration_s:
            break
        fresh = first if context == 0 else int(np.clip(
            _lognormal_mean(rng, wc.mean_followup_prompt, 0.5, 1)[0],
            16, spec.max_prompt))
        prompt = min(context + fresh, spec.max_prompt)
        if prompt <= context:            # context hit the length ceiling
            break
        output = int(np.clip(
            _lognormal_mean(rng, spec.mean_output, spec.sigma_output, 1)[0],
            4, spec.max_output))
        out.append(Request(rid=-1, arrival=t, prompt_len=prompt,
                           max_new_tokens=output, slo_class=wc.name,
                           session_id=session_id,
                           cached_prefix_len=context))
        context = prompt + output
        t += rng.exponential(wc.think_time_s)
    return out


def generate_multiclass_trace(
        qps: float, duration_s: float, seed: int = 0,
        mix: Optional[Mapping[str, float]] = None,
        classes: Optional[Mapping[str, WorkloadClass]] = None,
        rate_fn: Optional[Callable[[float], float]] = None
) -> List[Request]:
    """A multi-tenant trace: arrivals at ``qps`` total (Poisson, or the
    non-homogeneous ``rate_fn`` — see ``diurnal_rate``), each assigned an
    SLO class by ``mix``.  Sessionful classes treat their arrivals as
    session STARTS and append follow-up turns (so the emitted request
    rate exceeds ``qps`` by roughly the sessionful share × mean_turns).
    Requests come back arrival-sorted with dense rids."""
    mix = dict(DEFAULT_MIX if mix is None else mix)
    classes = dict(WORKLOAD_CLASSES if classes is None else classes)
    total = sum(mix.values())
    if total <= 0:
        raise ValueError("mix must have positive total weight")
    names = sorted(mix)
    probs = np.array([mix[n] / total for n in names])
    rng = np.random.default_rng(seed)
    if rate_fn is None:
        starts: List[float] = []
        t = 0.0
        while True:
            t += rng.exponential(1.0 / qps)
            if t >= duration_s:
                break
            starts.append(t)
    else:
        starts = nhpp_arrivals(rate_fn, duration_s, rng)
    reqs: List[Request] = []
    n_sessions = 0
    for start in starts:
        name = names[int(rng.choice(len(names), p=probs))]
        wc = classes[name]
        spec = TRACES[wc.trace]
        if wc.sessions:
            sid = f"{name}-{n_sessions}"
            n_sessions += 1
            reqs.extend(_session_turns(wc, spec, sid, start, duration_s,
                                       rng))
        else:
            prompt = int(np.clip(
                _lognormal_mean(rng, spec.mean_prompt, spec.sigma_prompt,
                                1)[0], 16, spec.max_prompt))
            output = int(np.clip(
                _lognormal_mean(rng, spec.mean_output, spec.sigma_output,
                                1)[0], 4, spec.max_output))
            reqs.append(Request(rid=-1, arrival=start, prompt_len=prompt,
                                max_new_tokens=output, slo_class=name))
    reqs.sort(key=lambda r: r.arrival)
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs
