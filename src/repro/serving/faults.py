"""Deterministic fault injection for the serving gateway.

Everything here is scripted against the gateway's *clock* (virtual
``EventLoop`` in CI, ``RealTimeClock`` in a live deployment), so an
arbitrary fault schedule replays bit-identically: a ``FaultPlan`` is a
frozen list of ``Fault`` records, and ``FaultInjector.arm()`` schedules
each one at its tick.  Supported fault kinds:

  * ``crash``   — abrupt worker crash at tick ``t`` (``kill_worker``:
    engine halts, heartbeats stop; detection waits for the registry's
    heartbeat timeout, like a real hung process).
  * ``restart`` — a *fresh* worker of mode ``mode`` joins at ``t``
    (capacity recovery; a fenced dead worker can never rejoin as
    itself — see ``WorkerRegistry.heartbeat``).
  * ``flap``    — worker misses its next ``count`` heartbeats but keeps
    running (GC pause / transient partition).  Under the timeout it must
    be invisible; over it, the worker is declared dead and *fenced*.
  * ``drop`` / ``corrupt`` — lossy worker→gateway event wire: the next
    ``count`` token lines for ``rid`` (any rid when ``rid < 0``) are
    dropped, or corrupted so they fail the channel's index check.  Only
    **token** lines are lossy — terminal events ride the reliable
    control channel, otherwise a dropped terminal would leak the
    request forever (the exactly-once-termination property would be
    meaningless).
  * ``stall``   — the request's consumer wedges for ``duration``
    seconds: its channel buffers (even inline consumers), engaging the
    gateway's real slow-consumer backpressure/eviction machinery.

``RetryPolicy`` is the bounded failover policy the gateway consults on
worker death: at most ``max_retries`` re-dispatches per request, each
delayed by truncated exponential backoff (thundering-herd control when
a crash orphans a whole batch at once).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Tuple

from repro.core.events import TokenEvent

FAULT_KINDS = ("crash", "restart", "flap", "drop", "corrupt", "stall")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded failover retries with truncated exponential backoff.

    ``delay(n)`` is the pause before the ``n``-th re-dispatch (n >= 1):
    ``backoff_base_s * backoff_mult**(n-1)``, capped at
    ``backoff_max_s``.  The gateway adds the checkpoint-restore
    transfer time on top when resuming from a snapshot."""
    max_retries: int = 2
    backoff_base_s: float = 0.05
    backoff_mult: float = 2.0
    backoff_max_s: float = 2.0

    def delay(self, retries: int) -> float:
        if retries <= 0:
            return 0.0
        return min(self.backoff_base_s * self.backoff_mult ** (retries - 1),
                   self.backoff_max_s)


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scripted fault.  Field use by kind:

    crash/flap: ``wid`` (flap also ``count`` = beats missed);
    restart: ``mode`` (worker mode to add);
    drop/corrupt: ``rid`` (-1 = any), ``count`` = token lines affected;
    stall: ``rid``, ``duration`` seconds."""
    kind: str
    t: float
    wid: int = -1
    rid: int = -1
    count: int = 1
    duration: float = 0.0
    mode: str = "rapid"

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, replayable fault schedule."""
    faults: Tuple[Fault, ...] = ()

    def __iter__(self):
        return iter(self.faults)

    def __len__(self) -> int:
        return len(self.faults)

    @classmethod
    def crash_storm(cls, seed: int, workers: int, t0: float, t1: float,
                    crashes: int, restart_after: float = 2.0,
                    mode: str = "rapid") -> "FaultPlan":
        """A deterministic storm: ``crashes`` worker kills at uniform
        random ticks in [t0, t1), each followed by a fresh replacement
        worker ``restart_after`` seconds later (so fleet capacity
        recovers and survivors exist for failover).  Same seed, same
        storm — the two arms of benchmarks/fig17_recovery.py replay the
        identical schedule."""
        rng = random.Random(seed)
        faults: List[Fault] = []
        for _ in range(crashes):
            t = rng.uniform(t0, t1)
            wid = rng.randrange(workers)
            faults.append(Fault(kind="crash", t=t, wid=wid))
            faults.append(Fault(kind="restart", t=t + restart_after,
                                mode=mode))
        faults.sort(key=lambda f: f.t)
        return cls(tuple(faults))


class FaultInjector:
    """Arms a ``FaultPlan`` against a gateway's clock.

    One injector owns one wire tap on the gateway (installed lazily,
    removed never — an exhausted tap passes everything through), plus
    per-fault scheduled callbacks.  ``injected`` counts fired faults by
    kind; ``dropped_lines`` / ``corrupted_lines`` count affected wire
    lines."""

    def __init__(self, gateway, plan: FaultPlan):
        self.gw = gateway
        self.plan = plan
        self.injected: Dict[str, int] = {k: 0 for k in FAULT_KINDS}
        self.dropped_lines = 0
        self.corrupted_lines = 0
        # pending wire faults: list of [rid, remaining, corrupt?]
        self._wire_budget: List[List] = []
        self._tap_installed = False

    def arm(self) -> "FaultInjector":
        for f in self.plan:
            self.gw.clock.at(f.t, lambda f=f: self._fire(f))
        return self

    # -- firing --------------------------------------------------------------

    def _fire(self, f: Fault) -> None:
        self.injected[f.kind] += 1
        if f.kind == "crash":
            self.gw.kill_worker(f.wid)
        elif f.kind == "restart":
            self.gw.add_worker(f.mode)
        elif f.kind == "flap":
            w = self.gw.registry.workers.get(f.wid)
            if w is not None:
                w.suppress_beats(f.count)
        elif f.kind in ("drop", "corrupt"):
            self._ensure_tap()
            self._wire_budget.append([f.rid, f.count, f.kind == "corrupt"])
        elif f.kind == "stall":
            st = self.gw._live.get(f.rid)
            if st is None:
                return
            ch = st.channel
            ch.stall()
            self.gw.clock.after(f.duration, ch.unstall)

    # -- wire tap ------------------------------------------------------------

    def _ensure_tap(self) -> None:
        if not self._tap_installed:
            self._tap_installed = True
            self.gw.add_wire_tap(self._tap)

    def _tap(self, worker, ev):
        # only token lines are lossy (see module docstring)
        if not isinstance(ev, TokenEvent):
            return ev
        for entry in self._wire_budget:
            rid, remaining, corrupt = entry
            if remaining <= 0 or (rid >= 0 and rid != ev.rid):
                continue
            entry[1] -= 1
            if corrupt:
                # mangled index: fails the channel's contiguity check,
                # so the line is counted and discarded downstream
                self.corrupted_lines += 1
                return dataclasses.replace(ev, index=-(ev.index + 1))
            self.dropped_lines += 1
            return None
        return ev


def line_corruptor(rng: Optional[random.Random] = None,
                   rate: float = 0.0):
    """An NDJSON wire-line hook for the HTTP server: flips a byte in a
    fraction ``rate`` of outgoing lines (deterministic under a seeded
    ``rng``).  Returns the (possibly mangled) line — consumers must
    treat a non-parsing line as loss, not crash (event_from_json raises
    ``ValueError``, which the client-side reader skips)."""
    rng = rng if rng is not None else random.Random(0)

    def hook(line: bytes) -> bytes:
        if rate > 0.0 and line and rng.random() < rate:
            i = rng.randrange(len(line))
            return line[:i] + bytes([line[i] ^ 0x20]) + line[i + 1:]
        return line

    return hook
