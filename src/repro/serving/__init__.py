from repro.serving.admission import (  # noqa: F401
    AdmissionController, AdmissionPolicy,
)
from repro.serving.clock import RealTimeClock  # noqa: F401
from repro.serving.cluster import (  # noqa: F401
    ROUTERS, BucketedRouter, Cluster, ProjectionPolicy, RebalancePolicy,
    Replica, ReplicaSpec, ScalePolicy, make_router, parse_mix, run_fleet,
)
from repro.serving.faults import (  # noqa: F401
    Fault, FaultInjector, FaultPlan, RetryPolicy, line_corruptor,
)
from repro.serving.gateway import (  # noqa: F401
    Gateway, GatewayPolicy, RequestChannel, WorkerRegistry,
)
from repro.serving.http import GatewayHTTPServer, run_http  # noqa: F401
from repro.serving.metrics import (  # noqa: F401
    RequestRecord, StreamMetrics, fleet_summarize, per_class_summaries,
    records_from_events, rejections_by_reason, summarize,
)
from repro.serving.sim import EventLoop  # noqa: F401
from repro.serving.traces import TRACES, TraceSpec, generate_trace  # noqa: F401
from repro.serving.worker import ReplicaWorker, WorkerState  # noqa: F401
from repro.serving.workloads import (  # noqa: F401
    DEFAULT_MIX, WORKLOAD_CLASSES, WorkloadClass, class_slos,
    diurnal_rate, flash_crowd_rate, generate_multiclass_trace,
    nhpp_arrivals,
)
