from repro.serving.sim import EventLoop  # noqa: F401
from repro.serving.traces import TRACES, generate_trace, TraceSpec  # noqa: F401
from repro.serving.metrics import (  # noqa: F401
    RequestRecord, fleet_summarize, summarize)
from repro.serving.admission import (  # noqa: F401
    AdmissionController, AdmissionPolicy)
from repro.serving.cluster import (  # noqa: F401
    BucketedRouter, Cluster, ROUTERS, RebalancePolicy, Replica,
    ReplicaSpec, ScalePolicy, make_router, parse_mix, run_fleet)
