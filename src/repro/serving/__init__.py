from repro.serving.sim import EventLoop  # noqa: F401
from repro.serving.traces import TRACES, generate_trace, TraceSpec  # noqa: F401
from repro.serving.metrics import (  # noqa: F401
    RequestRecord, fleet_summarize, summarize)
from repro.serving.cluster import (  # noqa: F401
    Cluster, ROUTERS, Replica, ScalePolicy, make_router, run_fleet)
