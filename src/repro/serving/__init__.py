from repro.serving.sim import EventLoop  # noqa: F401
from repro.serving.traces import TRACES, generate_trace, TraceSpec  # noqa: F401
from repro.serving.metrics import summarize, RequestRecord  # noqa: F401
