"""Synthetic request traces matching the paper's three datasets (§5.1).

  * LMSYS  — interactive chat, short prompts (avg ~2K tokens)
  * arXiv  — long-document summarization (avg ~8K)
  * Loogle — very long context summarization (avg ~20K)

Prompt lengths are lognormal (heavy right tail, as in the real traces),
truncated to [16, max_len]; output lengths lognormal around chat-typical
values.  Arrivals are Poisson at the requested QPS.  Everything is
deterministic under the seed (numpy Generator), and generation is
stratified the way the paper subsamples (quantile-binned by prompt
length) so load sweeps see a stable mix.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List

import numpy as np

from repro.core.request import Request


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    name: str
    mean_prompt: int
    sigma_prompt: float     # lognormal sigma
    mean_output: int
    sigma_output: float
    max_prompt: int
    max_output: int


TRACES = {
    "lmsys": TraceSpec("lmsys", 2000, 0.9, 240, 0.7, 16_384, 1024),
    "arxiv": TraceSpec("arxiv", 8000, 0.5, 300, 0.6, 30_000, 1024),
    "loogle": TraceSpec("loogle", 20_000, 0.35, 400, 0.5, 31_000, 1024),
}


def _lognormal_mean(rng, mean: float, sigma: float, n: int) -> np.ndarray:
    """Lognormal samples with the requested arithmetic mean."""
    mu = math.log(mean) - 0.5 * sigma * sigma
    return rng.lognormal(mu, sigma, size=n)


def generate_trace(spec: TraceSpec, qps: float, duration_s: float,
                   seed: int = 0, stratify_bins: int = 8) -> List[Request]:
    rng = np.random.default_rng(seed)
    n = max(1, rng.poisson(qps * duration_s))
    gaps = rng.exponential(1.0 / qps, size=n)
    arrivals = np.cumsum(gaps)
    arrivals = arrivals[arrivals < duration_s]
    n = len(arrivals)
    if n == 0:
        return []
    prompts = _lognormal_mean(rng, spec.mean_prompt, spec.sigma_prompt, n)
    prompts = np.clip(prompts, 16, spec.max_prompt).astype(int)
    outputs = _lognormal_mean(rng, spec.mean_output, spec.sigma_output, n)
    outputs = np.clip(outputs, 4, spec.max_output).astype(int)
    # stratified shuffle by prompt-length quantile (paper §5.1): sort into
    # bins, then round-robin across bins so every load window sees the mix
    order = np.argsort(prompts)
    bins = np.array_split(order, stratify_bins)
    interleaved = []
    for i in range(max(len(b) for b in bins)):
        for b in bins:
            if i < len(b):
                interleaved.append(b[i])
    perm = np.array(interleaved)
    prompts, outputs = prompts[perm], outputs[perm]
    return [Request(rid=i, arrival=float(arrivals[i]),
                    prompt_len=int(prompts[i]),
                    max_new_tokens=int(outputs[i]))
            for i in range(n)]
