"""Virtual-clock discrete-event loop.

The serving engines are real control-flow code (queues, block allocation,
scheduling decisions); only *durations* come from the perfmodel.  The loop
is a plain heapq of (time, seq, callback) — engines schedule their own
step completions; arrivals are seeded up front from a trace.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional


class EventLoop:
    def __init__(self):
        self._heap = []
        self._seq = itertools.count()
        self.now = 0.0

    def at(self, t: float, fn: Callable[[], None]) -> None:
        if t < self.now - 1e-12:
            t = self.now
        heapq.heappush(self._heap, (t, next(self._seq), fn))

    def after(self, dt: float, fn: Callable[[], None]) -> None:
        self.at(self.now + dt, fn)

    def run(self, until: Optional[float] = None,
            max_events: int = 50_000_000) -> None:
        n = 0
        while self._heap and n < max_events:
            # peek before popping: an event past the horizon must stay on
            # the heap so a resumed run() still delivers it
            if until is not None and self._heap[0][0] > until:
                self.now = until
                return
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            fn()
            n += 1
        if n >= max_events:
            raise RuntimeError("event budget exceeded (runaway sim?)")
        if until is not None and until > self.now:
            self.now = until
