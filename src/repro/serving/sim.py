"""Virtual-clock discrete-event loop.

The serving engines are real control-flow code (queues, block allocation,
scheduling decisions); only *durations* come from the perfmodel.  The loop
is a plain heapq of (time, seq, callback) — engines schedule their own
step completions; arrivals are seeded up front from a trace.

``EventLoop.stats`` tracks loop health so consumers (notably
``benchmarks/bench_hotpath.py``) can report it: events dispatched,
past-due schedules clamped to ``now`` (``at()`` silently snapped these
with no record before PR-5), and the peak heap size.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Optional


@dataclasses.dataclass
class LoopStats:
    """Event-loop health counters (reset with the loop, never cleared).

    ``clamped`` counts ``at()`` calls whose target time was already in
    the past (beyond float tolerance) and were snapped to ``now`` — a
    persistent non-zero rate means some component schedules against a
    stale clock.  ``peak_heap`` is the high-water mark of pending
    events."""
    dispatched: int = 0
    clamped: int = 0
    peak_heap: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class EventLoop:
    # Clock-protocol flag (see serving/clock.py): virtual clocks advance
    # by draining the heap; gateway periodic ticks must stop re-arming
    # when idle or run() would never return.
    virtual = True

    def __init__(self):
        self._heap = []
        self._seq = itertools.count()
        self.now = 0.0
        self.stats = LoopStats()

    def at(self, t: float, fn: Callable[[], None]) -> None:
        if t < self.now - 1e-12:
            t = self.now
            self.stats.clamped += 1
        heapq.heappush(self._heap, (t, next(self._seq), fn))
        if len(self._heap) > self.stats.peak_heap:
            self.stats.peak_heap = len(self._heap)

    def after(self, dt: float, fn: Callable[[], None]) -> None:
        self.at(self.now + dt, fn)

    def run(self, until: Optional[float] = None,
            max_events: int = 50_000_000) -> None:
        n = 0
        stats = self.stats
        while self._heap and n < max_events:
            # peek before popping: an event past the horizon must stay on
            # the heap so a resumed run() still delivers it
            if until is not None and self._heap[0][0] > until:
                self.now = until
                return
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            fn()
            n += 1
            stats.dispatched += 1
        if n >= max_events:
            raise RuntimeError("event budget exceeded (runaway sim?)")
        if until is not None and until > self.now:
            self.now = until
