"""Cluster-level KV-aware admission control.

The PR-1 routers place every arrival unconditionally; under KV pressure
the engine then discovers the overflow *mid-flight* and preempts
(recompute-on-resume), which burns prefill work exactly when the fleet
can least afford it.  The admission controller moves that discovery to
arrival time: it projects the new request's KV footprint
(``kvcache.manager.kv_pages_for`` over prompt + expected decode tokens)
against each replica's live pool state (``LoadSnapshot.kv_utilization``
/ ``kv_free_blocks`` plus the pages its queued-but-unallocated requests
will claim) and

  * **admits** on the subset of replicas with headroom (the router picks
    among those — a redirect when its unconstrained choice was full),
  * **queues** the arrival cluster-side and retries when no replica has
    headroom right now, and
  * **rejects** cleanly when the prompt can never fit any replica's pool
    or the queueing deadline expires — instead of letting an engine hit
    ``OutOfBlocks`` (or preemption-thrash) mid-flight.

Split-pool (disagg) targets are checked against BOTH pools: the prompt's
transient *prefill-side* footprint must also fit the prefill pool's
projected occupancy (live pages plus the claims of every queued-but-
unstarted prompt, from ``LoadSnapshot.prefill_kv_*``).  Without this the
controller admits work whose transient prefill KV the replica cannot
hold — the request then sits in ``waiting_prefill`` starving the batch
former, exactly the §3.2.2 imbalance the decode-side check cannot see.
``prefill_pool_aware=False`` restores the decode-only projection.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.request import Request
from repro.kvcache import kv_pages_for

# class-ordered headroom multipliers: lower-importance classes see a
# tighter effective pool, so under pressure best_effort is shed first,
# batch queues, and interactive admits up to the full headroom
DEFAULT_CLASS_HEADROOM: Mapping[str, float] = {
    "interactive": 1.0,
    "batch": 0.95,
    "best_effort": 0.80,
}


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Knobs for the KV-aware admission controller.

    ``kv_headroom`` is the pool fraction the projected post-admit
    occupancy may not exceed (the margin absorbs decode growth of
    already-running requests).  ``projected_output_frac`` scales the
    request's ``max_new_tokens`` in the footprint projection — 1.0
    reserves for the worst case, smaller values statistically multiplex.

    ``prefill_pool_aware`` additionally projects the prompt's transient
    footprint against split-pool (disagg) replicas' *prefill* pools;
    ``prefill_headroom`` is that pool's occupancy ceiling (transient
    pages churn faster than decode KV, so it defaults looser).

    ``class_aware`` multiplies ``kv_headroom`` by the request's SLO
    class's entry in ``class_headroom`` (serving/workloads.py defines
    the classes).  Best-effort requests that miss their tighter ceiling
    are *shed* (rejected immediately, reason ``class_shed``) rather than
    queued — interactive requests are never shed and always see the full
    headroom.  Off by default: the class-blind controller treats every
    class identically (golden parity).
    """
    kv_headroom: float = 0.90
    projected_output_frac: float = 0.5
    retry_s: float = 0.25           # cluster-side queue poll interval
    max_wait_s: float = 60.0        # queued longer than this => reject
    prefill_pool_aware: bool = True
    prefill_headroom: float = 0.95
    class_aware: bool = False
    class_headroom: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_CLASS_HEADROOM))

    def headroom_for(self, slo_class: str) -> float:
        if not self.class_aware:
            return self.kv_headroom
        return self.kv_headroom * self.class_headroom.get(slo_class, 1.0)


class AdmissionController:
    """Stateful decision maker; one per cluster."""

    def __init__(self, policy: AdmissionPolicy):
        self.policy = policy
        self.stats: Dict[str, int] = collections.Counter()
        self._first_seen: Dict[int, float] = {}

    # -- projections --------------------------------------------------------
    def projected_pages(self, r: Request, page_size: int) -> int:
        horizon = r.prompt_len + int(
            round(self.policy.projected_output_frac * r.max_new_tokens))
        return kv_pages_for(horizon, page_size)

    def prefill_pool_fits(self, replica, r: Request, snap=None) -> bool:
        """Split-pool targets only: would the prompt's transient
        prefill-side pages keep the *prefill* pool's projected occupancy
        (live pages + every queued prompt's claim + this request) under
        ``prefill_headroom``?  Colocated replicas report a zero-sized
        prefill pool and pass vacuously."""
        if not self.policy.prefill_pool_aware:
            return True
        s = snap if snap is not None else replica.snapshot()
        if getattr(s, "prefill_kv_total_blocks", 0) <= 0:
            return True        # colocated engine: no transient pool
        pages = kv_pages_for(r.prompt_len, replica.serve.page_size)
        used = s.prefill_kv_total_blocks - s.prefill_kv_free_blocks
        return used + s.queued_prefill_kv_pages + pages <= \
            self.policy.prefill_headroom * s.prefill_kv_total_blocks

    def fits(self, replica, r: Request, snap=None) -> bool:
        """Would admitting ``r`` keep the replica's projected pool
        occupancy (live + queued claims + this request) under the
        request's class headroom?  Disagg replicas must fit BOTH the
        decode pool (prompt + projected output) and the transient
        prefill pool (prompt).  Parked session-prefix blocks are
        reclaimable on demand, so they count as free in the projection.
        """
        s = snap if snap is not None else replica.snapshot()
        if s.kv_total_blocks <= 0:
            return True        # engine without a paged pool: no signal
        pages = self.projected_pages(r, replica.serve.page_size)
        used = s.kv_total_blocks - s.kv_free_blocks - \
            getattr(s, "kv_session_blocks", 0)
        if used + s.queued_kv_pages + pages > \
                self.policy.headroom_for(r.slo_class) * s.kv_total_blocks:
            return False
        return self.prefill_pool_fits(replica, r, snap=s)

    def feasible(self, replica, r: Request, snap=None) -> bool:
        """Can the prompt *ever* fit this replica's pools?"""
        s = snap if snap is not None else replica.snapshot()
        if s.kv_total_blocks <= 0:
            return True
        pages = kv_pages_for(r.prompt_len, replica.serve.page_size)
        if pages > s.kv_total_blocks:
            return False
        if self.policy.prefill_pool_aware and \
                getattr(s, "prefill_kv_total_blocks", 0) > 0:
            return pages <= s.prefill_kv_total_blocks
        return True

    # -- the decision -------------------------------------------------------
    def decide(self, r: Request, replicas: Sequence, now: float
               ) -> Tuple[str, Optional[List], Optional[str]]:
        """Returns ``("admit", fit_replicas, None)``, ``("wait", None,
        None)`` or ``("reject", None, reason)`` with ``reason`` one of
        ``never_fits`` / ``kv_headroom`` / ``class_shed`` (the
        ``RejectedEvent.reason`` vocabulary)."""
        # one snapshot per replica per decision: snapshots walk whole
        # queues, and decide() re-runs every retry tick under overload
        snaps = [(rep, rep.snapshot()) for rep in replicas]
        feasible = [(rep, s) for rep, s in snaps
                    if self.feasible(rep, r, snap=s)]
        if not feasible:
            self.stats["rejected_infeasible"] += 1
            self._first_seen.pop(r.rid, None)
            return "reject", None, "never_fits"
        fit = [rep for rep, s in feasible if self.fits(rep, r, snap=s)]
        if fit:
            self.stats["admitted"] += 1
            if len(fit) < len(replicas):
                self.stats["redirected"] += 1
            self._first_seen.pop(r.rid, None)
            return "admit", fit, None
        if self.policy.class_aware and r.slo_class == "best_effort":
            # shed: queueing best-effort work behind its tight ceiling
            # only delays the reclaim the higher classes need
            self.stats["shed"] += 1
            self._first_seen.pop(r.rid, None)
            return "reject", None, "class_shed"
        first = self._first_seen.setdefault(r.rid, now)
        if now - first >= self.policy.max_wait_s:
            self.stats["rejected_timeout"] += 1
            self._first_seen.pop(r.rid, None)
            return "reject", None, "kv_headroom"
        self.stats["delayed"] += 1
        return "wait", None, None
