"""Cluster-level KV-aware admission control.

The PR-1 routers place every arrival unconditionally; under KV pressure
the engine then discovers the overflow *mid-flight* and preempts
(recompute-on-resume), which burns prefill work exactly when the fleet
can least afford it.  The admission controller moves that discovery to
arrival time: it projects the new request's KV footprint
(``kvcache.manager.kv_pages_for`` over prompt + expected decode tokens)
against each replica's live pool state (``LoadSnapshot.kv_utilization``
/ ``kv_free_blocks`` plus the pages its queued-but-unallocated requests
will claim) and

  * **admits** on the subset of replicas with headroom (the router picks
    among those — a redirect when its unconstrained choice was full),
  * **queues** the arrival cluster-side and retries when no replica has
    headroom right now, and
  * **rejects** cleanly when the prompt can never fit any replica's pool
    or the queueing deadline expires — instead of letting an engine hit
    ``OutOfBlocks`` (or preemption-thrash) mid-flight.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.request import Request
from repro.kvcache import kv_pages_for


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Knobs for the KV-aware admission controller.

    ``kv_headroom`` is the pool fraction the projected post-admit
    occupancy may not exceed (the margin absorbs decode growth of
    already-running requests).  ``projected_output_frac`` scales the
    request's ``max_new_tokens`` in the footprint projection — 1.0
    reserves for the worst case, smaller values statistically multiplex.
    """
    kv_headroom: float = 0.90
    projected_output_frac: float = 0.5
    retry_s: float = 0.25           # cluster-side queue poll interval
    max_wait_s: float = 60.0        # queued longer than this => reject


class AdmissionController:
    """Stateful decision maker; one per cluster."""

    def __init__(self, policy: AdmissionPolicy):
        self.policy = policy
        self.stats: Dict[str, int] = collections.Counter()
        self._first_seen: Dict[int, float] = {}

    # -- projections --------------------------------------------------------
    def projected_pages(self, r: Request, page_size: int) -> int:
        horizon = r.prompt_len + int(
            round(self.policy.projected_output_frac * r.max_new_tokens))
        return kv_pages_for(horizon, page_size)

    def fits(self, replica, r: Request, snap=None) -> bool:
        """Would admitting ``r`` keep the replica's projected pool
        occupancy (live + queued claims + this request) under headroom?"""
        s = snap if snap is not None else replica.snapshot()
        if s.kv_total_blocks <= 0:
            return True        # engine without a paged pool: no signal
        pages = self.projected_pages(r, replica.serve.page_size)
        used = s.kv_total_blocks - s.kv_free_blocks
        return used + s.queued_kv_pages + pages <= \
            self.policy.kv_headroom * s.kv_total_blocks

    def feasible(self, replica, r: Request, snap=None) -> bool:
        """Can the prompt *ever* fit this replica's pool?"""
        s = snap if snap is not None else replica.snapshot()
        if s.kv_total_blocks <= 0:
            return True
        return kv_pages_for(r.prompt_len, replica.serve.page_size) <= \
            s.kv_total_blocks

    # -- the decision -------------------------------------------------------
    def decide(self, r: Request, replicas: Sequence, now: float
               ) -> Tuple[str, Optional[List]]:
        """Returns ``("admit", fit_replicas)``, ``("wait", None)`` or
        ``("reject", None)``."""
        # one snapshot per replica per decision: snapshots walk whole
        # queues, and decide() re-runs every retry tick under overload
        snaps = [(rep, rep.snapshot()) for rep in replicas]
        feasible = [(rep, s) for rep, s in snaps
                    if self.feasible(rep, r, snap=s)]
        if not feasible:
            self.stats["rejected_infeasible"] += 1
            self._first_seen.pop(r.rid, None)
            return "reject", None
        fit = [rep for rep, s in feasible if self.fits(rep, r, snap=s)]
        if fit:
            self.stats["admitted"] += 1
            if len(fit) < len(replicas):
                self.stats["redirected"] += 1
            self._first_seen.pop(r.rid, None)
            return "admit", fit
        first = self._first_seen.setdefault(r.rid, now)
        if now - first >= self.policy.max_wait_s:
            self.stats["rejected_timeout"] += 1
            self._first_seen.pop(r.rid, None)
            return "reject", None
        self.stats["delayed"] += 1
        return "wait", None
