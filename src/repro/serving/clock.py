"""Clock abstraction for the serving gateway.

The gateway (serving/gateway.py) schedules everything — heartbeats,
health checks, admission retries, failover re-dispatch — through a
*clock* object instead of calling asyncio directly, so the same code
runs in two modes:

  * simulated: the existing ``serving.sim.EventLoop``.  ``run()``
    drains the heap deterministically; churn tests (worker crash
    mid-decode, rolling-upgrade drain, slow consumers) execute in CI
    without sockets, sleeps, or flaky wall-clock timing.
  * real time: ``RealTimeClock`` below, a thin adapter over an asyncio
    event loop for the HTTP server (serving/http.py).

Clock protocol (duck-typed; both implementations provide it):

  ``now``          current time in seconds (attribute or property)
  ``at(t, fn)``    run ``fn()`` at absolute time ``t`` (clamped to now)
  ``after(dt, fn)``run ``fn()`` after ``dt`` seconds
  ``virtual``      True when time only advances by draining scheduled
                   events.  Periodic tasks (heartbeats, health ticks)
                   must gate their re-arming on pending work when this
                   is set, or the simulated loop never goes idle.
  ``stats``        ``LoopStats``-compatible counters for /metrics.
"""
from __future__ import annotations

from typing import Callable

from repro.serving.sim import EventLoop, LoopStats

__all__ = ["EventLoop", "RealTimeClock"]


class RealTimeClock:
    """Clock over an asyncio event loop (``loop.time()`` timebase).

    Construction is loop-free so a gateway (whose constructor already
    arms worker heartbeats) can be built before asyncio starts;
    ``bind()`` attaches the running loop and flushes anything scheduled
    in the meantime — pre-bind delays are measured from bind time,
    which is when serving actually begins.

    ``now`` counts seconds *since bind* (0.0 before), not raw
    ``loop.time()``: timestamps recorded pre-bind (worker ``last_beat``
    at registration, request arrivals) must stay comparable after the
    loop attaches, or every worker looks heartbeat-timed-out the
    instant serving starts.
    """

    virtual = False

    def __init__(self):
        self._loop = None
        self._t0 = 0.0               # loop.time() at bind
        self._pending: list = []     # (dt, fn) queued before bind()
        self.stats = LoopStats()

    def bind(self, loop) -> None:
        self._loop = loop
        self._t0 = loop.time()
        pending, self._pending = self._pending, []
        for dt, fn in pending:
            self.after(dt, fn)

    @property
    def now(self) -> float:
        if self._loop is None:
            return 0.0
        return self._loop.time() - self._t0

    def at(self, t: float, fn: Callable[[], None]) -> None:
        if self._loop is None:
            self._pending.append((max(t, 0.0), fn))
            return
        if t < self.now:
            t = self.now
            self.stats.clamped += 1
        self.stats.dispatched += 1
        self._loop.call_at(t + self._t0, fn)

    def after(self, dt: float, fn: Callable[[], None]) -> None:
        if self._loop is None:
            self._pending.append((max(dt, 0.0), fn))
            return
        if dt < 0:
            dt = 0.0
            self.stats.clamped += 1
        self.stats.dispatched += 1
        self._loop.call_later(dt, fn)
