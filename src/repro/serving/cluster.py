"""Multi-replica cluster serving layer (DistServe-style fleet scale).

Runs N engine replicas — any mix of ``rapid`` / ``hybrid`` / ``disagg``
from ``core/engines.py`` — under ONE shared ``EventLoop`` (a single
virtual clock), behind a pluggable router:

  * ``round_robin``   — classic cycling over routable replicas.
  * ``least_loaded``  — fewest queued prefill tokens (the quantity that
    actually backs up TTFT), tie-broken by queued request count.
  * ``slo_aware``     — projects per-replica TTFT and ITL for the new
    request from the perfmodel (prefill cost of the queued + new prompt
    tokens; decode cost of the grown batch) and picks the replica with
    the lowest combined SLO-normalized score.

Routing happens at arrival time on the shared loop, so routers see each
replica's live load — exactly the information a fleet front-end has.

  * ``bucketed``      — BucketServe-style length bucketing for
    *heterogeneous* fleets: each replica advertises a prompt-length
    ceiling proportional to its chip count; requests go to the smallest
    compatible tier (tie-broken by capacity-normalized load), so short
    prompts never occupy the big replicas that long prompts need.

Optional SLO-driven scaling, two policies:

  * ``ScalePolicy`` — reactive: a periodic controller watches the
    recent TTFT-attainment window and adds replicas (up to
    ``max_replicas``) while the fleet is missing SLO.
  * ``ProjectionPolicy`` — projection-driven (paper §4.5.3 at cluster
    scale): every replica's live ``LoadSnapshot`` is priced by the
    perfmodel (``forecast_phase_times``) to forecast TTFT/ITL over the
    next horizon, the trailing arrival token rate sizes the capacity
    deficit, and the controller scales *before* violations happen —
    adding as many replicas as the deficit needs in one tick and, for
    split-pool (disagg) replicas, growing the prefill and decode chip
    pools *independently* (``Engine.resize_lane``).

Either way retired replicas stop receiving traffic but keep running
until their queues drain, so no request is lost.

Optional KV-aware admission (``AdmissionPolicy``, serving/admission.py):
arrivals whose projected KV footprint would overflow every replica's
pool are queued cluster-side (and eventually rejected) instead of being
placed and preempted mid-flight.

Optional cross-replica preemption/migration (``RebalancePolicy``): a
periodic tick picks victims on KV-overloaded replicas via the shared
``PreemptionPolicy`` (core/preemption.py), charges the KV-transfer cost
from perfmodel/costs.py, and re-enqueues them on the least-loaded
compatible replica — the placement is *revoked*, which the PR-1 router
never did.  The tick is hysteretic (a replica must stay hot for
``hot_ticks`` consecutive checks before losing live KV) and cost/benefit
gated (a live-context move is skipped when the KV transfer plus the
destination's queue beats nothing — i.e. when ``kv_migration_seconds``
exceeds the projected queue relief).

Serving API v2: the cluster is an event-stream node.  Every replica
engine's typed stream (core/events.py) is forwarded into one fleet
stream (``cluster.subscribe`` / ``cluster.events``), cluster-side
admission rejections are emitted as ``RejectedEvent``s, and both the
autoscaler's TTFT-attainment window and ``run_fleet``'s summary consume
the stream (via ``serving.metrics.StreamMetrics``) instead of scraping
records after the fact.
"""
from __future__ import annotations

import bisect
import copy
import dataclasses
import math
from typing import (TYPE_CHECKING, Callable, Dict, List, Optional,
                    Sequence, Union)

import numpy as np

from repro.config import ServeConfig
from repro.core.events import EventStream, RejectedEvent
from repro.core.preemption import PreemptionPolicy
from repro.core.queues import IndexedQueue
from repro.core.request import Request, State
from repro.perfmodel import batch as B
from repro.perfmodel import costs as C
from repro.perfmodel import interference as I
from repro.perfmodel.hw import TPU_V5E, HardwareSpec
from repro.serving.admission import AdmissionController, AdmissionPolicy
from repro.serving.metrics import (RequestRecord, StreamMetrics,
                                   fleet_summarize, ttft_ceiling)
from repro.serving.sim import EventLoop

if TYPE_CHECKING:   # deferred to break the serving <-> core import cycle
    from repro.core.engines import BaseEngine, LoadSnapshot


@dataclasses.dataclass(frozen=True)
class ReplicaSpec:
    """One replica's recipe: engine mode plus optional per-replica
    overrides (heterogeneous fleets).  ``chips`` rescales the base
    ``ServeConfig`` (disagg splits follow); ``serve`` replaces it
    wholesale.  Split-pool replicas may instead size their pools
    independently with ``chips_p``/``chips_d`` (prefill / decode chip
    groups — both required together; ``chips`` is then derived)."""
    mode: str
    chips: Optional[int] = None
    serve: Optional[ServeConfig] = None
    chips_p: Optional[int] = None
    chips_d: Optional[int] = None


def parse_mix(mix: str) -> List[ReplicaSpec]:
    """Parse ``--mix`` syntax.  Three forms compose freely:

      * ``rapid,rapid,hybrid``      — one replica per entry, default chips
      * ``rapid:2x4,hybrid:1x8``    — ``mode:COUNTxCHIPS`` groups
      * ``disagg:1x8+24``           — ``mode:COUNTxP+D`` per-pool chip
        groups (8 prefill chips, 24 decode chips per replica)
    """
    specs: List[ReplicaSpec] = []
    for part in mix.split(","):
        part = part.strip()
        if not part:
            continue
        if ":" in part:
            mode, shape = part.split(":", 1)
            count_s, _, chips_s = shape.lower().partition("x")
            if not chips_s:
                raise ValueError(
                    f"bad --mix group {part!r}: want mode:COUNTxCHIPS "
                    "or mode:COUNTxP+D")
            if "+" in chips_s:
                p_s, _, d_s = chips_s.partition("+")
                spec = ReplicaSpec(mode.strip(), chips_p=int(p_s),
                                   chips_d=int(d_s))
            else:
                spec = ReplicaSpec(mode.strip(), chips=int(chips_s))
            specs.extend([spec] * int(count_s))
        else:
            specs.append(ReplicaSpec(part))
    if not specs:
        raise ValueError(f"empty --mix {mix!r}")
    return specs


@dataclasses.dataclass
class Replica:
    idx: int
    mode: str
    engine: BaseEngine
    serve: ServeConfig
    routable: bool = True
    # indexed so the rebalance tick's eviction is O(1), not an O(n)
    # list.remove over every request the replica ever served
    assigned: IndexedQueue = dataclasses.field(
        default_factory=IndexedQueue)

    @property
    def name(self) -> str:
        return f"{self.mode}-{self.idx}"

    def snapshot(self) -> LoadSnapshot:
        return self.engine.load_snapshot()


# ---------------------------------------------------------------------------
# Routers
# ---------------------------------------------------------------------------


class Router:
    """Picks a replica index for each arriving request."""

    name = "base"
    # perfmodel-backed routers score the whole candidate list through
    # perfmodel.batch in one call when this is set (the cluster copies
    # its own batch_pricing flag here); the scalar per-replica path is
    # kept as the reference/fallback and is bit-identical by the batch
    # layer's contract
    batch_pricing = True

    def choose(self, r: Request, replicas: List[Replica]) -> int:
        raise NotImplementedError

    def bind(self, fleet: List[Replica]) -> None:
        """Give the router a reference to the cluster's FULL replica
        list (the live list object, so later scale-ups are visible).
        ``choose`` may be handed a filtered subset (admission control,
        retired replicas); size-aware routers must compute fleet-relative
        quantities like bucket ceilings against the full fleet, not the
        subset."""

    def admits(self, length: int, rep: Replica,
               replicas: List[Replica]) -> bool:
        """Whether a sequence of ``length`` tokens may be (re)placed on
        ``rep`` — the rebalancer asks before migrating.  Size-agnostic
        routers accept anything."""
        return True


class RoundRobinRouter(Router):
    name = "round_robin"

    def __init__(self):
        self._next = 0

    def choose(self, r: Request, replicas: List[Replica]) -> int:
        i = self._next % len(replicas)
        self._next += 1
        return i


class LeastLoadedRouter(Router):
    """Balance queued prefill tokens — counts back up TTFT, tokens do."""

    name = "least_loaded"

    def choose(self, r: Request, replicas: List[Replica]) -> int:
        def key(i: int):
            s = replicas[i].snapshot()
            return (s.queued_prefill_tokens, s.queued_requests,
                    s.running_decode, i)
        return min(range(len(replicas)), key=key)


class SloAwareRouter(Router):
    """Project TTFT/ITL per replica from the perfmodel and route to the
    replica with the lowest SLO-normalized combined score (DistServe's
    placement insight applied at the router)."""

    name = "slo_aware"

    def __init__(self, cfg, serve: ServeConfig, hw: HardwareSpec = TPU_V5E):
        self.cfg = cfg
        self.serve = serve
        self.hw = hw

    def _score(self, r: Request, rep: Replica) -> float:
        s = rep.snapshot()
        # disagg replicas split their chips into prefill/decode pools
        # (engine exposes chips_p/chips_d); colocated engines use them
        # all — per-replica, so heterogeneous fleets score correctly
        chips_p = getattr(rep.engine, "chips_p", rep.serve.chips)
        chips_d = getattr(rep.engine, "chips_d", rep.serve.chips)
        # projected TTFT: every queued prompt token plus ours must be
        # prefilled before our first token can exist
        p_cost = C.prefill_cost(
            self.cfg, [s.queued_prefill_tokens + r.prompt_len], chips_p)
        proj_ttft = I.phase_time(p_cost, self.hw, chips_p)
        # projected ITL: the decode batch we would eventually join
        bs = s.running_decode + 1
        ctx = float(s.decode_ctx_tokens + r.prompt_len)
        d_cost = C.decode_cost(self.cfg, bs, ctx, chips_d)
        proj_itl = I.phase_time(d_cost, self.hw, chips_d)
        slo = self.serve.slo
        return (proj_ttft / ttft_ceiling(r.prompt_len, slo)
                + proj_itl / (slo.itl_ms / 1e3))

    def _scores(self, r: Request, replicas: List[Replica]) -> np.ndarray:
        """Vectorized ``_score`` over the whole candidate list: one
        batched prefill pricing and one batched decode pricing for the
        fleet instead of 2N scalar cost calls per arrival.  Loads come
        from ``Engine.router_load()`` — the three priced counters read
        directly, not the full 16-field snapshot the scalar reference
        path builds per replica (value-identical either way)."""
        loads = [rep.engine.router_load() for rep in replicas]
        chips_p = np.asarray(
            [getattr(rep.engine, "chips_p", rep.serve.chips)
             for rep in replicas], dtype=np.int64)
        chips_d = np.asarray(
            [getattr(rep.engine, "chips_d", rep.serve.chips)
             for rep in replicas], dtype=np.int64)
        pl = r.prompt_len
        pb = B.prefill_cost(
            self.cfg, [[tok + pl] for tok, _, _ in loads], chips_p)
        proj_ttft = B.phase_time(pb, self.hw, chips_p)
        db = B.decode_cost(
            self.cfg, [run + 1 for _, run, _ in loads],
            [float(ctx + pl) for _, _, ctx in loads], chips_d)
        proj_itl = B.phase_time(db, self.hw, chips_d)
        slo = self.serve.slo
        return (proj_ttft / ttft_ceiling(pl, slo)
                + proj_itl / (slo.itl_ms / 1e3))

    def choose(self, r: Request, replicas: List[Replica]) -> int:
        if not self.batch_pricing:
            return min(range(len(replicas)),
                       key=lambda i: (self._score(r, replicas[i]), i))
        # scores are bit-identical to the scalar path and np.argmin
        # returns the FIRST minimum, so the (score, i) tie-break holds
        return int(np.argmin(self._scores(r, replicas)))


class BucketedRouter(Router):
    """BucketServe-style length bucketing for heterogeneous fleets.

    Each replica advertises a prompt-length *bucket ceiling* proportional
    to its chip count (the largest tier always advertises the full
    ``max_seq_len``, so any servable prompt has a compatible replica).
    A request is routed among the replicas whose ceiling covers its
    prompt, preferring lower capacity-normalized load and, on ties, the
    smallest compatible tier — short prompts stay off the big replicas
    that long prompts need.
    """

    name = "bucketed"

    def __init__(self):
        self._fleet: Optional[List[Replica]] = None

    def bind(self, fleet: List[Replica]) -> None:
        self._fleet = fleet

    @staticmethod
    def ceiling(rep: Replica, replicas: Sequence[Replica]) -> int:
        cmax = max(p.serve.chips for p in replicas)
        return max(1, rep.serve.max_seq_len * rep.serve.chips // cmax)

    def _reference(self, replicas: List[Replica]) -> Sequence[Replica]:
        # ceilings are relative to the biggest replica in the FULL fleet;
        # computing them over a filtered subset (admission fit-list) would
        # silently inflate the small tiers' ceilings
        return self._fleet if self._fleet else replicas

    def admits(self, length: int, rep: Replica,
               replicas: List[Replica]) -> bool:
        return self.ceiling(rep, self._reference(replicas)) >= length

    def choose(self, r: Request, replicas: List[Replica]) -> int:
        ref = self._reference(replicas)
        ceils = [self.ceiling(rep, ref) for rep in replicas]
        compatible = [i for i in range(len(replicas))
                      if ceils[i] >= r.prompt_len]
        if not compatible:
            # oversized for every offered replica (whole-fleet oversize,
            # or admission filtered out the big tier): best-effort on the
            # biggest ceiling available rather than dropping the request
            return max(range(len(replicas)), key=lambda i: (ceils[i], -i))

        def key(i: int):
            s = replicas[i].snapshot()
            norm_load = s.queued_prefill_tokens / max(1,
                                                      replicas[i].serve.chips)
            return (norm_load, ceils[i], s.queued_requests, i)
        return min(compatible, key=key)


ROUTERS: Dict[str, Callable[..., Router]] = {
    "round_robin": lambda cfg, serve, hw: RoundRobinRouter(),
    "least_loaded": lambda cfg, serve, hw: LeastLoadedRouter(),
    "slo_aware": lambda cfg, serve, hw: SloAwareRouter(cfg, serve, hw),
    "bucketed": lambda cfg, serve, hw: BucketedRouter(),
}


def make_router(name: str, cfg, serve: ServeConfig,
                hw: HardwareSpec = TPU_V5E) -> Router:
    if name not in ROUTERS:
        raise KeyError(f"unknown router {name!r}; known: {sorted(ROUTERS)}")
    return ROUTERS[name](cfg, serve, hw)


# ---------------------------------------------------------------------------
# SLO-driven replica scaling
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScalePolicy:
    """Reactive autoscaler: add a replica while the recent TTFT-attainment
    window misses ``target_attainment``; retire an idle surplus replica
    after ``idle_windows`` consecutive quiet checks."""
    min_replicas: int = 1
    max_replicas: int = 4
    check_interval_s: float = 5.0
    window_s: float = 10.0
    target_attainment: float = 0.9
    idle_windows: int = 2
    scale_up_mode: Optional[str] = None   # None => clone replica 0's mode


@dataclasses.dataclass(frozen=True)
class ProjectionPolicy:
    """Projection-driven autoscaler (paper §4.5.3 at cluster scale).

    Where ``ScalePolicy`` reacts to a *trailing* TTFT-attainment window —
    it cannot act until delayed requests have already finished late —
    this policy runs every replica's live ``LoadSnapshot`` through the
    perfmodel (``perfmodel.costs`` + ``perfmodel.interference.
    forecast_phase_times``) and scales on what the fleet is *about* to
    do over the next ``horizon_s``:

      * **TTFT forecast** — each replica's queued prefill backlog, plus
        its share of the trailing arrival token rate extended over the
        horizon, is priced as one prefill; a drain time beyond the
        length-dependent TTFT ceiling (x ``ttft_margin``) flags the
        replica prefill-pressed *before* any request misses SLO.
      * **ITL forecast** — the decode batch the replica will be running
        once queued work joins is priced against the ITL SLO
        (x ``itl_margin``).
      * **capacity forecast** — fleet-wide prefill token throughput vs
        the arrival token rate; the controller adds as many replicas as
        the projected deficit needs in ONE tick (the reactive policy
        drips one replica per window and chases the backlog).

    Split-pool (disagg) replicas scale their pools *independently* when
    ``pool_scaling`` is on: a prefill-pressed replica grows only its
    prefill chip group (``pool_chip_step`` chips, up to
    ``max_pool_chips``) — decode chips and every live decode-pool KV
    page are untouched — and vice versa.  Whole-replica adds remain the
    fallback once pools are maxed (or for colocated replicas).

    Scale-down reuses the reactive policy's conservative idle-retire
    rule; pools never shrink (live KV cannot be evicted out from under
    running requests).
    """
    min_replicas: int = 1
    max_replicas: int = 4
    check_interval_s: float = 5.0
    horizon_s: float = 5.0
    ttft_margin: float = 1.0       # scale when proj TTFT > margin*ceiling
    itl_margin: float = 1.0        # scale when proj ITL > margin*SLO
    idle_windows: int = 2
    scale_up_mode: Optional[str] = None   # None => clone replica 0's mode
    pool_scaling: bool = True      # disagg: grow P/D pools independently
    pool_chip_step: int = 4
    max_pool_chips: int = 64


@dataclasses.dataclass(frozen=True)
class RebalancePolicy:
    """Cross-replica preemption/migration: while a replica's KV pool sits
    above ``kv_high`` and another routable replica sits below ``kv_low``,
    move up to ``max_moves_per_tick`` victims per check.  Queued victims
    are re-routed for free; running victims are preempted via the shared
    ``PreemptionPolicy`` and charged the KV-transfer time of their live
    context (perfmodel ``kv_migration_seconds``) before re-enqueueing.

    Two guards keep the tick from thrashing:

    * **hysteresis** — a replica must report ``kv_utilization >=
      kv_high`` for ``hot_ticks`` *consecutive* checks before any live
      KV is evicted from it (queued victims, which hold no KV, may still
      be re-routed on the first hot tick);
    * **cost/benefit** — a live-context move is skipped when the KV
      transfer time plus the destination's projected prefill backlog
      exceeds the victim's projected wait on the source, i.e. when
      ``kv_migration_seconds`` exceeds the projected queue relief.
      ``cost_benefit=False`` restores the unguarded PR-2 behaviour.
    """
    check_interval_s: float = 1.0
    kv_high: float = 0.85
    kv_low: float = 0.65
    max_moves_per_tick: int = 2
    max_migrations_per_request: int = 2
    link_gbps: Optional[float] = None   # None => serve.kv_transfer_gbps
    hot_ticks: int = 2                  # consecutive hot checks required
    cost_benefit: bool = True           # gate live-KV moves on net win


class Cluster:
    """N engine replicas sharing one EventLoop behind a Router."""

    def __init__(self, cfg, serve: ServeConfig,
                 modes: Sequence[Union[str, ReplicaSpec]],
                 router: str = "round_robin", hw: HardwareSpec = TPU_V5E,
                 scale: Optional[Union[ScalePolicy,
                                       ProjectionPolicy]] = None,
                 admission: Optional[AdmissionPolicy] = None,
                 rebalance: Optional[RebalancePolicy] = None,
                 loop: Optional[EventLoop] = None,
                 session_affinity: bool = False,
                 preempt_policy: Optional[PreemptionPolicy] = None,
                 batch_pricing: bool = True):
        if not modes:
            raise ValueError("cluster needs at least one replica mode")
        self.cfg = cfg
        self.serve = serve
        self.hw = hw
        # fleet-vectorized pricing: projection/rebalance ticks and the
        # slo_aware router price all replicas through perfmodel.batch in
        # one call; False restores the scalar per-replica loops (same
        # numbers bit-for-bit — the batch layer is a pure vectorization)
        self.batch_pricing = batch_pricing
        self.loop = loop if loop is not None else EventLoop()
        # session -> replica idx holding the session's parked prefix KV;
        # affinity routing sends the next turn there so the prefix hits
        self.session_affinity = session_affinity
        self._session_home: Dict[str, int] = {}
        self._preempt_policy = preempt_policy
        self._base_specs: Dict[str, ReplicaSpec] = {}
        # fleet event stream: replica streams forward here, plus cluster-
        # side rejections; the autoscaler window and run_fleet consume it
        self.stream = EventStream()
        self.metrics = StreamMetrics()
        self.stream.subscribe(self.metrics)
        self.replicas: List[Replica] = []
        for spec in modes:
            self._add_replica(spec)
        self.router = make_router(router, cfg, serve, hw)
        self.router.batch_pricing = batch_pricing
        # the live list object: scale-ups appended later stay visible
        self.router.bind(self.replicas)
        self.scale = scale
        self.admission = AdmissionController(admission) \
            if admission is not None else None
        self.rebalance = rebalance
        self.rejected: List[Request] = []
        self._all: List[Request] = []
        # (t, action, n): action in {"up","down"} with n = routable count,
        # or {"pool_prefill","pool_decode"} with n = the lane's new chips
        self._scale_events: List[tuple] = []
        self._migrations: List[tuple] = []     # (t, src, dst, rid, had_kv)
        self._migration_counts: Dict[int, int] = {}
        self._idle_checks = 0
        self._hot_streak: Dict[int, int] = {}  # replica idx -> hot ticks
        self._pressed_streak = 0   # consecutive pressed projection ticks
        # arrival index for the projection policy's trailing token rate:
        # sorted arrival times + prefix token sums, rebuilt lazily at
        # the first tick after an enqueue (ticks are far sparser than
        # incremental enqueues can be)
        self._arr_t: List[float] = []
        self._arr_cum: List[int] = []
        self._arr_dirty = False

    # -- replica lifecycle ---------------------------------------------------
    def _add_replica(self, spec: Union[str, ReplicaSpec]) -> Replica:
        # local import: core.engines itself imports serving.metrics/sim
        from repro.core.engines import make_engine
        if isinstance(spec, str):
            spec = ReplicaSpec(spec)
        serve = spec.serve if spec.serve is not None else self.serve
        if (spec.chips_p is None) != (spec.chips_d is None):
            raise ValueError(
                f"ReplicaSpec({spec.mode}): chips_p and chips_d must be "
                "given together")
        if spec.chips_p is not None:
            # independently-sized P/D pools (split-pool replicas)
            serve = dataclasses.replace(
                serve, chips=spec.chips_p + spec.chips_d,
                disagg_split=(spec.chips_p, spec.chips_d))
        elif spec.chips is not None and spec.chips != serve.chips:
            serve = dataclasses.replace(
                serve, chips=spec.chips,
                disagg_split=(max(1, spec.chips // 2),
                              max(1, spec.chips - spec.chips // 2)))
        if self._preempt_policy is not None:
            engine = make_engine(spec.mode, self.cfg, serve, self.hw,
                                 loop=self.loop,
                                 preempt_policy=self._preempt_policy)
        else:
            engine = make_engine(spec.mode, self.cfg, serve, self.hw,
                                 loop=self.loop)
        if spec.chips_p is not None and \
                getattr(engine.scheduler, "colocated", True):
            raise ValueError(
                f"ReplicaSpec({spec.mode}): chips_p/chips_d describe "
                "split-pool replicas; colocated modes share every chip "
                f"between both phases — use chips={serve.chips} instead")
        # scale-up clones a mode's ORIGINAL spec, not the bare mode
        # string, so autoscaled replicas keep per-pool chip shapes
        self._base_specs.setdefault(spec.mode, spec)
        rep = Replica(idx=len(self.replicas), mode=spec.mode,
                      engine=engine, serve=serve,
                      assigned=IndexedQueue(serve.page_size))
        rep.engine.subscribe(self.stream.emit)   # forward into fleet stream
        self.replicas.append(rep)
        return rep

    # -- streaming API -------------------------------------------------------
    def subscribe(self, fn, rid: Optional[int] = None):
        """Attach a consumer to the merged fleet event stream (all
        replicas plus cluster-side rejections)."""
        return self.stream.subscribe(fn, rid)

    def events(self):
        return self.stream.events()

    @property
    def routable(self) -> List[Replica]:
        return [rep for rep in self.replicas if rep.routable]

    @property
    def num_replicas(self) -> int:
        return len(self.replicas)

    # -- ingress ---------------------------------------------------------------
    def submit(self, r: Request) -> None:
        """Route an arriving request to a replica (called on the loop at
        the request's arrival time).  With admission control enabled the
        arrival may instead be queued cluster-side or rejected."""
        # scale-down can empty routable() between arrival and routing:
        # retired replicas still run, so fall back to the full fleet
        # rather than crashing the router on an empty list
        live = self.routable or self.replicas
        if self.admission is not None:
            verdict, fit, reason = self.admission.decide(r, live,
                                                         self.loop.now)
            if verdict == "reject":
                r.state = State.REJECTED
                r.reject_reason = reason
                self.rejected.append(r)
                self.stream.emit(RejectedEvent(
                    r.rid, self.loop.now, r.arrival, r.prompt_len,
                    reason, 0, 0, r.slo_class))
                return
            if verdict == "wait":
                self.loop.after(self.admission.policy.retry_s,
                                lambda r=r: self.submit(r))
                return
            live = fit
        rep = None
        if self.session_affinity and r.session_id is not None:
            # route the session's next turn to the replica parking its
            # prefix KV — but only if admission still allows it there
            home = self._session_home.get(r.session_id)
            if home is not None:
                rep = next((cand for cand in live if cand.idx == home),
                           None)
        if rep is None:
            rep = live[self.router.choose(r, live)]
        if r.session_id is not None:
            self._session_home[r.session_id] = rep.idx
        rep.assigned.append(r)
        rep.engine.submit(r)

    def enqueue(self, requests: Sequence[Request]) -> None:
        self._all.extend(requests)
        self._arr_dirty = True
        for r in requests:
            self.loop.at(r.arrival, lambda r=r: self.submit(r))

    def run(self, requests: Sequence[Request]):
        """Serve a trace to completion.  Returns (records, span_s)."""
        self.enqueue(requests)
        if self.scale is not None:
            self.loop.after(self.scale.check_interval_s, self._scale_tick)
        if self.rebalance is not None:
            self.loop.after(self.rebalance.check_interval_s,
                            self._rebalance_tick)
        self.loop.run()
        span = self.loop.now if self.loop.now > 0 else 1.0
        return [RequestRecord.from_request(r) for r in self._all], span

    def _outstanding(self) -> bool:
        # O(1): every request ends with exactly one terminal event
        # (FinishedEvent / RejectedEvent, incl. cluster-side admission
        # rejections), and StreamMetrics folds each into one record —
        # so "any request still in flight" is a count comparison, not a
        # walk over every request ever enqueued (the PR-4 version
        # rescanned self._all on every rebalance/scale tick)
        return len(self._all) > len(self.metrics.records)

    # -- per-replica views -----------------------------------------------------
    def per_replica_records(self) -> Dict[str, List[RequestRecord]]:
        return {rep.name: [RequestRecord.from_request(r)
                           for r in rep.assigned]
                for rep in self.replicas}

    def per_replica_counts(self) -> Dict[str, int]:
        return {rep.name: len(rep.assigned) for rep in self.replicas}

    # -- autoscaler ------------------------------------------------------------
    def _recent_attainment(self) -> Optional[float]:
        # stream consumer: the window comes from FinishedEvents folded by
        # StreamMetrics, not from walking every replica's request list
        window = [rec for rec in self.metrics.finished_since(
            self.loop.now - self.scale.window_s) if rec.ttft is not None]
        if not window:
            return None
        ok = sum(1 for rec in window
                 if rec.ttft <= ttft_ceiling(rec.prompt_len, self.serve.slo))
        return ok / len(window)

    def _scale_tick(self) -> None:
        outstanding = self._outstanding()
        if isinstance(self.scale, ProjectionPolicy):
            self._projection_tick()
        else:
            self._reactive_tick()
        if outstanding:
            self.loop.after(self.scale.check_interval_s, self._scale_tick)

    def _scale_up_one(self) -> None:
        mode = self.scale.scale_up_mode or self.replicas[0].mode
        # reactivate a retired replica before constructing a new one,
        # else oscillating load grows self.replicas without bound
        retired = [rep for rep in self.replicas if not rep.routable
                   and rep.mode == mode]
        if retired:
            retired[0].routable = True
        else:
            # clone the mode's original spec so per-pool chip shapes
            # (chips_p/chips_d) survive autoscaling
            self._add_replica(self._base_specs.get(mode,
                                                   ReplicaSpec(mode)))
        self._scale_events.append((self.loop.now, "up",
                                   len(self.routable)))

    def _retire_if_idle(self, busy: bool) -> None:
        if not busy and len(self.routable) > self.scale.min_replicas:
            self._idle_checks += 1
            if self._idle_checks >= self.scale.idle_windows:
                # retire the newest routable replica: it stops receiving
                # traffic (it is already drained — fleet was idle)
                self.routable[-1].routable = False
                self._scale_events.append((self.loop.now, "down",
                                           len(self.routable)))
                self._idle_checks = 0
        else:
            self._idle_checks = 0

    def _reactive_tick(self) -> None:
        att = self._recent_attainment()
        snaps = [rep.snapshot() for rep in self.replicas]
        # prefill_busy covers the window where a batch is in flight but
        # sits in no queue — a replica mid-prefill is NOT drained
        busy = any(s.queued_requests or s.running_decode
                   or s.prefill_busy or s.decode_busy for s in snaps)
        # backlog is the *leading* indicator (attainment only moves once
        # delayed requests finish): queued prefill work beyond one full
        # prefill step per routable replica means TTFTs are already sliding
        backlog = sum(s.queued_prefill_tokens for s in snaps) / \
            max(1, len(self.routable))
        pressed = (att is not None and att < self.scale.target_attainment) \
            or backlog > self.serve.prefill_max_tokens
        if pressed and len(self.routable) < self.scale.max_replicas:
            self._scale_up_one()
            self._idle_checks = 0
        else:
            self._retire_if_idle(busy)

    # -- projection-driven scaling (perfmodel forecasts) -----------------------
    def _arrival_token_rate(self, window_s: float) -> float:
        """Prompt tokens/s that ARRIVED over the trailing window — the
        observed inbound rate the projections extend over the horizon."""
        if self._arr_dirty:
            # only the projection tick reads the index; reactive / non-
            # scaling clusters never pay for the sort
            arr = sorted((r.arrival, r.prompt_len) for r in self._all)
            self._arr_t = [a for a, _ in arr]
            cum = 0
            self._arr_cum = []
            for _, pl in arr:
                cum += pl
                self._arr_cum.append(cum)
            self._arr_dirty = False
        now = self.loop.now
        window = min(window_s, now) if now > 0 else window_s
        if not self._arr_t or window <= 0:
            return 0.0
        hi = bisect.bisect_right(self._arr_t, now)
        lo = bisect.bisect_left(self._arr_t, now - window)
        if hi <= lo:
            return 0.0
        toks = self._arr_cum[hi - 1] - (self._arr_cum[lo - 1] if lo else 0)
        return toks / window

    def _prefill_token_rate(self, rep: Replica,
                            snap: "LoadSnapshot") -> float:
        """Sustained prefill throughput (tokens/s) of one replica at a
        representative saturating prompt batch.  Colocated replicas are
        priced WITH their current decode batch co-resident — prefill
        only ever gets its interference share of the chips there, and
        an idealized solo rate would overstate capacity and starve the
        scale-up decision."""
        chips_p = snap.chips_prefill or rep.serve.chips
        chips_d = snap.chips_decode or rep.serve.chips
        tokens = max(1, self.serve.prefill_max_tokens // 4)
        p_cost = C.prefill_cost(self.cfg, [tokens], chips_p)
        colocated = getattr(rep.engine.scheduler, "colocated", True)
        d_cost = None
        if colocated and snap.running_decode:
            d_cost = C.decode_cost(self.cfg, snap.running_decode,
                                   float(snap.decode_ctx_tokens), chips_d)
        t_p, _ = I.forecast_phase_times(p_cost, d_cost, self.hw, chips_p,
                                        chips_d, colocated=colocated)
        return tokens / max(t_p, 1e-9)

    def _project_replica(self, rep: Replica, s: "LoadSnapshot",
                         inbound_rate: float,
                         prefill_rate: float) -> tuple:
        """(projected-TTFT / ceiling, projected-ITL / SLO) for one
        replica: its queued backlog, plus the part of its arrival-rate
        share it cannot drain compounded over the horizon, priced by
        the perfmodel.

        Only the *surplus* over the replica's sustained prefill rate
        accumulates, so steady sub-capacity load projects an (almost)
        empty backlog and never reads as pressure.  The drain time is
        compared against the TIGHTEST arrival ceiling
        (``ttft_ceiling(1) == ttft_base_s``): the TTFT SLO is
        length-dependent and short prompts queued behind the backlog
        are the first to violate — a token-weighted mean ceiling would
        let a few long documents mask their misses."""
        pol = self.scale
        chips_p = s.chips_prefill or rep.serve.chips
        chips_d = s.chips_decode or rep.serve.chips
        surplus = max(0.0, inbound_rate - prefill_rate)
        backlog = s.queued_prefill_tokens + int(surplus * pol.horizon_s)
        p_cost = C.prefill_cost(self.cfg, [backlog], chips_p) \
            if backlog > 0 else None
        bs = s.running_decode + s.queued_requests
        ctx = float(s.decode_ctx_tokens + s.queued_prefill_tokens)
        d_cost = C.decode_cost(self.cfg, bs, ctx, chips_d) if bs else None
        t_p, t_d = I.forecast_phase_times(
            p_cost, d_cost, self.hw, chips_p, chips_d,
            colocated=getattr(rep.engine.scheduler, "colocated", True))
        ttft_ratio = t_p / ttft_ceiling(1, self.serve.slo)
        itl_ratio = t_d / (self.serve.slo.itl_ms / 1e3)
        return ttft_ratio, itl_ratio

    def _fleet_forecast(self, prefill_tokens, decode_bs, decode_ctx,
                        chips_p, chips_d, colocated):
        """THE batched forecast call site: price a fleet of replica load
        points through ``perfmodel.batch`` in one call and return the
        ``(t_prefill, t_decode)`` arrays.  Both projection passes (the
        sustained-rate pass and the backlog pass) route through here —
        this replaces the per-replica ``interference.
        forecast_phase_times`` loops of the scalar path.

        Entry ``i`` carries no prefill phase when
        ``prefill_tokens[i] <= 0`` and no decode phase when
        ``decode_bs[i] == 0`` (the scalar API's ``None`` costs)."""
        tp_p = np.asarray(chips_p, dtype=np.int64)
        tp_d = np.asarray(chips_d, dtype=np.int64)
        pb = B.prefill_cost(self.cfg, [[t] for t in prefill_tokens], tp_p)
        db = B.decode_cost(self.cfg, decode_bs, decode_ctx, tp_d)
        return B.forecast_phase_times(
            pb, db, self.hw, tp_p, tp_d,
            colocated=np.asarray(colocated, dtype=bool),
            p_mask=np.asarray([t > 0 for t in prefill_tokens]),
            d_mask=np.asarray([b > 0 for b in decode_bs]),
            f_decode=np.nan)

    def _projection_forecasts(self, live: List[Replica],
                              snaps: Dict[int, "LoadSnapshot"],
                              share: float) -> "tuple[dict, dict]":
        """Batched replacement for the per-replica
        ``_prefill_token_rate`` / ``_project_replica`` loops: two
        ``_fleet_forecast`` invocations per tick (the backlog pass
        depends on the rates through the arrival surplus), each pricing
        every live replica at once.  Returns the same ``rates`` and
        ``(ttft_ratio, itl_ratio)`` maps as the scalar loops,
        bit-for-bit."""
        pol = self.scale
        chips_p, chips_d, coloc = [], [], []
        for rep in live:
            s = snaps[rep.idx]
            chips_p.append(s.chips_prefill or rep.serve.chips)
            chips_d.append(s.chips_decode or rep.serve.chips)
            coloc.append(getattr(rep.engine.scheduler, "colocated", True))
        # sustained-rate pass: a saturating prompt batch, co-resident
        # with the current decode batch on colocated replicas only
        tokens = max(1, self.serve.prefill_max_tokens // 4)
        rate_bs = [snaps[rep.idx].running_decode if c else 0
                   for rep, c in zip(live, coloc)]
        rate_ctx = [float(snaps[rep.idx].decode_ctx_tokens)
                    for rep in live]
        t_rate, _ = self._fleet_forecast([tokens] * len(live), rate_bs,
                                         rate_ctx, chips_p, chips_d,
                                         coloc)
        rates = {rep.idx: tokens / max(float(t), 1e-9)
                 for rep, t in zip(live, t_rate)}
        # backlog pass: queued work plus the undrainable arrival surplus
        backlogs, bss, ctxs = [], [], []
        for rep in live:
            s = snaps[rep.idx]
            surplus = max(0.0, share - rates[rep.idx])
            backlogs.append(s.queued_prefill_tokens +
                            int(surplus * pol.horizon_s))
            bss.append(s.running_decode + s.queued_requests)
            ctxs.append(float(s.decode_ctx_tokens +
                              s.queued_prefill_tokens))
        t_p, t_d = self._fleet_forecast(backlogs, bss, ctxs,
                                        chips_p, chips_d, coloc)
        ceil = ttft_ceiling(1, self.serve.slo)
        itl = self.serve.slo.itl_ms / 1e3
        proj = {rep.idx: (float(tp) / ceil, float(td) / itl)
                for rep, tp, td in zip(live, t_p, t_d)}
        return rates, proj

    def _grow_pool(self, rep: Replica, lane: str) -> bool:
        """Independent P/D pool scaling: add ``pool_chip_step`` chips to
        ONE pool of a split-pool replica (the other pool's chips and
        live KV are untouched).  Returns False for colocated replicas or
        when the lane is already at ``max_pool_chips``."""
        pol = self.scale
        eng = rep.engine
        if getattr(eng.scheduler, "colocated", True):
            return False
        cur = eng.scheduler.lane_chips(eng.serve)[lane]
        new = min(cur + pol.pool_chip_step, pol.max_pool_chips)
        if new <= cur:
            return False
        eng.resize_lane(lane, new)
        rep.serve = eng.serve          # keep the Replica view in sync
        self._scale_events.append((self.loop.now, f"pool_{lane}", new))
        return True

    def _projection_tick(self) -> None:
        pol = self.scale
        snaps = {rep.idx: rep.snapshot() for rep in self.replicas}
        busy = any(s.queued_requests or s.running_decode
                   or s.prefill_busy or s.decode_busy
                   for s in snaps.values())
        live = self.routable or self.replicas
        inbound = self._arrival_token_rate(
            max(pol.horizon_s, pol.check_interval_s))
        share = inbound / max(1, len(live))
        # one perfmodel rate evaluation per replica per tick, shared by
        # the per-replica projections and the fleet capacity forecast;
        # batch_pricing collapses both per-replica loops into two
        # fleet-wide perfmodel.batch calls with identical numbers
        if self.batch_pricing:
            rates, proj = self._projection_forecasts(live, snaps, share)
        else:
            rates = {rep.idx: self._prefill_token_rate(rep,
                                                       snaps[rep.idx])
                     for rep in live}
            proj = {rep.idx: self._project_replica(
                rep, snaps[rep.idx], share, rates[rep.idx])
                for rep in live}
        pressed: List[tuple] = []      # (ratio, lane, replica)
        for rep in live:
            ttft_r, itl_r = proj[rep.idx]
            if ttft_r > pol.ttft_margin:
                pressed.append((ttft_r, "prefill", rep))
            if itl_r > pol.itl_margin:
                pressed.append((itl_r, "decode", rep))
        pool_acted = False
        if pol.pool_scaling:
            # grow the worst-pressed pool first; one pool action per tick
            # keeps growth observable between forecasts
            for _, lane, rep in sorted(pressed, key=lambda x: -x[0]):
                if self._grow_pool(rep, lane):
                    pool_acted = True
                    break
        self._pressed_streak = self._pressed_streak + 1 if pressed else 0
        added = 0
        if pressed and len(self.routable) < pol.max_replicas:
            # capacity forecast: add enough replicas IN THIS TICK to
            # cover the projected deficit — arrival rate plus draining
            # the standing queues within one horizon — instead of
            # dripping one per window while the backlog compounds.
            # Without a deficit, a whole replica is the FALLBACK for
            # pressure the pools could not absorb this tick, or that
            # persists into a second tick despite pool growth
            fleet_rate = sum(rates.values())
            per_rep = fleet_rate / max(1, len(live))
            queued = sum(snaps[rep.idx].queued_prefill_tokens
                         for rep in live)
            deficit = inbound + queued / max(pol.horizon_s, 1e-9) \
                - fleet_rate
            if deficit > 0:
                n_add = max(1, int(math.ceil(deficit /
                                             max(per_rep, 1e-9))))
            elif not pool_acted or self._pressed_streak >= 2:
                n_add = 1
            else:
                n_add = 0
            for _ in range(n_add):
                if len(self.routable) >= pol.max_replicas:
                    break
                self._scale_up_one()
                added += 1
        if pool_acted or added:
            self._idle_checks = 0
        else:
            self._retire_if_idle(busy)

    # -- cross-replica preemption / migration ----------------------------------
    def _migration_ok(self, victim: Request, tgt: Replica,
                      live: List[Replica]) -> bool:
        if self._migration_counts.get(victim.rid, 0) >= \
                self.rebalance.max_migrations_per_request:
            return False
        # a migrated request re-prefills its whole live context on the
        # destination, so bucket compatibility is against context_len
        return self.router.admits(victim.context_len, tgt, live)

    def _prefill_seconds(self, rep: Replica, tokens: int) -> float:
        """Projected time for ``rep`` to prefill ``tokens`` prompt tokens
        (its queued backlog plus a migrated victim's re-prefill)."""
        chips = getattr(rep.engine, "chips_p", rep.serve.chips)
        if tokens <= 0:
            return 0.0
        cost = C.prefill_cost(self.cfg, [tokens], chips)
        return I.phase_time(cost, self.hw, chips)

    def _benefit_ok(self, victim: Request, src: Replica, tgt: Replica,
                    snaps: Dict[int, "LoadSnapshot"]) -> bool:
        """Cost/benefit gate for live-KV moves: migrate only when the KV
        transfer plus the destination's projected queue beats waiting out
        the source's backlog — i.e. the transfer time must not exceed the
        projected queue relief."""
        if not self.rebalance.cost_benefit:
            return True
        gbps = self.rebalance.link_gbps or self.serve.kv_transfer_gbps
        xfer = C.kv_migration_seconds(self.cfg, victim.context_len, gbps)
        src_wait = self._prefill_seconds(
            src, snaps[src.idx].queued_prefill_tokens + victim.context_len)
        dst_wait = xfer + self._prefill_seconds(
            tgt, snaps[tgt.idx].queued_prefill_tokens + victim.context_len)
        return dst_wait < src_wait

    def _benefit_filter(self, victim: Request, src: Replica,
                        targets: List[Replica],
                        snaps: Dict[int, "LoadSnapshot"]
                        ) -> List[Replica]:
        """Batched cost/benefit gate: the source's projected wait and
        every candidate destination's price in ONE ``perfmodel.batch``
        call instead of a scalar cost pair per target."""
        if not self.rebalance.cost_benefit or not targets:
            return targets
        if not self.batch_pricing:
            return [rep for rep in targets
                    if self._benefit_ok(victim, src, rep, snaps)]
        gbps = self.rebalance.link_gbps or self.serve.kv_transfer_gbps
        xfer = C.kv_migration_seconds(self.cfg, victim.context_len, gbps)
        reps = [src] + targets
        tokens = [snaps[r.idx].queued_prefill_tokens + victim.context_len
                  for r in reps]
        chips = np.asarray([getattr(r.engine, "chips_p", r.serve.chips)
                            for r in reps], dtype=np.int64)
        waits = B.phase_time(
            B.prefill_cost(self.cfg, [[t] for t in tokens], chips),
            self.hw, chips)
        src_wait = float(waits[0])
        return [rep for rep, w in zip(targets, waits[1:])
                if xfer + float(w) < src_wait]

    def _rebalance_tick(self) -> None:
        pol = self.rebalance
        live = self.routable or self.replicas
        # hysteresis bookkeeping for EVERY replica, every tick: a replica
        # that cools down (or sits retired/solo) must lose its streak, or
        # it would migrate live KV on its first hot tick after rejoining
        snaps = {rep.idx: rep.snapshot() for rep in self.replicas}
        for rep in self.replicas:
            if snaps[rep.idx].kv_utilization >= pol.kv_high:
                self._hot_streak[rep.idx] = \
                    self._hot_streak.get(rep.idx, 0) + 1
            else:
                self._hot_streak[rep.idx] = 0
        if len(live) > 1:
            hot = sorted((rep for rep in live
                          if snaps[rep.idx].kv_utilization >= pol.kv_high),
                         key=lambda rep: -snaps[rep.idx].kv_utilization)
            moves = 0
            for src in hot:
                while moves < pol.max_moves_per_tick:
                    targets = [rep for rep in live if rep is not src
                               and snaps[rep.idx].kv_utilization
                               <= pol.kv_low]
                    cand = src.engine.migration_candidate()
                    if not targets or cand is None:
                        break
                    victim, has_kv = cand
                    if has_kv and \
                            self._hot_streak.get(src.idx, 0) < pol.hot_ticks:
                        # queued victims are free to move on the first hot
                        # tick; live KV waits out the hysteresis window
                        break
                    targets = [rep for rep in targets
                               if self._migration_ok(victim, rep, live)]
                    if has_kv:
                        targets = self._benefit_filter(victim, src,
                                                       targets, snaps)
                    if not targets:
                        break
                    tgt = min(targets, key=lambda rep: (
                        snaps[rep.idx].kv_utilization,
                        snaps[rep.idx].queued_prefill_tokens, rep.idx))
                    self._migrate(src, tgt, victim, has_kv)
                    moves += 1
                    # refresh the pair we touched; a single move rarely
                    # flips the rest of the fleet inside one tick
                    snaps[src.idx] = src.snapshot()
                    snaps[tgt.idx] = tgt.snapshot()
                    if snaps[src.idx].kv_utilization < pol.kv_high:
                        break
                if moves >= pol.max_moves_per_tick:
                    break
        if self._outstanding():
            self.loop.after(pol.check_interval_s, self._rebalance_tick)

    def _migrate(self, src: Replica, tgt: Replica, expected: Request,
                 expected_kv: bool) -> None:
        evicted = src.engine.evict_for_migration()
        assert evicted is not None and evicted[0] is expected, \
            "migration candidate changed under eviction"
        victim, had_kv = evicted
        del expected_kv
        if victim.session_id is not None:
            # the session's parked prefix (if any) stays on src where the
            # next turn will no longer land: invalidate it and re-home
            # the session — the victim re-prefills from scratch on tgt
            drop = getattr(src.engine.kv, "drop_session", None)
            if drop is not None:
                drop(victim.session_id)
            victim.cached_prefix_len = 0
            self._session_home[victim.session_id] = tgt.idx
        src.assigned.remove(victim)
        tgt.assigned.append(victim)
        self._migration_counts[victim.rid] = \
            self._migration_counts.get(victim.rid, 0) + 1
        self._migrations.append((self.loop.now, src.name, tgt.name,
                                 victim.rid, had_kv))
        if had_kv:
            gbps = self.rebalance.link_gbps or self.serve.kv_transfer_gbps
            xfer = C.kv_migration_seconds(self.cfg, victim.context_len,
                                          gbps)
            self.loop.after(xfer, lambda: tgt.engine.submit(victim))
        else:
            tgt.engine.submit(victim)

    @property
    def admission_stats(self) -> Dict[str, int]:
        return dict(self.admission.stats) if self.admission else {}


def run_fleet(cfg, serve: ServeConfig,
              modes: Sequence[Union[str, ReplicaSpec]], router: str,
              requests: Sequence[Request], hw: HardwareSpec = TPU_V5E,
              scale: Optional[Union[ScalePolicy, ProjectionPolicy]] = None,
              admission: Optional[AdmissionPolicy] = None,
              rebalance: Optional[RebalancePolicy] = None,
              session_affinity: bool = False,
              preempt_policy: Optional[PreemptionPolicy] = None,
              batch_pricing: bool = True):
    """Build a cluster, serve a trace, and return
    ``(fleet_summarize(...) dict, cluster)``.  Requests are deep-copied so
    the caller's trace can be replayed against other configurations."""
    cluster = Cluster(cfg, serve, modes, router=router, hw=hw, scale=scale,
                      admission=admission, rebalance=rebalance,
                      session_affinity=session_affinity,
                      preempt_policy=preempt_policy,
                      batch_pricing=batch_pricing)
    _, span = cluster.run([copy.deepcopy(r) for r in requests])
    # the fleet-wide summary is built from the cluster's event stream
    # (StreamMetrics), which already carries cluster-side rejections
    summary = fleet_summarize(cluster.per_replica_records(), serve.slo,
                              span, fleet_records=cluster.metrics.records,
                              loop_stats=cluster.loop.stats)
    f = summary["fleet"]
    f["migrations"] = len(cluster._migrations)
    if cluster.admission is not None:
        summary["admission"] = cluster.admission_stats
    return summary, cluster
