"""Multi-replica cluster serving layer (DistServe-style fleet scale).

Runs N engine replicas — any mix of ``rapid`` / ``hybrid`` / ``disagg``
from ``core/engines.py`` — under ONE shared ``EventLoop`` (a single
virtual clock), behind a pluggable router:

  * ``round_robin``   — classic cycling over routable replicas.
  * ``least_loaded``  — fewest queued prefill tokens (the quantity that
    actually backs up TTFT), tie-broken by queued request count.
  * ``slo_aware``     — projects per-replica TTFT and ITL for the new
    request from the perfmodel (prefill cost of the queued + new prompt
    tokens; decode cost of the grown batch) and picks the replica with
    the lowest combined SLO-normalized score.

Routing happens at arrival time on the shared loop, so routers see each
replica's live load — exactly the information a fleet front-end has.

Optional SLO-driven scaling (``ScalePolicy``): a periodic controller
watches the recent TTFT-attainment window and adds replicas (up to
``max_replicas``) while the fleet is missing SLO, and retires drained
surplus replicas down to ``min_replicas``.  Retired replicas stop
receiving traffic but keep running until their queues drain, so no
request is lost.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import (TYPE_CHECKING, Callable, Dict, List, Optional,
                    Sequence)

from repro.config import ServeConfig
from repro.core.request import Request
from repro.perfmodel import costs as C
from repro.perfmodel import interference as I
from repro.perfmodel.hw import TPU_V5E, HardwareSpec
from repro.serving.metrics import (RequestRecord, fleet_summarize,
                                   ttft_ceiling)
from repro.serving.sim import EventLoop

if TYPE_CHECKING:   # deferred to break the serving <-> core import cycle
    from repro.core.engines import BaseEngine, LoadSnapshot


@dataclasses.dataclass
class Replica:
    idx: int
    mode: str
    engine: BaseEngine
    routable: bool = True
    assigned: List[Request] = dataclasses.field(default_factory=list)

    @property
    def name(self) -> str:
        return f"{self.mode}-{self.idx}"

    def snapshot(self) -> LoadSnapshot:
        return self.engine.load_snapshot()


# ---------------------------------------------------------------------------
# Routers
# ---------------------------------------------------------------------------


class Router:
    """Picks a replica index for each arriving request."""

    name = "base"

    def choose(self, r: Request, replicas: List[Replica]) -> int:
        raise NotImplementedError


class RoundRobinRouter(Router):
    name = "round_robin"

    def __init__(self):
        self._next = 0

    def choose(self, r: Request, replicas: List[Replica]) -> int:
        i = self._next % len(replicas)
        self._next += 1
        return i


class LeastLoadedRouter(Router):
    """Balance queued prefill tokens — counts back up TTFT, tokens do."""

    name = "least_loaded"

    def choose(self, r: Request, replicas: List[Replica]) -> int:
        def key(i: int):
            s = replicas[i].snapshot()
            return (s.queued_prefill_tokens, s.queued_requests,
                    s.running_decode, i)
        return min(range(len(replicas)), key=key)


class SloAwareRouter(Router):
    """Project TTFT/ITL per replica from the perfmodel and route to the
    replica with the lowest SLO-normalized combined score (DistServe's
    placement insight applied at the router)."""

    name = "slo_aware"

    def __init__(self, cfg, serve: ServeConfig, hw: HardwareSpec = TPU_V5E):
        self.cfg = cfg
        self.serve = serve
        self.hw = hw

    def _score(self, r: Request, rep: Replica) -> float:
        s = rep.snapshot()
        # disagg replicas split their chips into prefill/decode pools
        # (engine exposes chips_p/chips_d); colocated engines use them all
        chips_p = getattr(rep.engine, "chips_p", self.serve.chips)
        chips_d = getattr(rep.engine, "chips_d", self.serve.chips)
        # projected TTFT: every queued prompt token plus ours must be
        # prefilled before our first token can exist
        p_cost = C.prefill_cost(
            self.cfg, [s.queued_prefill_tokens + r.prompt_len], chips_p)
        proj_ttft = I.phase_time(p_cost, self.hw, chips_p)
        # projected ITL: the decode batch we would eventually join
        bs = s.running_decode + 1
        ctx = float(s.decode_ctx_tokens + r.prompt_len)
        d_cost = C.decode_cost(self.cfg, bs, ctx, chips_d)
        proj_itl = I.phase_time(d_cost, self.hw, chips_d)
        slo = self.serve.slo
        return (proj_ttft / ttft_ceiling(r.prompt_len, slo)
                + proj_itl / (slo.itl_ms / 1e3))

    def choose(self, r: Request, replicas: List[Replica]) -> int:
        return min(range(len(replicas)),
                   key=lambda i: (self._score(r, replicas[i]), i))


ROUTERS: Dict[str, Callable[..., Router]] = {
    "round_robin": lambda cfg, serve, hw: RoundRobinRouter(),
    "least_loaded": lambda cfg, serve, hw: LeastLoadedRouter(),
    "slo_aware": lambda cfg, serve, hw: SloAwareRouter(cfg, serve, hw),
}


def make_router(name: str, cfg, serve: ServeConfig,
                hw: HardwareSpec = TPU_V5E) -> Router:
    if name not in ROUTERS:
        raise KeyError(f"unknown router {name!r}; known: {sorted(ROUTERS)}")
    return ROUTERS[name](cfg, serve, hw)


# ---------------------------------------------------------------------------
# SLO-driven replica scaling
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScalePolicy:
    """Reactive autoscaler: add a replica while the recent TTFT-attainment
    window misses ``target_attainment``; retire an idle surplus replica
    after ``idle_windows`` consecutive quiet checks."""
    min_replicas: int = 1
    max_replicas: int = 4
    check_interval_s: float = 5.0
    window_s: float = 10.0
    target_attainment: float = 0.9
    idle_windows: int = 2
    scale_up_mode: Optional[str] = None   # None => clone replica 0's mode


class Cluster:
    """N engine replicas sharing one EventLoop behind a Router."""

    def __init__(self, cfg, serve: ServeConfig, modes: Sequence[str],
                 router: str = "round_robin", hw: HardwareSpec = TPU_V5E,
                 scale: Optional[ScalePolicy] = None,
                 loop: Optional[EventLoop] = None):
        if not modes:
            raise ValueError("cluster needs at least one replica mode")
        self.cfg = cfg
        self.serve = serve
        self.hw = hw
        self.loop = loop if loop is not None else EventLoop()
        self.replicas: List[Replica] = []
        for mode in modes:
            self._add_replica(mode)
        self.router = make_router(router, cfg, serve, hw)
        self.scale = scale
        self._all: List[Request] = []
        self._scale_events: List[tuple] = []   # (t, action, n_routable)
        self._idle_checks = 0

    # -- replica lifecycle ---------------------------------------------------
    def _add_replica(self, mode: str) -> Replica:
        # local import: core.engines itself imports serving.metrics/sim
        from repro.core.engines import make_engine
        rep = Replica(idx=len(self.replicas), mode=mode,
                      engine=make_engine(mode, self.cfg, self.serve,
                                         self.hw, loop=self.loop))
        self.replicas.append(rep)
        return rep

    @property
    def routable(self) -> List[Replica]:
        return [rep for rep in self.replicas if rep.routable]

    @property
    def num_replicas(self) -> int:
        return len(self.replicas)

    # -- ingress ---------------------------------------------------------------
    def submit(self, r: Request) -> None:
        """Route an arriving request to a replica (called on the loop at
        the request's arrival time)."""
        live = self.routable
        rep = live[self.router.choose(r, live)]
        rep.assigned.append(r)
        rep.engine.submit(r)

    def enqueue(self, requests: Sequence[Request]) -> None:
        self._all.extend(requests)
        for r in requests:
            self.loop.at(r.arrival, lambda r=r: self.submit(r))

    def run(self, requests: Sequence[Request]):
        """Serve a trace to completion.  Returns (records, span_s)."""
        self.enqueue(requests)
        if self.scale is not None:
            self.loop.after(self.scale.check_interval_s, self._scale_tick)
        self.loop.run()
        span = self.loop.now if self.loop.now > 0 else 1.0
        return [RequestRecord.from_request(r) for r in self._all], span

    # -- per-replica views -----------------------------------------------------
    def per_replica_records(self) -> Dict[str, List[RequestRecord]]:
        return {rep.name: [RequestRecord.from_request(r)
                           for r in rep.assigned]
                for rep in self.replicas}

    def per_replica_counts(self) -> Dict[str, int]:
        return {rep.name: len(rep.assigned) for rep in self.replicas}

    # -- autoscaler ------------------------------------------------------------
    def _recent_attainment(self) -> Optional[float]:
        now = self.loop.now
        lo = now - self.scale.window_s
        window = [r for rep in self.replicas for r in rep.assigned
                  if r.t_finish is not None and r.t_finish >= lo
                  and r.token_times]
        if not window:
            return None
        ok = sum(1 for r in window
                 if r.ttft <= ttft_ceiling(r.prompt_len, self.serve.slo))
        return ok / len(window)

    def _scale_tick(self) -> None:
        outstanding = any(r.t_finish is None for r in self._all)
        att = self._recent_attainment()
        snaps = [rep.snapshot() for rep in self.replicas]
        # prefill_busy covers the window where a batch is in flight but
        # sits in no queue — a replica mid-prefill is NOT drained
        busy = any(s.queued_requests or s.running_decode
                   or s.prefill_busy or s.decode_busy for s in snaps)
        # backlog is the *leading* indicator (attainment only moves once
        # delayed requests finish): queued prefill work beyond one full
        # prefill step per routable replica means TTFTs are already sliding
        backlog = sum(s.queued_prefill_tokens for s in snaps) / \
            max(1, len(self.routable))
        pressed = (att is not None and att < self.scale.target_attainment) \
            or backlog > self.serve.prefill_max_tokens
        if pressed and len(self.routable) < self.scale.max_replicas:
            mode = self.scale.scale_up_mode or self.replicas[0].mode
            # reactivate a retired replica before constructing a new one,
            # else oscillating load grows self.replicas without bound
            retired = [rep for rep in self.replicas if not rep.routable
                       and rep.mode == mode]
            if retired:
                retired[0].routable = True
            else:
                self._add_replica(mode)
            self._scale_events.append((self.loop.now, "up",
                                       len(self.routable)))
            self._idle_checks = 0
        elif not busy and len(self.routable) > self.scale.min_replicas:
            self._idle_checks += 1
            if self._idle_checks >= self.scale.idle_windows:
                # retire the newest routable replica: it stops receiving
                # traffic (it is already drained — fleet was idle)
                self.routable[-1].routable = False
                self._scale_events.append((self.loop.now, "down",
                                           len(self.routable)))
                self._idle_checks = 0
        else:
            self._idle_checks = 0
        if outstanding:
            self.loop.after(self.scale.check_interval_s, self._scale_tick)


def run_fleet(cfg, serve: ServeConfig, modes: Sequence[str], router: str,
              requests: Sequence[Request], hw: HardwareSpec = TPU_V5E,
              scale: Optional[ScalePolicy] = None):
    """Build a cluster, serve a trace, and return
    ``(fleet_summarize(...) dict, cluster)``.  Requests are deep-copied so
    the caller's trace can be replayed against other configurations."""
    cluster = Cluster(cfg, serve, modes, router=router, hw=hw, scale=scale)
    _, span = cluster.run([copy.deepcopy(r) for r in requests])
    summary = fleet_summarize(cluster.per_replica_records(), serve.slo,
                              span)
    return summary, cluster
