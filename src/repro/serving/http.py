"""Minimal dependency-free HTTP surface for the serving gateway.

Stdlib ``asyncio.start_server`` only — the container has no aiohttp /
fastapi, and the protocol needs are tiny:

  * ``POST /v1/generate`` — JSON body ``{"prompt_len": int,
    "max_new_tokens": int, "slo_class"?: str, "session_id"?: str,
    "cached_prefix_len"?: int}``.  Streams the request's typed event
    stream as newline-delimited JSON (``application/x-ndjson``, one
    ``core.events`` event per line via ``event_to_json``) and closes
    after the terminal ``finished`` / ``rejected`` / ``cancelled`` line.
  * ``POST /v1/cancel``  — JSON body ``{"rid": int}``; cancels a live
    request (terminal ``cancelled`` line on its stream, engine slot and
    parked checkpoint freed).  Response says whether it was still live.
  * ``GET /healthz``  — gateway + worker states.
  * ``GET /metrics``  — ``fleet_summarize`` output (incl. event-loop
    ``clamped`` / ``peak_heap`` counters and the fault-tolerance
    counters: checkpoints, resumes, replayed_tokens, cancelled,
    fenced_beats).

Streaming backpressure composes with the gateway's channel watermarks:
the writer task only ``take()``s another event after
``await writer.drain()`` returns, so a slow client stops draining its
channel, the channel pauses, and the gateway evicts that one request
from its engine until the client catches up — other streams unaffected.

Robustness contract (pinned in tests/test_gateway.py): malformed bodies
and header junk are 400s, unexpected handler failures are 500s — never
an exception escaping the handler task — and a client that disconnects
mid-stream gets its request *cancelled* (slot + checkpoint freed
immediately) instead of generating into a dead socket until the
slow-consumer eviction path notices.
"""
from __future__ import annotations

import asyncio
import json
from typing import Optional, Tuple

from repro.core.events import event_to_json
from repro.core.request import Request


class HTTPError(Exception):
    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status
        self.message = message


_STATUS = {200: "OK", 400: "Bad Request", 404: "Not Found",
           405: "Method Not Allowed", 500: "Internal Server Error"}


def _response_head(status: int, ctype: str,
                   length: Optional[int] = None) -> bytes:
    lines = [f"HTTP/1.1 {status} {_STATUS.get(status, 'Unknown')}",
             f"Content-Type: {ctype}", "Connection: close"]
    if length is not None:
        lines.append(f"Content-Length: {length}")
    return ("\r\n".join(lines) + "\r\n\r\n").encode()


async def _read_request(reader) -> Tuple[str, str, bytes]:
    """Parse method, path and body from one HTTP/1.1 request."""
    line = await reader.readline()
    if not line:
        raise HTTPError(400, "empty request")
    try:
        method, path, _ = line.decode("latin-1").split(None, 2)
    except ValueError:
        raise HTTPError(400, "malformed request line") from None
    length = 0
    while True:
        hdr = await reader.readline()
        if hdr in (b"\r\n", b"\n", b""):
            break
        name, _, value = hdr.decode("latin-1").partition(":")
        if name.strip().lower() == "content-length":
            try:
                length = int(value.strip())
            except ValueError:
                raise HTTPError(400, "bad Content-Length") from None
    if length < 0 or length > 1_000_000:
        raise HTTPError(400, "unreasonable Content-Length")
    body = await reader.readexactly(length) if length else b""
    return method.upper(), path, body


class GatewayHTTPServer:
    """Serves a ``Gateway`` built on a ``RealTimeClock`` over TCP."""

    def __init__(self, gateway, host: str = "127.0.0.1", port: int = 8080):
        self.gateway = gateway
        self.host = host
        self.port = port
        self._server = None
        # fault injection (serving/faults.line_corruptor): bytes->bytes
        # hook applied to each outgoing NDJSON line
        self.line_hook = None

    async def start(self) -> None:
        loop = asyncio.get_running_loop()
        clock = self.gateway.clock
        if hasattr(clock, "bind"):
            clock.bind(loop)
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)

    async def serve_forever(self) -> None:
        if self._server is None:
            await self.start()
        async with self._server:
            await self._server.serve_forever()

    async def close(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    # -- request handling ---------------------------------------------------

    async def _handle(self, reader, writer) -> None:
        try:
            try:
                method, path, body = await _read_request(reader)
                if method == "POST" and path == "/v1/generate":
                    await self._generate(body, writer)
                elif method == "POST" and path == "/v1/cancel":
                    self._cancel(body, writer)
                elif method == "GET" and path == "/healthz":
                    self._send_json(writer, self.gateway.health())
                elif method == "GET" and path == "/metrics":
                    self._send_json(writer, self.gateway.metrics_summary())
                elif path in ("/v1/generate", "/v1/cancel", "/healthz",
                              "/metrics"):
                    raise HTTPError(405, f"{method} not allowed on {path}")
                else:
                    raise HTTPError(404, f"no route for {path}")
            except HTTPError as e:
                self._send_json(writer, {"error": e.message},
                                status=e.status)
            except (asyncio.IncompleteReadError, ConnectionError):
                return               # client went away; nothing to send
            except (ValueError, asyncio.LimitOverrunError) as e:
                # oversized/undecodable header lines etc. — client error
                self._send_json(writer, {"error": f"malformed request: {e}"},
                                status=400)
            except Exception as e:   # noqa: BLE001 — last-resort 500:
                # an exception must never escape the handler task (it
                # would be swallowed by asyncio and kill this stream)
                self._send_json(
                    writer,
                    {"error": f"internal error: {type(e).__name__}"},
                    status=500)
            try:
                await writer.drain()
            except ConnectionError:
                pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except ConnectionError:
                pass

    @staticmethod
    def _send_json(writer, obj, status: int = 200) -> None:
        payload = json.dumps(obj).encode()
        writer.write(_response_head(status, "application/json",
                                    len(payload)))
        writer.write(payload)

    async def _generate(self, body: bytes, writer) -> None:
        try:
            spec = json.loads(body or b"{}")
        except json.JSONDecodeError:
            raise HTTPError(400, "body is not valid JSON") from None
        if not isinstance(spec, dict):
            raise HTTPError(400, "body must be a JSON object")
        try:
            prompt_len = int(spec["prompt_len"])
            max_new = int(spec["max_new_tokens"])
            prefix = int(spec.get("cached_prefix_len", 0))
        except (KeyError, TypeError, ValueError):
            raise HTTPError(
                400, "prompt_len, max_new_tokens (ints) required; "
                     "cached_prefix_len must be an int") from None
        if prompt_len < 1 or max_new < 1 or prefix < 0:
            raise HTTPError(400, "prompt_len and max_new_tokens must be "
                                 ">=1, cached_prefix_len >=0")
        session_id = spec.get("session_id")
        if session_id is not None and not isinstance(session_id, str):
            raise HTTPError(400, "session_id must be a string")
        gw = self.gateway
        r = Request(rid=gw.next_rid(), arrival=gw.clock.now,
                    prompt_len=prompt_len, max_new_tokens=max_new,
                    slo_class=str(spec.get("slo_class", "interactive")),
                    session_id=session_id,
                    cached_prefix_len=prefix)
        wake = asyncio.Event()
        channel = gw.submit(r, notify=wake.set)
        writer.write(_response_head(200, "application/x-ndjson"))
        try:
            await writer.drain()
            while not channel.done:
                ev = channel.take()
                if ev is None:
                    wake.clear()
                    if channel.closed and not channel.buf:
                        break
                    await wake.wait()
                    continue
                line = (event_to_json(ev) + "\n").encode()
                if self.line_hook is not None:
                    line = self.line_hook(line)
                writer.write(line)
                # drain before taking the next event: a slow client parks
                # us here, the channel fills, and the gateway
                # backpressures this one request out of its engine
                await writer.drain()
        except (ConnectionError, asyncio.CancelledError):
            # mid-stream client abort: cancel instead of generating into
            # a dead socket (frees the engine slot + parked checkpoint)
            gw.cancel(r.rid, reason="disconnect")
            raise

    def _cancel(self, body: bytes, writer) -> None:
        try:
            spec = json.loads(body or b"{}")
            rid = int(spec["rid"])
        except (json.JSONDecodeError, KeyError, TypeError, ValueError):
            raise HTTPError(400, "body must be JSON with an int rid") \
                from None
        ok = self.gateway.cancel(rid, reason="client_cancel")
        self._send_json(writer, {"rid": rid, "cancelled": ok})


def run_http(gateway, host: str = "127.0.0.1", port: int = 8080) -> None:
    """Blocking entry point for ``launch/serve.py --serve http``."""
    server = GatewayHTTPServer(gateway, host, port)

    async def main():
        await server.start()
        addrs = ", ".join(str(s.getsockname())
                          for s in server._server.sockets)
        print(f"gateway listening on {addrs}")
        await server.serve_forever()

    try:
        asyncio.run(main())
    except KeyboardInterrupt:
        pass
