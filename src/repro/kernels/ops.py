"""Jit'd model-facing wrappers around the Pallas kernels.

The models pass (B, S, H, D)-layout tensors; the kernels want
(B, H, S, D).  On CPU (this container) every kernel runs interpret=True;
on TPU the same call sites compile to Mosaic.  ``INTERPRET`` is resolved
once from the backend.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_prefill as _fp
from repro.kernels import paged_attention as _pa
from repro.kernels import ssm_scan as _ssm
from repro.kernels import unified_pd as _updk


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_prefill(q, k, v, *, window: Optional[int] = None,
                  block_q: int = 512, block_k: int = 512):
    """q (B,S,Hq,D), k/v (B,S,Hkv,D) -> (B,S,Hq,D)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    S = q.shape[1]
    bq = min(block_q, max(8, S))
    bk = min(block_k, max(8, S))
    o = _fp.flash_prefill(qt, kt, vt, window=window, block_q=bq,
                          block_k=bk, interpret=_interpret())
    return o.transpose(0, 2, 1, 3)


def paged_attention(q, k_pages, v_pages, block_tables, seq_lens):
    """q (B,Hq,D) over paged cache -> (B,Hq,D)."""
    return _pa.paged_attention(q, k_pages, v_pages, block_tables,
                               seq_lens, interpret=_interpret())


def paged_attention_dense(q, cache_k, cache_v, seq_lens, *,
                          window: Optional[int] = None,
                          page: int = 64):
    """Decode attention over a *dense slot* cache via the paged kernel.

    q (B,Hq,D); cache_k/v (B,Sc,Hkv,D); seq_lens (B,) valid tokens
    (for ring-buffer windows pass min(len, window) — all slots valid).
    The dense cache is viewed as trivially-paged: sequence b owns pages
    [b*np, (b+1)*np), identity block table.
    """
    B, Sc, Hkv, D = cache_k.shape
    page = min(page, Sc)
    while Sc % page:
        page -= 1
    n_pages = Sc // page
    kp = cache_k.reshape(B * n_pages, page, Hkv, D)
    vp = cache_v.reshape(B * n_pages, page, Hkv, D)
    tables = (jnp.arange(B)[:, None] * n_pages +
              jnp.arange(n_pages)[None, :]).astype(jnp.int32)
    lens = seq_lens.astype(jnp.int32)
    if window is not None:
        lens = jnp.minimum(lens, window)
    return _pa.paged_attention(q, kp, vp, tables, lens,
                               interpret=_interpret())


def ssm_scan(xs, dt, A, Bm, Cm, *, h0=None, chunk: int = 128,
             tile_d: int = 256):
    """Chunked selective scan.  h0 continuation falls back to the jnp
    reference (state injection is not expressible as a rank-1 step; only
    the serving chunked-prefill path needs it)."""
    if h0 is not None:
        from repro.kernels import ref
        return ref.ssm_scan(xs, dt, A, Bm, Cm, h0=h0)
    return _ssm.ssm_scan(xs, dt, A, Bm, Cm, chunk=chunk, tile_d=tile_d,
                         interpret=_interpret())


def unified_pd(q_p, k_p, v_p, q_d, k_pages, v_pages, block_tables,
               seq_lens, *, f_decode: float = 0.5,
               window: Optional[int] = None, block_q: int = 512,
               block_k: int = 512):
    """Fused concurrent P/D attention step (layouts as models produce):
    q_p/k_p/v_p (Bp,S,H,D); q_d (Bd,Hq,D).  Returns
    (o_p (Bp,S,Hq,D), o_d (Bd,Hq,D))."""
    Sp = q_p.shape[1]
    bq = min(block_q, max(8, Sp))
    bk = min(block_k, max(8, Sp))
    o_p, o_d = _updk.unified_pd(
        q_p.transpose(0, 2, 1, 3), k_p.transpose(0, 2, 1, 3),
        v_p.transpose(0, 2, 1, 3), q_d, k_pages, v_pages, block_tables,
        seq_lens, f_decode=f_decode, window=window, block_q=bq,
        block_k=bk, interpret=_interpret())
    return o_p.transpose(0, 2, 1, 3), o_d
