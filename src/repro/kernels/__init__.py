"""Pallas TPU kernels for the perf-critical attention/scan hot-spots.

Each kernel has: <name>.py (pl.pallas_call + BlockSpec), a jit'd wrapper
in ops.py, and a pure-jnp oracle in ref.py; all validated interpret=True
on CPU (tests/test_kernels.py) and targeted at TPU v5e.
"""
from repro.kernels import ops, ref  # noqa: F401
