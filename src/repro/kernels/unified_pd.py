"""Unified P/D attention step — the paper's technique as one Pallas kernel.

RAPID-Serve's CU masking gives prefill and decode disjoint *spatial*
shares of the GPU.  A TPU core timeslices one program, so the spatial
knob becomes a *grid-slot* knob: this kernel issues prefill q-tiles and
decode requests from a single ``pallas_call`` whose slot schedule
interleaves the two kinds at a controllable ratio.  ``f_decode`` — the
Adaptive Resource Manager's control variable — sets how densely decode
slots are packed at the head of the schedule:

    f_decode = 1.0  -> all decode tiles issue first (decode priority;
                       min ITL, prefill waits)
    f_decode = 0.25 -> one decode tile every 4 slots; decode's last tile
                       completes ~4x later, prefill proceeds meanwhile

so decode latency scales ~1/f_decode while prefill throughput scales
~1/(1-f_decode·n_d/n), exactly the trade the paper's Fig 7 sweeps.  Both
phases' tiles live in ONE launch: when decode runs out of tiles, the
remaining slots are all prefill — the overallocation behaviour of Fig 6c
falls out for free (no gaps, no second launch).

Mechanics:
  * a scalar-prefetched descriptor table (n_slots, 7) drives every
    BlockSpec index map: [kind, pb, ph, pkvh, pqi, db, dkvh];
  * grid = (n_slots, n_inner): prefill slots loop k-blocks (flash,
    causal-culled), decode slots loop KV pages (block-table indirection);
  * flash scratch (acc, m, l) is shared — decode uses the first G rows;
  * wrong-kind output windows are routed to a trash block (index Bp/Bd)
    and sliced off, so real blocks are written exactly once.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
PREFILL, DECODE = 0, 1


def build_slot_schedule(n_prefill: int, n_decode: int,
                        f_decode: float) -> np.ndarray:
    """Merged issue order: position of each decode tile i is
    floor(i / f_decode); prefill tiles fill the remaining slots."""
    n = n_prefill + n_decode
    f = min(max(f_decode, 1e-3), 1.0)
    kinds = np.zeros(n, np.int32)
    pos = np.minimum((np.arange(n_decode) / f).astype(np.int64),
                     n - np.arange(n_decode, 0, -1))
    # resolve collisions by shifting right
    used = np.zeros(n, bool)
    for i, p in enumerate(pos):
        p = int(p)
        while used[p]:
            p += 1
        used[p] = True
        kinds[p] = DECODE
    return kinds


def _make_descriptors(Bp: int, Hq: int, nq: int, Bd: int, Hkv: int,
                      G: int, f_decode: float) -> np.ndarray:
    prefill_tiles = [(b, h, h // G, qi) for b in range(Bp)
                     for h in range(Hq) for qi in range(nq)]
    decode_tiles = [(db, dh) for db in range(Bd) for dh in range(Hkv)]
    kinds = build_slot_schedule(len(prefill_tiles), len(decode_tiles),
                                f_decode)
    desc = np.zeros((len(kinds), 7), np.int32)
    ip = id_ = 0
    for s, kind in enumerate(kinds):
        if kind == PREFILL:
            b, h, kvh, qi = prefill_tiles[ip]
            desc[s] = (PREFILL, b, h, kvh, qi, 0, 0)
            ip += 1
        else:
            db, dh = decode_tiles[id_]
            desc[s] = (DECODE, 0, 0, 0, 0, db, dh)
            id_ += 1
    return desc


def _unified_kernel(desc_ref, tab_ref, lens_ref,
                    qp_ref, kp_ref, vp_ref, qd_ref, kpg_ref, vpg_ref,
                    op_ref, od_ref, acc_ref, m_ref, l_ref, *,
                    block_q: int, block_k: int, nk: int, page: int,
                    max_pages: int, n_inner: int, G: int,
                    window: Optional[int], sm_scale: float):
    s = pl.program_id(0)
    j = pl.program_id(1)
    kind = desc_ref[s, 0]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # ---------------- prefill flash tile ---------------------------------
    qi = desc_ref[s, 4]
    q_start = qi * block_q
    k_start = j * block_k
    p_needed = (kind == PREFILL) & (j < nk) & \
        (k_start <= q_start + block_q - 1)
    if window is not None:
        p_needed &= (k_start + block_k - 1) > (q_start - window)

    @pl.when(p_needed)
    def _prefill():
        q = qp_ref[0, 0].astype(jnp.float32)
        k = kp_ref[0, 0].astype(jnp.float32)
        v = vp_ref[0, 0].astype(jnp.float32)
        sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        sc *= sm_scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        sc = jnp.where(mask, sc, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(sc, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(sc - m_cur[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    # ---------------- decode paged tile -----------------------------------
    db = desc_ref[s, 5]
    n_valid = lens_ref[db]
    d_needed = (kind == DECODE) & (j < max_pages) & (j * page < n_valid)

    @pl.when(d_needed)
    def _decode():
        q = qd_ref[0, 0].astype(jnp.float32)            # (G, D)
        k = kpg_ref[0, :, 0].astype(jnp.float32)        # (page, D)
        v = vpg_ref[0, :, 0].astype(jnp.float32)
        sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        sc *= sm_scale
        pos = j * page + jax.lax.broadcasted_iota(jnp.int32, sc.shape, 1)
        sc = jnp.where(pos < n_valid, sc, NEG_INF)
        m_prev = m_ref[:G]
        m_cur = jnp.maximum(m_prev, jnp.max(sc, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(sc - m_cur[:, None])
        l_ref[:G] = l_ref[:G] * alpha + jnp.sum(p, axis=1)
        acc_ref[:G] = acc_ref[:G] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[:G] = m_cur

    # ---------------- finalize --------------------------------------------
    @pl.when((j == n_inner - 1) & (kind == PREFILL))
    def _fin_p():
        l = jnp.maximum(l_ref[...], 1e-30)
        op_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(op_ref.dtype)

    @pl.when((j == n_inner - 1) & (kind == DECODE))
    def _fin_d():
        l = jnp.maximum(l_ref[:G], 1e-30)
        od_ref[0, 0] = (acc_ref[:G] / l[:, None]).astype(od_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_q", "block_k", "f_decode",
                              "interpret"))
def unified_pd(q_p, k_p, v_p, q_d, k_pages, v_pages, block_tables,
               seq_lens, *, f_decode: float = 0.5,
               window: Optional[int] = None, block_q: int = 512,
               block_k: int = 512, interpret: bool = False
               ) -> Tuple[jax.Array, jax.Array]:
    """One fused P/D attention step.

    q_p (Bp,Hq,Sp,D), k_p/v_p (Bp,Hkv,Sp,D)        — prefill batch
    q_d (Bd,Hq,D), k/v_pages (N,page,Hkv,D),
    block_tables (Bd,max_pages), seq_lens (Bd,)     — decode batch
    Returns (o_p (Bp,Hq,Sp,D), o_d (Bd,Hq,D)).
    """
    Bp, Hq, Sp, D = q_p.shape
    Hkv = k_p.shape[1]
    G = Hq // Hkv
    Bd = q_d.shape[0]
    N, page, _, _ = k_pages.shape
    max_pages = block_tables.shape[1]

    block_q = min(block_q, Sp)
    block_k = min(block_k, Sp)
    pad = (-Sp) % block_q
    pad_k = (-Sp) % block_k
    if pad or pad_k:
        q_p = jnp.pad(q_p, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k_p = jnp.pad(k_p, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v_p = jnp.pad(v_p, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq = Sp + pad
    nq, nk = Sq // block_q, (Sp + pad_k) // block_k
    n_inner = max(nk, max_pages)

    desc = jnp.asarray(_make_descriptors(Bp, Hq, nq, Bd, Hkv, G, f_decode))
    n_slots = desc.shape[0]
    qd_g = q_d.reshape(Bd, Hkv, G, D)

    kernel = functools.partial(
        _unified_kernel, block_q=block_q, block_k=block_k, nk=nk,
        page=page, max_pages=max_pages, n_inner=n_inner, G=G,
        window=window, sm_scale=1.0 / (D ** 0.5))

    def clamp(x, hi):
        return jnp.minimum(x, hi)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_slots, n_inner),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda s, j, d, t, ln: (d[s, 1], d[s, 2],
                                                 d[s, 4], 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda s, j, d, t, ln: (d[s, 1], d[s, 3],
                                                 clamp(j, nk - 1), 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda s, j, d, t, ln: (d[s, 1], d[s, 3],
                                                 clamp(j, nk - 1), 0)),
            pl.BlockSpec((1, 1, G, D),
                         lambda s, j, d, t, ln: (d[s, 5], d[s, 6], 0, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda s, j, d, t, ln: (
                             t[d[s, 5], clamp(j, max_pages - 1)], 0,
                             d[s, 6], 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda s, j, d, t, ln: (
                             t[d[s, 5], clamp(j, max_pages - 1)], 0,
                             d[s, 6], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda s, j, d, t, ln: (
                             jnp.where(d[s, 0] == PREFILL, d[s, 1], Bp),
                             d[s, 2], d[s, 4], 0)),
            pl.BlockSpec((1, 1, G, D),
                         lambda s, j, d, t, ln: (
                             jnp.where(d[s, 0] == DECODE, d[s, 5], Bd),
                             d[s, 6], 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((max(block_q, G), D), jnp.float32),
            pltpu.VMEM((max(block_q, G),), jnp.float32),
            pltpu.VMEM((max(block_q, G),), jnp.float32),
        ],
    )
    o_p, o_d = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((Bp + 1, Hq, Sq, D), q_p.dtype),
            jax.ShapeDtypeStruct((Bd + 1, Hkv, G, D), q_d.dtype),
        ],
        interpret=interpret,
    )(desc, block_tables, seq_lens, q_p, k_p, v_p, qd_g, k_pages, v_pages)
    return o_p[:Bp, :, :Sp], o_d[:Bd].reshape(Bd, Hq, D)
