"""Causal flash attention (prefill) — Pallas TPU kernel.

Grid: (B, Hq, num_q_blocks, num_k_blocks); the k-block dim is innermost
and sequential, carrying the running (max, sum, acc) in VMEM scratch —
the canonical TPU flash schedule.  GQA is handled in the k/v BlockSpec
index maps (kv head = q head // group), so no KV repeat is materialized.

VMEM working set per program:
    q block  (block_q, D)           bf16
    k block  (block_k, D)           bf16
    v block  (block_k, D)           bf16
    acc      (block_q, D)           f32 scratch
    m, l     (block_q,)             f32 scratch
With block_q = block_k = 512, D = 128: ~0.9 MB — far under the ~16 MB
VMEM budget, leaving room for double buffering; dims are multiples of
(8, 128) so the MXU tiles cleanly.

Causality is enforced at two levels: whole k-blocks strictly above the
diagonal are skipped (no MXU work), and the diagonal block is masked
elementwise.  Sliding windows additionally skip k-blocks entirely below
the window.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_k: int, num_k_blocks: int,
                  window: Optional[int], sm_scale: float):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = kj * block_k
    # block-level causal / window culling
    needed = k_start <= q_start + block_q - 1
    if window is not None:
        needed &= (k_start + block_k - 1) > (q_start - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (block_q, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (block_k, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(kj == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "block_q", "block_k", "interpret"))
def flash_prefill(q, k, v, *, window: Optional[int] = None,
                  block_q: int = 512, block_k: int = 512,
                  interpret: bool = False):
    """q (B,Hq,S,D), k/v (B,Hkv,S,D) -> (B,Hq,S,D).  S padded to blocks."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    pad_q = (-S) % block_q
    pad_k = (-S) % block_k
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq, Sk = S + pad_q, S + pad_k
    nq, nk = Sq // block_q, Sk // block_k
    grid = (B, Hq, nq, nk)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, num_k_blocks=nk,
        window=window, sm_scale=1.0 / (D ** 0.5))

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S]
