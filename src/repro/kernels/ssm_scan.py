"""Chunked selective-scan (Mamba S6) — Pallas TPU kernel.

Grid: (B, num_din_tiles, num_chunks); the chunk dim is innermost and
sequential, carrying the SSM state h (tile_d, ds) in VMEM scratch across
chunks — HBM traffic is O(L·(din+ds)) instead of O(L·din·ds) for the
materialized-state formulation.

VMEM working set per program (chunk=128, tile_d=256, ds=16):
    xs, dt blocks (chunk, tile_d)  f32      ~256 KB
    B, C blocks   (chunk, ds)      f32      tiny
    A tile        (tile_d, ds)     f32      tiny
    h scratch     (tile_d, ds)     f32      tiny
tile_d is a multiple of 128 (lane dim for the (chunk, tile_d) blocks);
ds (=16 for Mamba) rides the minor dim of the small state tensors and is
lane-padded by Mosaic on real hardware — acceptable because the state
tensors are tiny relative to xs/dt (noted hardware adaptation).

Within a chunk the recurrence is a sequential fori_loop (ds-wide FMAs);
across chunks only h persists.  The final state is emitted for decode
continuity (same protocol as the KV cache, DESIGN.md §5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(xs_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref,
                h_ref, *, chunk: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...]                       # (tile_d, ds)

    # all ref indices go through pl.ds slices (never raw Python ints):
    # interpret-mode's swap discharge rule only understands Slice objects.
    def _row(ref, t):
        return pl.load(ref, (pl.ds(0, 1), pl.ds(t, 1), slice(None)))[0, 0]

    def step(t, h):
        dt_t = _row(dt_ref, t)           # (tile_d,)
        x_t = _row(xs_ref, t)            # (tile_d,)
        b_t = _row(b_ref, t)             # (ds,)
        c_t = _row(c_ref, t)             # (ds,)
        a_t = jnp.exp(dt_t[:, None] * A)             # (tile_d, ds)
        h = a_t * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1)      # (tile_d,)
        pl.store(y_ref, (pl.ds(0, 1), pl.ds(t, 1), slice(None)),
                 y_t[None, None])
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        pl.store(hout_ref, (pl.ds(0, 1), slice(None), slice(None)),
                 h_ref[...][None])


@functools.partial(jax.jit,
                   static_argnames=("chunk", "tile_d", "interpret"))
def ssm_scan(xs, dt, A, Bm, Cm, *, chunk: int = 128, tile_d: int = 256,
             interpret: bool = False):
    """xs/dt (B,L,din) f32; A (din,ds) f32; Bm/Cm (B,L,ds) f32.
    Returns y (B,L,din) f32 and final state (B,din,ds) f32.
    (h0 continuation is handled by the ops wrapper via a state-injection
    chunk; the kernel itself starts from h=0.)
    """
    B, L, din = xs.shape
    ds = A.shape[1]
    chunk = min(chunk, L)
    while L % chunk:
        chunk -= 1
    tile_d = min(tile_d, din)
    while din % tile_d:
        tile_d -= 1
    nc, nd = L // chunk, din // tile_d
    grid = (B, nd, nc)

    kernel = functools.partial(_ssm_kernel, chunk=chunk, num_chunks=nc)
    y, h_last = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, tile_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, tile_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((tile_d, ds), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, d, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, tile_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, tile_d, ds), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, din), jnp.float32),
            jax.ShapeDtypeStruct((B, din, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((tile_d, ds), jnp.float32)],
        interpret=interpret,
    )(xs, dt, A, Bm, Cm)
    return y, h_last
