"""Paged decode attention — Pallas TPU kernel over block-table KV.

One query token per sequence attends to its paged KV cache.  The block
table and sequence lengths are *scalar-prefetched* (SMEM) so that the
k/v-page BlockSpec index maps can chase the page indirection: the page
streamed into VMEM for grid step (b, h, p) is physical page
``block_tables[b, p]`` — the TPU-native analogue of vLLM's gather, with
no host-side KV reshuffle.

Grid: (B, Hkv, max_pages); the page dim is innermost/sequential, carrying
flash-style (m, l, acc) scratch for the G grouped query heads.

VMEM working set per program (page=64, G<=8, D=128):
    q     (G, D)        f32     k/v page (page, D)   bf16
    acc   (G, D)        f32     m, l     (G,)        f32
well under budget; `page` is a multiple of 8 and D of 128 for clean
(8,128) tiling.  Out-of-range pages (seq ended) are culled at block level
via @pl.when, so short sequences cost only their own pages.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, page: int, max_pages: int,
                  sm_scale: float):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    n = lens_ref[b]

    @pl.when(p * page < n)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)         # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # (G, page)
        pos = p * page + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < n, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        pexp = jnp.exp(s - m_cur[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(p == max_pages - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                    interpret: bool = False):
    """q (B,Hq,D); k/v_pages (N,page,Hkv,D); block_tables (B,max_pages)
    int32; seq_lens (B,).  Returns (B,Hq,D)."""
    B, Hq, D = q.shape
    N, page, Hkv, _ = k_pages.shape
    G = Hq // Hkv
    max_pages = block_tables.shape[1]
    # (B, Hkv, G, D) query layout: G grouped heads ride the sublane dim
    qg = q.reshape(B, Hkv, G, D)

    grid = (B, Hkv, max_pages)
    kernel = functools.partial(_paged_kernel, page=page,
                               max_pages=max_pages,
                               sm_scale=1.0 / (D ** 0.5))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, p, tab, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, p, tab, lens: (tab[b, p], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, p, tab, lens: (tab[b, p], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, p, tab, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(block_tables, seq_lens, qg, k_pages, v_pages)
    return out.reshape(B, Hq, D)
