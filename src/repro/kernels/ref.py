"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

No chunking, no tiling, no flash tricks — the simplest correct math, used
by tests/test_kernels.py to validate the kernels across shape/dtype sweeps
(interpret=True on CPU).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def causal_attention(q, k, v, *, window: Optional[int] = None,
                     q_offset: int = 0):
    """q (B,Hq,Sq,D), k/v (B,Hkv,Sk,D) -> (B,Hq,Sq,D).  GQA by repeat.

    Query position i (absolute q_offset + i) attends to keys <= its
    position, and within `window` when set.
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (D ** 0.5)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(k.shape[2])
    mask = kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attention(q, k_pages, v_pages, block_tables, seq_lens):
    """Decode attention over a paged KV cache.

    q (B,Hq,D); k/v_pages (N, page, Hkv, D); block_tables (B, max_pages)
    int32; seq_lens (B,) = valid tokens per sequence (including the
    current token, already written to its slot).  Returns (B,Hq,D).
    """
    B, Hq, D = q.shape
    N, page, Hkv, _ = k_pages.shape
    G = Hq // Hkv
    max_pages = block_tables.shape[1]

    def one(qb, tab, n):
        # gather this sequence's pages -> (max_pages*page, Hkv, D)
        kk = k_pages[tab].reshape(max_pages * page, Hkv, D)
        vv = v_pages[tab].reshape(max_pages * page, Hkv, D)
        qg = qb.reshape(Hkv, G, D).astype(jnp.float32)
        scores = jnp.einsum("hgd,khd->hgk", qg,
                            kk.astype(jnp.float32)) / (D ** 0.5)
        valid = jnp.arange(max_pages * page) < n
        scores = jnp.where(valid[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("hgk,khd->hgd", probs, vv.astype(jnp.float32))
        return out.reshape(Hq, D)

    return jax.vmap(one)(q, block_tables, seq_lens).astype(q.dtype)


def ssm_scan(xs, dt, A, Bm, Cm, h0=None):
    """Sequential (token-by-token) selective scan — the slow exact oracle.

    xs/dt (B,L,din) f32; A (din,ds); Bm/Cm (B,L,ds) f32.
    Returns y (B,L,din) f32, h_last (B,din,ds) f32.
    """
    B, L, din = xs.shape
    ds = A.shape[1]
    h = h0.astype(jnp.float32) if h0 is not None else \
        jnp.zeros((B, din, ds), jnp.float32)

    def step(h, args):
        x_t, dt_t, B_t, C_t = args  # (B,din),(B,din),(B,ds),(B,ds)
        a = jnp.exp(dt_t[..., None] * A)
        b = (dt_t * x_t)[..., None] * B_t[:, None]
        h = a * h + b
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y

    h_last, ys = jax.lax.scan(
        step, h, (xs.transpose(1, 0, 2), dt.transpose(1, 0, 2),
                  Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2), h_last


def unified_pd(q_p, k_p, v_p, q_d, k_pages, v_pages, block_tables,
               seq_lens, *, window: Optional[int] = None):
    """Oracle for the unified P/D step: prefill flash output + decode
    paged output, computed independently (they share no data)."""
    o_p = causal_attention(q_p, k_p, v_p, window=window)
    o_d = paged_attention(q_d, k_pages, v_pages, block_tables, seq_lens)
    return o_p, o_d
