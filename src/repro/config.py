"""Configuration system for the RAPID-Serve reproduction framework.

Every architecture is described by a frozen ``ModelConfig``; input shapes by
``ShapeConfig``; distribution by ``MeshConfig``.  Architectures register
themselves in ``ARCH_REGISTRY`` (populated by importing ``repro.configs``)
and are selectable with ``--arch <id>`` from every launcher.

Divisibility rules (TPU/GSPMD requires sharded dims to divide evenly):
  * head counts are padded to ``ceil(H / tp) * tp`` when head-sharded,
  * vocab is padded to a multiple of 256,
  * KV sharding mode is chosen per arch: ``heads`` when padding the KV-head
    count at most doubles it, otherwise ``seq`` (sequence-sharded KV, i.e.
    context-parallel decode).
All padding is recorded on the config so the roofline accounting can report
both logical and padded quantities.
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from dataclasses import dataclass, field
from typing import Callable, Optional

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # "ep": shard the expert dim over the model axis; "tp": shard each
    # expert's hidden dim over the model axis (used when E % tp != 0).
    partition: str = "auto"


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    # which positions within the layer pattern are sLSTM (rest are mLSTM)
    proj_factor: float = 2.0
    num_heads: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # Per-layer mixer pattern, cycled over layers: entries in
    # {"attn", "mamba", "mlstm", "slstm"}.
    layer_pattern: tuple = ("attn",)
    # Per-layer FFN pattern cycled over layers: entries in {"dense","moe","none"}.
    ffn_pattern: tuple = ("dense",)
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    qkv_bias: bool = False
    rope_type: str = "rope"   # rope | mrope | none
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None
    frontend: str = "token"   # token | embed_stub (audio/vlm backbones)
    norm_eps: float = 1e-5
    act: str = "silu"
    ffn_glu: bool = True      # SwiGLU-style 3-matrix FFN vs plain 2-matrix
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # Optimizer-state dtype for the training shapes.  bf16 moments for the
    # very large archs so train_4k fits 16 GB/chip (see DESIGN.md §4).
    opt_dtype: str = "float32"
    # Number of gradient-accumulation microbatches for train_4k.
    train_microbatches: int = 1  # single-pod target; launcher clamps to mesh
    source: str = ""          # provenance note [arXiv/hf; tier]

    # ----- derived helpers -------------------------------------------------
    def heads_padded(self, tp: int) -> int:
        return int(math.ceil(self.num_heads / tp) * tp)

    def kv_heads_padded(self, tp: int) -> int:
        if self.kv_shard_mode(tp) == "heads":
            return int(math.ceil(self.num_kv_heads / tp) * tp)
        return self.num_kv_heads

    def kv_shard_mode(self, tp: int) -> str:
        """'heads' when padding KV heads costs <= 2x, else 'seq'."""
        padded = math.ceil(self.num_kv_heads / tp) * tp
        return "heads" if padded <= 2 * self.num_kv_heads else "seq"

    @property
    def vocab_padded(self) -> int:
        return int(math.ceil(self.vocab_size / 256) * 256)

    @property
    def period(self) -> int:
        """Length of the repeating layer group (scan unit)."""
        p = _lcm(len(self.layer_pattern), len(self.ffn_pattern))
        if self.num_layers % p:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"pattern period {p}")
        return p

    @property
    def num_periods(self) -> int:
        return self.num_layers // self.period

    def mixer_at(self, pos: int) -> str:
        return self.layer_pattern[pos % len(self.layer_pattern)]

    def ffn_at(self, pos: int) -> str:
        return self.ffn_pattern[pos % len(self.ffn_pattern)]

    # NOTE on the ``self.__dict__`` memos below: the serving perfmodel
    # prices every simulated step through these derived scalars, and each
    # walks the full layer pattern.  They are pure in the (frozen) config,
    # so the first result is stashed in the instance ``__dict__`` — the
    # generated ``__eq__``/``__hash__`` only see declared fields, so the
    # memo never leaks into config identity, and ``object.__setattr__``
    # is not needed because the dict itself is mutable.

    @property
    def attn_layer_count(self) -> int:
        v = self.__dict__.get("_attn_layer_count")
        if v is None:
            v = sum(1 for i in range(self.num_layers)
                    if self.mixer_at(i) == "attn")
            self.__dict__["_attn_layer_count"] = v
        return v

    @property
    def d_inner(self) -> int:
        m = self.mamba or MambaConfig()
        return m.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        m = self.mamba or MambaConfig()
        return m.dt_rank or math.ceil(self.d_model / 16)

    @property
    def sub_quadratic(self) -> bool:
        """True when the arch can run 500K-token decode: SSM/hybrid
        (recurrent state + few attn layers) or sliding-window attention
        (bounded KV).  Pure full-attention archs skip long_500k
        (DESIGN.md §5 records the skips)."""
        if self.family in ("ssm", "hybrid"):
            return True
        if not any(m == "attn" for m in self.layer_pattern):
            return True
        return self.sliding_window is not None

    def param_count(self) -> int:
        """Analytic parameter count (logical, unpadded)."""
        v = self.__dict__.get("_param_count")
        if v is None:
            v = self.__dict__["_param_count"] = self._param_count()
        return v

    def _param_count(self) -> int:
        d, L = self.d_model, self.num_layers
        D = self.head_dim
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for i in range(L):
            mx = self.mixer_at(i)
            if mx == "attn":
                total += d * (self.num_heads * D) * 2  # q, o
                total += d * (self.num_kv_heads * D) * 2  # k, v
                if self.qkv_bias:
                    total += (self.num_heads + 2 * self.num_kv_heads) * D
            elif mx == "mamba":
                din = self.d_inner
                m = self.mamba or MambaConfig()
                total += d * 2 * din            # in_proj
                total += din * m.d_conv         # conv
                total += din * (self.dt_rank + 2 * m.d_state)  # x_proj
                total += self.dt_rank * din     # dt_proj
                total += din * m.d_state + din  # A, D
                total += din * d                # out_proj
            elif mx in ("mlstm", "slstm"):
                x = self.xlstm or XLSTMConfig()
                if mx == "mlstm":
                    din = int(x.proj_factor * d)
                    total += d * din * 2 + din * d  # up(2x), down
                    total += din * din * 3          # q,k,v inner
                    total += 3 * din                # i,f,o gates (per-ch)
                else:
                    total += 4 * d * d * 2          # 4 gates, x & recurrent
            fn = self.ffn_at(i)
            if fn == "dense":
                total += (3 if self.ffn_glu else 2) * d * self.d_ff
            elif fn == "moe":
                assert self.moe is not None
                total += d * self.moe.num_experts  # router
                total += self.moe.num_experts * 3 * d * self.moe.d_ff_expert
            total += 2 * d  # norms
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        v = self.__dict__.get("_active_param_count")
        if v is None:
            v = self.__dict__["_active_param_count"] = \
                self._active_param_count()
        return v

    def _active_param_count(self) -> int:
        if self.moe is None:
            return self.param_count()
        total = self.param_count()
        moe_layers = sum(1 for i in range(self.num_layers)
                         if self.ffn_at(i) == "moe")
        full = moe_layers * self.moe.num_experts * 3 * self.d_model * \
            self.moe.d_ff_expert
        active = moe_layers * self.moe.top_k * 3 * self.d_model * \
            self.moe.d_ff_expert
        return total - full + active

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """Eq. (1) of the paper: 2 * L_attn * H_kv * D * E per token."""
        key = ("_kv_bytes_per_token", dtype_bytes)
        v = self.__dict__.get(key)
        if v is None:
            v = self.__dict__[key] = 2 * self.attn_layer_count * \
                self.num_kv_heads * self.head_dim * dtype_bytes
        return v

    def state_bytes_per_seq(self, dtype_bytes: int = 2) -> int:
        """Recurrent-state bytes per sequence (SSM/xLSTM layers)."""
        key = ("_state_bytes_per_seq", dtype_bytes)
        v = self.__dict__.get(key)
        if v is None:
            v = self.__dict__[key] = \
                self._state_bytes_per_seq(dtype_bytes)
        return v

    def _state_bytes_per_seq(self, dtype_bytes: int = 2) -> int:
        total = 0
        m = self.mamba or MambaConfig()
        x = self.xlstm or XLSTMConfig()
        for i in range(self.num_layers):
            mx = self.mixer_at(i)
            if mx == "mamba":
                total += (self.d_inner * m.d_state +
                          self.d_inner * m.d_conv) * dtype_bytes
            elif mx == "mlstm":
                din = int(x.proj_factor * self.d_model)
                hd = din // x.num_heads
                total += (x.num_heads * hd * hd + 2 * din) * dtype_bytes
            elif mx == "slstm":
                total += 4 * self.d_model * dtype_bytes
        return total


# ---------------------------------------------------------------------------
# Shapes / mesh / serving
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | long_decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "long_decode"),
}


@dataclass(frozen=True)
class MeshConfig:
    shape: tuple
    axes: tuple

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def tp(self) -> int:
        return self.shape[self.axes.index("model")]

    @property
    def dp(self) -> int:
        return self.num_devices // self.tp


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclass(frozen=True)
class SLOConfig:
    itl_ms: float = 100.0           # inter-token latency ceiling
    ttft_base_s: float = 1.0        # TTFT ceiling for <=1000 prompt tokens
    ttft_tokens_per_ceiling: int = 1000  # +1s ceiling per 1000 tokens


@dataclass(frozen=True)
class ServeConfig:
    """Serving-engine configuration (one engine instance)."""
    mode: str = "rapid"             # rapid | hybrid | disagg
    chips: int = 8                  # chips per serving instance
    slo: SLOConfig = field(default_factory=SLOConfig)
    max_batch_slots: int = 64       # decode batch slots
    max_seq_len: int = 32_768
    page_size: int = 16             # tokens per KV page
    kv_reserve_frac: float = 0.05   # HBM held back from the KV pool
    chunk_size: int = 512           # hybrid batching prefill chunk
    token_budget: int = 2048        # hybrid per-iteration token budget
    prefill_max_tokens: int = 16_384  # rapid: max prompt tokens per prefill step
    # disagg split (prefill chips, decode chips)
    disagg_split: tuple = (4, 4)
    kv_transfer_gbps: float = 50.0  # ICI link for intra-node KV transfer
    # session prefix cache: fraction of the decode pool a finished
    # session's KV may keep occupying so the next turn skips re-prefill
    # of the shared prefix.  Inert (no blocks retained) unless requests
    # carry session ids, so the default single-class path is unchanged.
    session_cache_frac: float = 0.25
    # adaptive resource manager
    overalloc_decode_bs_limit: int = 16  # Fig 7 crossover (profiled)
    scheduler_overhead_ms: float = 2.0   # CPU work per step (sync path)
    async_scheduling: bool = True


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_REGISTRY: dict = {}
_REDUCED_REGISTRY: dict = {}

ARCH_IDS = (
    "jamba-1.5-large-398b",
    "xlstm-125m",
    "starcoder2-3b",
    "granite-8b",
    "qwen2.5-14b",
    "minicpm-2b",
    "musicgen-large",
    "qwen3-moe-235b-a22b",
    "mixtral-8x22b",
    "qwen2-vl-72b",
    # paper's own evaluation models
    "llama3-70b",
    "mixtral-8x7b",
)


def register(config: ModelConfig, reduced: Callable[[], ModelConfig]):
    ARCH_REGISTRY[config.name] = config
    _REDUCED_REGISTRY[config.name] = reduced


def get_config(arch: str) -> ModelConfig:
    _ensure_loaded()
    if arch not in ARCH_REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_REGISTRY)}")
    return ARCH_REGISTRY[arch]


def get_reduced_config(arch: str) -> ModelConfig:
    _ensure_loaded()
    return _REDUCED_REGISTRY[arch]()


def list_archs():
    _ensure_loaded()
    return sorted(ARCH_REGISTRY)


def _ensure_loaded():
    if not ARCH_REGISTRY:
        importlib.import_module("repro.configs")


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
