# One-command local/CI entry points.
#
#   make dev-deps   install test-only dependencies (hypothesis etc.)
#   make test       tier-1 suite (what the driver runs)
#   make smoke      tier-1 + a quick cluster-benchmark smoke
#   make ci         dev-deps + smoke

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: dev-deps test smoke ci bench

dev-deps:
	$(PY) -m pip install -r requirements-dev.txt

test:
	$(PY) -m pytest -x -q

smoke: test
	$(PY) -m benchmarks.fig12_cluster_goodput --smoke

ci: dev-deps smoke

bench:
	$(PY) -m benchmarks.run
