# One-command local/CI entry points.
#
#   make dev-deps   install test-only dependencies (hypothesis etc.)
#   make test       tier-1 suite (what the driver runs) + junit report
#   make smoke      tier-1 + gateway churn/fault suite (crash/drain/
#                   slow-consumer/flap/wire-loss/checkpoint-resume under
#                   the simulated clock, hard wall-clock timeout via
#                   coreutils since pytest-timeout is not a dep; the
#                   hypothesis chaos properties ride in tier-1 when
#                   dev-deps are installed) + quick benchmark smokes
#                   (single-engine fig8/9/10/11, cluster fig12,
#                   admission/preemption fig13, projection-driven
#                   scaling fig14, multi-tenant workload classes fig15,
#                   gateway churn fault-injection fig16, checkpoint-
#                   resume vs re-prefill crash recovery fig17, hot-path
#                   simulator-throughput bench, and the 128-replica
#                   fleet-vectorized pricing gate: batched vs scalar
#                   cluster ticks, identical simulation outputs
#                   asserted)
#   make bench-hotpath  full hot-path macro-benchmark; writes
#                   BENCH_hotpath.json (simulated req/wall-s, per-event
#                   cost, speedup vs the pinned pre-PR-5 baseline)
#   make bench-fleet  full 128-replica fleet pricing benchmark;
#                   updates the "fleet" section of BENCH_hotpath.json
#   make ci         dev-deps + smoke  (the one command CI runs)
#   make lint       ruff style gate (blocking CI job)

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: dev-deps test smoke ci bench bench-hotpath bench-fleet lint

dev-deps:
	$(PY) -m pip install -r requirements-dev.txt || \
		echo "WARNING: offline? dev deps not installed; hypothesis tests will be skipped"

test:
	$(PY) -m pytest -x -q --junitxml=pytest-report.xml

smoke: test
	# churn + fault-injection suites re-run under a hard timeout: a
	# liveness regression in the gateway's tick re-arming (or a fault
	# schedule that leaks a request) would otherwise hang CI forever
	timeout 300 $(PY) -m pytest -x -q tests/test_gateway.py \
		tests/test_gateway_churn.py tests/test_faults.py \
		tests/test_event_wire.py
	$(PY) -m benchmarks.fig8_throughput --smoke
	$(PY) -m benchmarks.fig9_goodput --smoke
	$(PY) -m benchmarks.fig10_itl_goodput --smoke
	$(PY) -m benchmarks.fig11_tail_latency --smoke
	$(PY) -m benchmarks.fig12_cluster_goodput --smoke
	$(PY) -m benchmarks.fig13_admission_preemption --smoke
	$(PY) -m benchmarks.fig14_projection_scaling --smoke
	$(PY) -m benchmarks.fig15_workload_classes --smoke
	$(PY) -m benchmarks.fig16_gateway_churn --smoke
	$(PY) -m benchmarks.fig17_recovery --smoke --json BENCH_fig17.json
	$(PY) -m benchmarks.bench_hotpath --smoke
	$(PY) -m benchmarks.bench_hotpath --fleet --smoke

bench-hotpath:
	$(PY) -m benchmarks.bench_hotpath

bench-fleet:
	$(PY) -m benchmarks.bench_hotpath --fleet

ci: dev-deps smoke

lint:
	$(PY) -m ruff check src benchmarks examples tests

bench:
	$(PY) -m benchmarks.run
