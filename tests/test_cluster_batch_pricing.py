"""Batched fleet pricing == scalar per-replica pricing, decision for
decision.

``Cluster(batch_pricing=True)`` routes slo_aware scores, the projection
autoscaler's rate/backlog forecasts, and rebalance cost/benefit through
``perfmodel.batch`` in one fleet-wide call per tick; ``False`` is the
scalar reference walk.  The batch layer's bit-identity contract means
the two must produce the *same virtual history* — same routing, same
migrations, same scale events, same spans — not merely similar
aggregate metrics.  This is the fast tier-1 pin of that end-to-end
guarantee (the fleet benchmark asserts it again at 128 replicas).
"""
import random

from repro.config import ServeConfig, get_config
from repro.core.request import Request
from repro.serving.cluster import (ProjectionPolicy, RebalancePolicy,
                                   run_fleet)


def _trace(n, seed=3):
    """Loaded mixed trace: sessions, long-document tail, enough pressure
    that projections scale the fleet and the rebalancer migrates."""
    rng = random.Random(seed)
    reqs, t = [], 0.0
    for i in range(n):
        t += rng.expovariate(150.0)
        pl = rng.randint(2000, 8000) if rng.random() < 0.25 \
            else rng.randint(64, 900)
        reqs.append(Request(rid=i, arrival=t, prompt_len=pl,
                            max_new_tokens=rng.randint(32, 256),
                            session_id=f"s{i % 37}" if i % 5 == 0
                            else None))
    return reqs


def _run(reqs, batch_pricing):
    cfg = get_config("qwen2.5-14b")
    serve = ServeConfig(chips=8)
    summary, cl = run_fleet(
        cfg, serve, ["rapid", "hybrid", "disagg"], "slo_aware", reqs,
        scale=ProjectionPolicy(min_replicas=3, max_replicas=6,
                               check_interval_s=0.5, horizon_s=2.0),
        rebalance=RebalancePolicy(check_interval_s=0.5, kv_high=0.3,
                                  kv_low=0.25),
        session_affinity=True, batch_pricing=batch_pricing)
    return summary, cl


def test_batched_and_scalar_pricing_same_history():
    reqs = _trace(400)
    summary_b, cl_b = _run(reqs, batch_pricing=True)
    summary_s, cl_s = _run(reqs, batch_pricing=False)

    # the trace must actually exercise the priced decision points,
    # otherwise this test proves nothing
    assert cl_b._migrations, "trace never triggered the rebalancer"
    assert cl_b._scale_events, "trace never triggered the autoscaler"

    assert summary_b == summary_s
    assert cl_b._migrations == cl_s._migrations
    assert cl_b._scale_events == cl_s._scale_events
    assert cl_b.per_replica_counts() == cl_s.per_replica_counts()
    assert cl_b.loop.now == cl_s.loop.now


def test_batch_pricing_flag_reaches_router():
    reqs = _trace(5)
    _, cl_b = _run(reqs, batch_pricing=True)
    _, cl_s = _run(reqs, batch_pricing=False)
    assert cl_b.router.batch_pricing is True
    assert cl_s.router.batch_pricing is False
