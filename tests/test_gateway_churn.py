"""Gateway churn: crash failover, rolling-upgrade drain, slow consumers.

All scenarios run on the simulated clock, so worker death detection,
re-prefill recovery and backpressure eviction are fully deterministic.
The invariant under every churn shape: **no accepted request is lost**
— each one either finishes (after retries, with a contiguous deduped
token stream) or ends with a typed ``RejectedEvent``.
"""
from repro.config import SLOConfig, ServeConfig, get_config
from repro.core.events import FinishedEvent, RejectedEvent, TokenEvent
from repro.core.request import Request
from repro.serving import Gateway
from repro.serving.worker import WorkerState

CFG = get_config("llama3-70b")


def _serve(chips=16):
    return ServeConfig(mode="rapid", chips=chips,
                       slo=SLOConfig(itl_ms=100.0), chunk_size=512,
                       disagg_split=(chips // 2, chips // 2),
                       max_batch_slots=64)


def _capture(gw, reqs, seen):
    """Submit ``reqs`` at their arrival times with per-request capture
    consumers (inline: no buffering, no backpressure)."""
    gw._expected += len(reqs)
    for r in reqs:
        def go(r=r):
            seen[r.rid] = []
            gw.submit(r, consumer=seen[r.rid].append)
        gw.clock.at(r.arrival, go)


def _terminal(evs):
    return evs[-1] if evs and isinstance(
        evs[-1], (FinishedEvent, RejectedEvent)) else None


def _token_indices(evs):
    return [e.index for e in evs if isinstance(e, TokenEvent)]


def test_crash_mid_decode_loses_no_request():
    """Kill one of two workers mid-decode: every accepted request still
    terminates — the victims re-prefill on the survivor with retries
    counted, and each consumer sees one contiguous token stream."""
    gw = Gateway(CFG, _serve(), modes=["rapid", "rapid"],
                 router="round_robin")
    seen = {}
    reqs = [Request(rid=i, arrival=0.01 * i, prompt_len=256,
                    max_new_tokens=300) for i in range(8)]
    _capture(gw, reqs, seen)
    gw.clock.at(0.2, lambda: gw.kill_worker(0))
    gw.clock.run()

    assert len(seen) == 8
    retried = 0
    for rid, evs in seen.items():
        fin = _terminal(evs)
        assert isinstance(fin, FinishedEvent), (rid, type(fin))
        idxs = _token_indices(evs)
        assert idxs == list(range(300)), (rid, len(idxs))
        retried += fin.retries
    # round_robin put half the trace on the dead worker
    assert retried == 4
    assert gw.registry.workers[0].state is WorkerState.DEAD
    recs = {r.rid: r for r in gw.metrics.records}
    assert sum(r.retries for r in recs.values()) == 4
    assert all(not r.rejected for r in recs.values())


def test_crash_with_no_survivor_rejects_worker_lost():
    """Sole worker dies: accepted requests end with a typed
    ``RejectedEvent(reason=worker_lost)`` carrying the partial output
    count — never a silent hang."""
    gw = Gateway(CFG, _serve(), modes=["rapid"], router="round_robin")
    seen = {}
    reqs = [Request(rid=i, arrival=0.01 * i, prompt_len=256,
                    max_new_tokens=300) for i in range(4)]
    _capture(gw, reqs, seen)
    gw.clock.at(0.2, lambda: gw.kill_worker(0))
    gw.clock.run()

    for rid, evs in seen.items():
        rej = _terminal(evs)
        assert isinstance(rej, RejectedEvent), rid
        assert rej.reason == "worker_lost"
        assert rej.output_len == len(_token_indices(evs))
    assert gw.health()["status"] == "degraded"


def test_worker_restart_after_crash_restores_service():
    gw = Gateway(CFG, _serve(), modes=["rapid"], router="round_robin")
    seen = {}
    first = [Request(rid=0, arrival=0.0, prompt_len=256,
                     max_new_tokens=300)]
    _capture(gw, first, seen)
    gw.clock.at(0.2, lambda: gw.kill_worker(0))
    gw.clock.run()
    assert isinstance(_terminal(seen[0]), RejectedEvent)

    gw.add_worker("rapid")                       # replacement comes up
    second = [Request(rid=1, arrival=gw.clock.now + 0.1, prompt_len=256,
                      max_new_tokens=32)]
    _capture(gw, second, seen)
    gw.clock.run()
    fin = _terminal(seen[1])
    assert isinstance(fin, FinishedEvent) and fin.output_len == 32
    assert gw.health()["status"] == "ok"


def test_heartbeat_flap_under_timeout_is_invisible():
    """A worker that misses beats for *less* than the registry timeout
    (GC pause, transient partition) must not trigger failover: defaults
    are 0.5 s beats with a 1.75 s timeout, so a 2-beat flap stays a full
    beat under the line."""
    gw = Gateway(CFG, _serve(), modes=["rapid", "rapid"],
                 router="round_robin")
    seen = {}
    reqs = [Request(rid=i, arrival=0.01 * i, prompt_len=256,
                    max_new_tokens=300) for i in range(6)]
    _capture(gw, reqs, seen)
    gw.clock.at(0.3, lambda: gw.registry.workers[0].suppress_beats(2))
    gw.clock.run()

    for rid, evs in seen.items():
        fin = _terminal(evs)
        assert isinstance(fin, FinishedEvent), rid
        assert fin.retries == 0, rid
        assert _token_indices(evs) == list(range(300)), rid
    assert gw.registry.workers[0].state is WorkerState.UP
    assert gw.registry.fenced_beats == 0


def test_heartbeat_flap_past_timeout_fails_over_and_fences():
    """A flap *longer* than the timeout is indistinguishable from a
    crash: the worker is declared dead, its requests fail over, and —
    fencing — a late beat from the zombie can never resurrect it (its
    requests were already re-homed; resurrection would double-serve)."""
    gw = Gateway(CFG, _serve(), modes=["rapid", "rapid"],
                 router="round_robin")
    seen = {}
    reqs = [Request(rid=i, arrival=0.01 * i, prompt_len=256,
                    max_new_tokens=300) for i in range(6)]
    _capture(gw, reqs, seen)
    # 6 missed beats = 3.0 s of silence >> 1.75 s timeout
    gw.clock.at(0.3, lambda: gw.registry.workers[0].suppress_beats(6))
    late = []
    def zombie_beat():
        gw.registry.heartbeat(0)             # late beat from the "dead"
        late.append(gw.registry.workers[0].state)
    gw.clock.at(4.0, zombie_beat)
    gw.clock.run()

    retried = 0
    for rid, evs in seen.items():
        fin = _terminal(evs)
        assert isinstance(fin, FinishedEvent), rid
        assert _token_indices(evs) == list(range(300)), rid
        retried += fin.retries
    assert retried == 3                      # round_robin: half the trace
    assert gw.registry.workers[0].state is WorkerState.DEAD
    assert late == [WorkerState.DEAD]        # the beat did NOT revive it
    assert gw.registry.fenced_beats >= 1
    assert gw.metrics_summary()["fleet"]["fenced_beats"] >= 1
    # a fenced worker only rejoins as a *fresh* worker
    w = gw.add_worker("rapid")
    assert w.wid == 2 and len(gw.registry.healthy()) == 2


def test_drain_completes_in_flight_without_retries():
    """A drained worker finishes its in-flight decodes in place (no
    crash-style retries), hands queued work to peers, then retires and
    leaves the registry."""
    gw = Gateway(CFG, _serve(), modes=["rapid", "rapid"],
                 router="round_robin")
    seen = {}
    reqs = [Request(rid=i, arrival=0.01 * i, prompt_len=256,
                    max_new_tokens=200) for i in range(8)]
    _capture(gw, reqs, seen)
    retired_at = []
    gw.clock.at(0.3, lambda: gw.drain_worker(
        0, on_retired=lambda: retired_at.append(gw.clock.now)))
    gw.clock.run()

    for rid, evs in seen.items():
        fin = _terminal(evs)
        assert isinstance(fin, FinishedEvent), rid
        assert fin.retries == 0, rid
        assert _token_indices(evs) == list(range(200)), rid
    assert retired_at and 0 not in gw.registry.workers
    assert gw.health()["workers"] == {"rapid-1": "up"}


def test_rolling_upgrade_replaces_fleet_without_loss():
    gw = Gateway(CFG, _serve(), modes=["rapid", "rapid"],
                 router="round_robin")
    reqs = [Request(rid=i, arrival=0.01 * i, prompt_len=256,
                    max_new_tokens=150) for i in range(10)]
    done = []
    gw.clock.at(0.3, lambda: gw.rolling_upgrade(
        on_done=lambda: done.append(gw.clock.now)))
    recs, _ = gw.serve_trace(reqs)

    assert done, "upgrade never completed"
    assert all(r.finish is not None for r in recs)
    assert sum(r.retries for r in recs) == 0
    # the original workers (wids 0,1) are gone; two replacements serve
    assert sorted(gw.registry.workers) == [2, 3]
    assert all(w.state is WorkerState.UP
               for w in gw.registry.workers.values())


def test_slow_consumer_backpressures_only_its_own_stream():
    """One stalled consumer fills its channel: that request is evicted
    from the engine (preemptions >= 1) while a concurrent fast stream
    proceeds untouched; draining resumes and completes the slow one."""
    gw = Gateway(CFG, _serve(), modes=["rapid"], router="round_robin")
    fast_evs = []
    r_slow = Request(rid=0, arrival=0.0, prompt_len=128,
                     max_new_tokens=300)
    r_fast = Request(rid=1, arrival=0.0, prompt_len=128,
                     max_new_tokens=300)
    gw._expected = 2
    hold = {}
    gw.clock.at(0.0, lambda: hold.setdefault("ch", gw.submit(r_slow)))
    gw.clock.at(0.0, lambda: gw.submit(r_fast, consumer=fast_evs.append))

    drained = []

    def drain_loop():
        drained.extend(hold["ch"].drain())
        if not hold["ch"].done:
            gw.clock.after(0.01, drain_loop)

    gw.clock.at(3.0, drain_loop)                 # consumer wakes up late
    gw.clock.run()

    fast_fin = _terminal(fast_evs)
    slow_fin = _terminal(drained)
    assert isinstance(fast_fin, FinishedEvent)
    assert isinstance(slow_fin, FinishedEvent)
    assert fast_fin.preemptions == 0             # isolation
    assert slow_fin.preemptions >= 1             # it WAS parked
    assert _token_indices(fast_evs) == list(range(300))
    assert _token_indices(drained) == list(range(300))
    assert slow_fin.t > fast_fin.t
    rec = {r.rid: r for r in gw.metrics.records}
    assert not rec[0].rejected and not rec[1].rejected
