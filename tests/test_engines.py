"""Serving-engine behaviour + property tests (hypothesis).

The invariants the RAPID protocol (paper Fig 4) must keep:
  * conservation — every submitted request finishes exactly once (given
    enough virtual time), emits <= max_new_tokens tokens, monotone
    token times;
  * decode-owned KV — block allocation precedes prefill; blocks are
    freed exactly once; the pool never leaks (all blocks free at drain);
  * lock-freedom proxy — prefill and decode steps overlap in virtual
    time under concurrent load;
  * SLO structure — RAPID's p95 ITL <= hybrid's at equal load (the
    paper's core claim).
"""
import copy

import pytest
from hypothesis import given, settings, strategies as st

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core import (RapidEngine, build_decode_profile, drive,
                        make_engine)
from repro.kvcache import BlockAllocator, KVCacheManager, OutOfBlocks
from repro.perfmodel.hw import TPU_V5E
from repro.serving import TRACES, generate_trace, summarize

CFG = get_config("llama3-70b")
SERVE = dict(chips=32, slo=SLOConfig(itl_ms=100.0),
             disagg_split=(16, 16), max_batch_slots=128)


def _run(mode, reqs, **over):
    serve = ServeConfig(mode=mode, **{**SERVE, **over})
    eng = make_engine(mode, CFG, serve)
    recs, span = drive(eng, [copy.deepcopy(r) for r in reqs])
    return eng, recs, span


# ---------------------------------------------------------------------------
# Block allocator / KV manager properties
# ---------------------------------------------------------------------------


@given(st.lists(st.tuples(st.integers(1, 500), st.integers(0, 40)),
                min_size=1, max_size=60))
@settings(max_examples=50, deadline=None)
def test_kv_manager_never_leaks(ops):
    """Allocate prompts, append random decode tokens, free — pool full."""
    kv = KVCacheManager(num_blocks=256, page_size=16)
    live = []
    for i, (plen, extra) in enumerate(ops):
        if kv.can_allocate(plen):
            kv.allocate_prompt(i, plen)
            live.append((i, extra))
    for rid, extra in live:
        for _ in range(extra):
            try:
                kv.append_token(rid)
            except OutOfBlocks:
                break
    for rid, _ in live:
        kv.free(rid)
    assert kv.allocator.free_count == 256
    assert kv.num_requests == 0


@given(st.lists(st.integers(1, 64), min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_block_allocator_unique(sizes):
    """No block handed out twice while live."""
    alloc = BlockAllocator(512)
    seen = set()
    held = []
    for n in sizes:
        if n > alloc.free_count:
            continue
        blocks = alloc.alloc(n)
        assert not (set(blocks) & seen)
        seen.update(blocks)
        held.append(blocks)
    for b in held:
        alloc.free(b)
        seen.difference_update(b)
    assert alloc.free_count == 512


# ---------------------------------------------------------------------------
# Engine conservation + protocol invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["rapid", "hybrid", "disagg"])
def test_conservation(mode):
    reqs = generate_trace(TRACES["lmsys"], qps=4.0, duration_s=30, seed=1)
    eng, recs, span = _run(mode, reqs)
    assert len(recs) == len(reqs)
    finished = [r for r in recs if r.finish is not None]
    assert len(finished) == len(reqs)            # drained
    for r in finished:
        assert r.output_len >= 1
        assert r.ttft is not None and r.ttft >= 0
    # KV pool fully reclaimed
    assert eng.kv.allocator.free_count == eng.kv.allocator.num_blocks


def test_rapid_token_times_monotone():
    reqs = generate_trace(TRACES["lmsys"], qps=6.0, duration_s=20, seed=2)
    serve = ServeConfig(mode="rapid", **SERVE)
    eng = RapidEngine(CFG, serve)
    drive(eng, [copy.deepcopy(r) for r in reqs])
    for r in eng.finished:
        ts = r.token_times
        assert all(b >= a for a, b in zip(ts, ts[1:]))
        assert r.tokens_generated <= r.max_new_tokens


def test_rapid_blocks_before_prefill():
    """Fig 4 ordering: block allocation timestamp <= prefill start."""
    reqs = generate_trace(TRACES["lmsys"], qps=6.0, duration_s=20, seed=3)
    serve = ServeConfig(mode="rapid", **SERVE)
    eng = RapidEngine(CFG, serve)
    drive(eng, [copy.deepcopy(r) for r in reqs])
    for r in eng.finished:
        assert r.t_blocks is not None
        assert r.t_prefill_start is not None
        assert r.t_blocks <= r.t_prefill_start + 1e-9


def test_rapid_overlaps_pd():
    """Concurrency: some decode step must complete while a prefill is in
    flight (strictly impossible for the lockstep hybrid engine)."""
    reqs = generate_trace(TRACES["arxiv"], qps=6.0, duration_s=30, seed=4)
    serve = ServeConfig(mode="rapid", **SERVE)
    eng = RapidEngine(CFG, serve)

    overlaps = []
    orig = eng._decode_done

    def spy(batch):
        overlaps.append(eng.prefill_busy)
        orig(batch)

    eng._decode_done = spy
    drive(eng, [copy.deepcopy(r) for r in reqs])
    assert any(overlaps), "no P/D overlap observed"


def test_rapid_itl_beats_hybrid():
    """The paper's core claim at saturating load."""
    reqs = generate_trace(TRACES["lmsys"], qps=16.0, duration_s=40, seed=5)
    _, r_recs, r_span = _run("rapid", reqs)
    _, h_recs, h_span = _run("hybrid", reqs)
    slo = SLOConfig(itl_ms=100.0)
    s_r = summarize(r_recs, slo, r_span)
    s_h = summarize(h_recs, slo, h_span)
    assert s_r["itl_p95_s"] < s_h["itl_p95_s"]
    assert s_r["goodput_req_s"] >= 0.95 * s_h["goodput_req_s"]


def test_disagg_pays_transfer_ttft():
    """§3.2.1: at low load disagg TTFT > rapid TTFT (KV transfer +
    first-token recompute on the decode instance)."""
    reqs = generate_trace(TRACES["arxiv"], qps=1.0, duration_s=30, seed=6)
    _, r_recs, r_span = _run("rapid", reqs)
    _, d_recs, d_span = _run("disagg", reqs)
    slo = SLOConfig(itl_ms=100.0)
    assert summarize(d_recs, slo, d_span)["ttft_p95_s"] > \
        summarize(r_recs, slo, r_span)["ttft_p95_s"]


def test_preemption_recovers():
    """Tiny KV pool forces preemptions; requests must still finish."""
    reqs = generate_trace(TRACES["loogle"], qps=3.0, duration_s=20, seed=7)
    serve = ServeConfig(mode="rapid", chips=32,
                        slo=SLOConfig(itl_ms=100.0), max_batch_slots=8,
                        max_seq_len=32768)
    eng = RapidEngine(CFG, serve)
    # shrink the pool to force pressure
    eng.kv = type(eng.kv)(num_blocks=4096, page_size=16)
    drive(eng, [copy.deepcopy(r) for r in reqs])
    assert all(r.done for r in eng.finished)
    assert len(eng.finished) == len(reqs)


# ---------------------------------------------------------------------------
# Adaptive Resource Manager (paper §4.5.3)
# ---------------------------------------------------------------------------


def test_profile_monotone():
    """Min f_d to meet the SLO grows with the decode batch size."""
    prof = build_decode_profile(CFG, TPU_V5E, 32, 0.1, 4096)
    fs = [prof.min_f[b] for b in prof.buckets]
    assert all(b >= a for a, b in zip(fs, fs[1:]))


def test_arm_switches_modes():
    from repro.core import AdaptiveResourceManager
    prof = build_decode_profile(CFG, TPU_V5E, 32, 0.02, 8192)
    arm = AdaptiveResourceManager(prof)
    lo = arm.allocate(max(1, prof.overalloc_bs_limit), True)
    assert lo.f_decode is None        # overallocation at low load
    hi = arm.allocate(256, True)
    if prof.overalloc_bs_limit < 256:
        assert hi.mode == "distinct" and hi.f_decode is not None
        assert hi.f_prefill == pytest.approx(1.0 - hi.f_decode)


@given(st.integers(1, 256), st.booleans())
@settings(max_examples=60, deadline=None)
def test_arm_total_never_oversubscribed(bs, prefill_active):
    """Distinct allocations always leave prefill a positive share."""
    from repro.core import AdaptiveResourceManager
    prof = build_decode_profile(CFG, TPU_V5E, 32, 0.05, 4096)
    arm = AdaptiveResourceManager(prof)
    a = arm.allocate(bs, prefill_active)
    if a.f_decode is not None:
        assert 0.0 < a.f_decode < 1.0
        assert 0.0 < a.f_prefill < 1.0
        assert a.f_decode + a.f_prefill == pytest.approx(1.0)
