"""Property-based wire-format round-trips (hypothesis).

Arbitrary events — including adversarial floats (subnormals, huge
magnitudes, negative zero) and unicode reason/class strings — must
survive ``event_to_json`` / ``event_from_json`` bit-identically, and
the JSON encoding must be a fixed point.  Needs ``hypothesis``
(dev-only dep); skipped at collection when absent (see conftest.py).
"""
import math

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.events import (FinishedEvent, PhaseEvent, RejectedEvent,
                               TokenEvent, event_from_json, event_to_json)

_t = st.floats(min_value=0.0, allow_nan=False, allow_infinity=False,
               width=64)
_rid = st.integers(min_value=0, max_value=2**53)
_small = st.integers(min_value=0, max_value=10**9)
_name = st.text(min_size=0, max_size=24)

_events = st.one_of(
    st.builds(TokenEvent, rid=_rid, t=_t, index=_small),
    st.builds(PhaseEvent, rid=_rid, t=_t, phase=_name),
    st.builds(FinishedEvent, rid=_rid, t=_t, arrival=_t,
              prompt_len=_small, output_len=_small, preemptions=_small,
              slo_class=_name, retries=_small, truncated=st.booleans()),
    st.builds(RejectedEvent, rid=_rid, t=_t, arrival=_t,
              prompt_len=_small, reason=_name, output_len=_small,
              preemptions=_small, slo_class=_name, retries=_small),
)


@settings(max_examples=300, deadline=None)
@given(ev=_events)
def test_wire_roundtrip_bit_identical(ev):
    line = event_to_json(ev)
    back = event_from_json(line)
    assert type(back) is type(ev)
    assert back == ev
    # float equality above is not enough for -0.0 vs 0.0; compare signs
    assert math.copysign(1.0, back.t) == math.copysign(1.0, ev.t)
    assert event_to_json(back) == line
