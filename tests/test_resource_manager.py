"""AdaptiveResourceManager.allocate: conservative extrapolation beyond
the largest profiled batch size (``distinct_clamped``), exact-boundary
lookups, pinned solo-regime corners, monotone solo -> overalloc ->
distinct mode transitions in decode_bs, and the build_decode_profile
crossover stopping at the FIRST SLO miss on non-monotone curves."""
import dataclasses

import pytest

from repro.config import get_reduced_config
from repro.core import resource_manager as rm
from repro.core.resource_manager import (BS_BUCKETS, F_GRID,
                                         AdaptiveResourceManager,
                                         DecodeProfile,
                                         build_decode_profile)
from repro.perfmodel.hw import TPU_V5E

MODE_ORDER = {"solo": 0, "overalloc": 1, "distinct": 2,
              "distinct_clamped": 3}


def _profile(overalloc_limit: int = 16) -> DecodeProfile:
    # synthetic but structurally faithful: min_f grows with bs
    min_f = {bs: min(0.9, 0.1 + 0.003 * bs) for bs in BS_BUCKETS}
    return DecodeProfile(list(BS_BUCKETS), min_f, overalloc_limit,
                         slo_itl_s=0.1)


def test_allocate_above_largest_bucket_extrapolates_conservatively():
    """bs > 256 has no profile data: decode must get F_GRID[-1] (not the
    last bucket's smaller f_d) and the clamp must be visible in mode."""
    arm = AdaptiveResourceManager(_profile())
    top = BS_BUCKETS[-1]
    for bs in (top + 1, top + 100, 10 * top):
        a = arm.allocate(bs, prefill_active=True)   # must not raise
        assert a.mode == "distinct_clamped"
        assert a.f_decode == F_GRID[-1]
        assert a.f_decode >= arm.profile.min_f[top]
    # the clamp is recorded in history, not silently folded into distinct
    assert [h.mode for h in arm.history] == ["distinct_clamped"] * 3


@pytest.mark.parametrize("bs", BS_BUCKETS)
def test_allocate_exact_bucket_boundaries(bs):
    arm = AdaptiveResourceManager(_profile(overalloc_limit=0))
    a = arm.allocate(bs, prefill_active=True)
    # an exact boundary must hit its own bucket, not the next one up
    assert a.f_decode == arm.profile.min_f[bs]
    assert a.f_prefill == pytest.approx(1.0 - a.f_decode)


def test_allocate_between_buckets_rounds_up():
    arm = AdaptiveResourceManager(_profile(overalloc_limit=0))
    # bs=65 falls between buckets 64 and 96: conservative => bucket 96
    a = arm.allocate(65, prefill_active=True)
    assert a.f_decode == arm.profile.min_f[96]


def test_mode_transitions_monotone_in_decode_bs():
    arm = AdaptiveResourceManager(_profile(overalloc_limit=16))
    seen = []
    for bs in range(0, 2 * BS_BUCKETS[-1] + 1):
        a = arm.allocate(bs, prefill_active=True)
        seen.append(MODE_ORDER[a.mode])
    assert seen == sorted(seen), "mode must be monotone in decode_bs"
    assert seen[0] == MODE_ORDER["solo"]          # bs == 0
    assert MODE_ORDER["overalloc"] in seen
    assert MODE_ORDER["distinct"] in seen
    assert seen[-1] == MODE_ORDER["distinct_clamped"]   # bs > top bucket


@pytest.mark.parametrize("boundary", [16, 17, 48, 49, 128, 129, 256])
def test_regime_switch_across_bucket_boundaries(boundary):
    """solo -> overalloc -> distinct regime edges at exact-bucket and
    between-bucket batch sizes around the crossover."""
    arm = AdaptiveResourceManager(_profile(overalloc_limit=16))
    a = arm.allocate(boundary, prefill_active=True)
    if boundary <= 16:
        assert a.mode == "overalloc" and a.f_decode is None
    else:
        assert a.mode == "distinct"
        import bisect
        bucket = BS_BUCKETS[bisect.bisect_left(BS_BUCKETS, boundary)]
        assert bucket >= boundary            # conservative: round UP
        assert a.f_decode == arm.profile.min_f[bucket]


def test_solo_whenever_prefill_idle():
    arm = AdaptiveResourceManager(_profile())
    for bs in (0, 1, 64, BS_BUCKETS[-1] + 5):
        assert arm.allocate(bs, prefill_active=False).mode == "solo"
        assert arm.allocate(bs, prefill_active=False).f_decode is None


def test_zero_decode_bs_corner_pinned():
    """decode_bs == 0 is solo under EVERY ordering of the other inputs —
    including prefill_active=True and a zero overalloc crossover, where
    the old branch order was the only thing keeping bs=0 out of the
    distinct-bucket lookup."""
    for limit in (0, 16):
        arm = AdaptiveResourceManager(_profile(overalloc_limit=limit))
        for prefill_active in (True, False):
            a = arm.allocate(0, prefill_active=prefill_active)
            assert a.mode == "solo"
            assert a.f_decode is None
            assert a.f_prefill == 1.0
    # negative batch sizes (defensive) also resolve to solo, not a
    # bisect into bucket 1
    assert arm.allocate(-1, prefill_active=True).mode == "solo"


def test_real_profile_clamps_and_is_consistent():
    cfg = get_reduced_config("llama3-70b")
    prof = build_decode_profile(cfg, TPU_V5E, chips=1, slo_itl_s=0.1,
                                avg_ctx=1024, tp=1)
    arm = AdaptiveResourceManager(prof)
    a = arm.allocate(BS_BUCKETS[-1] + 123, prefill_active=True)
    assert a.mode in ("overalloc", "distinct_clamped")
    if a.mode == "distinct_clamped":
        assert a.f_decode == F_GRID[-1]


def test_crossover_stops_at_first_slo_miss(monkeypatch):
    """A non-monotone interference curve (mid bs misses the SLO, larger
    bs passes again) must NOT re-open the overallocation regime above
    the first miss."""
    cfg = get_reduced_config("llama3-70b")
    slo = 0.1
    # synthetic overlapped-decode times: pass at bs<=4, miss at 8, then
    # "pass" again from 16 up (a non-monotone profile the old scan read
    # as overalloc_bs_limit == 256)
    def fake_overlapped(p_cost, d_cost, hw, chips, *, f_decode=None):
        bs = fake_overlapped.calls
        fake_overlapped.calls += 1
        t_d = slo / 2 if BS_BUCKETS[bs] != 8 else slo * 2
        return dataclasses.replace(
            rm.I.OverlapResult(0.0, 0.0, 0.5, 0.5, "overalloc"),
            t_decode=t_d)
    fake_overlapped.calls = 0
    monkeypatch.setattr(rm.I, "overlapped_times", fake_overlapped)
    prof = build_decode_profile(cfg, TPU_V5E, chips=1, slo_itl_s=slo,
                                avg_ctx=1024, tp=1)
    assert prof.overalloc_bs_limit == 4, (
        "crossover must stop at the first SLO miss (bs=8), not resume "
        "raising the limit when larger batches pass again")
    # and the runtime regime switch follows the fixed crossover
    arm = AdaptiveResourceManager(prof)
    assert arm.allocate(4, prefill_active=True).mode == "overalloc"
    assert arm.allocate(16, prefill_active=True).mode == "distinct"
