"""AdaptiveResourceManager.allocate: bucket clamping at and beyond the
largest profiled batch size, exact-boundary lookups, and monotone
solo -> overalloc -> distinct mode transitions in decode_bs."""
import pytest

from repro.config import get_reduced_config
from repro.core.resource_manager import (BS_BUCKETS,
                                         AdaptiveResourceManager,
                                         DecodeProfile,
                                         build_decode_profile)
from repro.perfmodel.hw import TPU_V5E

MODE_ORDER = {"solo": 0, "overalloc": 1, "distinct": 2}


def _profile(overalloc_limit: int = 16) -> DecodeProfile:
    # synthetic but structurally faithful: min_f grows with bs
    min_f = {bs: min(0.9, 0.1 + 0.003 * bs) for bs in BS_BUCKETS}
    return DecodeProfile(list(BS_BUCKETS), min_f, overalloc_limit,
                         slo_itl_s=0.1)


def test_allocate_above_largest_bucket_clamps():
    arm = AdaptiveResourceManager(_profile())
    top = BS_BUCKETS[-1]
    for bs in (top + 1, top + 100, 10 * top):
        a = arm.allocate(bs, prefill_active=True)   # must not raise
        assert a.mode == "distinct"
        assert a.f_decode == arm.profile.min_f[top]


@pytest.mark.parametrize("bs", BS_BUCKETS)
def test_allocate_exact_bucket_boundaries(bs):
    arm = AdaptiveResourceManager(_profile(overalloc_limit=0))
    a = arm.allocate(bs, prefill_active=True)
    # an exact boundary must hit its own bucket, not the next one up
    assert a.f_decode == arm.profile.min_f[bs]
    assert a.f_prefill == pytest.approx(1.0 - a.f_decode)


def test_allocate_between_buckets_rounds_up():
    arm = AdaptiveResourceManager(_profile(overalloc_limit=0))
    # bs=65 falls between buckets 64 and 96: conservative => bucket 96
    a = arm.allocate(65, prefill_active=True)
    assert a.f_decode == arm.profile.min_f[96]


def test_mode_transitions_monotone_in_decode_bs():
    arm = AdaptiveResourceManager(_profile(overalloc_limit=16))
    seen = []
    for bs in range(0, 2 * BS_BUCKETS[-1] + 1):
        a = arm.allocate(bs, prefill_active=True)
        seen.append(MODE_ORDER[a.mode])
    assert seen == sorted(seen), "mode must be monotone in decode_bs"
    assert seen[0] == MODE_ORDER["solo"]          # bs == 0
    assert MODE_ORDER["overalloc"] in seen
    assert seen[-1] == MODE_ORDER["distinct"]


def test_solo_whenever_prefill_idle():
    arm = AdaptiveResourceManager(_profile())
    for bs in (0, 1, 64, BS_BUCKETS[-1] + 5):
        assert arm.allocate(bs, prefill_active=False).mode == "solo"
        assert arm.allocate(bs, prefill_active=False).f_decode is None


def test_real_profile_clamps_and_is_consistent():
    cfg = get_reduced_config("llama3-70b")
    prof = build_decode_profile(cfg, TPU_V5E, chips=1, slo_itl_s=0.1,
                                avg_ctx=1024, tp=1)
    arm = AdaptiveResourceManager(prof)
    a = arm.allocate(BS_BUCKETS[-1] + 123, prefill_active=True)
    assert a.mode in ("overalloc", "distinct")
    if a.mode == "distinct":
        assert 0.0 < a.f_decode <= 0.9
