"""IndexedQueue (core/queues.py): ordering, O(1) ops, aggregates."""
import pytest

from repro.core.queues import IndexedQueue
from repro.core.request import Request
from repro.kvcache import kv_pages_for


def _r(rid, prompt=100, done=0, generated=0):
    r = Request(rid=rid, arrival=0.0, prompt_len=prompt, max_new_tokens=8)
    r.prefill_tokens_done = done
    r.tokens_generated = generated
    return r


def _assert_aggregates(q):
    members = list(q)
    assert q.prompt_tokens == sum(r.prompt_len for r in members)
    assert q.kv_pages == sum(kv_pages_for(r.prompt_len, q.page_size)
                             for r in members)


def test_fifo_order_and_appendleft():
    q = IndexedQueue(page_size=16)
    a, b, c = _r(1), _r(2), _r(3)
    q.append(a)
    q.append(b)
    q.appendleft(c)
    assert list(q) == [c, a, b]
    assert q[0] is c and q[-1] is b
    assert q.popleft() is c
    assert q.pop() is b
    assert list(q) == [a]


def test_remove_preserves_order_and_aggregates():
    q = IndexedQueue(page_size=16)
    reqs = [_r(i, prompt=10 * (i + 1)) for i in range(5)]
    for r in reqs:
        q.append(r)
    q.remove(reqs[2])
    assert list(q) == [reqs[0], reqs[1], reqs[3], reqs[4]]
    assert reqs[2] not in q and reqs[0] in q
    _assert_aggregates(q)
    assert len(q) == 4 and bool(q)


def test_duplicate_rid_rejected():
    q = IndexedQueue()
    q.append(_r(7))
    with pytest.raises(ValueError):
        q.append(_r(7))


def test_remove_absent_raises():
    q = IndexedQueue()
    q.append(_r(1))
    with pytest.raises(ValueError):
        q.remove(_r(2))
    # same rid, different object: must not silently remove the member
    with pytest.raises(ValueError):
        q.remove(_r(1))
    assert len(q) == 1


def test_pending_tokens_follow_chunk_progress():
    q = IndexedQueue(page_size=16)
    r = _r(1, prompt=1000)
    q.append(r)
    assert q.pending_prefill_tokens == 1000
    r.prefill_tokens_done += 300
    q.note_chunk_progress(r, 300)
    assert q.pending_prefill_tokens == 700
    # removal subtracts the *tracked* contribution, not a stale one
    q.remove(r)
    assert q.pending_prefill_tokens == 0
    assert q.prompt_tokens == 0 and q.kv_pages == 0


def test_ctx_tokens_follow_note_token():
    q = IndexedQueue()
    r = _r(1, prompt=50, generated=2)
    q.append(r)
    assert q.ctx_tokens == 52
    r.tokens_generated += 1
    q.note_token(r)
    assert q.ctx_tokens == 53
    q.remove(r)
    assert q.ctx_tokens == 0


def test_contribution_snapshot_survives_unnoted_mutation():
    """A field mutated while queued WITHOUT a note hook (e.g. a chunking
    request emitting its first token just before leaving the queue) must
    not corrupt the aggregate on removal."""
    q = IndexedQueue()
    r = _r(1, prompt=50)
    q.append(r)
    r.tokens_generated += 4          # no note_token on purpose
    q.remove(r)
    assert q.ctx_tokens == 0


def test_peek_empty_and_middle_index():
    q = IndexedQueue()
    with pytest.raises(IndexError):
        q[0]
    reqs = [_r(i) for i in range(4)]
    for r in reqs:
        q.append(r)
    assert q[1] is reqs[1] and q[-2] is reqs[2]
    with pytest.raises(IndexError):
        q[9]
