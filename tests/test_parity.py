"""Golden parity: the scheduler/executor engines (Serving API v2) must
reproduce the pre-split monolithic engines' per-request metrics EXACTLY.

tests/golden/engine_parity.json was recorded from the PR-2 engines
(commit bf5b531) on fixed traces: per-request ttft / itl_p95 / finish /
output_len / preemptions / rejected plus the total span, for all three
modes, including preemption-heavy and admission-rejection regimes.
JSON round-trips Python floats exactly (repr), so comparison is ``==``,
not approx."""
import copy
import json
import pathlib

import pytest

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core import drive, make_engine
from repro.kvcache import KVCacheManager
from repro.serving import TRACES, generate_trace

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" /
     "engine_parity.json").read_text())
CFG = get_config("llama3-70b")

STANDARD_POINTS = [(trace, qps, dur, seed)
                   for trace, qps, dur, seed in
                   [("lmsys", 6.0, 20.0, 3), ("arxiv", 4.0, 15.0, 11)]]


def _standard_serve(mode):
    return ServeConfig(mode=mode, chips=32, slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(16, 16), max_batch_slots=128)


def _assert_parity(key, eng, reqs):
    recs, span = drive(eng, [copy.deepcopy(r) for r in reqs])
    golden = GOLDEN[key]
    assert span == golden["span"], f"{key}: span diverged"
    assert len(recs) == len(golden["records"])
    for rec, want in zip(recs, golden["records"]):
        got = dict(rid=rec.rid, ttft=rec.ttft, itl_p95=rec.itl_p95,
                   finish=rec.finish, output_len=rec.output_len,
                   preemptions=rec.preemptions, rejected=rec.rejected)
        assert got == want, f"{key}: rid {rec.rid} diverged"


@pytest.mark.parametrize("mode", ["rapid", "hybrid", "disagg"])
@pytest.mark.parametrize("point", STANDARD_POINTS,
                         ids=[f"{t}-qps{q}" for t, q, _, _ in
                              STANDARD_POINTS])
def test_standard_trace_parity(mode, point):
    trace, qps, dur, seed = point
    reqs = generate_trace(TRACES[trace], qps=qps, duration_s=dur,
                          seed=seed)
    eng = make_engine(mode, CFG, _standard_serve(mode))
    _assert_parity(f"{mode}/{trace}@{qps}s{seed}", eng, reqs)


def test_rapid_preemption_parity():
    """Tiny pool => preemption + rejection paths must also be bit-equal."""
    serve = ServeConfig(mode="rapid", chips=32, slo=SLOConfig(itl_ms=100.0),
                        max_batch_slots=8, max_seq_len=32768)
    reqs = generate_trace(TRACES["loogle"], qps=3.0, duration_s=15, seed=7)
    eng = make_engine("rapid", CFG, serve)
    eng.kv = KVCacheManager(num_blocks=1500, page_size=16)
    _assert_parity("rapid/loogle-tinypool", eng, reqs)


def test_hybrid_preemption_parity():
    serve = ServeConfig(mode="hybrid", chips=32,
                        slo=SLOConfig(itl_ms=100.0), max_batch_slots=32)
    reqs = generate_trace(TRACES["loogle"], qps=3.0, duration_s=15, seed=7)
    eng = make_engine("hybrid", CFG, serve)
    eng.kv = KVCacheManager(num_blocks=1500, page_size=16)
    _assert_parity("hybrid/loogle-tinypool", eng, reqs)


def test_disagg_backpressure_parity():
    """Shrunken decode pool => admission retries + rejections bit-equal."""
    serve = ServeConfig(mode="disagg", chips=32,
                        slo=SLOConfig(itl_ms=100.0), disagg_split=(16, 16),
                        max_batch_slots=128)
    reqs = generate_trace(TRACES["loogle"], qps=3.0, duration_s=15, seed=9)
    eng = make_engine("disagg", CFG, serve)
    eng.kv = KVCacheManager(num_blocks=1500, page_size=16)
    _assert_parity("disagg/loogle-tinypool", eng, reqs)
