"""Projection-driven adaptive resource management at cluster scale:
per-pool LoadSnapshot fields, runtime pool growth (Engine.resize_lane),
independent P/D pool scaling and deficit-sized replica adds under
ProjectionPolicy, prefill-pool-aware admission for disagg targets, and
the parity guarantee that a cluster with projections disabled reproduces
the bare engine exactly."""
import copy

import pytest

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core import drive, make_engine
from repro.core.request import Request
from repro.kvcache import BlockAllocator, KVCacheManager, kv_pages_for
from repro.perfmodel import forecast_phase_times, prefill_cost
from repro.perfmodel.hw import TPU_V5E
from repro.serving import (TRACES, AdmissionController, AdmissionPolicy,
                           Cluster, ProjectionPolicy, ReplicaSpec,
                           generate_trace, parse_mix)

ARCH = "llama3-70b"


def _serve(mode="rapid", chips=32):
    return ServeConfig(mode=mode, chips=chips, slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(chips // 2, chips // 2),
                       max_batch_slots=128)


def _trace(qps=24.0, duration=20.0, seed=0):
    return generate_trace(TRACES["lmsys"], qps=qps, duration_s=duration,
                          seed=seed)


# ---------------------------------------------------------------------------
# per-pool LoadSnapshot fields
# ---------------------------------------------------------------------------


def test_disagg_snapshot_exposes_prefill_pool():
    cfg = get_config(ARCH)
    eng = make_engine("disagg", cfg, _serve("disagg"))
    s = eng.load_snapshot()
    assert s.prefill_kv_total_blocks == eng.kv_p.allocator.num_blocks > 0
    assert s.prefill_kv_free_blocks == s.prefill_kv_total_blocks
    assert s.prefill_kv_utilization == 0.0
    assert (s.chips_prefill, s.chips_decode) == (16, 16)
    # a queued prompt claims transient prefill pages before any launch
    eng.submit(Request(rid=0, arrival=0.0, prompt_len=640,
                       max_new_tokens=8))
    s2 = eng.load_snapshot()
    # submit() wakes the scheduler, which may launch the prefill at once;
    # the claim then shows as live pool pages instead of a queued claim
    ps = eng.serve.page_size
    claimed = s2.queued_prefill_kv_pages + \
        (s2.prefill_kv_total_blocks - s2.prefill_kv_free_blocks)
    assert claimed >= kv_pages_for(640, ps)


@pytest.mark.parametrize("mode", ["rapid", "hybrid"])
def test_colocated_snapshot_has_zero_prefill_pool(mode):
    cfg = get_config(ARCH)
    eng = make_engine(mode, cfg, _serve(mode))
    s = eng.load_snapshot()
    assert s.prefill_kv_total_blocks == 0
    assert s.queued_prefill_kv_pages == 0
    assert s.prefill_kv_utilization == 0.0
    assert s.chips_prefill == s.chips_decode == eng.serve.chips


# ---------------------------------------------------------------------------
# runtime pool growth
# ---------------------------------------------------------------------------


def test_block_allocator_grows_and_refuses_shrink():
    alloc = BlockAllocator(4)
    got = alloc.alloc(3)
    alloc.grow(4)
    assert alloc.num_blocks == 8 and alloc.free_count == 5
    more = alloc.alloc(5)
    assert len(set(got) | set(more)) == 8       # no duplicate block ids
    with pytest.raises(ValueError):
        alloc.grow(-1)
    mgr = KVCacheManager(2, 16)
    mgr.allocate_prompt(0, 32)
    mgr.grow(2)
    assert mgr.allocator.num_blocks == 4
    assert mgr.utilization == 0.5               # live KV untouched


def test_disagg_resize_lane_grows_one_pool_only():
    cfg = get_config(ARCH)
    eng = make_engine("disagg", cfg, _serve("disagg"))
    before = eng.load_snapshot()
    eng.resize_lane("prefill", 24)
    after = eng.load_snapshot()
    assert after.chips_prefill == 24 and after.chips_decode == 16
    assert after.prefill_kv_total_blocks > before.prefill_kv_total_blocks
    assert after.kv_total_blocks == before.kv_total_blocks  # decode pool
    assert eng.serve.chips == 40
    assert eng.serve.disagg_split == (24, 16)
    assert eng.executor.lane_chips["prefill"] == 24
    with pytest.raises(ValueError):
        eng.resize_lane("prefill", 8)           # pools only grow
    with pytest.raises(KeyError):
        eng.resize_lane("step", 8)


def test_colocated_resize_lane_refused():
    cfg = get_config(ARCH)
    eng = make_engine("rapid", cfg, _serve())
    with pytest.raises(NotImplementedError):
        eng.resize_lane("prefill", 64)


# ---------------------------------------------------------------------------
# per-pool ReplicaSpec / --mix syntax
# ---------------------------------------------------------------------------


def test_parse_mix_per_pool_syntax():
    specs = parse_mix("disagg:2x12+20,rapid:1x16")
    assert specs[0] == ReplicaSpec("disagg", chips_p=12, chips_d=20)
    assert specs[:2] == [specs[0]] * 2
    assert specs[2] == ReplicaSpec("rapid", chips=16)


def test_per_pool_replica_spec_builds_asymmetric_split():
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve("disagg"),
                      [ReplicaSpec("disagg", chips_p=12, chips_d=20)])
    eng = cluster.replicas[0].engine
    assert (eng.chips_p, eng.chips_d) == (12, 20)
    assert cluster.replicas[0].serve.chips == 32
    with pytest.raises(ValueError):
        Cluster(cfg, _serve("disagg"), [ReplicaSpec("disagg", chips_p=12)])
    # per-pool chips on a colocated mode is a misconfiguration, not a
    # silently-ignored disagg_split
    with pytest.raises(ValueError):
        Cluster(cfg, _serve(),
                [ReplicaSpec("rapid", chips_p=12, chips_d=20)])


def test_scale_up_clones_per_pool_spec():
    """Autoscaled replicas keep the mode's original per-pool chip shape
    instead of falling back to the base ServeConfig's split."""
    cfg = get_config(ARCH)
    pol = ProjectionPolicy(min_replicas=1, max_replicas=2)
    cluster = Cluster(cfg, _serve("disagg"),
                      [ReplicaSpec("disagg", chips_p=12, chips_d=20)],
                      scale=pol)
    cluster._scale_up_one()
    clone = cluster.replicas[1].engine
    assert (clone.chips_p, clone.chips_d) == (12, 20)


# ---------------------------------------------------------------------------
# ProjectionPolicy scaling behaviour
# ---------------------------------------------------------------------------


def test_projection_scales_before_first_slo_miss():
    """Under load clearly beyond one replica's capacity, the projection
    tick (queued backlog + arrival-rate surplus) must scale up at the
    FIRST check, even though no request has finished yet (the reactive
    attainment window is still empty then)."""
    cfg = get_config(ARCH)
    reqs = _trace(qps=48.0, duration=15.0)   # ~2x one replica's rate
    pol = ProjectionPolicy(min_replicas=1, max_replicas=3,
                           check_interval_s=2.0)
    cluster = Cluster(cfg, _serve(), ["rapid"], router="least_loaded",
                      scale=pol)
    recs, _ = cluster.run([copy.deepcopy(r) for r in reqs])
    ups = [t for t, a, _ in cluster._scale_events if a == "up"]
    assert ups and ups[0] == pytest.approx(pol.check_interval_s), \
        "projection must act on the first tick, before any SLO miss"
    assert 1 < cluster.num_replicas <= 3
    assert sum(1 for r in recs if r.finish is not None) == len(reqs)


def test_projection_grows_disagg_prefill_pool_independently():
    cfg = get_config(ARCH)
    reqs = _trace(qps=24.0, duration=15.0)
    pol = ProjectionPolicy(min_replicas=1, max_replicas=1,   # pools only
                           check_interval_s=2.0, pool_chip_step=4,
                           max_pool_chips=32)
    cluster = Cluster(cfg, _serve("disagg"), ["disagg"],
                      router="least_loaded", scale=pol)
    recs, _ = cluster.run([copy.deepcopy(r) for r in reqs])
    eng = cluster.replicas[0].engine
    pool_events = [(a, n) for _, a, n in cluster._scale_events
                   if a.startswith("pool_")]
    assert pool_events, "prefill-bound load must trigger pool growth"
    assert eng.chips_p > 16, "prefill pool grew"
    assert eng.chips_d == 16, "decode pool untouched"
    assert not any(a == "up" for _, a, _ in cluster._scale_events)
    assert sum(1 for r in recs if r.finish is not None) == len(reqs)


def test_projection_deficit_adds_multiple_replicas_per_tick():
    """A large projected capacity deficit is covered in ONE tick instead
    of dripping one replica per window."""
    cfg = get_config(ARCH)
    # a hot burst: inbound token rate many times one replica's prefill
    # throughput, so the capacity forecast demands several replicas
    reqs = [Request(rid=i, arrival=0.01 * i, prompt_len=8000,
                    max_new_tokens=32) for i in range(200)]
    pol = ProjectionPolicy(min_replicas=1, max_replicas=4,
                           check_interval_s=2.0)
    cluster = Cluster(cfg, _serve(), ["rapid"], router="least_loaded",
                      scale=pol)
    cluster.run([copy.deepcopy(r) for r in reqs])
    ups = [t for t, a, _ in cluster._scale_events if a == "up"]
    first_tick = [t for t in ups if t == pytest.approx(2.0)]
    assert len(first_tick) >= 2, \
        f"deficit-sized scale-up expected >=2 adds at t=2, got {ups}"


def test_projection_holds_fleet_under_comfortable_load():
    """Steady sub-capacity traffic must NOT read as pressure: only the
    surplus a replica cannot drain compounds over the horizon, so a
    fleet comfortably meeting SLO stays at min_replicas."""
    cfg = get_config(ARCH)
    reqs = _trace(qps=4.0, duration=30.0, seed=1)
    pol = ProjectionPolicy(min_replicas=1, max_replicas=4,
                           check_interval_s=2.0)
    cluster = Cluster(cfg, _serve(), ["rapid"], router="least_loaded",
                      scale=pol)
    recs, _ = cluster.run([copy.deepcopy(r) for r in reqs])
    assert cluster._scale_events == []
    assert cluster.num_replicas == 1
    assert sum(1 for r in recs if r.finish is not None) == len(reqs)


def test_projection_disabled_cluster_matches_bare_engine():
    """Golden-parity guarantee: with projections neutralized (no scale
    action possible) the cluster reproduces the bare engine exactly —
    the new per-pool snapshot fields and projection plumbing must be
    observation-only."""
    cfg = get_config(ARCH)
    reqs = generate_trace(TRACES["lmsys"], qps=6.0, duration_s=20.0,
                          seed=0)
    for mode in ("rapid", "disagg"):
        eng = make_engine(mode, cfg, _serve(mode))
        recs_bare, span_bare = drive(eng, [copy.deepcopy(r)
                                           for r in reqs])
        pol = ProjectionPolicy(min_replicas=1, max_replicas=1,
                               pool_scaling=False)
        cluster = Cluster(cfg, _serve(mode), [mode],
                          router="round_robin", scale=pol)
        recs_cl, _ = cluster.run([copy.deepcopy(r) for r in reqs])
        # per-request metrics must be bit-identical; the span is padded
        # by the final no-op scale tick (same as ScalePolicy), so it is
        # deliberately not compared
        assert recs_cl == recs_bare, f"{mode}: projections perturbed run"
        del span_bare


# ---------------------------------------------------------------------------
# prefill-pool-aware admission
# ---------------------------------------------------------------------------


class _FakeReplica:
    def __init__(self, snap, serve):
        self._snap = snap
        self.serve = serve

    def snapshot(self):
        return self._snap


def _snap(**kw):
    from repro.core.engines import LoadSnapshot
    base = dict(queued_requests=0, queued_prefill_tokens=0,
                running_decode=0, decode_ctx_tokens=0, kv_utilization=0.0,
                prefill_busy=False, decode_busy=False)
    base.update(kw)
    return LoadSnapshot(**base)


def test_admission_consults_prefill_pool_occupancy():
    """A disagg target whose decode pool has room but whose transient
    prefill pool is projected full must NOT be in the fit list."""
    serve = _serve("disagg")
    r = Request(rid=0, arrival=0.0, prompt_len=1600, max_new_tokens=16)
    ctl = AdmissionController(AdmissionPolicy(projected_output_frac=1.0))
    roomy_decode = dict(kv_free_blocks=10_000, kv_total_blocks=10_000)
    # prefill pool: 100 pages, 95 already claimed by queued prompts
    tight = _snap(**roomy_decode, prefill_kv_total_blocks=100,
                  prefill_kv_free_blocks=100, queued_prefill_kv_pages=95)
    open_ = _snap(**roomy_decode, prefill_kv_total_blocks=1000,
                  prefill_kv_free_blocks=1000)
    assert not ctl.fits(_FakeReplica(tight, serve), r)
    assert ctl.fits(_FakeReplica(open_, serve), r)
    # decode-only projection (the pre-fix behaviour) is still selectable
    legacy = AdmissionController(AdmissionPolicy(
        projected_output_frac=1.0, prefill_pool_aware=False))
    assert legacy.fits(_FakeReplica(tight, serve), r)


def test_admission_infeasible_for_prefill_pool():
    """A prompt that can never fit the prefill pool is rejected outright
    instead of being queued against a replica it can never start on."""
    serve = _serve("disagg")
    r = Request(rid=1, arrival=0.0, prompt_len=3200, max_new_tokens=4)
    ctl = AdmissionController(AdmissionPolicy())
    snap = _snap(kv_free_blocks=10_000, kv_total_blocks=10_000,
                 prefill_kv_total_blocks=100, prefill_kv_free_blocks=100)
    rep = _FakeReplica(snap, serve)
    assert not ctl.feasible(rep, r)
    verdict, fit, reason = ctl.decide(r, [rep], now=0.0)
    assert verdict == "reject" and fit is None
    assert reason == "never_fits"


def test_forecast_phase_times_split_vs_colocated():
    cfg = get_config(ARCH)
    p = prefill_cost(cfg, [4096], 16)
    from repro.perfmodel import decode_cost
    d = decode_cost(cfg, 32, 32 * 2048.0, 16)
    t_p_split, t_d_split = forecast_phase_times(
        p, d, TPU_V5E, 16, 16, colocated=False)
    t_p_co, t_d_co = forecast_phase_times(
        p, d, TPU_V5E, 16, 16, colocated=True)
    # split pools run interference-free; colocated phases slow each other
    assert t_p_split < t_p_co
    assert t_d_split < t_d_co
    # empty lanes cost nothing on split pools
    assert forecast_phase_times(None, d, TPU_V5E, 16, 16,
                                colocated=False)[0] == 0.0
