"""Perf-model consistency: analytic costs vs compiled HLO, interference
model shape (paper §3.1/§3.2/§3.4/Fig 7 calibration points)."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import get_config, get_reduced_config
from repro.perfmodel import costs as C
from repro.perfmodel import interference as I
from repro.perfmodel.hw import TPU_V5E

CFG = get_config("llama3-70b")
HW = TPU_V5E


def test_prefill_compute_bound_decode_memory_bound():
    """§3.3: the phases hit different roofline walls."""
    p = C.prefill_cost(CFG, [4096], tp=32)
    d = C.decode_cost(CFG, 64, 64 * 2048.0, tp=32)
    p_ai = p.flops / p.hbm_bytes
    d_ai = d.flops / d.hbm_bytes
    assert p_ai > HW.balance       # compute-bound
    assert d_ai < HW.balance       # bandwidth-bound


def test_chunking_tradeoff_matches_paper():
    """§3.1: chunk 1K vs 512 — higher throughput, higher per-step
    latency (paper: ~+20% thpt at ~+30% ITL on 8x MI300X).  The effect
    comes from amortizing the per-ITERATION fixed cost (host scheduling
    + launch) over more tokens; we include it at engine granularity.
    The exact percentages are hardware-ratio dependent (DESIGN.md §6)."""
    ctx, chips, sched = 4096, 256, 2e-3
    t512 = I.phase_time(C.chunk_prefill_cost(CFG, 512, ctx, chips),
                        HW, chips) + sched
    t1k = I.phase_time(C.chunk_prefill_cost(CFG, 1024, ctx, chips),
                       HW, chips) + sched
    thpt_gain = (1024 / t1k) / (512 / t512)
    itl_gain = t1k / t512
    assert 1.05 < thpt_gain < 1.8
    assert 1.1 < itl_gain < 2.1


def test_decode_insensitive_to_f_until_knee():
    """Fig 3b: decode holds performance down to ~40-50% compute, then
    degrades once the compute share starves it (large batch)."""
    d = C.decode_cost(CFG, 256, 256 * 2048.0, tp=32)
    t_full = I.phase_time(d, HW, 32, f=1.0)
    t_half = I.phase_time(d, HW, 32, f=0.5)
    assert t_half < 1.35 * t_full
    t_tenth = I.phase_time(d, HW, 32, f=0.1)
    assert t_tenth > 1.5 * t_full     # eventually compute-starved


def test_prefill_scales_with_f():
    """Fig 3a: prefill performance proportional to compute share."""
    p = C.prefill_cost(CFG, [4096], tp=32)
    t_full = I.phase_time(p, HW, 32, f=1.0)
    t_half = I.phase_time(p, HW, 32, f=0.5)
    assert t_half == pytest.approx(2 * t_full, rel=0.1)


def test_overalloc_degrades_with_batch():
    """Fig 7: P100-D100 decode latency grows with decode batch; distinct
    allocation caps it near the solo memory floor."""
    p = C.prefill_cost(CFG, [8192], tp=32)
    prev = 0.0
    for bs in (8, 32, 128, 256):
        d = C.decode_cost(CFG, bs, bs * 2048.0, tp=32)
        r = I.overlapped_times(p, d, HW, 32)
        assert r.t_decode >= prev * 0.999
        prev = r.t_decode
        solo = I.phase_time(d, HW, 32)
        distinct = I.overlapped_times(p, d, HW, 32, f_decode=0.5)
        assert distinct.t_decode <= r.t_decode * 1.05 or \
            r.t_decode < solo * 1.1


def test_kv_transfer_overhead_scale():
    """§3.2.1: KV transfer is a TTFT-scale cost for long prompts."""
    xfer = C.kv_transfer_bytes(CFG, 8000) / (50e9)
    prefill = I.phase_time(C.prefill_cost(CFG, [8000], 16), HW, 16)
    assert 0.05 < xfer / prefill < 5.0


def test_memory_interference_band():
    """§3.4: co-residency memory interference is a few percent."""
    assert 0.0 < I.MEM_INTERFERENCE_PREFILL <= 0.05
    assert 0.0 < I.MEM_INTERFERENCE_DECODE <= 0.05


def test_analytic_flops_vs_hlo():
    """Analytic decode/prefill FLOPs within 2x of XLA's cost analysis
    for the reduced model (keeps the simulator honest)."""
    cfg = get_reduced_config("granite-8b")
    from repro.models.transformer import init_model, forward
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 4, 64
    toks = jnp.zeros((B, S), jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    compiled = jax.jit(lambda p, t: forward(p, cfg, t, pos)).lower(
        params, toks).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    hlo_flops = float(ca.get("flops", 0))
    analytic = C.prefill_cost(cfg, [S] * B, tp=1).flops
    # HLO counts the lm-head + embed that analytic's 2*N*T includes too
    assert 0.4 < analytic / hlo_flops < 2.5


def test_eq1_kv_bytes():
    """Paper Eq (1): 2*L*H*D*E per token."""
    assert CFG.kv_bytes_per_token(2) == 2 * 80 * 8 * 128 * 2


# ---------------------------------------------------------------------------
# PR-5: memoized pricing must be invisible (same values, cheaper calls)
# ---------------------------------------------------------------------------


def test_cached_costs_equal_uncached():
    """The lru_cache layers must return exactly what a fresh computation
    returns — memoization changes cost, never values."""
    pts = [((CFG, (4096,), 32, 2), C._prefill_cost),
           ((CFG, 2048, 512, 32, 2), C.chunk_prefill_cost),
           ((CFG, 64, 64 * 2048.0, 32, 2), C.decode_cost)]
    for args, fn in pts:
        assert fn(*args) == fn.__wrapped__(*args)


def test_cached_costs_return_identical_objects():
    a = C.prefill_cost(CFG, [1024, 2048], tp=16)
    b = C.prefill_cost(CFG, (1024, 2048), tp=16)   # list/tuple same key
    assert a is b
    d1 = C.decode_cost(CFG, 32, 32 * 1000.0, 16)
    d2 = C.decode_cost(CFG, 32, 32 * 1000.0, 16)
    assert d1 is d2


def test_forecast_phase_times_memoized_and_exact():
    p = C.prefill_cost(CFG, [4096], 16)
    d = C.decode_cost(CFG, 8, 8 * 1024.0, 16)
    got = I.forecast_phase_times(p, d, HW, 16, 16, colocated=False)
    again = I.forecast_phase_times(p, d, HW, 16, 16, colocated=False)
    assert got is again
    want = (I.phase_time(p, HW, 16), I.phase_time(d, HW, 16))
    assert got == want


def test_cached_decode_profile_shared_and_equal():
    from repro.core.resource_manager import (build_decode_profile,
                                             cached_decode_profile)
    cfg = get_reduced_config("llama3-70b")
    a = cached_decode_profile(cfg, HW, 1, 0.1, 1024, tp=1)
    b = cached_decode_profile(cfg, HW, 1, 0.1, 1024, tp=1)
    assert a is b                                   # one shared profile
    fresh = build_decode_profile(cfg, HW, 1, 0.1, 1024, tp=1)
    assert a == fresh                               # and it is the real one


def test_config_derived_scalars_memo_invisible():
    """The __dict__ memos on ModelConfig must not leak into config
    identity (equality/hash are field-based)."""
    import dataclasses
    cfg2 = dataclasses.replace(CFG)
    assert CFG.param_count() == cfg2.param_count()
    assert CFG.attn_layer_count == cfg2.attn_layer_count
    assert CFG.kv_bytes_per_token() == cfg2.kv_bytes_per_token()
    assert CFG.state_bytes_per_seq() == cfg2.state_bytes_per_seq()
    assert cfg2 == CFG and hash(cfg2) == hash(CFG)


def test_percentile_linear_matches_numpy():
    import random

    import numpy as np

    from repro.serving.metrics import percentile_linear
    rng = random.Random(7)
    for _ in range(2000):
        n = rng.randint(1, 50)
        vals = [rng.uniform(0.0, 1.0) * 10 ** rng.randint(-4, 4)
                for _ in range(n)]
        for q in (50, 95, 99):
            assert percentile_linear(vals, q) == \
                float(np.percentile(vals, q))
