"""Chaos property suite (hypothesis; skipped when it is not installed).

The gateway's robustness contract, stated once and checked under
*arbitrary* deterministic fault schedules drawn by hypothesis:

    Every accepted request terminates **exactly once** — one terminal
    ``finished`` / ``rejected`` / ``cancelled`` event, as the last event
    on its stream — no matter what combination of worker crashes,
    replacement workers, heartbeat flaps, wire loss/corruption, consumer
    stalls and client cancels the schedule throws at it.

Supporting invariants ride along: every consumer sees one contiguous
token-index prefix (the channel dedupes failover replay and discards
out-of-order wire survivors), ``worker_lost`` rejections report exactly
the partial output the client actually received, and the fleet metrics
account for every request exactly once.

Fault times, fleet shape and the checkpoint interval are all drawn by
hypothesis, but each individual run is bit-deterministic (simulated
clock), so every shrunk counterexample replays.
"""
import hypothesis.strategies as st
from hypothesis import HealthCheck, given, settings

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core.events import (CancelledEvent, FinishedEvent, RejectedEvent,
                               TERMINAL_EVENTS, TokenEvent)
from repro.core.request import Request
from repro.serving import Fault, FaultInjector, FaultPlan, Gateway, \
    GatewayPolicy

CFG = get_config("llama3-70b")
N_WORKERS = 2
N_RIDS = 5            # requests per run (rids 0..N_RIDS-1)


def _serve(chips=16):
    return ServeConfig(mode="rapid", chips=chips,
                       slo=SLOConfig(itl_ms=100.0), chunk_size=512,
                       disagg_split=(chips // 2, chips // 2),
                       max_batch_slots=64)


_T = st.floats(min_value=0.05, max_value=4.0)
_WID = st.integers(min_value=0, max_value=N_WORKERS)    # may not exist: ok
_RID = st.integers(min_value=-1, max_value=N_RIDS - 1)

_FAULT = st.one_of(
    st.builds(Fault, kind=st.just("crash"), t=_T, wid=_WID),
    st.builds(Fault, kind=st.just("restart"), t=_T),
    st.builds(Fault, kind=st.just("flap"), t=_T, wid=_WID,
              count=st.integers(min_value=1, max_value=6)),
    st.builds(Fault, kind=st.just("drop"), t=_T, rid=_RID,
              count=st.integers(min_value=1, max_value=4)),
    st.builds(Fault, kind=st.just("corrupt"), t=_T, rid=_RID,
              count=st.integers(min_value=1, max_value=4)),
    st.builds(Fault, kind=st.just("stall"), t=_T,
              rid=st.integers(min_value=0, max_value=N_RIDS - 1),
              duration=st.floats(min_value=0.1, max_value=2.0)),
)

_PLAN = st.lists(_FAULT, max_size=6).map(
    lambda fs: FaultPlan(tuple(sorted(fs, key=lambda f: f.t))))

_CANCELS = st.lists(
    st.tuples(st.integers(min_value=0, max_value=N_RIDS - 1),
              st.floats(min_value=0.1, max_value=3.0)),
    max_size=2)


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(plan=_PLAN, cancels=_CANCELS,
       interval=st.sampled_from([0, 16]),
       max_new=st.integers(min_value=20, max_value=80))
def test_every_accepted_request_terminates_exactly_once(
        plan, cancels, interval, max_new):
    gw = Gateway(CFG, _serve(), modes=["rapid"] * N_WORKERS,
                 router="round_robin",
                 policy=GatewayPolicy(checkpoint_interval=interval))
    FaultInjector(gw, plan).arm()
    for rid, t in cancels:
        gw.clock.at(t, lambda rid=rid: gw.cancel(rid))
    seen = {}
    reqs = [Request(rid=i, arrival=0.05 * i, prompt_len=128,
                    max_new_tokens=max_new) for i in range(N_RIDS)]
    gw._expected = len(reqs)
    for r in reqs:
        def go(r=r):
            seen[r.rid] = []
            gw.submit(r, consumer=seen[r.rid].append)
        gw.clock.at(r.arrival, go)
    gw.clock.run()           # termination of the sim loop IS liveness

    assert set(seen) == set(range(N_RIDS))
    lossy = any(f.kind in ("drop", "corrupt") for f in plan)
    for rid, evs in seen.items():
        terminals = [e for e in evs if isinstance(e, TERMINAL_EVENTS)]
        # the contract: exactly one terminal, and nothing after it
        assert len(terminals) == 1, (rid, [type(e).__name__ for e in evs])
        assert evs[-1] is terminals[0], rid
        term = terminals[0]
        idxs = [e.index for e in evs if isinstance(e, TokenEvent)]
        # contiguous prefix: dedupe kills replays, wire loss only thins
        # the tail (later survivors are discarded as out-of-order)
        assert idxs == list(range(len(idxs))), (rid, idxs)
        if isinstance(term, (RejectedEvent, CancelledEvent)):
            # partial progress reported = tokens actually delivered
            assert term.output_len == len(idxs), rid
        else:
            assert isinstance(term, FinishedEvent)
            assert term.output_len == max_new, rid
            if not lossy:
                assert len(idxs) == max_new, rid
    # fleet accounting: each request exactly once
    recs = [r for r in gw.metrics.records]
    assert sorted(r.rid for r in recs) == list(range(N_RIDS))
    fleet = gw.metrics_summary()["fleet"]
    assert (fleet["completed"] + fleet["rejected"] + fleet["cancelled"]
            == N_RIDS)


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(min_value=0, max_value=10_000),
       crashes=st.integers(min_value=1, max_value=4))
def test_crash_storms_lose_nothing_and_checkpoints_bound_replay(
        seed, crashes):
    """Pure crash storms (always with replacement workers, so failover
    targets exist): nothing is lost, and the checkpointed arm never
    replays more than the re-prefill arm on the identical storm."""
    replayed = {}
    for interval in (0, 16):
        gw = Gateway(CFG, _serve(), modes=["rapid"] * (N_WORKERS + 1),
                     router="round_robin",
                     policy=GatewayPolicy(checkpoint_interval=interval))
        plan = FaultPlan.crash_storm(seed=seed, workers=N_WORKERS + 1,
                                     t0=0.5, t1=4.0, crashes=crashes,
                                     restart_after=1.0)
        FaultInjector(gw, plan).arm()
        reqs = [Request(rid=i, arrival=0.05 * i, prompt_len=128,
                        max_new_tokens=80) for i in range(N_RIDS)]
        recs, _ = gw.serve_trace(reqs)
        assert len(recs) == N_RIDS
        assert sorted(r.rid for r in recs) == list(range(N_RIDS))
        fleet = gw.metrics_summary()["fleet"]
        assert fleet["completed"] + fleet["rejected"] == N_RIDS
        replayed[interval] = gw.replayed_tokens
    assert replayed[16] <= replayed[0]
