"""Per-arch smoke tests (reduced configs) + decode/forward consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import get_reduced_config, list_archs
from repro.models.transformer import (decode_forward, forward, greedy_sample,
                                      init_cache, init_model, lm_loss,
                                      write_prefill_to_cache)

ARCHS = list_archs()


def _inputs(cfg, rng, B, S):
    if cfg.frontend == "embed_stub":
        x = jax.random.normal(rng, (B, S, cfg.d_model), jnp.float32)
    else:
        x = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    if cfg.rope_type == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, 3))
    else:
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return x, pos


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(rng, arch):
    """One forward step on the reduced config: shapes + no NaNs."""
    cfg = get_reduced_config(arch)
    params, specs = init_model(rng, cfg, tp=1)
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda s: isinstance(s, tuple))
    B, S = 2, 16
    x, pos = _inputs(cfg, rng, B, S)
    logits = forward(params, cfg, x, pos, 1)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(rng, arch):
    """One train step: finite loss, grads flow to every layer leaf."""
    cfg = get_reduced_config(arch)
    params, _ = init_model(rng, cfg, tp=1)
    B, S = 2, 16
    x, pos = _inputs(cfg, rng, B, S)
    if cfg.frontend == "embed_stub":
        labels = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    else:
        labels = jnp.roll(x, -1, axis=1)
    loss, grads = jax.value_and_grad(lm_loss)(params, cfg, x, labels, pos)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads["layers"]))
    assert gn > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(rng, arch):
    """Prefill->cache->decode next-token logits == full forward logits."""
    cfg = get_reduced_config(arch)
    params, _ = init_model(rng, cfg, tp=1)
    B, S = 2, 16
    x, pos = _inputs(cfg, rng, B, S)
    logits, aux = forward(params, cfg, x, pos, 1, return_aux=True)
    cache = init_cache(cfg, B, 32, 1)
    cache = write_prefill_to_cache(cfg, cache, aux, S)
    seq_lens = jnp.full((B,), S, jnp.int32)
    if cfg.frontend == "embed_stub":
        nxt = jax.random.normal(jax.random.PRNGKey(1), (B, 1, cfg.d_model),
                                jnp.float32)
        full_in = jnp.concatenate([x, nxt], axis=1)
    else:
        nxt = greedy_sample(logits[:, -1:], cfg.vocab_size)
        full_in = jnp.concatenate([x, nxt], axis=1)
    if cfg.rope_type == "mrope":
        dpos = jnp.broadcast_to(
            jnp.full((1, 1, 1), S), (B, 1, 3)).astype(jnp.int32)
        fpos = jnp.broadcast_to(jnp.arange(S + 1)[None, :, None],
                                (B, S + 1, 3))
    else:
        dpos = jnp.full((B, 1), S, jnp.int32)
        fpos = jnp.broadcast_to(jnp.arange(S + 1)[None], (B, S + 1))
    dl, _ = decode_forward(params, cfg, nxt, dpos, cache, seq_lens, 1)
    # reference = the inference-mode forward (return_aux=True): both use
    # the no-drop MoE capacity policy; the training path drops tokens
    fl, _ = forward(params, cfg, full_in, fpos, 1, return_aux=True)
    a = dl[:, 0, :cfg.vocab_size].astype(jnp.float32)
    b = fl[:, -1, :cfg.vocab_size].astype(jnp.float32)
    # bf16 models accumulate rounding differences between the two paths;
    # compare with a scale-aware tolerance
    scale = float(jnp.std(b)) + 1e-6
    assert float(jnp.max(jnp.abs(a - b))) / scale < 0.25, arch


def test_multi_token_greedy_decode(rng):
    """Decode 6 tokens greedily == teacher-forced full forward argmax."""
    cfg = get_reduced_config("granite-8b")
    params, _ = init_model(rng, cfg, tp=1)
    B, S, T = 1, 8, 6
    x, pos = _inputs(cfg, rng, B, S)
    logits, aux = forward(params, cfg, x, pos, 1, return_aux=True)
    cache = init_cache(cfg, B, S + T + 2, 1)
    cache = write_prefill_to_cache(cfg, cache, aux, S)
    toks = [int(greedy_sample(logits[:, -1:], cfg.vocab_size)[0, 0])]
    seq = x
    seq_lens = jnp.full((B,), S, jnp.int32)
    cur = greedy_sample(logits[:, -1:], cfg.vocab_size)
    for t in range(T - 1):
        dpos = (seq_lens[:, None]).astype(jnp.int32)
        dl, cache = decode_forward(params, cfg, cur, dpos, cache,
                                   seq_lens, 1)
        seq_lens = seq_lens + 1
        cur = greedy_sample(dl, cfg.vocab_size)
        toks.append(int(cur[0, 0]))
    # teacher-forced reference (inference-mode forward)
    full = jnp.concatenate(
        [x, jnp.array(toks[:-1], jnp.int32)[None]], axis=1)
    fpos = jnp.broadcast_to(jnp.arange(full.shape[1])[None],
                            (B, full.shape[1]))
    fl, _ = forward(params, cfg, full, fpos, 1, return_aux=True)
    want = [int(t) for t in
            jnp.argmax(fl[0, S - 1:, :cfg.vocab_size], -1)]
    assert toks == want


def test_sliding_window_ring_cache(rng):
    """Mixtral ring cache: context beyond the window is evicted but
    decode still matches full forward (which also only sees the window)."""
    cfg = get_reduced_config("mixtral-8x7b")   # window 16
    params, _ = init_model(rng, cfg, tp=1)
    B, S = 1, 24   # S > window
    x, pos = _inputs(cfg, rng, B, S)
    logits, aux = forward(params, cfg, x, pos, 1, return_aux=True)
    cache = init_cache(cfg, B, 64, 1)
    assert cache["pos0"]["k"].shape[2] == cfg.sliding_window
    cache = write_prefill_to_cache(cfg, cache, aux, S)
    nxt = greedy_sample(logits[:, -1:], cfg.vocab_size)
    dl, _ = decode_forward(params, cfg, nxt,
                           jnp.full((B, 1), S, jnp.int32), cache,
                           jnp.full((B,), S, jnp.int32), 1)
    full_in = jnp.concatenate([x, nxt], axis=1)
    fpos = jnp.broadcast_to(jnp.arange(S + 1)[None], (B, S + 1))
    fl, _ = forward(params, cfg, full_in, fpos, 1, return_aux=True)
    a = dl[0, 0, :cfg.vocab_size].astype(jnp.float32)
    b = fl[0, -1, :cfg.vocab_size].astype(jnp.float32)
    scale = float(jnp.std(b)) + 1e-6
    assert float(jnp.max(jnp.abs(a - b))) / scale < 0.25


def test_param_count_sanity():
    """Analytic param counts are in the advertised ballpark."""
    from repro.config import get_config
    expect = {"llama3-70b": 70e9, "mixtral-8x7b": 47e9,
              "qwen3-moe-235b-a22b": 235e9, "granite-8b": 8e9,
              "jamba-1.5-large-398b": 398e9, "xlstm-125m": 125e6,
              "mixtral-8x22b": 141e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.7 * n < got < 1.35 * n, (arch, got, n)


def test_moe_active_params():
    from repro.config import get_config
    cfg = get_config("qwen3-moe-235b-a22b")
    active = cfg.active_param_count()
    assert 15e9 < active < 30e9   # the "A22B" in the name
