"""Training substrate: descent, checkpoint/restart, elastic reshard,
gradient compression, data-pipeline determinism."""
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import get_reduced_config
from repro.data import TokenPipeline
from repro.training.checkpoint import (CheckpointManager, restore_checkpoint,
                                       save_checkpoint)
from repro.training.compression import (compress_gradients,
                                        decompress_gradients)
from repro.training.optimizer import OptConfig, wsd_schedule
from repro.training.resilience import (FailureEvent, HeartbeatMonitor,
                                       StragglerDetector, TrainingSupervisor)
from repro.training.train_lib import init_train_state, make_train_step

CFG = get_reduced_config("granite-8b")
OPT = OptConfig(lr=3e-3, warmup_steps=5, stable_steps=100, decay_steps=10)


def _batch(pipe, B, S):
    x, y = pipe.next_batch()
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return {"inputs": jnp.asarray(x), "labels": jnp.asarray(y),
            "positions": pos}


def test_loss_descends(rng):
    state = init_train_state(rng, CFG, OPT)
    step = jax.jit(make_train_step(CFG, OPT, microbatches=2))
    pipe = TokenPipeline(CFG.vocab_size, 4, 32, seed=0)
    losses = []
    for _ in range(25):
        state, m = step(state, _batch(pipe, 4, 32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5
    assert all(np.isfinite(losses))


def test_microbatching_equivalence(rng):
    """mb=1 and mb=4 produce (nearly) identical updates."""
    s1 = init_train_state(rng, CFG, OPT)
    s2 = init_train_state(rng, CFG, OPT)
    pipe = TokenPipeline(CFG.vocab_size, 4, 32, seed=3)
    batch = _batch(pipe, 4, 32)
    f1 = jax.jit(make_train_step(CFG, OPT, microbatches=1))
    f4 = jax.jit(make_train_step(CFG, OPT, microbatches=4))
    s1, m1 = f1(s1, batch)
    s2, m4 = f4(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-2)
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s1.params, s2.params)
    assert max(jax.tree.leaves(d)) < 0.1   # bf16 params, small drift


def test_wsd_schedule():
    opt = OptConfig(lr=1.0, warmup_steps=10, stable_steps=100,
                    decay_steps=50, min_lr_frac=0.1)
    assert float(wsd_schedule(5, opt)) == pytest.approx(0.5)
    assert float(wsd_schedule(50, opt)) == pytest.approx(1.0)
    assert float(wsd_schedule(160, opt)) == pytest.approx(0.1, abs=1e-6)


def test_checkpoint_roundtrip(rng):
    state = init_train_state(rng, CFG, OPT)
    d = tempfile.mkdtemp()
    try:
        save_checkpoint(d, 7, state)
        restored = restore_checkpoint(d, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        shutil.rmtree(d)


def test_checkpoint_atomic_and_retention(rng):
    state = init_train_state(rng, CFG, OPT)
    d = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(d, keep=2, async_save=True)
        for s in (1, 2, 3, 4):
            mgr.save(s, state)
        mgr.wait()
        kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert kept == ["step_00000003", "step_00000004"]
        assert not any(x.endswith(".tmp") for x in os.listdir(d))
    finally:
        shutil.rmtree(d)


def test_failure_restart_continuity(rng):
    """Supervisor restarts from the checkpoint and final loss still
    descends below the pre-failure level."""
    state = init_train_state(rng, CFG, OPT)
    step = jax.jit(make_train_step(CFG, OPT, microbatches=1))
    d = tempfile.mkdtemp()
    try:
        mgr = CheckpointManager(d, keep=2, async_save=False)
        sup = TrainingSupervisor(step, mgr, ckpt_every=5)
        pipe = TokenPipeline(CFG.vocab_size, 4, 32, seed=1)
        batches = [_batch(pipe, 4, 32) for _ in range(20)]
        out = sup.run(state, batches, failures=[FailureEvent(step=12)])
        assert sup.restarts == 1
        steps = [e for e in sup.log if e["event"] == "step"]
        assert steps[-1]["loss"] < steps[0]["loss"]
        assert int(out.step) >= 15
    finally:
        shutil.rmtree(d)


def test_elastic_restore_changes_placement(rng):
    """Restore under a different sharding (elastic mesh change)."""
    state = init_train_state(rng, CFG, OPT)
    d = tempfile.mkdtemp()
    try:
        save_checkpoint(d, 1, state)
        mesh = jax.make_mesh((1,), ("data",))
        from jax.sharding import NamedSharding, PartitionSpec as P
        shardings = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), state)
        restored = restore_checkpoint(d, state, shardings=shardings)
        leaf = jax.tree.leaves(restored)[0]
        assert isinstance(leaf.sharding, NamedSharding)
    finally:
        shutil.rmtree(d)


# ---------------------------------------------------------------------------
# resilience primitives
# ---------------------------------------------------------------------------


def test_heartbeat_monitor():
    t = [0.0]
    mon = HeartbeatMonitor(["w0", "w1"], timeout_s=10, clock=lambda: t[0])
    t[0] = 5.0
    mon.beat("w0")
    t[0] = 12.0
    assert mon.dead_workers() == ["w1"]


def test_straggler_detector():
    det = StragglerDetector(threshold=1.5, patience=2)
    assert det.observe({"a": 1.0, "b": 1.0, "c": 2.0}) == []
    assert det.observe({"a": 1.0, "b": 1.0, "c": 2.0}) == ["c"]
    assert det.observe({"a": 1.0, "b": 1.0, "c": 1.0}) == []


# ---------------------------------------------------------------------------
# compression + data pipeline
# ---------------------------------------------------------------------------


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([17, 256, 1000, 4096]))
@settings(max_examples=30, deadline=None)
def test_compression_bounded_error(seed, n):
    g = jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32)
    out = decompress_gradients(compress_gradients({"g": g}))["g"]
    assert out.shape == g.shape
    err = float(jnp.max(jnp.abs(out - g)))
    assert err <= float(jnp.max(jnp.abs(g))) / 127.0 + 1e-7


def test_training_with_compression_descends(rng):
    state = init_train_state(rng, CFG, OPT)
    step = jax.jit(make_train_step(CFG, OPT, microbatches=1,
                                   compress_grads=True))
    pipe = TokenPipeline(CFG.vocab_size, 4, 32, seed=2)
    losses = []
    for _ in range(15):
        state, m = step(state, _batch(pipe, 4, 32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3


@given(st.integers(0, 100), st.integers(1, 20))
@settings(max_examples=20, deadline=None)
def test_pipeline_restore_exact(start, n):
    """After restore(state) the stream continues identically."""
    p1 = TokenPipeline(1000, 2, 16, seed=9)
    for _ in range(start):
        p1.next_batch()
    snap = p1.state
    want = [p1.next_batch() for _ in range(n)]
    p2 = TokenPipeline(1000, 2, 16, seed=9)
    p2.restore(snap)
    got = [p2.next_batch() for _ in range(n)]
    for (a1, b1), (a2, b2) in zip(want, got):
        np.testing.assert_array_equal(a1, a2)
        np.testing.assert_array_equal(b1, b2)
