"""Property-based liveness: every submitted request terminates.

Hypothesis throws arbitrary multi-class, sessionful traces — servable
prompts, pool-oversized prompts, and (disagg only) prompts in the
"prompt fits but prompt+output never will" band — at each engine mode
and asserts the loop drains with every request in a terminal state:
FINISHED with exactly ``max_new_tokens`` tokens, or REJECTED with
``reject_reason == "never_fits"``.  This is the regression net for
ROADMAP item 5's two failure shapes: on disagg, a band request running
alone used to self-preempt on every decode step forever (fixed by the
lifetime admission check — now a ``never_fits`` rejection); on the
colocated modes it used to stall single-request decode (fixed by
admission-time output truncation — ``max_new_tokens`` is capped so
prompt+output fits the pool and the record carries ``truncated=True``),
so the band now runs everywhere.

This module needs ``hypothesis`` (dev-only dep) and is skipped at
collection when absent (see conftest.py).
"""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core import make_engine
from repro.core.request import Request, State
from repro.kvcache import KVCacheManager

CFG = get_config("llama3-70b")

TINY_BLOCKS = 64
PAGE = 16
POOL_TOKENS = TINY_BLOCKS * PAGE
MAX_OUT = 12

# servable (prompt + worst-case output fits) and oversized (prompt alone
# never fits) bands, plus the in-between band — prompt fits, prompt +
# output does not.  The band used to livelock disagg (self-preemption)
# and stall colocated single-request decode; lifetime admission now
# rejects it on disagg and truncates it on rapid/hybrid, so every mode
# draws from all three bands.
_safe = st.one_of(st.integers(16, POOL_TOKENS - MAX_OUT),
                  st.integers(POOL_TOKENS + 1, 1200))
_band = st.integers(POOL_TOKENS - MAX_OUT + 1, POOL_TOKENS)

_klass = st.sampled_from(["interactive", "batch", "best_effort"])
_session = st.one_of(st.none(), st.sampled_from(["sa", "sb"]))


def _serve(mode):
    return ServeConfig(mode=mode, chips=32, slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(16, 16), max_batch_slots=4,
                       max_seq_len=32768)


def _engine(mode):
    eng = make_engine(mode, CFG, _serve(mode))
    # give colocated engines a session budget so parked-prefix adoption
    # and LRU eviction run under real pool pressure; disagg keeps its
    # sessionless split pools
    budget = 0 if eng.kv_p is not None else 16
    eng.kv = KVCacheManager(num_blocks=TINY_BLOCKS, page_size=PAGE,
                            session_cache_blocks=budget)
    if eng.kv_p is not None:
        eng.kv_p = KVCacheManager(num_blocks=TINY_BLOCKS, page_size=PAGE)
    return eng


def _req(mode, rid, draw):
    prompt_st = st.one_of(_safe, _band)
    return Request(rid=rid, arrival=0.0,
                   prompt_len=draw(prompt_st),
                   max_new_tokens=draw(st.integers(1, MAX_OUT)),
                   slo_class=draw(_klass),
                   session_id=draw(_session),
                   cached_prefix_len=draw(st.integers(0, 64)))


@pytest.mark.parametrize("mode", ["rapid", "hybrid", "disagg"])
@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_every_request_terminates(mode, data):
    eng = _engine(mode)
    n = data.draw(st.integers(1, 10))
    reqs = [_req(mode, i, data.draw) for i in range(n)]
    for r in reqs:
        eng.submit(r)
    eng.loop.run()
    for r in reqs:
        assert r.state in (State.FINISHED, State.REJECTED), \
            (mode, r.rid, r.state)
        if r.state is State.REJECTED:
            assert r.reject_reason == "never_fits"
        else:
            assert r.tokens_generated == r.max_new_tokens
            # prefix-skip conservation holds even under preemption and
            # re-prefill (preempt zeroes the prefix claim with the KV)
            assert r.prefill_tokens_done + r.cached_prefix_len == \
                r.prompt_len
