"""Fault injection, retry policy, and KV checkpoint-resume recovery.

Everything here runs on the simulated clock, so crash timing, snapshot
commits and failover replay counts are bit-deterministic.  The headline
golden test pins the PR's bounded-replay guarantee: a crash mid-decode
with ``checkpoint_interval=N`` re-computes **at most N tokens** (the
channel's ``dup_tokens`` counts exactly the replayed indices), where the
re-prefill fallback replays the full generated prefix.
"""
import pytest

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core.events import (CancelledEvent, FinishedEvent, PhaseEvent,
                               RejectedEvent, TokenEvent)
from repro.core.request import Request
from repro.kvcache import CheckpointStore, KVCheckpoint
from repro.serving import (Fault, FaultInjector, FaultPlan, Gateway,
                           GatewayPolicy, RetryPolicy, line_corruptor)

CFG = get_config("llama3-70b")


def _serve(chips=16):
    return ServeConfig(mode="rapid", chips=chips,
                       slo=SLOConfig(itl_ms=100.0), chunk_size=512,
                       disagg_split=(chips // 2, chips // 2),
                       max_batch_slots=64)


def _tokens(evs):
    return [e.index for e in evs if isinstance(e, TokenEvent)]


def _phases(evs, name):
    return [e for e in evs if isinstance(e, PhaseEvent) and e.phase == name]


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------

def test_retry_policy_truncated_exponential_backoff():
    p = RetryPolicy(max_retries=3, backoff_base_s=0.1, backoff_mult=2.0,
                    backoff_max_s=0.35)
    assert p.delay(0) == 0.0
    assert p.delay(1) == pytest.approx(0.1)
    assert p.delay(2) == pytest.approx(0.2)
    assert p.delay(3) == pytest.approx(0.35)      # capped
    assert p.delay(9) == pytest.approx(0.35)


# ---------------------------------------------------------------------------
# checkpoint store
# ---------------------------------------------------------------------------

def test_checkpoint_store_newest_wins_and_budget():
    store = CheckpointStore(page_size=16, budget_blocks=40)
    assert store.put(KVCheckpoint(rid=0, generated=32, kv_tokens=256, t=1.0))
    assert store.put(KVCheckpoint(rid=0, generated=64, kv_tokens=288, t=2.0))
    assert store.get(0).generated == 64           # newest wins per rid
    assert len(store) == 1 and store.taken == 2
    # a snapshot bigger than the whole budget is refused outright
    assert not store.put(KVCheckpoint(rid=1, generated=8,
                                      kv_tokens=16 * 41, t=3.0))
    assert store.refused == 1 and store.get(1) is None
    # filling past the budget evicts the oldest-committed other request
    # (rid 0 holds 18 pages; 23 more would overflow the 40-page budget)
    assert store.put(KVCheckpoint(rid=2, generated=8, kv_tokens=368, t=4.0))
    assert store.evicted == 1 and store.get(0) is None
    assert store.get(2) is not None
    assert store.blocks <= 40
    store.drop(2)
    assert len(store) == 0 and store.blocks == 0


# ---------------------------------------------------------------------------
# checkpoint-resume failover (the tentpole golden test)
# ---------------------------------------------------------------------------

def _crash_recovery(interval, kill_at=3.0):
    """One long decode on worker 0 of 2; kill it mid-stream.  Returns
    the gateway, the consumer's event list, and a snapshot of
    (tokens delivered, newest committed checkpoint) taken at the kill."""
    gw = Gateway(CFG, _serve(), modes=["rapid", "rapid"],
                 router="round_robin",
                 policy=GatewayPolicy(checkpoint_interval=interval))
    evs = []
    r = Request(rid=0, arrival=0.0, prompt_len=256, max_new_tokens=300)
    gw._expected = 1
    gw.clock.at(0.0, lambda: gw.submit(r, consumer=evs.append))
    gw.clock.at(kill_at, lambda: gw.kill_worker(0))
    snap = {}

    def grab():
        ck = gw.checkpoints.get(0)
        snap["delivered"] = gw._live[0].channel.next_index
        snap["last_g"] = ck.generated if ck is not None else 0

    gw.clock.at(kill_at + 1e-6, grab)
    gw.clock.run()
    return gw, evs, snap


def test_checkpoint_resume_bounds_replay_to_interval():
    interval = 50
    gw, evs, snap = _crash_recovery(interval)
    fin = evs[-1]
    assert isinstance(fin, FinishedEvent)
    assert fin.retries == 1
    # rebasing restores the request's absolute coordinates
    assert fin.output_len == 300 and fin.prompt_len == 256
    assert _tokens(evs) == list(range(300))       # contiguous, exactly once
    # the crash landed mid-interval: the newest snapshot covers all full
    # intervals delivered before the kill
    assert snap["delivered"] > interval
    assert snap["last_g"] == interval * (snap["delivered"] // interval)
    # bounded replay: the resumed clone re-computed exactly the tokens
    # generated after the snapshot — never more than one interval
    assert gw.replayed_tokens == snap["delivered"] - snap["last_g"]
    assert 0 < gw.replayed_tokens <= interval
    assert gw.resumes == 1
    assert len(_phases(evs, "checkpoint")) == snap["last_g"] // interval
    assert len(_phases(evs, "resume")) == 1
    fleet = gw.metrics_summary()["fleet"]
    assert fleet["resumes"] == 1 and fleet["retries"] == 1
    assert fleet["replayed_tokens"] == gw.replayed_tokens


def test_reprefill_fallback_replays_full_prefix():
    """checkpoint_interval=0 (default): same crash, but the failover
    clone re-decodes every token the dead worker had produced."""
    gw, evs, snap = _crash_recovery(interval=0)
    fin = evs[-1]
    assert isinstance(fin, FinishedEvent) and fin.retries == 1
    assert _tokens(evs) == list(range(300))
    assert snap["last_g"] == 0
    assert gw.replayed_tokens == snap["delivered"]    # the whole prefix
    assert gw.resumes == 0 and gw.checkpoints.taken == 0
    assert not _phases(evs, "checkpoint") and not _phases(evs, "resume")


def test_resume_beats_reprefill_on_replayed_tokens():
    _, _, snap = _crash_recovery(interval=50)
    gw_ck, _, _ = _crash_recovery(interval=50)
    gw_rp, _, _ = _crash_recovery(interval=0)
    assert gw_ck.replayed_tokens < gw_rp.replayed_tokens
    assert snap["delivered"] == gw_rp.replayed_tokens


def test_inflight_checkpoint_dies_with_its_worker():
    """A snapshot copy that is on the wire when the source crashes must
    not commit (crash consistency): kill right after the interval
    boundary token, on a link so slow the transfer cannot finish."""
    gw = Gateway(CFG, _serve(), modes=["rapid", "rapid"],
                 router="round_robin",
                 policy=GatewayPolicy(checkpoint_interval=200,
                                      checkpoint_gbps=0.001))
    out = []

    def consume(ev):
        out.append(ev)
        if isinstance(ev, TokenEvent) and ev.index == 205:
            # copy of the g=200 snapshot is mid-flight on the slow link
            gw.clock.after(0, lambda: gw.kill_worker(0))

    gw._expected = 1
    gw.clock.at(0.0, lambda: gw.submit(
        Request(rid=0, arrival=0.0, prompt_len=256, max_new_tokens=300),
        consumer=consume))
    gw.clock.run()
    fin = out[-1]
    assert isinstance(fin, FinishedEvent) and fin.retries == 1
    assert _tokens(out) == list(range(300))
    # the only snapshot never committed -> pure re-prefill failover
    assert gw.checkpoints.taken == 0 and gw.resumes == 0
    assert not _phases(out, "checkpoint")


# ---------------------------------------------------------------------------
# fault plans / injector
# ---------------------------------------------------------------------------

def test_fault_kind_validated():
    with pytest.raises(ValueError):
        Fault(kind="meteor", t=1.0)


def test_crash_storm_is_deterministic_and_paired():
    a = FaultPlan.crash_storm(seed=7, workers=3, t0=1.0, t1=9.0, crashes=4)
    b = FaultPlan.crash_storm(seed=7, workers=3, t0=1.0, t1=9.0, crashes=4)
    assert a == b and len(a) == 8
    ts = [f.t for f in a]
    assert ts == sorted(ts)
    kinds = sorted(f.kind for f in a)
    assert kinds == ["crash"] * 4 + ["restart"] * 4
    for f in a:
        if f.kind == "crash":
            assert 1.0 <= f.t < 9.0 and 0 <= f.wid < 3
    assert a != FaultPlan.crash_storm(seed=8, workers=3, t0=1.0, t1=9.0,
                                      crashes=4)


def test_injector_wire_drop_and_corrupt_only_hit_tokens():
    """Lossy wire: dropped/corrupted *token* lines thin the stream (the
    channel counts them as gaps) but the terminal always arrives — the
    consumer still sees one contiguous prefix and exactly one terminal."""
    gw = Gateway(CFG, _serve(), modes=["rapid"], router="round_robin")
    plan = FaultPlan((Fault(kind="drop", t=0.5, rid=0, count=3),
                      Fault(kind="corrupt", t=1.0, rid=0, count=2)))
    inj = FaultInjector(gw, plan).arm()
    evs = []
    r = Request(rid=0, arrival=0.0, prompt_len=128, max_new_tokens=200)
    gw._expected = 1
    gw.clock.at(0.0, lambda: gw.submit(r, consumer=evs.append))
    gw.clock.run()

    assert inj.dropped_lines == 3 and inj.corrupted_lines == 2
    assert inj.injected["drop"] == 1 and inj.injected["corrupt"] == 1
    fin = evs[-1]
    assert isinstance(fin, FinishedEvent)         # terminals are reliable
    assert fin.output_len == 200                  # engine-side truth
    idxs = _tokens(evs)
    assert idxs == list(range(len(idxs)))         # contiguous prefix
    assert len(idxs) < 200                        # the wire really lost lines
    st_gap = 200 - len(idxs)
    assert st_gap >= 3                            # at least the dropped ones


def test_injector_stall_engages_backpressure_and_recovers():
    """A stalled consumer wedges its channel mid-decode: the gateway's
    slow-consumer machinery evicts that one request; unstall drains and
    the request completes with a contiguous stream."""
    gw = Gateway(CFG, _serve(), modes=["rapid"], router="round_robin")
    plan = FaultPlan((Fault(kind="stall", t=0.5, rid=0, duration=4.0),))
    FaultInjector(gw, plan).arm()
    slow, fast = [], []
    gw._expected = 2
    gw.clock.at(0.0, lambda: gw.submit(
        Request(rid=0, arrival=0.0, prompt_len=128, max_new_tokens=300),
        consumer=slow.append))
    gw.clock.at(0.0, lambda: gw.submit(
        Request(rid=1, arrival=0.0, prompt_len=128, max_new_tokens=300),
        consumer=fast.append))
    gw.clock.run()

    slow_fin, fast_fin = slow[-1], fast[-1]
    assert isinstance(slow_fin, FinishedEvent)
    assert isinstance(fast_fin, FinishedEvent)
    assert slow_fin.preemptions >= 1              # it WAS parked
    assert fast_fin.preemptions == 0              # isolation
    assert _tokens(slow) == list(range(300))
    assert _tokens(fast) == list(range(300))


def test_injector_flap_and_restart_fire():
    gw = Gateway(CFG, _serve(), modes=["rapid", "rapid"],
                 router="round_robin")
    plan = FaultPlan((Fault(kind="flap", t=0.3, wid=1, count=2),
                      Fault(kind="restart", t=0.6, mode="rapid"),
                      Fault(kind="crash", t=0.9, wid=99)))   # unknown: no-op
    inj = FaultInjector(gw, plan).arm()
    recs, _ = gw.serve_trace(
        [Request(rid=i, arrival=0.02 * i, prompt_len=128,
                 max_new_tokens=150) for i in range(4)])
    assert inj.injected == {"crash": 1, "restart": 1, "flap": 1,
                            "drop": 0, "corrupt": 0, "stall": 0}
    assert all(r.finish is not None for r in recs)
    assert sum(r.retries for r in recs) == 0      # flap under the timeout
    assert len(gw.registry.workers) == 3          # the restart joined


# ---------------------------------------------------------------------------
# client cancellation
# ---------------------------------------------------------------------------

def test_cancel_frees_slot_checkpoint_and_counts():
    gw = Gateway(CFG, _serve(), modes=["rapid"], router="round_robin",
                 policy=GatewayPolicy(checkpoint_interval=50))
    evs, other = [], []
    gw._expected = 2
    gw.clock.at(0.0, lambda: gw.submit(
        Request(rid=0, arrival=0.0, prompt_len=128, max_new_tokens=400),
        consumer=evs.append))
    gw.clock.at(0.0, lambda: gw.submit(
        Request(rid=1, arrival=0.0, prompt_len=128, max_new_tokens=400),
        consumer=other.append))
    state = {}

    def do_cancel():
        state["had_ckpt"] = gw.checkpoints.get(0) is not None
        assert gw.cancel(0, reason="client_cancel")
        state["ckpt_after"] = gw.checkpoints.get(0)
        state["delivered"] = len(_tokens(evs))

    gw.clock.at(4.0, do_cancel)
    gw.clock.run()

    term = evs[-1]
    assert isinstance(term, CancelledEvent)
    assert term.reason == "client_cancel"
    assert term.output_len == state["delivered"] > 0
    assert state["had_ckpt"] and state["ckpt_after"] is None
    # the survivor ran to completion on the freed capacity
    assert isinstance(other[-1], FinishedEvent)
    assert _tokens(other) == list(range(400))
    assert gw.cancellations == 1
    assert not gw._live and gw.health()["live_requests"] == 0
    # cancelling a non-live rid is a polite no-op
    assert not gw.cancel(0) and not gw.cancel(12345)
    s = gw.metrics_summary()["fleet"]
    assert s["cancelled"] == 1 and s["completed"] == 1
    rec = {r.rid: r for r in gw.metrics.records}
    assert rec[0].cancelled and not rec[0].rejected
    assert rec[0].output_len == state["delivered"]
    assert not rec[1].cancelled


# ---------------------------------------------------------------------------
# NDJSON line corruptor (HTTP-side fault hook)
# ---------------------------------------------------------------------------

def test_line_corruptor_deterministic_and_rate_zero_passthrough():
    import random
    line = b'{"type": "token", "rid": 1, "t": 0.5, "index": 3}\n'
    assert line_corruptor(rate=0.0)(line) == line
    a = line_corruptor(random.Random(3), rate=1.0)(line)
    b = line_corruptor(random.Random(3), rate=1.0)(line)
    assert a == b != line and len(a) == len(line)
