"""Event-stream wire format: JSON lines round-trip bit-identically.

The gateway streams the PR-3 typed event stream over HTTP as one JSON
line per event; these tests pin that ``event_to_json`` /
``event_from_json`` are exact inverses — including float timestamps
(json serializes via ``repr``, which Python guarantees parses back to
the identical float) — over both hand-built events and full
engine-generated traces.
"""
import dataclasses
import math

import pytest

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core import make_engine
from repro.core.events import (CancelledEvent, FinishedEvent, PhaseEvent,
                               RejectedEvent, TokenEvent, WIRE_TYPES,
                               event_from_json, event_from_wire,
                               event_to_json, event_to_wire)
from repro.core.request import Request

SAMPLES = [
    TokenEvent(rid=7, t=1.2345678901234567, index=0),
    TokenEvent(rid=7, t=0.1 + 0.2, index=41),          # classic repr case
    PhaseEvent(rid=3, t=0.0, phase="queued"),
    PhaseEvent(rid=3, t=5e-324, phase="preempted"),    # denormal min
    FinishedEvent(rid=1, t=9.75, arrival=0.5, prompt_len=512,
                  output_len=64, preemptions=2, slo_class="batch",
                  retries=1, truncated=True),
    FinishedEvent(rid=2, t=1.0, arrival=0.0, prompt_len=1, output_len=1),
    RejectedEvent(rid=9, t=3.5, arrival=3.25, prompt_len=9000,
                  reason="worker_lost", output_len=17, preemptions=1,
                  slo_class="best_effort", retries=3),
    RejectedEvent(rid=4, t=0.25, arrival=0.25, prompt_len=64),
    CancelledEvent(rid=5, t=2.5, arrival=1.0, prompt_len=128,
                   output_len=37, preemptions=1, slo_class="interactive",
                   retries=1, reason="disconnect"),
    CancelledEvent(rid=6, t=0.5, arrival=0.5, prompt_len=32),
]


@pytest.mark.parametrize("ev", SAMPLES, ids=lambda e: type(e).__name__)
def test_roundtrip_exact(ev):
    back = event_from_json(event_to_json(ev))
    assert type(back) is type(ev)
    assert back == ev
    for f in dataclasses.fields(ev):
        a, b = getattr(ev, f.name), getattr(back, f.name)
        assert type(a) is type(b)
        if isinstance(a, float):
            assert math.copysign(1.0, a) == math.copysign(1.0, b)
            assert a == b


def test_json_fixed_point():
    """decode(encode(x)) == x implies encode is a fixed point too."""
    for ev in SAMPLES:
        line = event_to_json(ev)
        assert event_to_json(event_from_json(line)) == line
        assert "\n" not in line                 # one event per line


def test_wire_dict_has_type_tag():
    for ev in SAMPLES:
        d = event_to_wire(ev)
        assert WIRE_TYPES[d["type"]] is type(ev)
        assert event_from_wire(d) == ev


def test_malformed_lines_raise_valueerror():
    with pytest.raises(ValueError):
        event_from_json("not json at all")
    with pytest.raises(ValueError):
        event_from_json("[1, 2, 3]")            # not an object
    with pytest.raises(ValueError):
        event_from_wire({"type": "nonsense", "rid": 1})
    with pytest.raises(ValueError):
        event_from_wire({"rid": 1, "t": 0.0})   # missing tag
    with pytest.raises(ValueError):
        event_from_wire({"type": "token", "rid": 1})  # missing fields
    with pytest.raises(ValueError):
        event_from_wire({"type": "token", "rid": 1, "t": 0.0, "index": 0,
                         "bogus": 1})           # unknown field


def test_engine_trace_roundtrips():
    """Every event a real engine emits survives the wire unchanged, in
    order — the gateway's HTTP stream is lossless by construction."""
    cfg = get_config("llama3-70b")
    for mode in ("rapid", "hybrid", "disagg"):
        serve = ServeConfig(mode=mode, chips=32,
                            slo=SLOConfig(itl_ms=100.0), chunk_size=512,
                            disagg_split=(16, 16), max_batch_slots=32)
        eng = make_engine(mode, cfg, serve)
        eng.enqueue([Request(rid=i, arrival=0.01 * i, prompt_len=128 + 64 * i,
                             max_new_tokens=8 + i) for i in range(6)])
        eng.loop.run()
        events = eng.stream.events()
        assert events, mode
        decoded = [event_from_json(event_to_json(ev)) for ev in events]
        assert list(events) == decoded, mode
