"""Serving gateway: registry, routing, streaming, admission, metrics.

Everything runs on the simulated clock (serving/clock.py protocol), so
these are fully deterministic — no sockets, no sleeps.  Churn scenarios
(crash, drain, slow consumer) live in tests/test_gateway_churn.py.
"""
import pytest

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core.events import (FinishedEvent, PhaseEvent, RejectedEvent,
                               TokenEvent)
from repro.core.request import Request
from repro.serving import Gateway, RequestChannel
from repro.serving.worker import WorkerState

CFG = get_config("llama3-70b")


def _serve(mode="rapid", chips=16, slots=64):
    return ServeConfig(mode=mode, chips=chips, slo=SLOConfig(itl_ms=100.0),
                       chunk_size=512, disagg_split=(chips // 2, chips // 2),
                       max_batch_slots=slots)


def _gateway(modes=("rapid", "rapid"), **kw):
    return Gateway(CFG, _serve(), modes=list(modes), **kw)


def _trace(n, max_new=16, prompt=256, gap=0.02, **kw):
    return [Request(rid=i, arrival=gap * i, prompt_len=prompt,
                    max_new_tokens=max_new, **kw) for i in range(n)]


# ---------------------------------------------------------------------------
# registry / workers
# ---------------------------------------------------------------------------

def test_registry_tracks_workers_and_replicas():
    gw = _gateway(modes=("rapid", "hybrid"))
    assert sorted(gw.registry.workers) == [0, 1]
    assert [rep.mode for rep in gw.registry.replicas] == ["rapid", "hybrid"]
    assert [w.name for w in gw.registry.healthy()] == ["rapid-0", "hybrid-1"]
    w = gw.add_worker("rapid")
    assert w.wid == 2 and len(gw.registry.replicas) == 3
    gw.registry.deregister(2)
    assert 2 not in gw.registry.workers and len(gw.registry.replicas) == 2


def test_heartbeat_timeout_declares_silent_worker_dead():
    gw = _gateway()
    r = _trace(1, max_new=600)[0]       # keep the gateway busy long enough
    gw._expected = 1
    gw.clock.at(0.0, lambda: gw.submit(r, consumer=lambda ev: None))
    gw.clock.at(0.2, lambda: gw.kill_worker(1))   # idle worker crashes
    states = []
    gw.clock.at(0.3, lambda: states.append(gw.registry.workers[1].state))
    gw.clock.run()
    # not yet detected right after the crash...
    assert states == [WorkerState.UP]
    # ...but the missing heartbeats eventually were
    assert gw.registry.workers[1].state is WorkerState.DEAD
    assert gw.registry.workers[1].replica not in gw.registry.replicas
    assert gw.health()["workers"]["rapid-1"] == "dead"


def test_healthy_workers_keep_beating_and_stay_up():
    gw = _gateway()
    recs, _ = gw.serve_trace(_trace(6))
    assert all(r.finish is not None for r in recs)
    assert all(w.state is WorkerState.UP
               for w in gw.registry.workers.values())


# ---------------------------------------------------------------------------
# streaming channels
# ---------------------------------------------------------------------------

def test_channel_dedupes_replayed_token_indices():
    got = []
    ch = RequestChannel(rid=1, consumer=got.append)
    assert ch.offer(TokenEvent(1, 0.1, 0))
    assert ch.offer(TokenEvent(1, 0.2, 1))
    assert not ch.offer(TokenEvent(1, 0.3, 0))    # failover replay
    assert not ch.offer(TokenEvent(1, 0.3, 1))
    assert ch.offer(TokenEvent(1, 0.4, 2))
    assert [e.index for e in got] == [0, 1, 2]
    assert ch.offer(FinishedEvent(1, 0.5, 0.0, 8, 3))
    assert ch.closed and ch.done
    assert not ch.offer(TokenEvent(1, 0.6, 3))    # closed -> dropped


def test_channel_pause_resume_watermarks():
    paused, resumed = [], []
    ch = RequestChannel(rid=1, capacity=4, resume_at=1,
                        on_pause=paused.append, on_resume=resumed.append)
    for i in range(4):
        ch.offer(TokenEvent(1, 0.1 * i, i))
    assert paused == [1] and ch.paused
    ch.offer(TokenEvent(1, 0.5, 4))               # buffered past capacity
    assert len(ch) == 5 and paused == [1]         # pause fires once
    while len(ch) > 1:
        ch.take()
    assert resumed == [1] and not ch.paused
    assert ch.drain()[0].index == 4


def test_streamed_events_reach_consumer_in_order():
    gw = _gateway(modes=("rapid",))
    evs = []
    r = Request(rid=0, arrival=0.0, prompt_len=128, max_new_tokens=12)
    gw._expected = 1
    gw.clock.at(0.0, lambda: gw.submit(r, consumer=evs.append))
    gw.clock.run()
    kinds = [type(e).__name__ for e in evs]
    assert kinds[0] == "PhaseEvent" and kinds[-1] == "FinishedEvent"
    idxs = [e.index for e in evs if isinstance(e, TokenEvent)]
    assert idxs == list(range(12))
    times = [e.t for e in evs]
    assert times == sorted(times)


# ---------------------------------------------------------------------------
# admission / routing
# ---------------------------------------------------------------------------

def test_oversized_prompt_rejected_through_channel():
    gw = _gateway(modes=("rapid",))
    evs = []
    r = Request(rid=0, arrival=0.0, prompt_len=10**7, max_new_tokens=4)
    gw._expected = 1
    gw.clock.at(0.0, lambda: gw.submit(r, consumer=evs.append))
    gw.clock.run()
    assert len(evs) == 1 and isinstance(evs[0], RejectedEvent)
    assert evs[0].reason == "never_fits"
    assert gw.metrics.records[0].rejected


def test_session_affinity_pins_turns_to_one_worker():
    gw = _gateway(router="round_robin", session_affinity=True)
    reqs = _trace(6, gap=2.0, session_id="s1")
    recs, _ = gw.serve_trace(reqs)
    assert all(r.finish is not None for r in recs)
    homes = {w.wid: len(w.replica.assigned)
             for w in gw.registry.workers.values()}
    assert sorted(homes.values()) == [0, 6]       # all turns on one worker


def test_truncated_band_request_finishes_with_flag():
    # build a prompt that fits the pool but whose prompt+output cannot:
    # engine admission caps max_new_tokens instead of stalling.  Gateway
    # admission is opened wide so the band request reaches the engine.
    from repro.serving import AdmissionPolicy
    gw = _gateway(modes=("rapid",),
                  admission=AdmissionPolicy(kv_headroom=1.0,
                                            projected_output_frac=0.0))
    eng = gw.registry.workers[0].engine
    pool_tokens = eng.kv.allocator.num_blocks * gw.serve.page_size
    r = Request(rid=0, arrival=0.0, prompt_len=pool_tokens - 3,
                max_new_tokens=64)
    gw._expected = 1
    evs = []
    gw.clock.at(0.0, lambda: gw.submit(r, consumer=evs.append))
    gw.clock.run()
    fin = evs[-1]
    assert isinstance(fin, FinishedEvent)
    assert fin.truncated and fin.output_len == 4
    rec = gw.metrics.records[0]
    assert rec.truncated and rec.output_len == 4


# ---------------------------------------------------------------------------
# observability
# ---------------------------------------------------------------------------

def test_metrics_summary_carries_loop_stats_and_workers():
    gw = _gateway()
    gw.serve_trace(_trace(8))
    s = gw.metrics_summary()
    loop = s["fleet"]["loop"]
    assert set(loop) == {"dispatched", "clamped", "peak_heap"}
    assert loop["dispatched"] > 0 and loop["clamped"] == 0
    assert set(s["per_replica"]) == {"rapid-0", "rapid-1"}
    assert s["fleet"]["completed"] == 8
    assert s["fleet"]["retries"] == 0 and s["fleet"]["truncated"] == 0


def test_health_endpoint_shape():
    gw = _gateway()
    h = gw.health()
    assert h["status"] == "ok"
    assert h["workers"] == {"rapid-0": "up", "rapid-1": "up"}
    assert h["live_requests"] == 0 and h["paused_streams"] == 0


def test_summarize_gains_retry_truncation_counters():
    from repro.serving.metrics import RequestRecord, summarize
    recs = [RequestRecord(rid=0, arrival=0.0, prompt_len=8, output_len=4,
                          ttft=0.1, itl_p95=0.01, finish=1.0, retries=2,
                          truncated=True),
            RequestRecord(rid=1, arrival=0.0, prompt_len=8, output_len=0,
                          ttft=None, itl_p95=None, finish=None,
                          rejected=True, retries=1)]
    s = summarize(recs, SLOConfig(itl_ms=100.0), 1.0)
    assert s["retries"] == 3          # rejected requests' retries count too
    assert s["truncated"] == 1


def test_run_fleet_summary_includes_loop_stats():
    from repro.serving import run_fleet
    serve = _serve()
    out, cluster = run_fleet(CFG, serve, ["rapid", "rapid"], "round_robin",
                             _trace(6))
    loop = out["fleet"]["loop"]
    assert loop == cluster.loop.stats.as_dict()
    assert loop["dispatched"] > 0


# ---------------------------------------------------------------------------
# HTTP surface: one real-socket end-to-end pass (skipped if the sandbox
# forbids binding localhost)
# ---------------------------------------------------------------------------

def test_http_generate_healthz_metrics():
    import asyncio
    import json as _json

    from repro.core.events import event_from_json
    from repro.serving import GatewayHTTPServer, RealTimeClock

    async def scenario():
        gw = Gateway(CFG, _serve(), modes=["rapid"], clock=RealTimeClock())
        server = GatewayHTTPServer(gw, host="127.0.0.1", port=0)
        try:
            await server.start()
        except OSError as e:
            pytest.skip(f"cannot bind localhost: {e}")
        port = server._server.sockets[0].getsockname()[1]

        async def call(method, path, body=b""):
            reader, writer = await asyncio.open_connection("127.0.0.1",
                                                           port)
            head = (f"{method} {path} HTTP/1.1\r\nHost: x\r\n"
                    f"Content-Length: {len(body)}\r\n\r\n").encode()
            writer.write(head + body)
            await writer.drain()
            raw = await reader.read()
            writer.close()
            await writer.wait_closed()
            header, _, payload = raw.partition(b"\r\n\r\n")
            status = int(header.split()[1])
            return status, payload

        status, payload = await call("GET", "/healthz")
        assert status == 200
        assert _json.loads(payload)["status"] == "ok"

        body = _json.dumps({"prompt_len": 64,
                            "max_new_tokens": 5}).encode()
        status, payload = await call("POST", "/v1/generate", body)
        assert status == 200
        events = [event_from_json(line)
                  for line in payload.decode().splitlines()]
        assert isinstance(events[-1], FinishedEvent)
        assert [e.index for e in events
                if isinstance(e, TokenEvent)] == list(range(5))

        status, payload = await call("GET", "/metrics")
        assert status == 200
        m = _json.loads(payload)
        assert m["fleet"]["completed"] == 1
        assert "loop" in m["fleet"]

        status, _ = await call("GET", "/nope")
        assert status == 404
        status, _ = await call("POST", "/v1/generate", b"{bad json")
        assert status == 400
        await server.close()

    asyncio.run(asyncio.wait_for(scenario(), timeout=60))


def test_http_malformed_inputs_get_400_and_server_survives():
    """Robustness contract: junk bodies, junk headers and junk request
    lines are client errors (400/405), never an exception escaping the
    handler — the server keeps answering afterwards."""
    import asyncio
    import json as _json

    from repro.serving import GatewayHTTPServer, RealTimeClock

    async def scenario():
        gw = Gateway(CFG, _serve(), modes=["rapid"], clock=RealTimeClock())
        server = GatewayHTTPServer(gw, host="127.0.0.1", port=0)
        try:
            await server.start()
        except OSError as e:
            pytest.skip(f"cannot bind localhost: {e}")
        port = server._server.sockets[0].getsockname()[1]

        async def raw(payload: bytes):
            reader, writer = await asyncio.open_connection("127.0.0.1",
                                                           port)
            writer.write(payload)
            await writer.drain()
            data = await reader.read()
            writer.close()
            await writer.wait_closed()
            header, _, body = data.partition(b"\r\n\r\n")
            return int(header.split()[1]), body

        async def call(method, path, body=b""):
            head = (f"{method} {path} HTTP/1.1\r\nHost: x\r\n"
                    f"Content-Length: {len(body)}\r\n\r\n").encode()
            return await raw(head + body)

        bad_bodies = [
            b"{bad json",                                  # not JSON
            b"[1, 2, 3]",                                  # not an object
            b'{"max_new_tokens": 4}',                      # missing field
            b'{"prompt_len": "x", "max_new_tokens": 4}',   # wrong type
            b'{"prompt_len": 0, "max_new_tokens": 4}',     # out of range
            b'{"prompt_len": 8, "max_new_tokens": 4, "cached_prefix_len": -1}',
            b'{"prompt_len": 8, "max_new_tokens": 4, "session_id": 5}',
        ]
        for body in bad_bodies:
            status, _ = await call("POST", "/v1/generate", body)
            assert status == 400, body
        for body in [b"notjson", b'{"rid": "x"}', b"{}"]:
            status, _ = await call("POST", "/v1/cancel", body)
            assert status == 400, body
        # cancel of an unknown rid is a clean "no"
        status, payload = await call("POST", "/v1/cancel", b'{"rid": 99}')
        assert status == 200
        assert _json.loads(payload) == {"rid": 99, "cancelled": False}
        # junk request line / headers
        status, _ = await raw(b"GARBAGE\r\n\r\n")
        assert status == 400
        status, _ = await raw(b"POST /v1/generate HTTP/1.1\r\n"
                              b"Content-Length: -5\r\n\r\n")
        assert status == 400
        status, _ = await raw(b"POST /v1/generate HTTP/1.1\r\n"
                              b"Content-Length: 9999999\r\n\r\n")
        assert status == 400
        status, _ = await call("GET", "/v1/generate")
        assert status == 405
        # the server is still healthy after all of that
        status, payload = await call("GET", "/healthz")
        assert status == 200
        assert _json.loads(payload)["status"] == "ok"
        await server.close()

    asyncio.run(asyncio.wait_for(scenario(), timeout=60))


def test_http_cancel_route_and_midstream_disconnect():
    """Streaming cancellation end to end: POST /v1/cancel terminates a
    live stream with a typed ``cancelled`` NDJSON line, and a client
    that disconnects mid-stream gets its request cancelled server-side
    (engine slot freed) instead of decoding into a dead socket."""
    import asyncio
    import json as _json

    from repro.core.events import CancelledEvent, event_from_json
    from repro.serving import GatewayHTTPServer, RealTimeClock

    async def scenario():
        gw = Gateway(CFG, _serve(), modes=["rapid"], clock=RealTimeClock())
        server = GatewayHTTPServer(gw, host="127.0.0.1", port=0)
        try:
            await server.start()
        except OSError as e:
            pytest.skip(f"cannot bind localhost: {e}")
        port = server._server.sockets[0].getsockname()[1]

        async def post(path, body):
            reader, writer = await asyncio.open_connection("127.0.0.1",
                                                           port)
            head = (f"POST {path} HTTP/1.1\r\nHost: x\r\n"
                    f"Content-Length: {len(body)}\r\n\r\n").encode()
            writer.write(head + body)
            await writer.drain()
            await reader.readuntil(b"\r\n\r\n")      # response headers
            return reader, writer

        # -- explicit cancel via the API (request rid 0) --------------
        body = _json.dumps({"prompt_len": 64,
                            "max_new_tokens": 4000}).encode()
        reader, writer = await post("/v1/generate", body)
        for _ in range(3):                           # stream is live
            await reader.readline()
        c_reader, c_writer = await post("/v1/cancel",
                                        _json.dumps({"rid": 0}).encode())
        resp = _json.loads(await c_reader.read())
        assert resp == {"rid": 0, "cancelled": True}
        c_writer.close()
        await c_writer.wait_closed()
        tail = await asyncio.wait_for(reader.read(), timeout=30)
        last = tail.decode().splitlines()[-1]
        term = event_from_json(last)
        assert isinstance(term, CancelledEvent)
        assert term.reason == "client_cancel" and term.rid == 0
        writer.close()
        await writer.wait_closed()

        # -- abrupt disconnect mid-stream (request rid 1) -------------
        reader, writer = await post("/v1/generate", body)
        for _ in range(3):
            await reader.readline()
        writer.transport.abort()                     # RST, no goodbye
        for _ in range(400):                         # server notices on
            if gw.cancellations >= 2:                # its next write
                break
            await asyncio.sleep(0.05)
        assert gw.cancellations == 2
        recs = {r.rid: r for r in gw.metrics.records}
        assert recs[0].cancelled and recs[1].cancelled
        assert gw.health()["live_requests"] == 0
        s = gw.metrics_summary()["fleet"]
        assert s["cancelled"] == 2
        await server.close()

    asyncio.run(asyncio.wait_for(scenario(), timeout=60))


def test_http_worker_lost_streams_partial_then_typed_reject():
    """Crash round-trip over the wire: the NDJSON stream carries the
    partial tokens generated before the crash, then the terminal
    ``rejected`` line with reason=worker_lost and the partial
    ``output_len`` — never a hung socket or a bare EOF."""
    import asyncio
    import json as _json

    from repro.core.events import event_from_json
    from repro.serving import (GatewayHTTPServer, GatewayPolicy,
                               RealTimeClock)

    async def scenario():
        # fast heartbeats so death detection fits in test time
        policy = GatewayPolicy(heartbeat_s=0.05, heartbeat_timeout_s=0.2,
                               health_check_s=0.05)
        gw = Gateway(CFG, _serve(), modes=["rapid"], clock=RealTimeClock(),
                     policy=policy)
        server = GatewayHTTPServer(gw, host="127.0.0.1", port=0)
        try:
            await server.start()
        except OSError as e:
            pytest.skip(f"cannot bind localhost: {e}")
        port = server._server.sockets[0].getsockname()[1]

        reader, writer = await asyncio.open_connection("127.0.0.1", port)
        body = _json.dumps({"prompt_len": 64,
                            "max_new_tokens": 4000}).encode()
        head = (f"POST /v1/generate HTTP/1.1\r\nHost: x\r\n"
                f"Content-Length: {len(body)}\r\n\r\n").encode()
        writer.write(head + body)
        await writer.drain()
        await reader.readuntil(b"\r\n\r\n")

        events, killed = [], False
        while True:
            line = await reader.readline()
            if not line:
                break
            if not line.strip():
                continue
            ev = event_from_json(line.decode())
            events.append(ev)
            if (not killed and isinstance(ev, TokenEvent)
                    and ev.index >= 3):
                killed = True
                gw.kill_worker(0)        # sole worker: no failover target
        writer.close()
        await writer.wait_closed()
        await server.close()

        assert killed, "stream never produced tokens"
        term = events[-1]
        assert isinstance(term, RejectedEvent)
        assert term.reason == "worker_lost"
        assert term.retries == 1             # one failover attempt made
        toks = [e for e in events if isinstance(e, TokenEvent)]
        assert [e.index for e in toks] == list(range(len(toks)))
        assert term.output_len == len(toks)  # partial progress reported

    asyncio.run(asyncio.wait_for(scenario(), timeout=60))


# ---------------------------------------------------------------------------
# real-time clock (no asyncio loop started; just the adapter contract)
# ---------------------------------------------------------------------------

def test_realtime_clock_contract():
    from repro.serving import RealTimeClock
    c = RealTimeClock()
    assert c.virtual is False and c.now == 0.0

    class _FakeLoop:
        def __init__(self):
            self.t = 100.0
            self.calls = []

        def time(self):
            return self.t

        def call_at(self, t, fn):
            self.calls.append(("at", t, fn))

        def call_later(self, dt, fn):
            self.calls.append(("later", dt, fn))

    # pre-bind schedules queue up and flush as delays at bind time
    c.after(0.25, lambda: None)
    loop = _FakeLoop()
    c.bind(loop)
    # the timebase rebases to bind: pre-bind timestamps (last_beat=0.0
    # at registration) stay comparable instead of jumping to loop.time()
    assert c.now == 0.0
    assert loop.calls == [("later", 0.25, loop.calls[0][2])]
    c.at(2.0, lambda: None)           # future: loop sees t0-offset time
    assert loop.calls[1][1] == 102.0 and c.stats.clamped == 0
    loop.t = 103.0                    # 3s of serving elapse
    assert c.now == 3.0
    c.at(2.5, lambda: None)           # past-due -> clamped to now
    assert loop.calls[2][1] == 103.0 and c.stats.clamped == 1
    c.after(-1.0, lambda: None)
    assert loop.calls[3][1] == 0.0 and c.stats.clamped == 2
    c.after(0.5, lambda: None)
    assert loop.calls[4][1] == 0.5 and c.stats.dispatched == 5
