"""Incremental load accounting: pinned values + recompute equivalence.

``Engine.load_snapshot()`` is now O(1) over ``IndexedQueue`` counters;
``Engine.load_snapshot_recompute()`` is the retained PR-4 full rescan.
This module (a) pins queued-token / queued-page numbers for all three
schedulers against hand-computed values — including the queues that
appear in BOTH ``token_queues`` and ``unalloc_queues``, which the old
implementation double-walked — and (b) asserts counter == recompute at
many points of real traces, including across preemption, migration and
full drain.  The hypothesis suite (test_engine_accounting_properties)
extends (b) to arbitrary op sequences.
"""
import copy

import pytest

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core import make_engine
from repro.core.request import Request
from repro.kvcache import KVCacheManager
from repro.serving import TRACES, generate_trace

CFG = get_config("llama3-70b")


def _serve(mode):
    return ServeConfig(mode=mode, chips=32, slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(16, 16), max_batch_slots=128)


def _req(rid, prompt, out=4, arrival=0.0):
    return Request(rid=rid, arrival=arrival, prompt_len=prompt,
                   max_new_tokens=out)


def _check(eng):
    snap = eng.load_snapshot()
    assert snap == eng.load_snapshot_recompute()
    # the router fast path reads the same counters without building the
    # snapshot; it must agree field-for-field
    assert eng.router_load() == (snap.queued_prefill_tokens,
                                 snap.running_decode,
                                 snap.decode_ctx_tokens)


# ---------------------------------------------------------------------------
# Hand-computed pins (page_size = 16 throughout)
# ---------------------------------------------------------------------------


def test_rapid_pinned_counts():
    eng = make_engine("rapid", CFG, _serve("rapid"))
    # decode pool of 16 pages: two 100-token prompts (7 pages each) fit,
    # the third is blocked in waiting_kv
    eng.kv = KVCacheManager(num_blocks=16, page_size=16)
    s = eng.load_snapshot()
    assert (s.queued_requests, s.queued_prefill_tokens,
            s.queued_kv_pages) == (0, 0, 0)
    eng.submit(_req(0, 100))     # admitted AND launched (prefill idle)
    s = eng.load_snapshot()
    # in-flight prefill tokens count toward the router's backlog signal
    assert (s.queued_requests, s.queued_prefill_tokens,
            s.queued_kv_pages) == (0, 100, 0)
    assert s.prefill_busy and s.kv_free_blocks == 16 - 7
    eng.submit(_req(1, 100))     # admitted, prefill busy -> queued
    eng.submit(_req(2, 100))     # needs 7 pages, 2 free -> waiting_kv
    s = eng.load_snapshot()
    assert (s.queued_requests, s.queued_prefill_tokens,
            s.queued_kv_pages) == (2, 300, 7)
    assert s.kv_free_blocks == 2
    _check(eng)


def test_hybrid_pinned_counts():
    eng = make_engine("hybrid", CFG, _serve("hybrid"))
    eng.submit(_req(0, 1000))
    s = eng.load_snapshot()
    # admitted straight into chunking (PREFILLING) with a 512-token chunk
    # launched; partial_token_queues count prompt - prefill_tokens_done,
    # and nothing has completed a step yet
    assert (s.queued_requests, s.queued_prefill_tokens,
            s.queued_kv_pages) == (1, 1000, 0)
    eng.loop.run()               # first step: 512 of 1000 tokens done
    _check(eng)
    # drained: every counter returns to zero exactly
    s = eng.load_snapshot()
    assert (s.queued_requests, s.queued_prefill_tokens, s.running_decode,
            s.decode_ctx_tokens, s.queued_kv_pages) == (0, 0, 0, 0, 0)


def test_hybrid_partial_tokens_after_one_chunk():
    eng = make_engine("hybrid", CFG, _serve("hybrid"))
    eng.enqueue([_req(0, 1000, out=8)])
    # run exactly the arrival + one step completion: 512 tokens chunked
    eng.loop.run(until=0.0)      # arrival only (chunk still in flight)
    assert eng.load_snapshot().queued_prefill_tokens == 1000
    eng.loop.run(until=10.0)     # step completes; second chunk in flight
    s = eng.load_snapshot()
    # whatever progressed, counters must equal the rescan exactly
    _check(eng)
    assert s.queued_prefill_tokens == \
        sum(r.prompt_len - r.prefill_tokens_done for r in eng.chunking)


def test_disagg_pinned_counts():
    eng = make_engine("disagg", CFG, _serve("disagg"))
    eng.submit(_req(0, 100))     # straight into the prefill launch
    s = eng.load_snapshot()
    assert (s.queued_requests, s.queued_prefill_tokens,
            s.queued_kv_pages) == (0, 100, 0)
    eng.submit(_req(1, 40))      # prefill busy: queued, 3 pages claimed
    eng.submit(_req(2, 100))     # queued, 7 pages
    s = eng.load_snapshot()
    assert (s.queued_requests, s.queued_prefill_tokens,
            s.queued_kv_pages) == (2, 240, 10)
    assert s.prefill_kv_total_blocks > 0
    assert s.queued_prefill_kv_pages == 10
    _check(eng)


def test_disagg_transfer_counts():
    """In-flight transfers count as imminent decode load (queued +
    running + ctx + pages) in both implementations."""
    eng = make_engine("disagg", CFG, _serve("disagg"))
    eng.enqueue([_req(0, 100, out=4)])
    # drain prefill, stop inside the KV transfer window
    while eng.inflight_transfers == 0 and eng.loop._heap:
        eng.loop.run(until=eng.loop.now + 1e-3)
    assert eng.inflight_transfers == 1
    s = eng.load_snapshot()
    assert s.queued_requests == 1 and s.running_decode == 1
    assert s.decode_ctx_tokens == 100 and s.queued_kv_pages == 7
    _check(eng)
    eng.loop.run()
    _check(eng)


# ---------------------------------------------------------------------------
# Recompute equivalence over real traces (sliced, preempting, migrating)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["rapid", "hybrid", "disagg"])
def test_counters_equal_recompute_over_trace(mode):
    reqs = generate_trace(TRACES["lmsys"], qps=6.0, duration_s=12, seed=3)
    eng = make_engine(mode, CFG, _serve(mode))
    eng.enqueue([copy.deepcopy(r) for r in reqs])
    t = 0.0
    while eng.loop._heap:
        t += 0.25
        eng.loop.run(until=t)
        _check(eng)
    _check(eng)
    assert len(eng.finished) + len(eng.rejected) == len(reqs)


@pytest.mark.parametrize("mode", ["rapid", "hybrid"])
def test_counters_survive_preemption(mode):
    """Tiny pool => preemption churn; counters must track evictions and
    re-queues exactly.  Uses the decode-heavy lmsys trace: lifetime
    admission now truncates any single request that could never fit
    (the old self-preemption source), so the churn must come from
    *concurrent* decode growth overflowing the pool — and a few
    requests still hit the truncation path, covering both."""
    serve = ServeConfig(mode=mode, chips=32, slo=SLOConfig(itl_ms=100.0),
                        max_batch_slots=8, max_seq_len=32768)
    reqs = generate_trace(TRACES["lmsys"], qps=10.0, duration_s=10, seed=7)
    eng = make_engine(mode, CFG, serve)
    eng.kv = KVCacheManager(num_blocks=200, page_size=16)
    eng.enqueue([copy.deepcopy(r) for r in reqs])
    t, preempted = 0.0, 0
    while eng.loop._heap:
        t += 0.5
        eng.loop.run(until=t)
        _check(eng)
        preempted = max(preempted,
                        sum(r.preemptions for r in eng._all))
    _check(eng)
    assert preempted > 0, "trace did not exercise preemption"
    assert any(r.truncated for r in eng._all), \
        "trace did not exercise lifetime truncation"


@pytest.mark.parametrize("mode", ["rapid", "hybrid", "disagg"])
def test_counters_survive_migration(mode):
    """evict_for_migration() + re-submit (the cluster rebalance path)
    must leave both engines' counters equal to their rescans."""
    reqs = generate_trace(TRACES["lmsys"], qps=8.0, duration_s=8, seed=5)
    src = make_engine(mode, CFG, _serve(mode))
    dst = make_engine(mode, CFG, _serve(mode), loop=src.loop)
    src.enqueue([copy.deepcopy(r) for r in reqs])
    t, moved = 0.0, 0
    while src.loop._heap:
        t += 0.5
        src.loop.run(until=t)
        evicted = src.evict_for_migration()
        if evicted is not None:
            dst.submit(evicted[0])
            moved += 1
        _check(src)
        _check(dst)
    assert moved > 0
    assert src.load_snapshot() == src.load_snapshot_recompute()
    assert dst.load_snapshot() == dst.load_snapshot_recompute()
    done = len(src.finished) + len(dst.finished) + \
        len(src.rejected) + len(dst.rejected)
    assert done == len(reqs)


def test_double_walk_queues_counted_once():
    """Regression for the PR-4 double walk: rapid's ``waiting_kv`` is in
    both ``token_queues`` and ``unalloc_queues``; its tokens must be
    counted once and its pages once — in both implementations."""
    eng = make_engine("rapid", CFG, _serve("rapid"))
    eng.kv = KVCacheManager(num_blocks=8, page_size=16)
    eng.submit(_req(0, 100))             # 7 pages: admitted + launched
    eng.submit(_req(1, 64))              # 4 pages > 1 free: waiting_kv
    eng.submit(_req(2, 32))              # 2 pages, FCFS-blocked behind r1
    for snap in (eng.load_snapshot(), eng.load_snapshot_recompute()):
        assert snap.queued_prefill_tokens == 100 + 64 + 32
        assert snap.queued_kv_pages == 4 + 2
        assert snap.queued_requests == 2
