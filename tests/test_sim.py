"""EventLoop: horizon semantics, resume, ordering."""
from repro.serving.sim import EventLoop


def test_run_until_does_not_drop_past_horizon_events():
    """Regression: run(until=...) used to pop an event past the horizon
    and return, silently losing that callback on resume."""
    loop = EventLoop()
    fired = []
    for t in (1.0, 2.0, 5.0):
        loop.at(t, lambda t=t: fired.append(t))
    loop.run(until=3.0)
    assert fired == [1.0, 2.0]
    assert loop.now == 3.0
    loop.run()                    # resume: the t=5 event must still fire
    assert fired == [1.0, 2.0, 5.0]
    assert loop.now == 5.0


def test_run_until_repeated_horizons():
    loop = EventLoop()
    fired = []
    for t in (0.5, 1.5, 2.5, 3.5):
        loop.at(t, lambda t=t: fired.append(t))
    for horizon in (1.0, 2.0, 3.0, 4.0):
        loop.run(until=horizon)
    assert fired == [0.5, 1.5, 2.5, 3.5]


def test_run_until_advances_clock_on_empty_heap():
    loop = EventLoop()
    loop.at(1.0, lambda: None)
    loop.run(until=10.0)
    assert loop.now == 10.0


def test_run_until_exact_boundary_fires():
    loop = EventLoop()
    fired = []
    loop.at(2.0, lambda: fired.append(2.0))
    loop.run(until=2.0)           # t == until is inside the horizon
    assert fired == [2.0]


def test_events_scheduled_during_run_respect_horizon():
    loop = EventLoop()
    fired = []

    def chain():
        fired.append(loop.now)
        loop.after(1.0, chain)

    loop.at(0.0, chain)
    loop.run(until=2.5)
    assert fired == [0.0, 1.0, 2.0]
    loop.run(until=4.5)
    assert fired == [0.0, 1.0, 2.0, 3.0, 4.0]


def test_stats_count_dispatched_and_peak_heap():
    loop = EventLoop()
    for t in (1.0, 2.0, 3.0):
        loop.at(t, lambda: None)
    assert loop.stats.peak_heap == 3
    assert loop.stats.dispatched == 0
    loop.run()
    assert loop.stats.dispatched == 3
    assert loop.stats.clamped == 0


def test_stats_count_past_due_clamps():
    """Regression: at() used to silently snap past-due times to now;
    the clamp is still applied (no reordering) but now it is counted."""
    loop = EventLoop()
    fired = []
    loop.at(5.0, lambda: loop.at(1.0, lambda: fired.append(loop.now)))
    loop.run()
    assert fired == [5.0]          # clamped to now, not delivered at 1.0
    assert loop.stats.clamped == 1
    assert loop.stats.dispatched == 2


def test_stats_float_jitter_not_counted_as_clamp():
    loop = EventLoop()
    loop.at(1.0, lambda: loop.at(loop.now - 1e-15, lambda: None))
    loop.run()
    assert loop.stats.clamped == 0


def test_stats_as_dict():
    loop = EventLoop()
    loop.at(0.5, lambda: None)
    loop.run()
    assert loop.stats.as_dict() == \
        {"dispatched": 1, "clamped": 0, "peak_heap": 1}
