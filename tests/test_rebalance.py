"""Cross-replica preemption/migration: shared victim policy, engine
eviction API, and the cluster rebalance tick (conservation under
migration, KV-transfer charging)."""
import copy

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core import PreemptionPolicy, make_engine
from repro.core.request import Request, State
from repro.kvcache import KVCacheManager
from repro.perfmodel.costs import kv_migration_seconds
from repro.serving import Cluster, RebalancePolicy

ARCH = "llama3-70b"


def _serve(mode="rapid", chips=32):
    return ServeConfig(mode=mode, chips=chips, slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(chips // 2, chips // 2),
                       max_batch_slots=128)


def _req(rid, arrival=0.0, prompt=500, out=100):
    return Request(rid=rid, arrival=arrival, prompt_len=prompt,
                   max_new_tokens=out)


# ---------------------------------------------------------------------------
# shared preemption policy (hoisted from the engines)
# ---------------------------------------------------------------------------


def test_preemption_policy_orders():
    reqs = [_req(0, 0.0), _req(1, 2.0), _req(2, 1.0)]
    reqs[0].tokens_generated = 5
    assert PreemptionPolicy().choose(reqs) is reqs[1]           # newest
    assert PreemptionPolicy("least_progress").choose(reqs) is reqs[1]
    reqs[1].tokens_generated = 9
    assert PreemptionPolicy("least_progress").choose(reqs) is reqs[2]
    assert PreemptionPolicy().choose([]) is None


def test_engines_share_the_policy():
    cfg = get_config(ARCH)
    for mode in ("rapid", "hybrid", "disagg"):
        eng = make_engine(mode, cfg, _serve(mode))
        assert isinstance(eng.preempt_policy, PreemptionPolicy)
        assert eng.preempt_policy.order == "newest"


# ---------------------------------------------------------------------------
# engine eviction API
# ---------------------------------------------------------------------------


def test_evict_queued_request_has_no_kv():
    cfg = get_config(ARCH)
    eng = make_engine("rapid", cfg, _serve())
    eng.kv = KVCacheManager(40, 16)     # room for exactly one 500-prompt
    for i in range(3):
        eng.submit(_req(i, arrival=float(i)))
    # rid 0 allocated; 1 and 2 stuck in waiting_kv
    cand = eng.migration_candidate()
    assert cand is not None
    victim, has_kv = cand
    assert victim.rid == 2 and not has_kv   # newest queued first, no KV
    evicted, had_kv = eng.evict_for_migration()
    assert evicted is victim and not had_kv
    assert evicted.state is State.ARRIVED
    assert all(r.rid != 2 for r in eng.waiting_kv)


def test_evict_running_request_frees_kv_and_counts_preemption():
    cfg = get_config(ARCH)
    eng = make_engine("rapid", cfg, _serve())
    for i in range(2):
        eng.submit(_req(i, arrival=float(i) * 0.01, out=2000))
    eng.loop.run(until=0.5)             # both prefilled and decoding
    assert len(eng.running) == 2 and not eng.waiting_kv
    before = eng.kv.num_requests
    evicted, had_kv = eng.evict_for_migration()
    assert had_kv and evicted.preemptions == 1
    assert eng.kv.num_requests == before - 1
    assert evicted not in eng.running
    # re-submission on another engine resumes it to completion
    other = make_engine("rapid", cfg, _serve(), loop=eng.loop)
    other.submit(evicted)
    eng.loop.run()
    assert evicted.state is State.FINISHED


# ---------------------------------------------------------------------------
# cluster rebalance tick
# ---------------------------------------------------------------------------


def _hot_cold_cluster(policy):
    """All load lands on replica 0 (replica 1 joins at t=0.6), so the
    rebalance tick sees a hot/cold pair."""
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(), ["rapid"] * 2, router="least_loaded",
                      rebalance=policy)
    for rep in cluster.replicas:
        rep.engine.kv = KVCacheManager(150, 16)   # 2400-token pools
    cluster.replicas[1].routable = False
    cluster.loop.at(0.6, lambda: setattr(cluster.replicas[1],
                                         "routable", True))
    reqs = [_req(i, arrival=0.05 * i, prompt=500, out=120)
            for i in range(8)]
    return cluster, reqs


def test_rebalance_migrates_from_hot_to_cold_replica():
    # hot_ticks=1 / cost_benefit=False exercises the raw (ungated)
    # migration machinery; the guards get their own tests below
    policy = RebalancePolicy(check_interval_s=0.5, kv_high=0.5,
                             kv_low=0.4, max_moves_per_tick=4,
                             hot_ticks=1, cost_benefit=False)
    cluster, reqs = _hot_cold_cluster(policy)
    recs, _ = cluster.run(copy.deepcopy(reqs))
    assert cluster._migrations, "no migrations under clear hot/cold skew"
    for t, src, dst, rid, had_kv in cluster._migrations:
        assert src == "rapid-0" and dst == "rapid-1"
    # conservation: every request finishes exactly once, ownership moved
    assert all(r.finish is not None for r in recs)
    counts = cluster.per_replica_counts()
    assert sum(counts.values()) == len(reqs)
    assert counts["rapid-1"] >= len(cluster._migrations)


def test_rebalance_respects_migration_cap():
    policy = RebalancePolicy(check_interval_s=0.5, kv_high=0.5,
                             kv_low=0.4, max_moves_per_tick=4,
                             max_migrations_per_request=1,
                             hot_ticks=1, cost_benefit=False)
    cluster, reqs = _hot_cold_cluster(policy)
    cluster.run(copy.deepcopy(reqs))
    per_rid = {}
    for _, _, _, rid, _ in cluster._migrations:
        per_rid[rid] = per_rid.get(rid, 0) + 1
    assert all(v <= 1 for v in per_rid.values())


def test_migration_charges_kv_transfer_cost():
    """A running victim's re-enqueue on the destination is delayed by the
    perfmodel KV-transfer time of its live context."""
    cfg = get_config(ARCH)
    xfer = kv_migration_seconds(cfg, 4096, 50.0)
    assert xfer > 0
    # linear in context and inversely in link speed
    assert kv_migration_seconds(cfg, 8192, 50.0) == \
        __import__("pytest").approx(2 * xfer)
    assert kv_migration_seconds(cfg, 4096, 100.0) == \
        __import__("pytest").approx(xfer / 2)


def test_hysteresis_blocks_live_kv_until_k_hot_ticks():
    """With ``hot_ticks=K`` a replica must stay KV-hot for K consecutive
    checks before any *live-context* victim is evicted; queued victims
    (no KV) may still be re-routed on the first hot tick."""
    for k in (1, 3):
        policy = RebalancePolicy(check_interval_s=0.5, kv_high=0.5,
                                 kv_low=0.4, max_moves_per_tick=4,
                                 hot_ticks=k, cost_benefit=False)
        # sustained pressure: long outputs keep replica 0 hot for seconds
        # (final context 1700 tokens = 107 pages still fits one pool)
        cfg = get_config(ARCH)
        cluster = Cluster(cfg, _serve(), ["rapid"] * 2,
                          router="least_loaded", rebalance=policy)
        for rep in cluster.replicas:
            rep.engine.kv = KVCacheManager(150, 16)
        cluster.replicas[1].routable = False
        cluster.loop.at(0.6, lambda c=cluster: setattr(c.replicas[1],
                                                       "routable", True))
        reqs = [_req(i, arrival=0.05 * i, prompt=500, out=1200)
                for i in range(8)]
        cluster.run(copy.deepcopy(reqs))
        live_moves = [(t, rid) for t, _, _, rid, had_kv
                      in cluster._migrations if had_kv]
        assert live_moves, f"hot_ticks={k}: no live-KV moves at all"
        first_t = min(t for t, _ in live_moves)
        # streaks accumulate from the first tick (0.5s) even while the
        # cold replica is still unroutable, so the K-th consecutive hot
        # observation lands at K * interval; migration additionally needs
        # a second live replica, which joins at 0.6 (first joint tick at
        # 1.0)
        floor = max(1.0, k * policy.check_interval_s)
        assert first_t >= floor - 1e-9, \
            f"hot_ticks={k}: live KV moved at t={first_t} < {floor}"


def test_cost_benefit_gate_skips_unprofitable_transfers():
    """A crawling migration link makes every live-context move cost more
    than the projected queue relief — the gate must suppress them while
    still allowing free queued re-routes."""
    cfg = get_config(ARCH)
    gated = RebalancePolicy(check_interval_s=0.5, kv_high=0.5, kv_low=0.4,
                            max_moves_per_tick=4, hot_ticks=1,
                            cost_benefit=True, link_gbps=0.001)
    cluster = Cluster(cfg, _serve(), ["rapid"] * 2, router="least_loaded",
                      rebalance=gated)
    for rep in cluster.replicas:
        rep.engine.kv = KVCacheManager(150, 16)
    cluster.replicas[1].routable = False
    cluster.loop.at(0.6, lambda: setattr(cluster.replicas[1],
                                         "routable", True))
    reqs = [_req(i, arrival=0.05 * i, prompt=500, out=1200)
            for i in range(8)]
    recs, _ = cluster.run(copy.deepcopy(reqs))
    assert not any(had_kv for *_, had_kv in cluster._migrations), \
        "live KV moved over a 1 MB/s link (transfer >> relief)"
    # the trace still completes: the gate degrades to local service
    assert all(r.finish is not None for r in recs)


def test_disagg_replica_can_receive_migrations():
    """Migration target compatibility is engine-agnostic: a victim evicted
    from a rapid replica finishes on a disagg one."""
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(), ["rapid", "disagg"],
                      router="least_loaded",
                      rebalance=RebalancePolicy(check_interval_s=0.5,
                                                kv_high=0.5, kv_low=0.4,
                                                max_moves_per_tick=4,
                                                hot_ticks=1,
                                                cost_benefit=False))
    cluster.replicas[0].engine.kv = KVCacheManager(150, 16)
    cluster.replicas[1].routable = False
    cluster.loop.at(0.6, lambda: setattr(cluster.replicas[1],
                                         "routable", True))
    reqs = [_req(i, arrival=0.05 * i, prompt=500, out=120)
            for i in range(8)]
    recs, _ = cluster.run(copy.deepcopy(reqs))
    assert all(r.finish is not None for r in recs)
    if cluster._migrations:
        assert cluster.per_replica_counts()["disagg-1"] > 0
