"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ssm_scan import ssm_scan
from repro.kernels.unified_pd import build_slot_schedule, unified_pd

TOL = {jnp.float32: dict(atol=3e-5, rtol=3e-5),
       jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


def _rand(rng, shape, dtype):
    return jax.random.normal(rng, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash_prefill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,Hq,Hkv,S,D,bq,bk,window", [
    (2, 4, 2, 128, 32, 64, 64, None),
    (1, 8, 2, 257, 64, 64, 128, None),     # ragged S (padding path)
    (2, 4, 4, 256, 32, 64, 64, 96),        # sliding window
    (1, 2, 1, 64, 16, 32, 32, None),       # MQA
    (1, 4, 1, 96, 32, 32, 32, 32),         # window == block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill(rng, B, Hq, Hkv, S, D, bq, bk, window, dtype):
    ks = jax.random.split(rng, 3)
    q = _rand(ks[0], (B, Hq, S, D), dtype)
    k = _rand(ks[1], (B, Hkv, S, D), dtype)
    v = _rand(ks[2], (B, Hkv, S, D), dtype)
    out = flash_prefill(q, k, v, window=window, block_q=bq, block_k=bk,
                        interpret=True)
    want = ref.causal_attention(q, k, v, window=window)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# paged_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,Hq,Hkv,D,page,max_pages,N", [
    (2, 4, 2, 32, 8, 4, 16),
    (3, 8, 4, 64, 16, 6, 32),
    (1, 4, 1, 16, 8, 3, 8),
    (4, 2, 2, 32, 4, 5, 24),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention(rng, B, Hq, Hkv, D, page, max_pages, N, dtype):
    ks = jax.random.split(rng, 3)
    q = _rand(ks[0], (B, Hq, D), dtype)
    kp = _rand(ks[1], (N, page, Hkv, D), dtype)
    vp = _rand(ks[2], (N, page, Hkv, D), dtype)
    rs = np.random.RandomState(0)
    tabs = jnp.asarray(np.stack(
        [rs.permutation(N)[:max_pages] for _ in range(B)]).astype(np.int32))
    lens = jnp.asarray(
        rs.randint(1, max_pages * page + 1, size=B).astype(np.int32))
    out = paged_attention(q, kp, vp, tabs, lens, interpret=True)
    want = ref.paged_attention(q, kp, vp, tabs, lens)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **TOL[dtype])


def test_paged_attention_len_one(rng):
    """Boundary: a sequence with exactly one valid token."""
    B, Hq, Hkv, D, page, mp, N = 2, 4, 2, 32, 8, 3, 8
    ks = jax.random.split(rng, 3)
    q = _rand(ks[0], (B, Hq, D), jnp.float32)
    kp = _rand(ks[1], (N, page, Hkv, D), jnp.float32)
    vp = _rand(ks[2], (N, page, Hkv, D), jnp.float32)
    tabs = jnp.tile(jnp.arange(mp, dtype=jnp.int32), (B, 1))
    lens = jnp.array([1, page * mp], jnp.int32)
    out = paged_attention(q, kp, vp, tabs, lens, interpret=True)
    want = ref.paged_attention(q, kp, vp, tabs, lens)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# ssm_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,L,din,ds,chunk,tile", [
    (2, 64, 32, 8, 16, 16),
    (1, 128, 64, 16, 32, 32),
    (2, 96, 48, 4, 24, 24),
    (1, 60, 40, 8, 16, 16),     # chunk/tile fallback (60 % 16 != 0)
])
def test_ssm_scan(rng, B, L, din, ds, chunk, tile):
    ks = jax.random.split(rng, 5)
    xs = jax.random.normal(ks[0], (B, L, din), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, din)))
    A = -jnp.exp(jax.random.normal(ks[2], (din, ds)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, ds))
    Cm = jax.random.normal(ks[4], (B, L, ds))
    y, h = ssm_scan(xs, dt, A, Bm, Cm, chunk=chunk, tile_d=tile,
                    interpret=True)
    y_ref, h_ref = ref.ssm_scan(xs, dt, A, Bm, Cm)
    np.testing.assert_allclose(y, y_ref, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(h, h_ref, atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# unified_pd — the paper's concurrent P/D step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("f_decode", [1.0, 0.5, 0.25, 0.1])
def test_slot_schedule(f_decode):
    kinds = build_slot_schedule(24, 6, f_decode)
    assert kinds.sum() == 6 and len(kinds) == 30
    dpos = np.where(kinds == 1)[0]
    # decode tiles finish within ~n_d / f_decode slots (+rounding)
    assert dpos[-1] <= int(6 / f_decode) + 6


@pytest.mark.parametrize("Bp,Bd,Hq,Hkv,Sp,D,page,mp,N,f,win", [
    (1, 2, 4, 2, 128, 32, 8, 4, 16, 0.5, None),
    (2, 3, 4, 4, 64, 16, 8, 3, 12, 0.25, None),
    (1, 2, 8, 2, 96, 32, 16, 2, 8, 1.0, 48),
    (2, 1, 4, 2, 64, 32, 8, 2, 8, 0.1, None),
])
def test_unified_pd(rng, Bp, Bd, Hq, Hkv, Sp, D, page, mp, N, f, win):
    ks = jax.random.split(rng, 6)
    q_p = _rand(ks[0], (Bp, Hq, Sp, D), jnp.float32)
    k_p = _rand(ks[1], (Bp, Hkv, Sp, D), jnp.float32)
    v_p = _rand(ks[2], (Bp, Hkv, Sp, D), jnp.float32)
    q_d = _rand(ks[3], (Bd, Hq, D), jnp.float32)
    kpg = _rand(ks[4], (N, page, Hkv, D), jnp.float32)
    vpg = _rand(ks[5], (N, page, Hkv, D), jnp.float32)
    rs = np.random.RandomState(1)
    tabs = jnp.asarray(np.stack(
        [rs.permutation(N)[:mp] for _ in range(Bd)]).astype(np.int32))
    lens = jnp.asarray(
        rs.randint(1, mp * page + 1, size=Bd).astype(np.int32))
    o_p, o_d = unified_pd(q_p, k_p, v_p, q_d, kpg, vpg, tabs, lens,
                          f_decode=f, window=win, block_q=32, block_k=32,
                          interpret=True)
    rp, rd = ref.unified_pd(q_p, k_p, v_p, q_d, kpg, vpg, tabs, lens,
                            window=win)
    np.testing.assert_allclose(o_p, rp, atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(o_d, rd, atol=3e-5, rtol=3e-5)


def test_unified_pd_matches_single_kernels(rng):
    """The fused step must agree with the standalone kernels exactly
    (same accumulation order per tile)."""
    Bp, Bd, Hq, Hkv, Sp, D, page, mp, N = 1, 2, 4, 2, 64, 32, 8, 3, 12
    ks = jax.random.split(rng, 6)
    q_p = _rand(ks[0], (Bp, Hq, Sp, D), jnp.float32)
    k_p = _rand(ks[1], (Bp, Hkv, Sp, D), jnp.float32)
    v_p = _rand(ks[2], (Bp, Hkv, Sp, D), jnp.float32)
    q_d = _rand(ks[3], (Bd, Hq, D), jnp.float32)
    kpg = _rand(ks[4], (N, page, Hkv, D), jnp.float32)
    vpg = _rand(ks[5], (N, page, Hkv, D), jnp.float32)
    tabs = jnp.tile(jnp.arange(mp, dtype=jnp.int32), (Bd, 1))
    lens = jnp.array([5, page * mp], jnp.int32)
    o_p, o_d = unified_pd(q_p, k_p, v_p, q_d, kpg, vpg, tabs, lens,
                          f_decode=0.5, block_q=32, block_k=32,
                          interpret=True)
    o_p2 = flash_prefill(q_p, k_p, v_p, block_q=32, block_k=32,
                         interpret=True)
    o_d2 = paged_attention(q_d, kpg, vpg, tabs, lens, interpret=True)
    np.testing.assert_allclose(o_p, o_p2, atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(o_d, o_d2, atol=1e-6, rtol=1e-6)
