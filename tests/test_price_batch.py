"""Executor.price_batch == sequential execute, bit-for-bit.

price_batch reroutes pricing through perfmodel.batch with vectorized
key dedup instead of the scalar entry points' lru_cache.  Both paths
share the _assemble control flow, and the batch layer is bit-identical
to the scalar formulas, so every LaunchOutcome must compare equal —
costs AND durations — not merely close.
"""
import dataclasses

from repro.config import ServeConfig, get_config
from repro.core.executor import PerfModelExecutor
from repro.core.queues import IndexedQueue
from repro.core.request import Request
from repro.core.scheduler import (DecodeLaunch, HybridLaunch, LaneState,
                                  PrefillLaunch, SchedView, StepPlan)
from repro.perfmodel import costs as C


def _req(rid, prompt_len, cached=0, done=0, generated=0):
    r = Request(rid=rid, arrival=0.0, prompt_len=prompt_len,
                max_new_tokens=64, cached_prefix_len=cached)
    r.prefill_tokens_done = done
    r.tokens_generated = generated
    return r


def _view(serve, running=(), lanes=None):
    return SchedView(now=0.0, serve=serve, queues={},
                     running=IndexedQueue(items=list(running)),
                     kv=None, kv_p=None, lanes=lanes or {}, wake=None)


def _cases():
    """(executor, plan, view) triples covering every _assemble branch,
    with deliberate operating-point duplicates to exercise the dedup."""
    cfg = get_config("llama3-70b")
    serve = ServeConfig(chips=8)
    coloc = PerfModelExecutor(cfg, colocated=True)
    split = PerfModelExecutor(cfg, colocated=False,
                              lane_chips={"prefill": 6, "decode": 2})

    running = [_req(100 + i, 512, generated=16 + i) for i in range(4)]
    dlane = LaneState(busy=True,
                      cost=C.decode_cost(cfg, 4, 2100.0, 8), f_decode=0.4)
    plane = LaneState(busy=True, cost=C.prefill_cost(cfg, [768], 8))

    cases = []
    for ex in (coloc, split):
        # prefill only, idle lanes
        cases.append((ex, StepPlan(prefill=PrefillLaunch(
            batch=[_req(1, 512), _req(2, 2048)], queue="prefill")),
            _view(serve)))
        # same prefill point again (dedup) but against a busy decode lane
        cases.append((ex, StepPlan(prefill=PrefillLaunch(
            batch=[_req(3, 512), _req(4, 2048)], queue="prefill")),
            _view(serve, lanes={"decode": dlane})))
        # session-prefix prefill: priced as per-request chunk costs
        cases.append((ex, StepPlan(prefill=PrefillLaunch(
            batch=[_req(5, 1024, cached=256), _req(6, 640)],
            queue="prefill")), _view(serve)))
        # prefill + decode in one plan: decode couples to the new prefill
        cases.append((ex, StepPlan(
            prefill=PrefillLaunch(batch=[_req(7, 900)], queue="prefill"),
            decode=DecodeLaunch(joins=[_req(8, 300, generated=1)],
                                f_decode=0.3)),
            _view(serve, running=running)))
        # decode only, prefill lane mid-flight
        cases.append((ex, StepPlan(
            decode=DecodeLaunch(joins=[], f_decode=None)),
            _view(serve, running=running, lanes={"prefill": plane})))
        # decode with empty batch -> ZERO_COST path
        cases.append((ex, StepPlan(decode=DecodeLaunch(joins=[])),
                      _view(serve)))
        # hybrid lockstep: chunks + running decodes in one fused step
        cases.append((ex, StepPlan(hybrid=HybridLaunch(
            chunks=[(_req(9, 4096, done=1024), 512),
                    (_req(10, 2048, cached=128), 256)])),
            _view(serve, running=running)))
        # hybrid chunks with no running decodes
        cases.append((ex, StepPlan(hybrid=HybridLaunch(
            chunks=[(_req(11, 4096, done=1024), 512)])), _view(serve)))
        # empty plan
        cases.append((ex, StepPlan(), _view(serve)))
    return cases


def test_price_batch_matches_execute():
    by_ex = {}
    for ex, plan, view in _cases():
        by_ex.setdefault(id(ex), (ex, [], []))
        by_ex[id(ex)][1].append(plan)
        by_ex[id(ex)][2].append(view)
    checked = 0
    for ex, plans, views in by_ex.values():
        want = [ex.execute(p, v) for p, v in zip(plans, views)]
        got = ex.price_batch(plans, views)
        assert len(got) == len(want)
        for g, w in zip(got, want):
            assert g == w          # frozen dataclasses: exact equality
            checked += 1
    assert checked == 18


def test_price_batch_zero_cost_identity():
    """Degenerate launches resolve to the ZERO_COST singleton, exactly
    like the scalar path."""
    cfg = get_config("llama3-70b")
    ex = PerfModelExecutor(cfg)
    serve = ServeConfig(chips=8)
    plan = StepPlan(decode=DecodeLaunch(joins=[]))
    out, = ex.price_batch([plan], [_view(serve)])
    assert out.decode.cost is C.ZERO_COST


def test_default_price_batch_is_sequential_execute():
    """The Executor base class default must fall back to execute()."""
    calls = []

    class Probe(PerfModelExecutor):
        def execute(self, plan, view):
            calls.append(plan)
            return super().execute(plan, view)

    # bypass PerfModelExecutor's override to test the protocol default
    cfg = get_config("llama3-70b")
    ex = Probe(cfg)
    serve = ServeConfig(chips=8)
    plans = [StepPlan(), StepPlan(decode=DecodeLaunch(joins=[]))]
    views = [_view(serve), _view(serve)]
    from repro.core.executor import Executor
    got = Executor.price_batch(ex, plans, views)
    assert calls == plans
    assert [dataclasses.asdict(g) for g in got] == \
        [dataclasses.asdict(o) for o in (ex.execute(p, v)
                                         for p, v in zip(plans, views))]
