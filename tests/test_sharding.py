"""Sharding translation + small-mesh integration (runs on 1 CPU device)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.config import get_reduced_config
from repro.sharding import (ShardingRules, make_constrain, param_sharding,
                            rules_for_mesh, spec_to_pspec)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_translation(mesh):
    rules = rules_for_mesh(mesh)
    assert spec_to_pspec((None, "model"), mesh, rules) == P(None, "model")
    assert spec_to_pspec(("batch", None), mesh, rules) == P("data", None)
    assert spec_to_pspec(("expert", None, "model"), mesh, rules) == \
        P("data", None, "model")


def test_indivisible_dims_dropped(mesh):
    rules = rules_for_mesh(mesh)
    big = jax.make_mesh((1, 2), ("data", "model")) if False else mesh
    # shape 3 not divisible by any axis size > 1 -> must drop on 2-wide
    p = spec_to_pspec(("model",), mesh, rules, shape=(3,))
    assert p == P("model") or p == P(None)  # 1-wide mesh: both legal


def test_param_sharding_tree(mesh):
    cfg = get_reduced_config("granite-8b")
    from repro.models.transformer import init_model_shapes
    shapes, specs = init_model_shapes(jax.random.PRNGKey(0), cfg, tp=1)
    sh = param_sharding(specs, shapes, mesh, fsdp=True)
    assert jax.tree.structure(sh) == jax.tree.structure(shapes)


def test_fsdp_skips_small_and_expert():
    from repro.sharding import _fsdp_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = ShardingRules()
    # small leaf untouched
    assert _fsdp_spec((None,), (64,), mesh, rules) == (None,)
    # expert leaf untouched
    s = ("expert", None, "model")
    assert _fsdp_spec(s, (128, 4096, 4096), mesh, rules) == s


def test_constrained_forward_runs(mesh):
    """forward under a (1,1) mesh with all constraints active."""
    cfg = get_reduced_config("mixtral-8x7b")
    from repro.models.transformer import init_model, forward
    params, _ = init_model(jax.random.PRNGKey(0), cfg, tp=1)
    constrain = make_constrain(mesh)
    B, S = 2, 16
    toks = jnp.zeros((B, S), jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    with mesh:
        out = jax.jit(lambda p, t: forward(p, cfg, t, pos, 1,
                                           constrain=constrain))(params,
                                                                 toks)
    assert out.shape == (B, S, cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(out.astype(jnp.float32))))


def test_train_step_under_mesh(mesh):
    """Full train step with constraints + remat under the host mesh."""
    cfg = get_reduced_config("qwen3-moe-235b-a22b")
    from repro.training.optimizer import OptConfig
    from repro.training.train_lib import init_train_state, make_train_step
    opt = OptConfig(lr=1e-3)
    constrain = make_constrain(mesh)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt, microbatches=2,
                                   constrain=constrain))
    B, S = 4, 16
    batch = {
        "inputs": jnp.zeros((B, S), jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
        "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
    }
    with mesh:
        state, m = step(state, batch)
    assert bool(jnp.isfinite(m["loss"]))
