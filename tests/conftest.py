"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — tests and
benches must see the real single CPU device; only launch/dryrun.py
forces 512 placeholder devices (task spec).

Property-based test modules need ``hypothesis`` (a dev-only dependency,
see requirements-dev.txt).  When it is absent we drop those modules at
collection time — tier-1 must never *error* at collection — and say so
in the report header.
"""
import importlib.util
import pathlib
import sys

import jax
import pytest

# tests import the benchmark harness (e.g. test_events' conservation
# check on the bench_hotpath trace); make the repo root importable even
# when pytest is launched as a bare console script (no cwd on sys.path)
_ROOT = str(pathlib.Path(__file__).resolve().parents[1])
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

_HAS_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None
_HYPOTHESIS_MODULES = ["test_engines.py", "test_training.py",
                       "test_batch_properties.py",
                       "test_router_properties.py",
                       "test_engine_accounting_properties.py",
                       "test_liveness_properties.py",
                       "test_wire_properties.py",
                       "test_chaos_properties.py"]

collect_ignore = [] if _HAS_HYPOTHESIS else list(_HYPOTHESIS_MODULES)


def pytest_report_header(config):
    if not _HAS_HYPOTHESIS:
        return ("hypothesis not installed -> skipping "
                + ", ".join(_HYPOTHESIS_MODULES)
                + "  (pip install -r requirements-dev.txt)")
    return None


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
