"""Shared fixtures.  NOTE: no XLA_FLAGS here on purpose — tests and
benches must see the real single CPU device; only launch/dryrun.py
forces 512 placeholder devices (task spec)."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
