"""Session prefix cache: KV manager park/adopt/evict mechanics,
prefix-skip token conservation through the engines, and session-affinity
routing vs migration invalidation at the cluster."""
import collections
import copy

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core import make_engine
from repro.core.request import Request, State
from repro.kvcache import KVCacheManager, OutOfBlocks, kv_pages_for
from repro.serving import Cluster, RebalancePolicy, StreamMetrics

ARCH = "llama3-70b"
PAGE = 16


def _serve(mode="rapid", chips=32, session_cache_frac=0.25):
    return ServeConfig(mode=mode, chips=chips, slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(chips // 2, chips // 2),
                       max_batch_slots=128,
                       session_cache_frac=session_cache_frac)


# ---------------------------------------------------------------------------
# KV manager session mechanics
# ---------------------------------------------------------------------------


def test_release_adopt_roundtrip():
    kv = KVCacheManager(64, PAGE, session_cache_blocks=16)
    blocks = kv.allocate_prompt(0, 100)           # 7 pages
    assert kv.release_to_session(0, "s1")
    assert kv.session_blocks == len(blocks)
    assert kv.session_tokens("s1") == 100
    assert kv.available_blocks == 64              # parked == reclaimable
    # the next turn adopts the parked pages and only claims the suffix
    need = kv.pages_needed(160, session_id="s1", max_prefix=100)
    assert need == kv_pages_for(160, PAGE) - kv_pages_for(100, PAGE)
    got = kv.allocate_prompt(1, 160, session_id="s1", max_prefix=100)
    assert got[:len(blocks)] == blocks            # same physical pages
    assert len(got) == kv_pages_for(160, PAGE)
    assert kv.session_blocks == 0                 # adopted, no longer parked


def test_session_hit_clamped_to_resident_and_prompt():
    kv = KVCacheManager(64, PAGE, session_cache_blocks=16)
    kv.allocate_prompt(0, 100)
    kv.release_to_session(0, "s1")
    assert kv.session_hit_tokens("s1", 160, 100) == 100
    assert kv.session_hit_tokens("s1", 160, 999) == 100   # claim > resident
    assert kv.session_hit_tokens("s1", 50, 100) == 49     # prompt-1 floor
    assert kv.session_hit_tokens("s1", 160, 0) == 0
    assert kv.session_hit_tokens(None, 160, 100) == 0
    assert kv.session_hit_tokens("nope", 160, 100) == 0


def test_budget_zero_is_plain_free():
    kv = KVCacheManager(64, PAGE)                 # no session budget
    kv.allocate_prompt(0, 100)
    assert not kv.release_to_session(0, "s1")
    assert kv.session_blocks == 0
    assert kv.allocator.free_count == 64


def test_lru_eviction_within_budget():
    kv = KVCacheManager(64, PAGE, session_cache_blocks=8)
    kv.allocate_prompt(0, 5 * PAGE)
    kv.release_to_session(0, "old")
    kv.allocate_prompt(1, 5 * PAGE)
    kv.release_to_session(1, "new")               # 10 > 8: evicts "old"
    assert kv.session_tokens("old") == 0
    assert kv.session_tokens("new") == 5 * PAGE
    assert kv.session_blocks == 5


def test_parked_blocks_never_starve_live_work():
    kv = KVCacheManager(16, PAGE, session_cache_blocks=16)
    kv.allocate_prompt(0, 10 * PAGE)
    kv.release_to_session(0, "s1")
    assert kv.allocator.free_count == 6
    # a sessionless prompt needing 12 pages must reclaim the parked KV
    blocks = kv.allocate_prompt(1, 12 * PAGE)
    assert len(blocks) == 12
    assert kv.session_tokens("s1") == 0           # evicted, not OutOfBlocks
    try:
        kv.allocate_prompt(2, 8 * PAGE)
    except OutOfBlocks:
        pass
    else:
        raise AssertionError("pool is genuinely full; expected OutOfBlocks")


def test_drop_session_frees_blocks():
    kv = KVCacheManager(64, PAGE, session_cache_blocks=16)
    kv.allocate_prompt(0, 100)
    kv.release_to_session(0, "s1")
    kv.drop_session("s1")
    assert kv.session_blocks == 0
    assert kv.allocator.free_count == 64
    kv.drop_session("s1")                         # idempotent


# ---------------------------------------------------------------------------
# prefix-skip conservation through the engines
# ---------------------------------------------------------------------------


def _session_trace(n_sessions=6, turns=3):
    reqs, rid = [], 0
    for s in range(n_sessions):
        ctx, t = 0, 0.3 * s
        for _ in range(turns):
            prompt = ctx + 600
            reqs.append(Request(rid=rid, arrival=t, prompt_len=prompt,
                                max_new_tokens=64, slo_class="interactive",
                                session_id=f"s{s}", cached_prefix_len=ctx))
            ctx = prompt + 64
            t += 2.0
            rid += 1
    reqs.sort(key=lambda r: r.arrival)
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


def test_prefill_token_conservation_rapid_and_hybrid():
    """After prefill, skipped + prefilled tokens must equal the prompt —
    and later turns must actually hit the parked prefix."""
    cfg = get_config(ARCH)
    for mode in ("rapid", "hybrid"):
        eng = make_engine(mode, cfg, _serve(mode))
        reqs = [copy.deepcopy(r) for r in _session_trace()]
        metrics = StreamMetrics()
        eng.subscribe(metrics)
        eng.enqueue(reqs)
        eng.loop.run()
        assert all(r.state is State.FINISHED for r in reqs), mode
        hits = [r for r in reqs if r.cached_prefix_len > 0]
        assert hits, f"{mode}: no prefix hits on a pure session trace"
        for r in reqs:
            assert r.prefill_tokens_done + r.cached_prefix_len == \
                r.prompt_len, (mode, r.rid)
        # every request still emits exactly max_new_tokens tokens
        for rec in metrics.records:
            assert rec.output_len == reqs[rec.rid].max_new_tokens


def test_disagg_ignores_sessions():
    """Split-pool engines transfer KV between pools; the session cache is
    colocated-only (budget 0) and requests must behave as sessionless."""
    cfg = get_config(ARCH)
    eng = make_engine("disagg", cfg, _serve("disagg"))
    assert eng.kv.session_cache_blocks == 0
    reqs = [copy.deepcopy(r) for r in _session_trace(n_sessions=2)]
    eng.enqueue(reqs)
    eng.loop.run()
    assert all(r.state is State.FINISHED for r in reqs)
    assert all(r.cached_prefix_len == 0 for r in reqs)  # clamped to miss


def test_session_cache_frac_sizes_budget():
    cfg = get_config(ARCH)
    on = make_engine("rapid", cfg, _serve("rapid"))
    off = make_engine("rapid", cfg, _serve("rapid", session_cache_frac=0.0))
    assert on.kv.session_cache_blocks > 0
    assert off.kv.session_cache_blocks == 0


# ---------------------------------------------------------------------------
# cluster: session affinity vs migration
# ---------------------------------------------------------------------------


def test_session_affinity_routes_turns_to_home_replica():
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(), ["rapid"] * 3,
                      router="round_robin", session_affinity=True)
    reqs = [copy.deepcopy(r) for r in _session_trace(n_sessions=4)]
    cluster.run(reqs)
    owner = {}
    for rep in cluster.replicas:
        for r in rep.assigned:
            owner.setdefault(r.session_id, set()).add(rep.idx)
    # every session's turns landed on ONE replica (round_robin would
    # scatter them), so later turns hit the parked prefix
    assert all(len(reps) == 1 for reps in owner.values())
    hits = sum(1 for rep in cluster.replicas for r in rep.assigned
               if r.cached_prefix_len > 0)
    assert hits > 0


def test_no_affinity_scatters_sessions():
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(), ["rapid"] * 3, router="round_robin")
    reqs = [copy.deepcopy(r) for r in _session_trace(n_sessions=4)]
    cluster.run(reqs)
    owner = collections.defaultdict(set)
    for rep in cluster.replicas:
        for r in rep.assigned:
            owner[r.session_id].add(rep.idx)
    assert any(len(reps) > 1 for reps in owner.values())


def test_migration_invalidates_session_prefix():
    """A migrated session's parked prefix on the source is dropped and
    the session re-homed: the next turn must not claim a stale prefix."""
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(), ["rapid"] * 2,
                      router="least_loaded", session_affinity=True,
                      rebalance=RebalancePolicy())
    src, tgt = cluster.replicas
    src.engine.kv = KVCacheManager(80, PAGE, session_cache_blocks=40)
    # a hog fills the pool so the session's next turn queues KV-less
    hog = Request(rid=9, arrival=0.0, prompt_len=1000, max_new_tokens=500)
    src.engine.submit(hog)
    victim = Request(rid=0, arrival=0.0, prompt_len=640, max_new_tokens=8,
                     session_id="sess", cached_prefix_len=576)
    src.assigned.append(victim)
    src.engine.submit(victim)
    cand = src.engine.migration_candidate()
    assert cand is not None and cand[0] is victim and not cand[1]
    # now park a prefix for the session on src and home it there
    src.engine.kv.allocate_prompt(999, 256)
    assert src.engine.kv.release_to_session(999, "sess")
    cluster._session_home["sess"] = src.idx
    cluster._migrate(src, tgt, victim, False)
    assert victim.cached_prefix_len == 0
    assert src.engine.kv.session_tokens("sess") == 0
    assert cluster._session_home["sess"] == tgt.idx
    assert any(r.rid == victim.rid for r in tgt.assigned)
