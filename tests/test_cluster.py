"""Multi-replica cluster layer: conservation, routing quality,
single-replica equivalence, mixed fleets, SLO-driven scaling."""
import copy

import numpy as np
import pytest

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core import drive, make_engine
from repro.core.engines import LoadSnapshot
from repro.core.request import Request
from repro.serving import (TRACES, Cluster, ScalePolicy, fleet_summarize,
                           generate_trace)

ARCH = "llama3-70b"


def _serve(mode="rapid"):
    return ServeConfig(mode=mode, chips=32, slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(16, 16), max_batch_slots=128)


def _trace(qps=6.0, duration=20.0, seed=0):
    return generate_trace(TRACES["lmsys"], qps=qps, duration_s=duration,
                          seed=seed)


def _skewed_trace(bursts=3, smalls=120):
    """Bursts of one huge prompt followed by a flood of tiny ones: a
    count-balancing router parks half the tiny prompts behind the huge
    prefill; a token-balancing router routes them around it."""
    reqs, rid, t = [], 0, 0.0
    for _ in range(bursts):
        reqs.append(Request(rid=rid, arrival=t, prompt_len=16_000,
                            max_new_tokens=64))
        rid += 1
        for j in range(smalls):
            reqs.append(Request(rid=rid, arrival=t + 0.005 * (j + 1),
                                prompt_len=64, max_new_tokens=16))
            rid += 1
        t += 5.0
    return reqs


def _p99_ttft(recs):
    return float(np.percentile(
        [r.ttft for r in recs if r.ttft is not None], 99))


# ---------------------------------------------------------------------------
# acceptance criteria
# ---------------------------------------------------------------------------


def test_four_replica_conservation():
    """Per-replica request counts sum to the trace total; every request
    finishes exactly once."""
    cfg = get_config(ARCH)
    reqs = _trace()
    cluster = Cluster(cfg, _serve(), ["rapid"] * 4, router="least_loaded")
    recs, span = cluster.run([copy.deepcopy(r) for r in reqs])
    counts = cluster.per_replica_counts()
    assert len(counts) == 4
    assert sum(counts.values()) == len(reqs)
    assert all(c > 0 for c in counts.values())
    assert sum(1 for r in recs if r.finish is not None) == len(reqs)
    per = cluster.per_replica_records()
    assert sum(len(v) for v in per.values()) == len(reqs)
    # fleet aggregation sees the union
    fs = fleet_summarize(per, _serve().slo, span)
    assert fs["fleet"]["completed"] == len(reqs)
    assert fs["fleet"]["replicas"] == 4


def test_least_loaded_beats_round_robin_p99_ttft_on_skew():
    cfg = get_config(ARCH)
    p99 = {}
    for router in ("round_robin", "least_loaded"):
        cluster = Cluster(cfg, _serve(), ["rapid"] * 2, router=router)
        recs, _ = cluster.run([copy.deepcopy(r) for r in _skewed_trace()])
        assert all(r.finish is not None for r in recs)
        p99[router] = _p99_ttft(recs)
    assert p99["least_loaded"] < p99["round_robin"]


def test_single_replica_cluster_matches_bare_engine_exactly():
    cfg = get_config(ARCH)
    reqs = _trace()
    for mode in ("rapid", "hybrid", "disagg"):
        eng = make_engine(mode, cfg, _serve(mode))
        recs_bare, span_bare = drive(eng,
                                     [copy.deepcopy(r) for r in reqs])
        cluster = Cluster(cfg, _serve(mode), [mode], router="round_robin")
        recs_cl, span_cl = cluster.run([copy.deepcopy(r) for r in reqs])
        assert recs_cl == recs_bare, f"{mode}: cluster != bare engine"
        assert span_cl == span_bare


# ---------------------------------------------------------------------------
# routers / mixed fleets / snapshots
# ---------------------------------------------------------------------------


def test_slo_aware_router_serves_everything():
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(), ["rapid"] * 2, router="slo_aware")
    recs, _ = cluster.run([copy.deepcopy(r) for r in _skewed_trace(2, 60)])
    assert all(r.finish is not None for r in recs)
    assert _p99_ttft(recs) < np.inf


def test_mixed_engine_fleet():
    cfg = get_config(ARCH)
    reqs = _trace(qps=4.0, duration=15.0)
    cluster = Cluster(cfg, _serve(), ["rapid", "hybrid", "disagg"],
                      router="least_loaded")
    recs, span = cluster.run([copy.deepcopy(r) for r in reqs])
    assert sum(1 for r in recs if r.finish is not None) == len(reqs)
    names = set(cluster.per_replica_counts())
    assert names == {"rapid-0", "hybrid-1", "disagg-2"}


def test_unknown_router_rejected():
    cfg = get_config(ARCH)
    with pytest.raises(KeyError):
        Cluster(cfg, _serve(), ["rapid"], router="fastest")


def test_load_snapshot_shape():
    cfg = get_config(ARCH)
    for mode in ("rapid", "hybrid", "disagg"):
        eng = make_engine(mode, cfg, _serve(mode))
        s = eng.load_snapshot()
        assert isinstance(s, LoadSnapshot)
        assert s.queued_requests == 0
        assert s.queued_prefill_tokens == 0
        assert s.running_decode == 0
        # after a submit (no loop run), work is queued
        eng.submit(Request(rid=0, arrival=0.0, prompt_len=256,
                           max_new_tokens=8))
        assert eng.load_snapshot().queued_prefill_tokens >= 256 or \
            eng.load_snapshot().queued_requests >= 1


# ---------------------------------------------------------------------------
# SLO-driven scaling
# ---------------------------------------------------------------------------


def test_autoscaler_grows_fleet_under_pressure():
    cfg = get_config(ARCH)
    reqs = _trace(qps=24.0, duration=20.0)   # far too hot for 1 replica
    policy = ScalePolicy(min_replicas=1, max_replicas=3,
                         check_interval_s=2.0, window_s=5.0)
    cluster = Cluster(cfg, _serve(), ["rapid"], router="least_loaded",
                      scale=policy)
    recs, _ = cluster.run([copy.deepcopy(r) for r in reqs])
    assert cluster.num_replicas > 1
    assert cluster.num_replicas <= 3
    assert any(a == "up" for _, a, _ in cluster._scale_events)
    # conservation survives scaling
    assert sum(1 for r in recs if r.finish is not None) == len(reqs)
    assert sum(cluster.per_replica_counts().values()) == len(reqs)
