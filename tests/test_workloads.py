"""Multi-tenant workloads: trace generator determinism and session
shape, class-ordered admission (best_effort shed first, interactive
never), class-ranked preemption, and per-class metrics."""
import collections

import numpy as np
import pytest

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core.preemption import PreemptionPolicy
from repro.core.request import Request, State, class_rank
from repro.serving import (WORKLOAD_CLASSES, AdmissionPolicy, Cluster,
                           diurnal_rate, flash_crowd_rate,
                           generate_multiclass_trace, nhpp_arrivals,
                           run_fleet)
from repro.serving.metrics import (RequestRecord, per_class_summaries,
                                   rejections_by_reason)

ARCH = "llama3-70b"


def _serve(chips=32):
    return ServeConfig(mode="rapid", chips=chips,
                       slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(chips // 2, chips // 2),
                       max_batch_slots=128)


# ---------------------------------------------------------------------------
# trace generation
# ---------------------------------------------------------------------------


def test_multiclass_trace_deterministic_and_sorted():
    a = generate_multiclass_trace(qps=4.0, duration_s=20.0, seed=9)
    b = generate_multiclass_trace(qps=4.0, duration_s=20.0, seed=9)
    key = lambda r: (r.rid, r.arrival, r.prompt_len, r.max_new_tokens,  # noqa: E731
                     r.slo_class, r.session_id, r.cached_prefix_len)
    assert [key(r) for r in a] == [key(r) for r in b]
    assert [r.rid for r in a] == list(range(len(a)))
    assert all(x.arrival <= y.arrival for x, y in zip(a, a[1:]))
    classes = {r.slo_class for r in a}
    assert classes <= set(WORKLOAD_CLASSES)
    assert len(classes) > 1, "default mix should produce several classes"


def test_session_turns_share_growing_prefix():
    reqs = generate_multiclass_trace(qps=4.0, duration_s=30.0, seed=3)
    by_sid = collections.defaultdict(list)
    for r in reqs:
        if r.session_id is not None:
            by_sid[r.session_id].append(r)
    assert by_sid, "interactive sessions expected in the default mix"
    multi = [t for t in by_sid.values() if len(t) > 1]
    assert multi, "some sessions should span multiple turns"
    for turns in by_sid.values():
        ctx = 0
        prev = -1.0
        for t in turns:
            assert t.arrival > prev
            # turn k's prompt extends the conversation so far; the
            # shared prefix is exactly that prior context
            assert t.cached_prefix_len == ctx
            assert t.prompt_len > t.cached_prefix_len
            ctx = t.prompt_len + t.max_new_tokens
            prev = t.arrival


def test_nhpp_thinning_tracks_rate():
    rng = np.random.default_rng(0)
    rate = flash_crowd_rate(2.0, 20.0, 100.0, 200.0)
    ts = nhpp_arrivals(rate, 300.0, rng)
    burst = sum(1 for t in ts if 100.0 <= t < 200.0)
    calm = len(ts) - burst
    # 100s at 20/s vs 200s at 2/s: the burst should dominate ~5x
    assert burst > 3 * calm
    d = diurnal_rate(4.0, amplitude=0.5, period_s=100.0)
    assert d.rate_max == pytest.approx(6.0)
    with pytest.raises(ValueError):
        diurnal_rate(1.0, amplitude=1.5)


# ---------------------------------------------------------------------------
# class-ordered admission
# ---------------------------------------------------------------------------


def _pressured_cluster(policy):
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(), ["rapid"], router="least_loaded",
                      admission=policy)
    from repro.kvcache import KVCacheManager
    cluster.replicas[0].engine.kv = KVCacheManager(200, 16)  # 3200 tokens
    return cluster


def test_class_aware_admission_sheds_best_effort_first():
    """Under identical pressure the class-aware controller sheds the
    best_effort arrival (reason class_shed) and still serves the
    interactive one — the class-blind controller treats them alike."""
    policy = AdmissionPolicy(kv_headroom=0.9, projected_output_frac=1.0,
                             retry_s=0.1, max_wait_s=60.0,
                             class_aware=True)
    cluster = _pressured_cluster(policy)
    hog = Request(rid=0, arrival=0.0, prompt_len=2000, max_new_tokens=400,
                  slo_class="batch")
    be = Request(rid=1, arrival=0.05, prompt_len=1500, max_new_tokens=64,
                 slo_class="best_effort")
    inter = Request(rid=2, arrival=0.1, prompt_len=1500, max_new_tokens=64,
                    slo_class="interactive")
    cluster.run([hog, be, inter])
    assert be.state is State.REJECTED
    assert be.reject_reason == "class_shed"
    assert cluster.admission.stats["shed"] == 1
    assert inter.state is State.FINISHED
    assert hog.state is State.FINISHED


def test_class_blind_admission_treats_classes_alike():
    policy = AdmissionPolicy(kv_headroom=0.9, projected_output_frac=1.0,
                             retry_s=0.1, max_wait_s=60.0)
    cluster = _pressured_cluster(policy)
    hog = Request(rid=0, arrival=0.0, prompt_len=2000, max_new_tokens=400,
                  slo_class="batch")
    be = Request(rid=1, arrival=0.05, prompt_len=1500, max_new_tokens=64,
                 slo_class="best_effort")
    cluster.run([hog, be])
    # no shedding: the best_effort arrival queues and is served once the
    # hog's decode frees pool headroom
    assert be.state is State.FINISHED
    assert cluster.admission.stats.get("shed", 0) == 0


def test_headroom_for_ordering():
    p = AdmissionPolicy(kv_headroom=0.9, class_aware=True)
    assert p.headroom_for("interactive") == pytest.approx(0.9)
    assert p.headroom_for("interactive") > p.headroom_for("batch") > \
        p.headroom_for("best_effort")
    blind = AdmissionPolicy(kv_headroom=0.9)
    assert blind.headroom_for("best_effort") == pytest.approx(0.9)


# ---------------------------------------------------------------------------
# class-ranked preemption
# ---------------------------------------------------------------------------


def test_preemption_ranks_class_before_order():
    inter = Request(rid=0, arrival=2.0, prompt_len=64, max_new_tokens=8,
                    slo_class="interactive")
    batch = Request(rid=1, arrival=1.0, prompt_len=64, max_new_tokens=8,
                    slo_class="batch")
    be = Request(rid=2, arrival=0.0, prompt_len=64, max_new_tokens=8,
                 slo_class="best_effort")
    pol = PreemptionPolicy(order="newest", class_aware=True)
    # best_effort loses despite being the OLDEST arrival
    assert pol.choose([inter, batch, be]) is be
    assert pol.choose([inter, batch]) is batch
    blind = PreemptionPolicy(order="newest", class_aware=False)
    # class-blind: newest arrival loses regardless of class
    assert blind.choose([inter, batch, be]) is inter
    # single-class batches tie on rank => identical to class-blind
    solo = [Request(rid=i, arrival=float(i), prompt_len=64,
                    max_new_tokens=8) for i in range(3)]
    assert pol.choose(solo) is blind.choose(solo)
    assert class_rank("best_effort") > class_rank("batch") > \
        class_rank("interactive")


# ---------------------------------------------------------------------------
# per-class metrics
# ---------------------------------------------------------------------------


def test_per_class_summaries_use_own_slos():
    slo = SLOConfig(itl_ms=100.0)
    recs = [
        RequestRecord(rid=0, arrival=0.0, prompt_len=100, output_len=10,
                      ttft=0.5, itl_p95=0.15, finish=2.0,
                      slo_class="interactive"),
        RequestRecord(rid=1, arrival=0.0, prompt_len=100, output_len=10,
                      ttft=0.5, itl_p95=0.15, finish=2.0,
                      slo_class="batch"),
        RequestRecord(rid=2, arrival=0.0, prompt_len=100, output_len=0,
                      ttft=None, itl_p95=None, finish=None, rejected=True,
                      slo_class="best_effort", reject_reason="class_shed"),
    ]
    per = per_class_summaries(recs, slo, span_s=10.0)
    # 150ms p95 ITL misses interactive's 100ms SLO but meets batch's 250ms
    assert per["interactive"]["slo_attainment"] == 0.0
    assert per["batch"]["slo_attainment"] == 1.0
    assert per["best_effort"]["rejected"] == 1
    assert rejections_by_reason(recs) == {"class_shed": 1}


def test_fleet_summary_carries_class_sections():
    cfg = get_config(ARCH)
    reqs = generate_multiclass_trace(qps=3.0, duration_s=10.0, seed=1)
    summary, _ = run_fleet(cfg, _serve(), ["rapid"], "least_loaded", reqs)
    assert set(summary["per_class"]) == {r.slo_class for r in reqs}
    assert "rejections_by_reason" in summary["fleet"]
    for s in summary["per_class"].values():
        assert {"goodput_req_s", "slo_attainment"} <= set(s)
