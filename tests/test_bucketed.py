"""Heterogeneous replicas + BucketServe-style bucketed routing:
``--mix`` parsing, per-replica chips/ServeConfig overrides, ceiling
computation, and deterministic routing behaviour."""
import copy

import pytest

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core.request import Request
from repro.serving import (BucketedRouter, Cluster, ReplicaSpec,
                           generate_trace, parse_mix)
from repro.serving.traces import TraceSpec

ARCH = "llama3-70b"


def _serve(chips=16):
    return ServeConfig(mode="rapid", chips=chips,
                       slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(chips // 2, chips // 2),
                       max_batch_slots=128)


# ---------------------------------------------------------------------------
# --mix parsing
# ---------------------------------------------------------------------------


def test_parse_mix_plain_modes():
    assert parse_mix("rapid,hybrid") == [ReplicaSpec("rapid"),
                                         ReplicaSpec("hybrid")]


def test_parse_mix_heterogeneous_groups():
    specs = parse_mix("rapid:2x16,hybrid:1x32")
    assert specs == [ReplicaSpec("rapid", chips=16),
                     ReplicaSpec("rapid", chips=16),
                     ReplicaSpec("hybrid", chips=32)]


def test_parse_mix_mixed_forms_and_errors():
    specs = parse_mix("rapid, hybrid:1x32")
    assert specs == [ReplicaSpec("rapid"), ReplicaSpec("hybrid", chips=32)]
    with pytest.raises(ValueError):
        parse_mix("rapid:2")
    with pytest.raises(ValueError):
        parse_mix("")


# ---------------------------------------------------------------------------
# heterogeneous replica construction
# ---------------------------------------------------------------------------


def test_per_replica_chips_override():
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(16), parse_mix("rapid:2x16,rapid:1x32"),
                      router="bucketed")
    chips = [rep.serve.chips for rep in cluster.replicas]
    assert chips == [16, 16, 32]
    # bigger replica => bigger KV pool
    pools = [rep.engine.kv.allocator.num_blocks for rep in cluster.replicas]
    assert pools[2] > pools[0] and pools[0] == pools[1]


def test_per_replica_serve_override():
    cfg = get_config(ARCH)
    custom = ServeConfig(mode="rapid", chips=32,
                         slo=SLOConfig(itl_ms=50.0),
                         disagg_split=(16, 16), max_batch_slots=16)
    cluster = Cluster(cfg, _serve(16),
                      [ReplicaSpec("rapid"),
                       ReplicaSpec("rapid", serve=custom)],
                      router="round_robin")
    assert cluster.replicas[0].serve.max_batch_slots == 128
    assert cluster.replicas[1].serve.max_batch_slots == 16
    assert cluster.replicas[1].serve.chips == 32


def test_disagg_split_follows_chips_override():
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(32), [ReplicaSpec("disagg", chips=24)],
                      router="round_robin")
    assert cluster.replicas[0].serve.disagg_split == (12, 12)


# ---------------------------------------------------------------------------
# bucketed routing
# ---------------------------------------------------------------------------


def test_bucket_ceilings_proportional_to_chips():
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(16), parse_mix("rapid:2x16,rapid:1x32"),
                      router="bucketed")
    reps = cluster.replicas
    ceils = [BucketedRouter.ceiling(rep, reps) for rep in reps]
    assert ceils == [16384, 16384, 32768]


def test_long_prompt_routes_to_big_replica_short_to_small():
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(16), parse_mix("rapid:2x16,rapid:1x32"),
                      router="bucketed")
    long_r = Request(rid=0, arrival=0.0, prompt_len=20_000,
                     max_new_tokens=8)
    short_r = Request(rid=1, arrival=0.0, prompt_len=1000,
                      max_new_tokens=8)
    assert cluster.router.choose(long_r, cluster.replicas) == 2
    # idle fleet: short prompts prefer the smallest compatible tier
    assert cluster.router.choose(short_r, cluster.replicas) in (0, 1)


def test_bucketed_cluster_end_to_end_respects_ceilings():
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(16), parse_mix("rapid:2x16,rapid:1x32"),
                      router="bucketed")
    short = generate_trace(TraceSpec("s", 1500, 0.4, 100, 0.3, 8000, 256),
                           qps=4.0, duration_s=8.0, seed=0)
    long_ = generate_trace(TraceSpec("l", 20_000, 0.2, 100, 0.3, 30_000,
                                     256),
                           qps=1.0, duration_s=8.0, seed=1)
    reqs = short + long_
    for i, r in enumerate(reqs):
        r.rid = i
    recs, _ = cluster.run(copy.deepcopy(reqs))
    assert all(r.finish is not None for r in recs)
    reps = cluster.replicas
    for rep in reps:
        ceil = BucketedRouter.ceiling(rep, reps)
        assert all(r.prompt_len <= ceil for r in rep.assigned), \
            f"{rep.name} got a prompt above its bucket ceiling {ceil}"
    # the long prompts actually exercised the big tier
    assert any(r.prompt_len > 16384 for r in reps[2].assigned)


def test_homogeneous_fleet_bucketed_degenerates_gracefully():
    """Equal chips => equal ceilings => bucketed behaves like a load
    balancer and everything is compatible everywhere."""
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(16), ["rapid"] * 3, router="bucketed")
    reqs = generate_trace(TraceSpec("s", 2000, 0.5, 100, 0.3, 16_000, 256),
                          qps=6.0, duration_s=6.0, seed=0)
    recs, _ = cluster.run(copy.deepcopy(reqs))
    assert all(r.finish is not None for r in recs)
    counts = cluster.per_replica_counts()
    assert all(c > 0 for c in counts.values())
