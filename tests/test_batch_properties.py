"""Bit-identity property suite: ``perfmodel.batch`` == scalar formulas,
elementwise (hypothesis, dev-only dep — skipped at collection when
hypothesis is absent, see conftest.py).

The scalar entry points in ``perfmodel.costs``/``interference`` are now
N=1 views over the batch layer, so comparing against them would be
circular.  The oracle here is independent: the PINNED pre-refactor
pure-Python cost bodies from ``benchmarks/bench_hotpath.py`` (the same
ones the hot-path benchmark's baseline runs) plus in-file copies of the
pre-refactor phase-time/overlap/forecast bodies.

Every assertion is ``==``, never approx: the batch layer's contract is
bit-identity (router argmin tie-breaks and the golden parity suite
depend on it), and float64 array arithmetic in the documented
evaluation order is IEEE-identical to the CPython float chain.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from benchmarks.bench_hotpath import _RAW_CHUNK, _RAW_DECODE, _RAW_PREFILL
from repro.config import get_config
from repro.perfmodel import batch as B
from repro.perfmodel.hw import TPU_V5E

ARCHS = ["qwen2.5-14b", "llama3-70b", "mixtral-8x7b",
         "jamba-1.5-large-398b", "xlstm-125m"]
TPS = [1, 2, 4, 8, 16]


# ---------------------------------------------------------------------------
# pinned scalar phase/overlap/forecast reference (pre-refactor
# interference.py bodies — do NOT "simplify" against the live module)
# ---------------------------------------------------------------------------

_MEM_P = 0.02
_MEM_D = 0.035


def _ref_phase_time(cost, hw, chips, f=1.0, mem_interference=0.0,
                    bw_share=1.0):
    if cost.flops == 0 and cost.hbm_bytes == 0:
        return 0.0
    t_compute = cost.flops / (chips * hw.peak_flops * max(f, 1e-3))
    t_mem = cost.hbm_bytes * (1.0 + mem_interference) / \
        (chips * hw.hbm_bw * bw_share)
    t_coll = cost.coll_bytes / hw.ici_bw
    return max(t_compute, t_mem) + t_coll + hw.launch_overhead_s


def _ref_util(cost, hw, chips):
    t_c = cost.flops / (chips * hw.peak_flops)
    t_m = cost.hbm_bytes / (chips * hw.hbm_bw)
    t_coll = cost.coll_bytes / hw.ici_bw
    denom = max(t_m, t_c) + t_coll
    if denom <= 0:
        return 0.0
    return min(1.0, t_c / denom)


def _ref_forecast(p_cost, d_cost, hw, chips_p, chips_d, colocated,
                  f_decode):
    if colocated:
        if d_cost is None and p_cost is None:
            return 0.0, 0.0
        if d_cost is None:
            return _ref_phase_time(p_cost, hw, chips_p), 0.0
        if p_cost is None:
            return 0.0, _ref_phase_time(d_cost, hw, chips_p)
        if f_decode is None:
            u_d = _ref_util(d_cost, hw, chips_p)
            u_p = _ref_util(p_cost, hw, chips_p)
            share_d = u_d / max(u_d + u_p, 1e-9)
            share_p = 1.0 - share_d
            f_d, f_p = max(share_d, 1e-3), max(share_p, 1e-3)
        else:
            f_d = min(max(f_decode, 0.05), 0.95)
            f_p = 1.0 - f_d
        t_d = _ref_phase_time(d_cost, hw, chips_p, f=f_d,
                              mem_interference=_MEM_D)
        t_p = _ref_phase_time(p_cost, hw, chips_p, f=f_p,
                              mem_interference=_MEM_P)
        return t_p, t_d
    t_p = _ref_phase_time(p_cost, hw, chips_p) \
        if p_cost is not None else 0.0
    t_d = _ref_phase_time(d_cost, hw, chips_d) \
        if d_cost is not None else 0.0
    return t_p, t_d


# ---------------------------------------------------------------------------
# plain check helpers (the properties; callable without hypothesis)
# ---------------------------------------------------------------------------


def _check_prefill(arch, seqs, tps):
    cfg = get_config(arch)
    pb = B.prefill_cost(cfg, seqs, np.asarray(tps, dtype=np.int64))
    assert len(pb) == len(seqs)
    for i, (row, tp) in enumerate(zip(seqs, tps)):
        assert pb.item(i) == _RAW_PREFILL(cfg, tuple(row), tp, 2)


def _check_chunk(arch, chunks, ctxs, tps):
    cfg = get_config(arch)
    cb = B.chunk_prefill_cost(cfg, chunks, ctxs,
                              np.asarray(tps, dtype=np.int64))
    for i, (ch, ctx, tp) in enumerate(zip(chunks, ctxs, tps)):
        assert cb.item(i) == _RAW_CHUNK(cfg, ch, ctx, tp, 2)


def _check_decode(arch, bss, ctxs, tps):
    cfg = get_config(arch)
    db = B.decode_cost(cfg, bss, ctxs, np.asarray(tps, dtype=np.int64))
    for i, (bs, ctx, tp) in enumerate(zip(bss, ctxs, tps)):
        assert db.item(i) == _RAW_DECODE(cfg, bs, ctx, tp, 2)


def _check_phase_time(arch, bss, ctxs, tps, f, mem, bw_share):
    cfg = get_config(arch)
    chips = np.asarray(tps, dtype=np.int64)
    db = B.decode_cost(cfg, bss, ctxs, chips)
    got = B.phase_time(db, TPU_V5E, chips, f=f, mem_interference=mem,
                       bw_share=bw_share)
    util = B.compute_utilization(db, TPU_V5E, chips)
    for i in range(len(db)):
        c = db.item(i)
        assert float(got[i]) == _ref_phase_time(
            c, TPU_V5E, tps[i], f=f, mem_interference=mem,
            bw_share=bw_share)
        assert float(util[i]) == _ref_util(c, TPU_V5E, tps[i])


def _check_forecast(arch, rows):
    """rows: (p_seqs|None, (bs, ctx)|None, chips_p, chips_d, colocated,
    f_decode|None) per replica — the full branch lattice of the scalar
    forecast in one batched call."""
    cfg = get_config(arch)
    p_costs = [None if p is None else _RAW_PREFILL(cfg, tuple(p), cp, 2)
               for p, _, cp, _, _, _ in rows]
    d_costs = [None if d is None else _RAW_DECODE(cfg, d[0], d[1], cp
                                                  if coloc else cd, 2)
               for _, d, cp, cd, coloc, _ in rows]
    pb, p_mask = B.pack_costs(p_costs)
    db, d_mask = B.pack_costs(d_costs)
    chips_p = np.asarray([r[2] for r in rows], dtype=np.int64)
    chips_d = np.asarray([r[3] for r in rows], dtype=np.int64)
    coloc = np.asarray([r[4] for r in rows], dtype=bool)
    f_dec = np.asarray([np.nan if r[5] is None else r[5] for r in rows])
    t_p, t_d = B.forecast_phase_times(
        pb, db, TPU_V5E, chips_p, chips_d, colocated=coloc,
        p_mask=p_mask, d_mask=d_mask, f_decode=f_dec)
    for i, (_, _, cp, cd, co, fd) in enumerate(rows):
        want = _ref_forecast(p_costs[i], d_costs[i], TPU_V5E, cp, cd,
                             co, fd)
        assert (float(t_p[i]), float(t_d[i])) == want


def _check_pack_roundtrip(arch, seqs, tps):
    cfg = get_config(arch)
    costs = [_RAW_PREFILL(cfg, tuple(row), tp, 2) if row else None
             for row, tp in zip(seqs, tps)]
    batch, mask = B.pack_costs(costs)
    for i, c in enumerate(costs):
        assert mask[i] == (c is not None)
        if c is not None:
            assert batch.item(i) == c


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

arch_st = st.sampled_from(ARCHS)
tp_st = st.sampled_from(TPS)
seq_row_st = st.lists(st.integers(1, 16_384), min_size=0, max_size=4)
ctx_st = st.floats(0.0, 2e6, allow_nan=False, allow_infinity=False)


@st.composite
def _rows(draw, row_st):
    n = draw(st.integers(1, 8))
    return ([draw(row_st) for _ in range(n)],
            [draw(tp_st) for _ in range(n)])


@st.composite
def _forecast_rows(draw):
    n = draw(st.integers(1, 8))
    rows = []
    for _ in range(n):
        p = draw(st.none() | st.lists(st.integers(1, 16_384),
                                      min_size=1, max_size=3))
        d = draw(st.none() | st.tuples(st.integers(1, 256), ctx_st))
        rows.append((p, d, draw(tp_st), draw(tp_st),
                     draw(st.booleans()),
                     draw(st.none() | st.floats(0.0, 1.0,
                                                allow_nan=False))))
    return rows


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------


@given(arch=arch_st, rows=_rows(seq_row_st))
@settings(max_examples=60, deadline=None)
def test_prefill_batch_matches_scalar(arch, rows):
    _check_prefill(arch, *rows)


@given(arch=arch_st, rows=_rows(st.tuples(st.integers(0, 4096),
                                          st.integers(0, 16_384))))
@settings(max_examples=60, deadline=None)
def test_chunk_batch_matches_scalar(arch, rows):
    pairs, tps = rows
    _check_chunk(arch, [c for c, _ in pairs], [x for _, x in pairs], tps)


@given(arch=arch_st, rows=_rows(st.tuples(st.integers(0, 256), ctx_st)))
@settings(max_examples=60, deadline=None)
def test_decode_batch_matches_scalar(arch, rows):
    pairs, tps = rows
    _check_decode(arch, [b for b, _ in pairs], [c for _, c in pairs], tps)


@given(arch=arch_st, rows=_rows(st.tuples(st.integers(0, 256), ctx_st)),
       f=st.floats(0.0, 1.0, allow_nan=False),
       mem=st.sampled_from([0.0, 0.02, 0.035]),
       bw=st.floats(0.1, 1.0, allow_nan=False))
@settings(max_examples=60, deadline=None)
def test_phase_time_matches_scalar(arch, rows, f, mem, bw):
    pairs, tps = rows
    _check_phase_time(arch, [b for b, _ in pairs],
                      [c for _, c in pairs], tps, f, mem, bw)


@given(arch=arch_st, rows=_forecast_rows())
@settings(max_examples=60, deadline=None)
def test_forecast_matches_scalar(arch, rows):
    _check_forecast(arch, rows)


@given(arch=arch_st, rows=_rows(seq_row_st))
@settings(max_examples=40, deadline=None)
def test_pack_costs_roundtrip(arch, rows):
    _check_pack_roundtrip(arch, *rows)
