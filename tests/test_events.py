"""Event-stream properties (Serving API v2, core/events.py).

For every engine mode on real traces:
  * per-request token events are monotone in time;
  * a finished request emits exactly ``max_new_tokens`` TokenEvents and
    exactly one FinishedEvent; a rejected one ends with RejectedEvent;
  * TTFT/ITL derived purely from the stream equal the ``RequestRecord``
    values from the legacy scrape path;
  * per-request ``subscribe(fn, rid=...)`` narrows correctly;
  * the cluster forwards replica streams (plus its own admission
    rejections) into one fleet stream.
"""
import copy

import pytest

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core import make_engine
from repro.core.events import (FinishedEvent, PhaseEvent, RejectedEvent,
                               TokenEvent)
from repro.kvcache import KVCacheManager
from repro.serving import (TRACES, Cluster, StreamMetrics, generate_trace,
                           records_from_events)

CFG = get_config("llama3-70b")


def _serve(mode):
    return ServeConfig(mode=mode, chips=32, slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(16, 16), max_batch_slots=128)


def _drained(mode, qps=5.0, duration=15.0, seed=2, tiny_pool=None):
    reqs = generate_trace(TRACES["lmsys"], qps=qps, duration_s=duration,
                          seed=seed)
    eng = make_engine(mode, CFG, _serve(mode))
    if tiny_pool is not None:
        eng.kv = KVCacheManager(num_blocks=tiny_pool, page_size=16)
    eng.enqueue([copy.deepcopy(r) for r in reqs])
    eng.loop.run()
    return eng, reqs


@pytest.mark.parametrize("mode", ["rapid", "hybrid", "disagg"])
def test_token_events_monotone_and_conserved(mode):
    eng, reqs = _drained(mode)
    by_rid = {}
    for ev in eng.events():
        if isinstance(ev, TokenEvent):
            by_rid.setdefault(ev.rid, []).append(ev)
    want = {r.rid: r.max_new_tokens for r in reqs}
    assert set(by_rid) == set(want)
    for rid, evs in by_rid.items():
        ts = [ev.t for ev in evs]
        assert all(b >= a for a, b in zip(ts, ts[1:])), "non-monotone"
        assert [ev.index for ev in evs] == list(range(len(evs)))
        assert len(evs) == want[rid]


@pytest.mark.parametrize("mode", ["rapid", "hybrid", "disagg"])
def test_exactly_one_terminal_event(mode):
    eng, reqs = _drained(mode)
    finals = {}
    for ev in eng.events():
        if isinstance(ev, (FinishedEvent, RejectedEvent)):
            finals[ev.rid] = finals.get(ev.rid, 0) + 1
    assert finals == {r.rid: 1 for r in reqs}


def test_rejected_requests_end_with_rejected_event():
    """Tiny pool: oversized prompts must terminate via RejectedEvent and
    emit no FinishedEvent (and the stream count matches the engine's)."""
    eng, reqs = _drained("rapid", tiny_pool=100)
    rejected = [ev.rid for ev in eng.events()
                if isinstance(ev, RejectedEvent)]
    finished = {ev.rid for ev in eng.events()
                if isinstance(ev, FinishedEvent)}
    assert rejected, "trace never hit the rejection path"
    assert len(rejected) == len(eng.rejected)
    assert not set(rejected) & finished
    # terminal means terminal: nothing after a request's RejectedEvent
    last_seen = {}
    for i, ev in enumerate(eng.events()):
        last_seen[ev.rid] = (i, ev)
    for rid in rejected:
        assert isinstance(last_seen[rid][1], RejectedEvent)


@pytest.mark.parametrize("mode", ["rapid", "hybrid", "disagg"])
def test_stream_metrics_equal_request_records(mode):
    """TTFT / p95 ITL / finish / output_len derived from the stream alone
    must equal the legacy ``records()`` scrape exactly."""
    eng, _ = _drained(mode)
    stream_recs = {r.rid: r for r in records_from_events(eng.events())}
    legacy = {r.rid: r for r in eng.records()}
    assert set(stream_recs) == set(legacy)
    for rid, rec in legacy.items():
        assert stream_recs[rid] == rec


def test_per_request_subscription():
    reqs = generate_trace(TRACES["lmsys"], qps=4.0, duration_s=10, seed=5)
    eng = make_engine("rapid", CFG, _serve("rapid"))
    target = reqs[3].rid
    only_mine, everything = [], []
    eng.subscribe(only_mine.append, rid=target)
    eng.subscribe(everything.append)
    eng.enqueue([copy.deepcopy(r) for r in reqs])
    eng.loop.run()
    assert only_mine and all(ev.rid == target for ev in only_mine)
    assert [ev for ev in everything if ev.rid == target] == only_mine
    assert any(isinstance(ev, FinishedEvent) for ev in only_mine)


def test_live_subscription_sees_events_at_emission_time():
    """Streaming, not post-hoc: a subscriber observes each token at the
    virtual-clock instant it is produced."""
    eng = make_engine("rapid", CFG, _serve("rapid"))
    seen = []
    eng.subscribe(lambda ev, eng=eng: seen.append((eng.loop.now, ev)))
    reqs = generate_trace(TRACES["lmsys"], qps=3.0, duration_s=5, seed=1)
    eng.enqueue([copy.deepcopy(r) for r in reqs])
    eng.loop.run()
    assert seen
    for now, ev in seen:
        assert now == ev.t


def test_phase_events_cover_lifecycle():
    eng, reqs = _drained("rapid")
    phases = {}
    for ev in eng.events():
        if isinstance(ev, PhaseEvent):
            phases.setdefault(ev.rid, []).append(ev.phase)
    for r in reqs:
        assert phases[r.rid][0] == "queued"
        assert "kv_allocated" in phases[r.rid]     # Fig 4 decode-side alloc
        assert "prefill" in phases[r.rid]


def test_cluster_fleet_stream_merges_replicas():
    reqs = generate_trace(TRACES["lmsys"], qps=8.0, duration_s=10, seed=4)
    cluster = Cluster(CFG, _serve("rapid"), ["rapid"] * 2,
                      router="least_loaded")
    fleet = StreamMetrics()
    cluster.subscribe(fleet)
    recs, _ = cluster.run([copy.deepcopy(r) for r in reqs])
    assert {r.rid for r in fleet.records} == {r.rid for r in reqs}
    legacy = {r.rid: r for r in recs}
    for rec in fleet.records:
        assert rec == legacy[rec.rid]
    # the cluster's own collector saw the same thing
    assert cluster.metrics.records == fleet.records


# ---------------------------------------------------------------------------
# PR-5: stream behavior under load (amortized events(), per-rid churn,
# token conservation on the hot-path benchmark trace)
# ---------------------------------------------------------------------------


def test_per_rid_subscribe_unsubscribe_under_load():
    """Per-rid consumers attach and detach while thousands of events
    flow; each sees exactly its window, and a fully-detached stream
    returns to the no-fanout fast path."""
    from repro.core.events import EventStream, TokenEvent

    stream = EventStream()
    seen = {rid: [] for rid in range(8)}
    subs = {}
    for i in range(5000):
        rid = i % 16
        if i == 500:
            for r in range(8):
                subs[r] = stream.subscribe(seen[r].append, rid=r)
        if i == 3500:
            for r in range(4):
                stream.unsubscribe(subs.pop(r), rid=r)
        stream.emit(TokenEvent(rid, float(i), i // 16))
    # rids 0-3: subscribed for emissions 500..3499 only
    for r in range(4):
        assert [ev.t for ev in seen[r]] == \
            [float(i) for i in range(500, 3500) if i % 16 == r]
    # rids 4-7: subscribed from 500 to the end
    for r in range(4, 8):
        assert [ev.t for ev in seen[r]] == \
            [float(i) for i in range(500, 5000) if i % 16 == r]
    for r in range(4, 8):
        stream.unsubscribe(subs[r], rid=r)
    assert not stream._per_rid     # empty-dict fast path restored


def test_events_stable_across_interleaved_emit_read():
    """events() snapshots are immutable and amortized: re-reads without
    new emissions return the same tuple; earlier snapshots never mutate
    under later emissions."""
    from repro.core.events import EventStream, TokenEvent

    stream = EventStream()
    snapshots = []
    for i in range(200):
        stream.emit(TokenEvent(0, float(i), i))
        if i % 10 == 0:
            view = stream.events()
            assert stream.events() is view          # cached until emit
            snapshots.append((i + 1, view))
    for n, view in snapshots:
        assert len(view) == n                       # old snapshots frozen
        assert [ev.index for ev in view] == list(range(n))
    assert len(stream.events()) == len(stream) == 200


def test_token_conservation_on_bench_trace():
    """On (a slice of) the hot-path benchmark's bimodal cluster trace:
    every token emitted is exactly one TokenEvent, and the stream's
    per-request counts equal the sealed records' output_len."""
    from benchmarks.bench_hotpath import REPLICAS, ROUTER, _serve, \
        bimodal_trace
    from repro.core.events import TokenEvent as TE

    reqs = bimodal_trace(400, seed=11)
    cluster = Cluster(CFG, _serve(), REPLICAS, router=ROUTER)
    cluster.run([copy.deepcopy(r) for r in reqs])
    tokens_by_rid = {}
    for ev in cluster.events():
        if isinstance(ev, TE):
            tokens_by_rid[ev.rid] = tokens_by_rid.get(ev.rid, 0) + 1
    recs = {r.rid: r for r in cluster.metrics.records}
    assert set(recs) == {r.rid for r in reqs}
    emitted = sum(tokens_by_rid.values())
    recorded = sum(r.output_len for r in recs.values())
    assert emitted == recorded, "token conservation violated"
    for rid, rec in recs.items():
        assert tokens_by_rid.get(rid, 0) == rec.output_len
        if not rec.rejected:
            assert rec.output_len > 0
