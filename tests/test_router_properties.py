"""Property tests for the BucketedRouter (hypothesis, dev-only dep —
skipped at collection when hypothesis is absent, see conftest.py).

The load-bearing invariant: for any fleet shape and any load state, a
prompt that fits the fleet's largest tier is NEVER routed to a replica
whose bucket ceiling is below the prompt length."""
import dataclasses

from hypothesis import given, settings, strategies as st

from repro.config import SLOConfig, ServeConfig
from repro.core.engines import LoadSnapshot
from repro.core.request import Request
from repro.serving import BucketedRouter, Replica


def _snapshot(queued_tokens: int) -> LoadSnapshot:
    return LoadSnapshot(
        queued_requests=queued_tokens // 512,
        queued_prefill_tokens=queued_tokens,
        running_decode=0, decode_ctx_tokens=0, kv_utilization=0.0,
        prefill_busy=False, decode_busy=False)


class _StubEngine:
    """Just enough engine for Router.choose: a load snapshot."""

    def __init__(self, queued_tokens: int):
        self._snap = _snapshot(queued_tokens)

    def load_snapshot(self) -> LoadSnapshot:
        return self._snap


def _fleet(chip_counts, loads):
    serve = ServeConfig(mode="rapid", chips=8, slo=SLOConfig())
    return [Replica(idx=i, mode="rapid", engine=_StubEngine(load),
                    serve=dataclasses.replace(serve, chips=chips))
            for i, (chips, load) in enumerate(zip(chip_counts, loads))]


@given(
    chip_counts=st.lists(st.sampled_from([4, 8, 16, 32]), min_size=2,
                         max_size=5),
    loads=st.lists(st.integers(0, 100_000), min_size=5, max_size=5),
    prompt_len=st.integers(16, 32_768),
)
@settings(max_examples=200, deadline=None)
def test_bucketed_never_routes_above_ceiling(chip_counts, loads,
                                             prompt_len):
    replicas = _fleet(chip_counts, loads[:len(chip_counts)])
    router = BucketedRouter()
    ceils = [BucketedRouter.ceiling(rep, replicas) for rep in replicas]
    # any prompt <= max_seq_len is covered by the largest tier
    assert max(ceils) == replicas[0].serve.max_seq_len
    chosen = router.choose(
        Request(rid=0, arrival=0.0, prompt_len=prompt_len,
                max_new_tokens=8), replicas)
    assert ceils[chosen] >= prompt_len


@given(
    chip_counts=st.lists(st.sampled_from([4, 8, 16, 32]), min_size=2,
                         max_size=5),
    length=st.integers(16, 200_000),
)
@settings(max_examples=100, deadline=None)
def test_admits_agrees_with_ceiling(chip_counts, length):
    replicas = _fleet(chip_counts, [0] * len(chip_counts))
    router = BucketedRouter()
    for rep in replicas:
        assert router.admits(length, rep, replicas) == \
            (BucketedRouter.ceiling(rep, replicas) >= length)
