"""Property-based load accounting: counters == recompute, always.

Hypothesis drives each engine mode through arbitrary interleavings of
the operations that move requests between containers — enqueue, admit
(via loop advance), preempt, migrate out / re-submit, finish — and after
every single step asserts the incremental ``load_snapshot()`` equals the
full-rescan ``load_snapshot_recompute()`` field for field.

This module needs ``hypothesis`` (dev-only dep) and is skipped at
collection when absent (see conftest.py).
"""
import itertools

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core import make_engine
from repro.core.request import Request
from repro.kvcache import KVCacheManager

CFG = get_config("llama3-70b")

# a tiny decode pool (and smaller batch) so arbitrary sequences actually
# hit admission blocking, preemption and rejection paths
TINY_BLOCKS = 64
PAGE = 16
POOL_TOKENS = TINY_BLOCKS * PAGE

# Prompt lengths come from two bands: "servable" prompts whose prompt +
# full output fits the pool (12-token output cap below), and "oversized"
# prompts the admission path must reject.  The band in between — fits
# the pool but prompt+output does not — is excluded because the
# COLOCATED modes still stall such a request at zero progress when it
# runs alone (disagg now rejects it at admission, ``never_fits``; see
# test_liveness_properties.py for the band's liveness coverage).
MAX_OUT = 12
_prompt = st.one_of(st.integers(16, POOL_TOKENS - MAX_OUT),
                    st.integers(POOL_TOKENS + 1, 1200))


def _serve(mode):
    return ServeConfig(mode=mode, chips=32, slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(16, 16), max_batch_slots=4,
                       max_seq_len=32768)


def _engine(mode):
    eng = make_engine(mode, CFG, _serve(mode))
    eng.kv = KVCacheManager(num_blocks=TINY_BLOCKS, page_size=PAGE)
    if eng.kv_p is not None:
        eng.kv_p = KVCacheManager(num_blocks=TINY_BLOCKS, page_size=PAGE)
    return eng


_op = st.one_of(
    st.tuples(st.just("submit"), _prompt, st.integers(1, MAX_OUT)),
    st.tuples(st.just("advance"), st.floats(0.001, 0.5,
                                            allow_nan=False),
              st.just(0)),
    st.tuples(st.just("preempt"), st.just(0), st.just(0)),
    st.tuples(st.just("migrate"), st.just(0), st.just(0)),
)


def _apply_ops(eng, ops):
    rids = itertools.count()
    parked = []           # migrated out, waiting to be re-submitted

    def check():
        assert eng.load_snapshot() == eng.load_snapshot_recompute()

    for kind, a, b in ops:
        if kind == "submit":
            eng.submit(Request(rid=next(rids), arrival=eng.loop.now,
                               prompt_len=a, max_new_tokens=b))
        elif kind == "advance":
            eng.loop.run(until=eng.loop.now + a)
        elif kind == "preempt":
            eng._preempt_victim()
        elif kind == "migrate":
            if parked:
                eng.submit(parked.pop())
            else:
                evicted = eng.evict_for_migration()
                if evicted is not None:
                    parked.append(evicted[0])
        check()
    for r in parked:      # bring the strays home, then drain fully
        eng.submit(r)
    check()
    eng.loop.run()
    check()
    snap = eng.load_snapshot()
    assert snap.queued_requests == 0
    assert snap.queued_prefill_tokens == 0
    assert snap.queued_kv_pages == 0
    assert snap.running_decode == 0 and snap.decode_ctx_tokens == 0


@pytest.mark.parametrize("mode", ["rapid", "hybrid", "disagg"])
@settings(max_examples=25, deadline=None)
@given(ops=st.lists(_op, min_size=1, max_size=30))
def test_incremental_counters_equal_recompute(mode, ops):
    _apply_ops(_engine(mode), ops)
