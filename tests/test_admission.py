"""KV-aware admission control: engine-level clean rejection (regression
for OutOfBlocks escaping the event loop), cluster-level queue/redirect/
reject, and router edge cases (empty routable list, all replicas over
the KV threshold)."""
import copy

import pytest

from repro.config import SLOConfig, ServeConfig, get_config
from repro.core import drive, make_engine
from repro.core.request import Request, State
from repro.kvcache import KVCacheManager
from repro.serving import (TRACES, AdmissionPolicy, Cluster,
                           fleet_summarize, generate_trace, summarize)

ARCH = "llama3-70b"


def _serve(mode="rapid", chips=32):
    return ServeConfig(mode=mode, chips=chips, slo=SLOConfig(itl_ms=100.0),
                       disagg_split=(chips // 2, chips // 2),
                       max_batch_slots=128)


def _shrink_pools(cluster, blocks=200, page=16):
    for rep in cluster.replicas:
        rep.engine.kv = KVCacheManager(blocks, page)


# ---------------------------------------------------------------------------
# engine-level rejection (satellite: no OutOfBlocks out of the event loop)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["rapid", "hybrid", "disagg"])
def test_engine_rejects_oversized_prompt_cleanly(mode):
    """A prompt that can never fit the pool must surface as a per-request
    rejection — not an exception, not a deadlocked queue head, and (for
    disagg) not an infinite decode-admission retry loop."""
    cfg = get_config(ARCH)
    eng = make_engine(mode, cfg, _serve(mode))
    eng.kv = KVCacheManager(8, 16)      # 128-token decode pool
    big = Request(rid=0, arrival=0.0, prompt_len=1000, max_new_tokens=8)
    ok = Request(rid=1, arrival=0.0, prompt_len=64, max_new_tokens=4)
    recs, _ = drive(eng, [big, ok])
    assert big.state is State.REJECTED
    assert [r.rid for r in eng.rejected] == [0]
    assert ok.state is State.FINISHED and len(eng.finished) == 1
    by_rid = {r.rid: r for r in recs}
    assert by_rid[0].rejected and by_rid[0].finish is None
    assert not by_rid[1].rejected and by_rid[1].finish is not None
    # the metric layer counts it
    assert summarize(recs, _serve().slo, 1.0)["rejected"] == 1


def test_rapid_oversized_head_does_not_starve_queue():
    """Regression: the oversized request used to wedge waiting_kv's head
    forever, starving every request behind it."""
    cfg = get_config(ARCH)
    eng = make_engine("rapid", cfg, _serve())
    eng.kv = KVCacheManager(32, 16)     # 512-token pool
    reqs = [Request(rid=0, arrival=0.0, prompt_len=5000, max_new_tokens=4)]
    reqs += [Request(rid=i, arrival=0.01 * i, prompt_len=128,
                     max_new_tokens=4) for i in range(1, 6)]
    drive(eng, reqs)
    assert len(eng.finished) == 5
    assert len(eng.rejected) == 1


def test_disagg_backpressure_retry_does_not_double_free():
    """Regression: a *transiently* full decode pool schedules a retry;
    the retry used to re-enter _kv_arrived and free the prefill-side KV
    sequence a second time (KeyError out of the event loop).

    Both lifetimes (prompt + max_new_tokens) fit the 640-token pool
    individually — requests whose lifetime can NEVER fit are now
    rejected up front (see test_disagg_rejects_lifetime_oversize)."""
    cfg = get_config(ARCH)
    eng = make_engine("disagg", cfg, _serve("disagg"))
    eng.kv = KVCacheManager(40, 16)     # fits one 500-prompt, not two
    first = Request(rid=0, arrival=0.0, prompt_len=500,
                    max_new_tokens=100)
    second = Request(rid=1, arrival=0.0, prompt_len=500, max_new_tokens=8)
    recs, _ = drive(eng, [first, second])  # KeyError before the fix
    assert first.state is State.FINISHED
    assert second.state is State.FINISHED
    assert not eng.rejected
    assert eng.kv.allocator.free_count == eng.kv.allocator.num_blocks


def test_disagg_rejects_lifetime_oversize():
    """Livelock regression (ROADMAP item 5): a prompt that fits the
    decode pool but whose prompt + worst-case output does not used to
    either spin the decode-admission retry loop or — once admitted and
    running alone — self-preempt on every decode step without emitting a
    token.  It is now rejected at admission, and co-arriving feasible
    work is unaffected."""
    cfg = get_config(ARCH)
    eng = make_engine("disagg", cfg, _serve("disagg"))
    eng.kv = KVCacheManager(100, 16)    # 1600-token decode pool
    # prompt fits (1500 <= 1600) but lifetime never does (1700 > 1600)
    doomed = Request(rid=0, arrival=0.0, prompt_len=1500,
                     max_new_tokens=200)
    ok = Request(rid=1, arrival=0.0, prompt_len=500, max_new_tokens=50)
    recs, _ = drive(eng, [doomed, ok])
    assert doomed.state is State.REJECTED
    assert doomed.reject_reason == "never_fits"
    assert ok.state is State.FINISHED
    assert eng.kv.allocator.free_count == eng.kv.allocator.num_blocks


def test_kv_reserve_frac_shrinks_pool():
    cfg = get_config(ARCH)
    base = make_engine("rapid", cfg, _serve())
    tight = make_engine("rapid", cfg,
                        ServeConfig(mode="rapid", chips=32,
                                    slo=SLOConfig(itl_ms=100.0),
                                    disagg_split=(16, 16),
                                    kv_reserve_frac=0.5))
    assert tight.kv.allocator.num_blocks < base.kv.allocator.num_blocks


# ---------------------------------------------------------------------------
# cluster-level admission
# ---------------------------------------------------------------------------


def test_all_replicas_over_kv_threshold_queues_then_serves():
    """When every replica's projected pool is over headroom, arrivals are
    queued cluster-side and admitted as KV frees — nobody is preempted,
    nobody is lost."""
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(), ["rapid"] * 2, router="least_loaded",
                      admission=AdmissionPolicy(
                          kv_headroom=0.9, projected_output_frac=1.0,
                          retry_s=0.1))
    _shrink_pools(cluster, blocks=200)   # 3200-token pools
    reqs = [Request(rid=i, arrival=0.0, prompt_len=1000, max_new_tokens=50)
            for i in range(10)]
    recs, _ = cluster.run(reqs)
    assert all(r.finish is not None for r in recs)
    assert cluster.admission.stats["delayed"] > 0
    assert sum(r.preemptions for r in recs) == 0
    assert sum(cluster.per_replica_counts().values()) == len(reqs)


def test_admission_rejects_infeasible_prompt():
    """A prompt bigger than every replica's whole pool is rejected at the
    cluster boundary, and surfaces in the fleet summary."""
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(), ["rapid"] * 2, router="least_loaded",
                      admission=AdmissionPolicy())
    _shrink_pools(cluster, blocks=100)   # 1600-token pools
    reqs = [Request(rid=0, arrival=0.0, prompt_len=5000, max_new_tokens=8),
            Request(rid=1, arrival=0.0, prompt_len=256, max_new_tokens=8)]
    recs, span = cluster.run(reqs)
    assert reqs[0].state is State.REJECTED
    assert [r.rid for r in cluster.rejected] == [0]
    assert cluster.admission.stats["rejected_infeasible"] == 1
    assert reqs[1].state is State.FINISHED
    # cluster-side rejections never reach a replica
    assert sum(cluster.per_replica_counts().values()) == 1


def test_admission_timeout_rejects():
    """Arrivals that cannot be placed before ``max_wait_s`` are rejected
    instead of polling forever."""
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(), ["rapid"], router="least_loaded",
                      admission=AdmissionPolicy(
                          kv_headroom=0.9, projected_output_frac=1.0,
                          retry_s=0.2, max_wait_s=1.0))
    _shrink_pools(cluster, blocks=100)
    # hog fits (800+300 tokens -> 69 pages < 90-page headroom) and then
    # pins the pool for ~3s of decode, past the newcomer's 1s deadline
    hog = Request(rid=0, arrival=0.0, prompt_len=800, max_new_tokens=300)
    late = Request(rid=1, arrival=0.1, prompt_len=1200, max_new_tokens=8)
    cluster.run([hog, late])
    assert hog.state is State.FINISHED
    assert late.state is State.REJECTED
    assert cluster.admission.stats["rejected_timeout"] == 1


def test_empty_routable_falls_back_to_full_fleet():
    """Scale-down can retire every replica; arrivals must still be served
    by the (still running) retired replicas instead of crashing the
    router on an empty list."""
    cfg = get_config(ARCH)
    cluster = Cluster(cfg, _serve(), ["rapid"] * 2, router="least_loaded")
    for rep in cluster.replicas:
        rep.routable = False
    reqs = generate_trace(TRACES["lmsys"], qps=3.0, duration_s=5.0, seed=0)
    recs, span = cluster.run([copy.deepcopy(r) for r in reqs])
    assert all(r.finish is not None for r in recs)
    fs = fleet_summarize(cluster.per_replica_records(), _serve().slo, span)
    assert fs["fleet"]["completed"] == len(reqs)
